"""Quickstart: SCALA rounds on the paper's AlexNet, end to end, through
the federation layer.

Runs the exact Algorithm-2 loop at toy scale: K=8 clients with
quantity-skewed (alpha=2 -> missing classes) synthetic CIFAR-shaped
data, partial participation (a uniform 50% subset masked *inside* the
compiled round by :mod:`repro.fed.participation` — priors and logit
adjustments are recomputed per subset), T=3 local iterations with
concatenated activations + dual logit-adjusted losses, then the
pluggable FL phase (:mod:`repro.fed.aggregators`, BESplit-style
bias-compensated FedAvg here) — the whole round compiled as ONE program
by the split-step engine's round runner
(:func:`repro.core.engine.make_round_runner`).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import fed, optim
from repro.configs import ScalaConfig
from repro.core import engine
from repro.core.scala import alexnet_split_model
from repro.data.loader import FederatedData, round_batches
from repro.data.partition import partition
from repro.data.synthetic import gaussian_images
from repro.models import alexnet as A

K, T, B, ROUNDS = 8, 3, 32, 4

# --- data: alpha=2 quantity skew => each client holds <=2 of 10 classes
x, y = gaussian_images(1200, num_classes=10, seed=0)
parts = partition(y[:1000], K, alpha=2, num_classes=10, seed=0)
data = FederatedData.from_partition(x[:1000], y[:1000], parts)
x_test, y_test = jnp.asarray(x[1000:]), jnp.asarray(y[1000:])

# --- model: AlexNet split at s2 (paper Fig. 6); width-scaled for CPU
model = alexnet_split_model("s2", num_classes=10)
full = A.init_params(jax.random.PRNGKey(0), num_classes=10, width=0.125)
wc, ws = A.split_params(full, "s2")
# all K clients stay stacked; participation is a per-round in-program mask
params = {"client": jax.tree.map(
    lambda a: jnp.broadcast_to(a[None], (K,) + a.shape), wc), "server": ws}

sc = ScalaConfig(num_clients=K, participation=0.5, local_iters=T,
                 server_batch=B, lr=0.05)

# --- federation layer: who participates, and how updates merge
participation = fed.uniform(K, 0.5)         # 4-of-8 clients per round
aggregator = fed.bias_compensated()          # downweight label-skewed clients
fed_state = fed.init_fed_state(jax.random.PRNGKey(1), aggregator,
                               participation)

# T local iterations (eqs. 4-9) + the FL phase in one scanned program
state = engine.init_train_state(params, optim.sgd())
round_fn = jax.jit(engine.make_round_runner(
    model, sc, backend="logits", unroll=True,
    aggregator=aggregator, participation=participation,
    opt_state_policy="carry"))
rng = np.random.default_rng(0)
all_clients = np.arange(K)

for rnd in range(ROUNDS):
    # eq. (3) sizing over all K slots; the in-program mask then keeps
    # ~half of it, so the participating batch is ~B/2 per local step
    rb = round_batches(data, all_clients, B, T, rng)
    sizes = jnp.asarray(rb.pop("sizes"))
    batches = {k: jnp.asarray(v) for k, v in rb.items()}
    state, fed_state, metrics = round_fn(state, batches, sizes, fed_state)
    merged = A.merge_params(jax.tree.map(lambda a: a[0],
                                         state.params["client"]),
                            state.params["server"])
    logits = A.forward(merged, x_test, "s2")
    acc = float((jnp.argmax(logits, -1) == y_test).mean())
    print(f"round {rnd}: server_loss={float(metrics['loss_server']):.3f} "
          f"client_loss={float(metrics['loss_client']):.3f} "
          f"test_acc={acc:.3f}")

assert np.isfinite(float(metrics["loss_server"]))
print("quickstart OK")
