"""Serve a (reduced) global model with batched requests: prefill a batch
of prompts through the decode path and generate greedily with a KV/SSM
cache — the same ``decode_step`` the decode_32k / long_500k dry-run
shapes lower on the production mesh.

  PYTHONPATH=src python examples/serve_batched.py [--arch qwen1.5-0.5b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)

    t0 = time.time()
    out = generate(params, cfg, prompts,
                   max_len=args.prompt_len + args.gen, gen=args.gen)
    dt = time.time() - t0
    assert out.shape == (args.batch, args.prompt_len + args.gen)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))
    toks = args.batch * args.gen
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}: {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s on CPU)")
    print("first sequence:", out[0].tolist())
    print("serve_batched OK")


if __name__ == "__main__":
    main()
