"""Serve a (reduced) global model two ways and check they agree: the
token-by-token reference loop (:func:`repro.launch.serve.generate`) and
the continuous-batching engine (:class:`repro.serve.ServeEngine`) with
fused prefill and an optionally paged cache — greedy decoding from the
same params must produce identical tokens.

  PYTHONPATH=src python examples/serve_batched.py [--arch qwen1.5-0.5b]
      [--pages 16]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import transformer as T
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--pages", type=int, default=0,
                    help="paged decode cache (0 = dense)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.gen

    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)

    t0 = time.time()
    ref = np.asarray(generate(params, cfg, prompts, max_len=max_len,
                              gen=args.gen))
    dt_ref = time.time() - t0
    assert ref.shape == (args.batch, max_len)

    engine = ServeEngine(params, cfg, slots=args.slots, max_len=max_len,
                         pages=args.pages, page_size=8)
    t0 = time.time()
    out = engine.generate(np.asarray(prompts), args.gen)
    dt_eng = time.time() - t0

    np.testing.assert_array_equal(out, ref)   # token-identical
    toks = args.batch * args.gen
    cache = "paged" if args.pages else "dense"
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}: reference {toks/dt_ref:.1f} tok/s, engine "
          f"({args.slots} slots, {cache}) {toks/dt_eng:.1f} tok/s on CPU")
    print("first sequence:", out[0].tolist())
    print("engine output token-identical to the reference loop")
    print("serve_batched OK")


if __name__ == "__main__":
    main()
