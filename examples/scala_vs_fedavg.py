"""The paper's headline claim at laptop scale: SCALA beats FedAvg (and
plain SFL without logit adjustment) under skewed label distributions.

Reproduces the Table-1 protocol on synthetic CIFAR-shaped data with
quantity skew alpha=2 (each client sees at most 2 of 10 classes) and
reports final + balanced accuracy for:

  - scala        (concatenated activations + dual logit adjustment)
  - scala_noadj  (concatenated activations only -- the ablation)
  - fedavg       (the reference lower bound)
  - fedlogit     (FL + eq. 15 local logit adjustment)

Every run is a declarative :class:`repro.api.ExperimentSpec` executed
by :class:`repro.api.Trainer` (``run_experiment`` is now a thin kwargs
adapter over exactly that — ``benchmarks.common.experiment_spec`` +
``Trainer.run()`` + ``Trainer.evaluate()``), so the same specs can be
dumped to JSON and replayed via ``python -m repro.launch.train
--config``. SCALA additionally runs through the engine's sparse-slot
execution path (``ExecutionSpec(mode="sparse")``: all K slots stay
stacked, the in-program uniform scheduler picks the r-subset, and the
engine gathers it into a dense axis before the local scan) — same
protocol, subset-sized compute; it must preserve the ordering over
FedAvg too.

  PYTHONPATH=src python examples/scala_vs_fedavg.py
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import run_experiment

SETTINGS = (("alpha=2", dict(alpha=2)), ("beta=0.1", dict(beta=0.1)))
METHODS = ("scala", "scala_noadj", "fedavg", "fedlogit")

for name, kw in SETTINGS:
    print(f"\n== label skew: {name} ==")
    results = {}
    for m in METHODS:
        res = run_experiment(m, rounds=10, **kw)
        results[m] = res
        print(f"  {m:12s} acc={res['acc']:.3f} "
              f"balanced={res['balanced_acc']:.3f} ({res['seconds']}s)")
    res = run_experiment("scala", rounds=10, execution="sparse", **kw)
    results["scala_sparse"] = res
    print(f"  scala_sparse acc={res['acc']:.3f} "
          f"balanced={res['balanced_acc']:.3f} ({res['seconds']}s)")
    # the paper's ordering: SCALA's balanced accuracy dominates FedAvg's
    assert results["scala"]["balanced_acc"] >= results["fedavg"]["balanced_acc"], \
        "SCALA should dominate FedAvg on balanced accuracy under skew"
    assert results["scala_sparse"]["balanced_acc"] >= \
        results["fedavg"]["balanced_acc"] - 0.02, \
        "sparse-slot SCALA should preserve the ordering over FedAvg"
print("\nscala_vs_fedavg OK")
