"""End-to-end driver: federated LM training with SCALA on a reduced
qwen1.5-0.5b (the framework's production path — transformer split model,
fused LACE loss, stacked-client layout) for a few hundred local steps.

This is the same code path the multi-pod dry-run lowers onto the
16x16 / 2x16x16 mesh; here it runs on CPU with a reduced config.

  PYTHONPATH=src python examples/train_lm_scala.py [--rounds 8]
"""
import argparse
import sys

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--participation", default="0.5",
                    help="fraction (legacy subset stacking) or fed-layer "
                         "spec: full | uniform:FRAC | dirichlet:FRAC[:A]")
    ap.add_argument("--aggregator", default="weighted")
    ap.add_argument("--opt-state-policy", default="carry")
    args = ap.parse_args()

    sys.argv = [
        "train", "--arch", args.arch, "--reduced",
        "--rounds", str(args.rounds), "--clients", "8",
        "--participation", args.participation,
        "--aggregator", args.aggregator,
        "--opt-state-policy", args.opt_state_policy,
        "--local-iters", "4",
        "--seq", "64", "--server-batch", "16", "--docs-per-client", "16",
    ]
    train.main()


if __name__ == "__main__":
    main()
