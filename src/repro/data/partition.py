"""Label-skew partitioners (paper §5.1).

* quantity-based skew (α): data of each label is divided into K·α/N
  portions; each client receives α randomly-assigned portions, so each
  client holds at most α classes (missing classes when α < N).
* distribution-based skew (β): p_k ~ Dir_N(β); client k receives a
  fraction p_{k,y} of the samples of class y.

Host-side numpy; returns per-client index arrays.
"""
from __future__ import annotations

from typing import List

import numpy as np


def quantity_skew(labels: np.ndarray, num_clients: int, alpha: int,
                  num_classes: int, rng: np.random.Generator) -> List[np.ndarray]:
    total_portions = num_clients * alpha
    per_class = max(1, total_portions // num_classes)

    # chop each class into `per_class` portions
    portions = []
    for y in range(num_classes):
        idx = rng.permutation(np.where(labels == y)[0])
        if len(idx) == 0:
            continue
        for chunk in np.array_split(idx, per_class):
            if len(chunk):
                portions.append(chunk)
    rng.shuffle(portions)

    clients: List[List[np.ndarray]] = [[] for _ in range(num_clients)]
    for i, portion in enumerate(portions[: num_clients * alpha]):
        clients[i % num_clients].append(portion)
    out = []
    for parts in clients:
        if parts:
            out.append(np.concatenate(parts))
        else:  # degenerate fallback: give an empty client one random sample
            out.append(rng.choice(len(labels), size=1))
    return out


def dirichlet_skew(labels: np.ndarray, num_clients: int, beta: float,
                   num_classes: int, rng: np.random.Generator,
                   min_size: int = 2) -> List[np.ndarray]:
    n = len(labels)
    for _ in range(100):
        idx_clients: List[List[np.ndarray]] = [[] for _ in range(num_clients)]
        for y in range(num_classes):
            idx = rng.permutation(np.where(labels == y)[0])
            if len(idx) == 0:
                continue
            p = rng.dirichlet(np.full(num_clients, beta))
            cuts = (np.cumsum(p) * len(idx)).astype(int)[:-1]
            for k, chunk in enumerate(np.split(idx, cuts)):
                if len(chunk):
                    idx_clients[k].append(chunk)
        sizes = [sum(len(c) for c in parts) for parts in idx_clients]
        if min(sizes) >= min_size:
            break
    out = []
    for parts in idx_clients:
        if parts:
            out.append(np.concatenate(parts))
        else:
            out.append(rng.choice(n, size=min_size))
    return out


def partition(labels: np.ndarray, num_clients: int, *, alpha: int = None,
              beta: float = None, num_classes: int = None,
              seed: int = 0) -> List[np.ndarray]:
    """Dispatch on (alpha | beta) — exactly one must be given."""
    assert (alpha is None) != (beta is None), "give exactly one of alpha/beta"
    num_classes = num_classes or int(labels.max()) + 1
    rng = np.random.default_rng(seed)
    if alpha is not None:
        return quantity_skew(labels, num_clients, alpha, num_classes, rng)
    return dirichlet_skew(labels, num_clients, beta, num_classes, rng)
