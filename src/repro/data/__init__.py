from repro.data import loader, partition, synthetic  # noqa: F401
from repro.data.loader import FederatedData, lm_round_batches, round_batches, sample_clients  # noqa: F401
from repro.data.partition import partition as partition_labels  # noqa: F401
