"""Synthetic datasets (the container has no CIFAR download; the paper's
accuracy *ordering* is reproduced on structurally-equivalent synthetics).

* ``gaussian_images`` — CIFAR-shaped 32x32x3 classification with class
  prototypes + structured noise; linearly separable only in deep
  features, so the CNN must actually learn.
* ``token_stream`` — synthetic LM data with per-client skewed unigram
  distributions (Zipf with per-client permutation), the LM analogue of
  label skew used by the transformer SCALA examples.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def gaussian_images(n: int, num_classes: int = 10, hw: int = 32,
                    channels: int = 3, noise: float = 0.6,
                    seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    # low-frequency class prototypes: random 4x4 patterns upsampled
    protos = rng.normal(size=(num_classes, 4, 4, channels)).astype(np.float32)
    protos = protos.repeat(hw // 4, axis=1).repeat(hw // 4, axis=2)
    labels = rng.integers(0, num_classes, size=n)
    x = protos[labels] + noise * rng.normal(
        size=(n, hw, hw, channels)).astype(np.float32)
    # per-sample random contrast/brightness so pixel means aren't trivial cues
    a = rng.uniform(0.8, 1.2, size=(n, 1, 1, 1)).astype(np.float32)
    b = rng.uniform(-0.2, 0.2, size=(n, 1, 1, 1)).astype(np.float32)
    return (x * a + b), labels.astype(np.int64)


def token_stream(n_docs: int, doc_len: int, vocab: int, num_domains: int = 8,
                 zipf_a: float = 1.2, seed: int = 0):
    """Returns tokens (n_docs, doc_len) int32 and domain ids (n_docs,).

    Each domain is a different permutation of a Zipf distribution — the
    per-domain unigram skew that SCALA's logit adjustment targets in the
    LM setting.
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    base_p = ranks ** -zipf_a
    base_p /= base_p.sum()
    perms = [rng.permutation(vocab) for _ in range(num_domains)]
    domains = rng.integers(0, num_domains, size=n_docs)
    docs = np.empty((n_docs, doc_len), np.int32)
    for d in range(num_domains):
        sel = np.where(domains == d)[0]
        if len(sel) == 0:
            continue
        p = base_p[np.argsort(perms[d])]
        docs[sel] = rng.choice(vocab, size=(len(sel), doc_len), p=p)
    return docs, domains.astype(np.int64)
