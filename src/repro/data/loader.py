"""Federated round-batch assembly.

Implements the paper's round protocol on the host side:
* client sampling (partial participation, rate r),
* eq. (3) minibatch sizing B_k ∝ |D_k| (padded to max B_k with
  zero-weight rows so client batches stack into a (C, B_k, ...) tensor),
* T local-iteration minibatches per round: leaves (T, C, Bk, ...).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

import numpy as np

from repro.core.split import client_minibatch_sizes


@dataclass
class FederatedData:
    """Per-client datasets: x[i], y[i] are client i's arrays."""

    xs: List[np.ndarray]
    ys: List[np.ndarray]

    @property
    def num_clients(self) -> int:
        return len(self.xs)

    @property
    def sizes(self) -> np.ndarray:
        return np.array([len(y) for y in self.ys], np.int64)

    @classmethod
    def from_partition(cls, x, y, parts: Sequence[np.ndarray]):
        return cls(xs=[x[p] for p in parts], ys=[y[p] for p in parts])


def sample_clients(num_clients: int, num_selected: int,
                   rng: np.random.Generator) -> np.ndarray:
    return rng.choice(num_clients, size=num_selected, replace=False)


def round_batches(data: FederatedData, selected: np.ndarray,
                  server_batch: int, local_iters: int,
                  rng: np.random.Generator,
                  x_key: str = "x") -> Dict[str, np.ndarray]:
    """Build one round's batches: {'x': (T,C,Bk,...), 'labels', 'weights'},
    plus 'sizes' (C,) for eq. (10) aggregation."""
    sizes = data.sizes[selected]
    bks = client_minibatch_sizes(sizes, server_batch)
    bk_max = int(bks.max())
    T = local_iters
    C = len(selected)

    x_shape = data.xs[0].shape[1:]
    xs = np.zeros((T, C, bk_max) + x_shape, data.xs[0].dtype)
    ys = np.zeros((T, C, bk_max), np.int32)
    ws = np.zeros((T, C, bk_max), np.float32)

    for ci, k in enumerate(selected):
        xk, yk = data.xs[k], data.ys[k]
        bk = int(bks[ci])
        for t in range(T):
            idx = rng.choice(len(yk), size=bk, replace=len(yk) < bk)
            xs[t, ci, :bk] = xk[idx]
            ys[t, ci, :bk] = yk[idx]
            ws[t, ci, :bk] = 1.0
    return {x_key: xs, "labels": ys, "weights": ws,
            "sizes": sizes.astype(np.float32)}


def lm_round_batches(docs_by_client: List[np.ndarray], selected: np.ndarray,
                     server_batch: int, local_iters: int,
                     rng: np.random.Generator) -> Dict[str, np.ndarray]:
    """LM variant: docs (n_k, L) int32 per client. tokens = doc[:-1],
    labels = doc[1:], next-token prediction."""
    sizes = np.array([len(d) for d in docs_by_client])[selected]
    bks = client_minibatch_sizes(sizes, server_batch)
    bk_max = int(bks.max())
    T, C = local_iters, len(selected)
    L = docs_by_client[0].shape[1]

    toks = np.zeros((T, C, bk_max, L - 1), np.int32)
    labs = np.zeros((T, C, bk_max, L - 1), np.int32)
    ws = np.zeros((T, C, bk_max, L - 1), np.float32)
    for ci, k in enumerate(selected):
        dk = docs_by_client[k]
        bk = int(bks[ci])
        for t in range(T):
            idx = rng.choice(len(dk), size=bk, replace=len(dk) < bk)
            toks[t, ci, :bk] = dk[idx, :-1]
            labs[t, ci, :bk] = dk[idx, 1:]
            ws[t, ci, :bk] = 1.0
    return {"tokens": toks, "labels": labs, "weights": ws,
            "sizes": sizes.astype(np.float32)}
