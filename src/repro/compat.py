"""JAX version-compat shims.

The framework targets the newer mesh-context API (``jax.set_mesh``,
``jax.sharding.get_abstract_mesh``, ``jax.shard_map(check_vma=...)``)
but must run on the baked-in toolchain (jax 0.4.x), where those names
live elsewhere or do not exist. Everything that touches an ambient mesh
or ``shard_map`` goes through this module:

* :func:`set_mesh` — context manager making ``mesh`` ambient. On new JAX
  it is ``jax.set_mesh``; on 0.4.x it enters the legacy resource-env
  (``with mesh:``, so ``with_sharding_constraint`` accepts bare
  ``PartitionSpec``) and records the mesh in a thread-local that
  :func:`ambient_mesh` reads.
* :func:`ambient_mesh` — the mesh made ambient by :func:`set_mesh`, or
  ``None``. Replaces ``jax.sharding.get_abstract_mesh()`` callers.
* :func:`in_shard_map` — True while tracing the body of a
  :func:`shard_map` from this module. Replaces the ``axis_types ==
  Manual`` test: inside a shard body all data is already device-local, so
  sharding constraints / ambient-mesh collectives must be skipped.
* :func:`shard_map` — ``jax.shard_map`` when present, else the
  ``jax.experimental.shard_map`` fallback (``check_vma`` mapped to
  ``check_rep``); either way the body is wrapped so :func:`in_shard_map`
  is visible to model code called from inside it.
"""
from __future__ import annotations

import contextlib
import threading

import jax

_HAS_NATIVE_SET_MESH = hasattr(jax, "set_mesh")
_HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")

_tls = threading.local()


def in_shard_map() -> bool:
    """True while tracing the body of a :func:`shard_map` call."""
    return getattr(_tls, "in_shard_map", False)


def ambient_mesh():
    """The mesh made ambient by :func:`set_mesh`, or ``None``."""
    if _HAS_NATIVE_SET_MESH:
        m = jax.sharding.get_abstract_mesh()
        return m if m is not None and m.axis_names else None
    return getattr(_tls, "mesh", None)


@contextlib.contextmanager
def set_mesh(mesh):
    """Make ``mesh`` the ambient mesh (compat for ``jax.set_mesh``)."""
    if _HAS_NATIVE_SET_MESH:
        with jax.set_mesh(mesh):
            yield mesh
        return
    prev = getattr(_tls, "mesh", None)
    _tls.mesh = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _tls.mesh = prev


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` compat wrapper (maps ``check_vma``->``check_rep``
    on old JAX) that also flags :func:`in_shard_map` during body tracing."""

    def body(*args, **kwargs):
        prev = getattr(_tls, "in_shard_map", False)
        _tls.in_shard_map = True
        try:
            return f(*args, **kwargs)
        finally:
            _tls.in_shard_map = prev

    if _HAS_NATIVE_SHARD_MAP:
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


def abstract_mesh(axis_sizes, axis_names):
    """``jax.sharding.AbstractMesh`` across the constructor API change
    (new JAX: ``(sizes, names)``; 0.4.x: one tuple of (name, size) pairs)."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def manual_axis_names(mesh) -> set:
    """Mesh axes currently in Manual (shard_map) mode. On old JAX the
    per-axis types do not exist; :func:`in_shard_map` covers the use."""
    types = getattr(mesh, "axis_types", None)
    if types is None:
        return set(mesh.axis_names) if in_shard_map() else set()
    try:
        return {n for n, t in zip(mesh.axis_names, types)
                if str(t) == "Manual"}
    except TypeError:
        return set()
