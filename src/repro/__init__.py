"""repro: SCALA (Split Federated Learning with Concatenated Activations
and Logit Adjustments) as a production multi-pod JAX framework."""
__version__ = "1.0.0"
