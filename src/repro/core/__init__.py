from repro.core import baselines, engine, label_stats, logit_adjust, losses, scala, split  # noqa: F401
from repro.core.engine import (  # noqa: F401
    SplitModel,
    TrainState,
    init_scala_params,
    init_train_state,
    make_round_runner,
    make_split_step,
    scala_aggregate,
    scala_round_scan,
    split_step_grads,
)
from repro.core.scala import (  # noqa: F401
    alexnet_split_model,
    scala_local_step,
    scala_local_step_fused,
    scala_round,
    transformer_split_model,
)
