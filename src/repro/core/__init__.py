from repro.core import baselines, label_stats, logit_adjust, losses, scala, split  # noqa: F401
from repro.core.scala import (  # noqa: F401
    SplitModel,
    alexnet_split_model,
    init_scala_params,
    scala_aggregate,
    scala_local_step,
    scala_local_step_fused,
    scala_round,
    transformer_split_model,
)
