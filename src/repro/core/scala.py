"""SCALA: the paper's algorithm (Alg. 2) as a composable JAX module.

One *local iteration* (paper lines 9-20) is :func:`scala_local_step`:

1. every participating client runs its client-side forward (vmapped over
   the stacked client axis — client-parallel on the mesh),
2. the server consumes the **concatenated** activations (eqs. 5-6; on the
   mesh the concat is the client-sharded batch dimension itself),
3. the server loss uses logits adjusted by the concatenated prior P_s
   (eq. 14) and updates w_s (eq. 7),
4. the gradients returned to client k come from a *second* pullback with
   the client-local prior P_k (eqs. 15, 8),
5. each client applies its chain-rule update (eq. 9).

The FL phase (eq. 10) is :func:`scala_aggregate`. Both are pure functions
of (params, batch) so they jit/pjit directly; the launcher supplies mesh
shardings.

Models plug in through :class:`SplitModel` — a pair of pure functions for
the two halves. Adapters for the transformer stack and the paper's
AlexNet live at the bottom.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ScalaConfig
from repro.core import losses
from repro.core.label_stats import client_and_concat_priors
from repro.core.split import fedavg, redistribute, stack_client_params


@dataclass(frozen=True)
class SplitModel:
    """Functional adapter: the two halves of a split model.

    client_fwd(wc, batch) -> acts dict with key 'x' (+ optional 'memory',
    'positions'); server_fwd(ws, acts) -> (logits, aux_loss).

    For the fused (LACE) production path, additionally:
    server_trunk(ws, acts) -> (features, aux) — everything *except* the
    classifier head — and head_weight(ws) -> (d, V) so the loss can fuse
    head-matmul + adjusted CE without materializing logits.
    """

    client_fwd: Callable[[Any, Dict[str, Any]], Dict[str, Any]]
    server_fwd: Callable[[Any, Dict[str, Any]], Any]
    num_classes: int
    server_trunk: Optional[Callable[[Any, Dict[str, Any]], Any]] = None
    head_weight: Optional[Callable[[Any], Any]] = None
    head_grad_merge: Optional[Callable[[Any, Any], Any]] = None
    # replicated-head ("dp") profile: route the fused loss through the
    # shard_map LACE so the head grad is psummed once (§Perf iteration 3)
    dp_loss: bool = False


def _prior_for_tokens(p, labels_shape):
    """Broadcast a (..., N) prior against token labels (...,) -> (..., 1s, N)."""
    extra = len(labels_shape) - (p.ndim - 1)
    return p.reshape(p.shape[:-1] + (1,) * extra + (p.shape[-1],))


def scala_local_step(model: SplitModel, params, batch, scala: ScalaConfig,
                     *, lr: Optional[float] = None):
    """One SCALA local iteration. params: {'client': stacked (C,...),
    'server': ...}; batch leaves: (C, B_k, ...). Returns (params, metrics).
    """
    lr = scala.lr if lr is None else lr
    N = model.num_classes
    labels = batch["labels"]
    weights = batch.get("weights")
    C = labels.shape[0]

    # --- label statistics (paper: clients upload Y_k with A_k) ---
    p_k, p_s = client_and_concat_priors(labels, N, weights,
                                        eps=scala.prior_eps)

    # --- parallel client forward (client-parallel == vmap over C) ---
    acts = jax.vmap(lambda w, b: model.client_fwd(w, b))(params["client"], batch)
    x = acts["x"]                                   # (C, B_k, ..., d)
    has_mem = "memory" in acts
    flat = lambda a: a.reshape((-1,) + a.shape[2:])
    labels_f = flat(labels)
    weights_f = flat(weights) if weights is not None else None

    positions = acts["positions"][0] if "positions" in acts else None

    # --- server forward once; two pullbacks (eq. 14 for w_s, eq. 15 for G_k)
    if has_mem:
        def srv(ws, xf, memf):
            a = {"x": xf, "memory": memf}
            if positions is not None:
                a["positions"] = positions
            return model.server_fwd(ws, a)
        (logits, aux), vjp = jax.vjp(srv, params["server"], flat(x),
                                     flat(acts["memory"]))
    else:
        def srv(ws, xf):
            a = {"x": xf}
            if positions is not None:
                a["positions"] = positions
            return model.server_fwd(ws, a)
        (logits, aux), vjp = jax.vjp(srv, params["server"], flat(x))

    def server_loss(lg):
        return losses.softmax_xent(
            lg, labels_f, weights=weights_f,
            prior=p_s if scala.adjust_server else None,
            tau=scala.tau, label_smoothing=scala.label_smoothing,
            prior_eps=scala.prior_eps)

    loss_s, g_s = jax.value_and_grad(server_loss)(logits)

    # per-client prior, broadcast over each client's token dims (eq. 15)
    pk_tok = _prior_for_tokens(p_k, labels.shape)            # (C,1..,N)
    pk_flat = flat(jnp.broadcast_to(
        pk_tok, labels.shape[:2] + (1,) * (labels.ndim - 2) + (N,)))

    def client_loss(lg):
        return losses.softmax_xent(
            lg, labels_f, weights=weights_f,
            prior=pk_flat if scala.adjust_client else None,
            tau=scala.tau, label_smoothing=scala.label_smoothing,
            prior_eps=scala.prior_eps)

    loss_k, g_k = jax.value_and_grad(client_loss)(logits)

    one = jnp.ones((), aux.dtype)
    zero = jnp.zeros((), aux.dtype)
    if has_mem:
        d_ws, _, _ = vjp((g_s, one))
        _, g_x, g_mem = vjp((g_k, zero))
    else:
        d_ws, _ = vjp((g_s, one))
        _, g_x = vjp((g_k, zero))
        g_mem = None

    # --- eq. (7): server SGD update every local iteration ---
    new_server = jax.tree.map(lambda w, g: w - lr * g.astype(w.dtype),
                              params["server"], d_ws)

    # --- eq. (9): per-client backward + update ---
    g_x = g_x.reshape(x.shape)
    if g_mem is not None:
        g_mem = g_mem.reshape(acts["memory"].shape)

    def client_grad(wc, b, gx_k, gmem_k):
        def f(w):
            a = model.client_fwd(w, b)
            if has_mem:
                return a["x"], a["memory"]
            return a["x"]
        _, cvjp = jax.vjp(f, wc)
        ct = (gx_k, gmem_k) if has_mem else gx_k
        return cvjp(ct)[0]

    if has_mem:
        d_wc = jax.vmap(client_grad)(params["client"], batch, g_x, g_mem)
    else:
        d_wc = jax.vmap(lambda w, b, g: client_grad(w, b, g, None))(
            params["client"], batch, g_x)

    new_client = jax.tree.map(lambda w, g: w - lr * g.astype(w.dtype),
                              params["client"], d_wc)

    metrics = {
        "loss_server": loss_s,
        "loss_client": loss_k,
        "aux": aux,
        "accuracy": losses.accuracy(logits, labels_f, weights_f),
    }
    return {"client": new_client, "server": new_server}, metrics


def scala_local_step_fused(model: SplitModel, params, batch,
                           scala: ScalaConfig, *, lr: Optional[float] = None,
                           ce_chunk: Optional[int] = None):
    """Production SCALA local iteration with the fused LACE loss.

    Identical semantics to :func:`scala_local_step`, but the classifier
    head matmul + adjusted softmax-CE are fused and chunked over tokens
    (:mod:`repro.kernels.lace`), so full-vocab logits are never
    materialized — required for the 262k-vocab archs at 1M tokens/step.
    """
    from repro.kernels.lace.ops import lace_loss, lace_loss_dp

    if model.dp_loss:
        lace = lace_loss_dp
    else:
        lace = lace_loss
    lr = scala.lr if lr is None else lr
    N = model.num_classes
    if ce_chunk is None:
        # larger chunks -> fewer head-grad all-reduce trips in the chunked
        # CE loop (the gW partial is re-reduced every trip); cap the global
        # chunk so logits stay ~2^32 elements (§Perf iteration 3)
        ce_chunk = max(4096, (1 << 32) // max(1, N))
    labels = batch["labels"]
    weights = batch.get("weights")
    C = labels.shape[0]

    p_k, p_s = client_and_concat_priors(labels, N, weights,
                                        eps=scala.prior_eps)

    acts = jax.vmap(lambda w, b: model.client_fwd(w, b))(params["client"], batch)
    x = acts["x"]                                    # (C, Bk, S, d)
    has_mem = "memory" in acts
    flat = lambda a: a.reshape((-1,) + a.shape[2:])
    positions = acts["positions"][0] if "positions" in acts else None

    # --- server trunk once, vjp shared by both losses ---
    if has_mem:
        def trunk(ws, xf, memf):
            a = {"x": xf, "memory": memf}
            if positions is not None:
                a["positions"] = positions
            return model.server_trunk(ws, a)
        (feats, aux), vjp = jax.vjp(trunk, params["server"], flat(x),
                                    flat(acts["memory"]))
    else:
        def trunk(ws, xf):
            a = {"x": xf}
            if positions is not None:
                a["positions"] = positions
            return model.server_trunk(ws, a)
        (feats, aux), vjp = jax.vjp(trunk, params["server"], flat(x))

    d = feats.shape[-1]
    bk, s_out = x.shape[1], feats.shape[1]
    feats_g = feats.reshape(C, bk * s_out, d)
    labels_g = labels.reshape(C, -1)
    weights_g = None if weights is None else weights.reshape(C, -1)
    w_head = model.head_weight(params["server"])

    # eq. (14): concatenated prior P_s for the server update
    def loss_s_fn(fg, wh):
        return lace(fg, wh, labels_g,
                         p_s[None] if scala.adjust_server else None,
                         None, weights_g, scala.tau, scala.prior_eps,
                         ce_chunk)

    loss_s, (gf_s, gW_s) = jax.value_and_grad(loss_s_fn, argnums=(0, 1))(
        feats_g, w_head)

    # eq. (15): per-client priors P_k for the gradients G_k sent back
    def loss_k_fn(fg):
        return lace(fg, w_head, labels_g,
                         p_k if scala.adjust_client else None,
                         jnp.arange(C) if scala.adjust_client else None,
                         weights_g, scala.tau, scala.prior_eps, ce_chunk)

    loss_k, gf_k = jax.value_and_grad(loss_k_fn)(feats_g)

    one = jnp.ones((), aux.dtype)
    zero = jnp.zeros((), aux.dtype)
    gf_s_t = gf_s.reshape(feats.shape)
    gf_k_t = gf_k.reshape(feats.shape)
    if has_mem:
        d_ws, _, _ = vjp((gf_s_t, one))
        _, g_x, g_mem = vjp((gf_k_t, zero))
    else:
        d_ws, _ = vjp((gf_s_t, one))
        _, g_x = vjp((gf_k_t, zero))
        g_mem = None

    d_ws = model.head_grad_merge(d_ws, gW_s)

    new_server = jax.tree.map(lambda w, g: w - lr * g.astype(w.dtype),
                              params["server"], d_ws)

    g_x = g_x.reshape(x.shape)
    if g_mem is not None:
        g_mem = g_mem.reshape(acts["memory"].shape)

    def client_grad(wc, b, gx_k, gmem_k):
        def f(w):
            a = model.client_fwd(w, b)
            if has_mem:
                return a["x"], a["memory"]
            return a["x"]
        _, cvjp = jax.vjp(f, wc)
        ct = (gx_k, gmem_k) if has_mem else gx_k
        return cvjp(ct)[0]

    if has_mem:
        d_wc = jax.vmap(client_grad)(params["client"], batch, g_x, g_mem)
    else:
        d_wc = jax.vmap(lambda w, b, g: client_grad(w, b, g, None))(
            params["client"], batch, g_x)

    new_client = jax.tree.map(lambda w, g: w - lr * g.astype(w.dtype),
                              params["client"], d_wc)

    metrics = {"loss_server": loss_s, "loss_client": loss_k, "aux": aux}
    return {"client": new_client, "server": new_server}, metrics


def scala_local_step_fused_dp(model: SplitModel, params, batch,
                              scala: ScalaConfig, mesh, batch_specs,
                              *, lr: Optional[float] = None,
                              ce_chunk: Optional[int] = None):
    """Manual-SPMD SCALA local iteration for the replicated-weight ("dp")
    profile — the whole step runs inside one ``shard_map``.

    Layout: client axis over ``("pod","data")``, per-client batch over
    ``("model",)``, every weight replicated. Inside the shard: all model
    math is local; the only collectives are (a) label-histogram psums for
    P_k (over "model") and P_s (over all axes), (b) two scalar loss
    psums, (c) ONE psum of the server-side gradient tree, (d) a psum over
    "model" of each client's own gradient. Under GSPMD the same step
    re-all-reduces weight-gradient partials on every chunk of every
    internal scan (mLSTM chunkwise, CE chunking) — this variant makes the
    per-step wire cost exactly 2x|w_s| + 2x|w_c|, the DDP lower bound
    (EXPERIMENTS.md §Perf).

    batch_specs: PartitionSpec pytree matching ``batch`` (the same
    logical->mesh resolution the launcher uses for in_shardings).
    """
    from jax.sharding import PartitionSpec as P

    lr = scala.lr if lr is None else lr
    N = model.num_classes
    if ce_chunk is None:
        ce_chunk = max(4096, (1 << 32) // max(1, N))

    names = set(mesh.axis_names)
    client_axes = tuple(a for a in ("pod", "data") if a in names)
    inner_axes = tuple(a for a in ("model",) if a in names)
    all_axes = client_axes + inner_axes

    # params: client leaves carry a leading stacked-client dim; server
    # leaves replicated.
    p_specs = {
        "client": jax.tree.map(lambda _: P(client_axes or None),
                               params["client"]),
        "server": jax.tree.map(lambda _: P(), params["server"]),
    }
    b_specs = batch_specs

    def local_step(p, b):
        labels = b["labels"]                      # (C_l, Bk_l, S)
        weights = b.get("weights")
        C_l = labels.shape[0]

        # --- label stats: local histogram -> psums (paper eq. 14/15) ---
        from repro.core.label_stats import histogram
        hist_k = jax.vmap(
            lambda l, w: histogram(l, N, w))(
            labels.reshape(C_l, -1),
            (jnp.ones((C_l, labels[0].size), jnp.float32) if weights is None
             else weights.reshape(C_l, -1)))               # (C_l, N)
        if inner_axes:
            hist_k = jax.lax.psum(hist_k, inner_axes)      # full client hist
        hist_s = jax.lax.psum(hist_k.sum(0), client_axes) \
            if client_axes else hist_k.sum(0)
        p_k = hist_k / jnp.maximum(hist_k.sum(-1, keepdims=True), 1e-8)
        p_s = hist_s / jnp.maximum(hist_s.sum(), 1e-8)

        # --- client forward (local client shard) ---
        acts = jax.vmap(lambda w, bb: model.client_fwd(w, bb))(
            p["client"], b)
        x = acts["x"]
        has_mem = "memory" in acts
        flat = lambda a: a.reshape((-1,) + a.shape[2:])
        positions = acts["positions"][0] if "positions" in acts else None

        if has_mem:
            def trunk(ws, xf, memf):
                a = {"x": xf, "memory": memf}
                if positions is not None:
                    a["positions"] = positions
                return model.server_trunk(ws, a)
            (feats, aux), vjp = jax.vjp(trunk, p["server"], flat(x),
                                        flat(acts["memory"]))
        else:
            def trunk(ws, xf):
                a = {"x": xf}
                if positions is not None:
                    a["positions"] = positions
                return model.server_trunk(ws, a)
            (feats, aux), vjp = jax.vjp(trunk, p["server"], flat(x))

        d = feats.shape[-1]
        bk, s_out = x.shape[1], feats.shape[1]
        feats_g = feats.reshape(C_l, bk * s_out, d)
        labels_g = labels.reshape(C_l, -1)
        weights_g = None if weights is None else weights.reshape(C_l, -1)
        w_head = model.head_weight(p["server"])

        from repro.kernels.lace.ops import lace_nll_sum

        # differentiate LOCAL nll sums only (never through a psum: with
        # vma checking off, the psum transpose would re-reduce an
        # already-replicated cotangent and over-count by |axes|); the
        # global normalization is applied to values/grads afterwards.
        wsum_local = (jnp.sum(weights_g) if weights_g is not None
                      else jnp.float32(labels_g.size))
        w_global = jnp.maximum(jax.lax.psum(
            jnp.asarray(wsum_local, jnp.float32), all_axes), 1e-8)

        # eq. (14): concatenated prior P_s
        def nll_s_fn(fg, wh):
            return lace_nll_sum(fg, wh, labels_g,
                                p_s[None] if scala.adjust_server else None,
                                None, weights_g, scala.tau,
                                scala.prior_eps, ce_chunk)

        nll_s, (gf_s, gW_s) = jax.value_and_grad(
            nll_s_fn, argnums=(0, 1))(feats_g, w_head)
        loss_s = jax.lax.psum(nll_s, all_axes) / w_global
        gf_s = gf_s / w_global
        gW_s = gW_s / w_global

        # eq. (15): per-client priors P_k
        def nll_k_fn(fg):
            return lace_nll_sum(fg, w_head, labels_g,
                                p_k if scala.adjust_client else None,
                                jnp.arange(C_l) if scala.adjust_client
                                else None, weights_g, scala.tau,
                                scala.prior_eps, ce_chunk)

        nll_k, gf_k = jax.value_and_grad(nll_k_fn)(feats_g)
        loss_k = jax.lax.psum(nll_k, all_axes) / w_global
        gf_k = gf_k / w_global

        one = jnp.ones((), aux.dtype)
        zero = jnp.zeros((), aux.dtype)
        gf_s_t = gf_s.reshape(feats.shape).astype(feats.dtype)
        gf_k_t = gf_k.reshape(feats.shape).astype(feats.dtype)
        if has_mem:
            d_ws, _, _ = vjp((gf_s_t, one))
            _, g_x, g_mem = vjp((gf_k_t, zero))
        else:
            d_ws, _ = vjp((gf_s_t, one))
            _, g_x = vjp((gf_k_t, zero))
            g_mem = None

        d_ws = model.head_grad_merge(d_ws, gW_s)
        # the ONE server-grad reduction: every leaf is a local partial
        # (the psum transpose passes the global cotangent through, so
        # grads wrt replicated weights are per-shard contributions).
        # optionally compress the reduction to bf16 (halves the only
        # remaining wire traffic and its buffers).
        rdt = (jnp.dtype(scala.grad_reduce_dtype)
               if scala.grad_reduce_dtype else None)
        if rdt is not None:
            d_ws = jax.tree.map(lambda g: g.astype(rdt), d_ws)
        d_ws = jax.lax.psum(d_ws, all_axes)

        new_server = jax.tree.map(lambda w, g: w - lr * g.astype(w.dtype),
                                  p["server"], d_ws)

        g_x = g_x.reshape(x.shape)
        if g_mem is not None:
            g_mem = g_mem.reshape(acts["memory"].shape)

        def client_grad(wc, bb, gx_k, gmem_k):
            def f(w):
                a = model.client_fwd(w, bb)
                if has_mem:
                    return a["x"], a["memory"]
                return a["x"]
            _, cvjp = jax.vjp(f, wc)
            ct = (gx_k, gmem_k) if has_mem else gx_k
            return cvjp(ct)[0]

        if has_mem:
            d_wc = jax.vmap(client_grad)(p["client"], b, g_x, g_mem)
        else:
            d_wc = jax.vmap(lambda w, bb, g: client_grad(w, bb, g, None))(
                p["client"], b, g_x)
        if inner_axes:
            # each client's batch is itself sharded over `model`
            if rdt is not None:
                d_wc = jax.tree.map(lambda g: g.astype(rdt), d_wc)
            d_wc = jax.lax.psum(d_wc, inner_axes)

        new_client = jax.tree.map(lambda w, g: w - lr * g.astype(w.dtype),
                                  p["client"], d_wc)

        metrics = {"loss_server": loss_s, "loss_client": loss_k,
                   "aux": jax.lax.pmean(aux, all_axes)}
        return {"client": new_client, "server": new_server}, metrics

    fn = jax.shard_map(
        local_step, mesh=mesh,
        in_specs=(p_specs, b_specs),
        out_specs=(p_specs, jax.tree.map(lambda _: P(), {"loss_server": 0,
                                                         "loss_client": 0,
                                                         "aux": 0})),
        check_vma=False)
    return fn(params, batch)


def scala_aggregate(params, data_sizes=None):
    """FL phase (eq. 10): FedAvg the client halves, redistribute."""
    return {"client": redistribute(params["client"], data_sizes),
            "server": params["server"]}


def scala_round(model: SplitModel, params, round_batches, scala: ScalaConfig,
                data_sizes=None, *, local_step=None):
    """T local iterations + aggregation. round_batches: leaves (T, C, Bk, ...).

    Python loop (each step separately jitted by the caller via
    ``local_step``); used by the CPU-scale examples/benchmarks.
    """
    step = local_step or (lambda p, b: scala_local_step(model, p, b, scala))
    T = jax.tree.leaves(round_batches)[0].shape[0]
    metrics = None
    for t in range(T):
        batch_t = jax.tree.map(lambda a: a[t], round_batches)
        params, metrics = step(params, batch_t)
    return scala_aggregate(params, data_sizes), metrics


def init_scala_params(key, init_client, init_server, num_clients: int):
    """Build the stacked-client SCALA param layout from per-half inits."""
    kc, ks = jax.random.split(key)
    return {"client": stack_client_params(init_client(kc), num_clients),
            "server": init_server(ks)}


# ---------------------------------------------------------------------------
# adapters
# ---------------------------------------------------------------------------


def transformer_split_model(cfg: ModelConfig, *, remat: bool = True) -> SplitModel:
    from repro.models import transformer as T

    def client_fwd(wc, batch):
        return T.client_forward(wc, batch, cfg)

    def server_fwd(ws, acts):
        return T.server_forward(ws, acts, cfg, remat=remat)

    def server_trunk(ws, acts):
        return T.server_forward(ws, acts, cfg, remat=remat, head_mode="feats")

    def head_weight(ws):
        return ws["head"]["out"]

    def head_grad_merge(d_ws, g_w):
        d_ws = dict(d_ws)
        d_ws["head"] = {"out": d_ws["head"]["out"] + g_w.astype(
            d_ws["head"]["out"].dtype)}
        return d_ws

    return SplitModel(client_fwd=client_fwd, server_fwd=server_fwd,
                      num_classes=cfg.vocab_size, server_trunk=server_trunk,
                      head_weight=head_weight, head_grad_merge=head_grad_merge,
                      dp_loss=cfg.sharding_profile == "dp")


def alexnet_split_model(split: str = "s2", num_classes: int = 10) -> SplitModel:
    from repro.models import alexnet as A

    def client_fwd(wc, batch):
        return {"x": A.client_forward_from_split(wc, batch["x"], split)}

    def server_fwd(ws, acts):
        logits = A.server_forward_from_split(ws, acts["x"], split)
        return logits, jnp.zeros((), jnp.float32)

    return SplitModel(client_fwd=client_fwd, server_fwd=server_fwd,
                      num_classes=num_classes)
