"""SCALA legacy API — a thin compatibility layer over the split-step
engine (:mod:`repro.core.engine`).

The three historical step variants are now *names for engine loss
backends*; each wrapper below is a one-line delegation to
:func:`repro.core.engine.local_step` with the paper's plain-SGD update:

  ===========================  ==================  =======================
  legacy entry point           engine backend      semantics
  ===========================  ==================  =======================
  scala_local_step             ``"logits"``        materialized logits
  scala_local_step_fused       ``"lace"``          fused chunked head+CE
  scala_local_step_fused_dp    ``"lace_dp"``       manual-SPMD shard_map
  ===========================  ==================  =======================

New code should use the engine directly: :func:`engine.make_split_step`
for optimizer/schedule support and :func:`engine.scala_round_scan` /
:func:`engine.make_round_runner` for the scan-compiled round (T local
iterations + FedAvg in one XLA program). The model adapters
(:func:`transformer_split_model`, :func:`alexnet_split_model`) and the
param/aggregation helpers remain here and are re-exported unchanged.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ScalaConfig
from repro.core import engine
from repro.core.engine import (  # noqa: F401  (compat re-exports)
    SplitModel,
    init_scala_params,
    scala_aggregate,
    scala_round_scan,
)

# legacy entry points that already warned this process (warn once each)
_DEPRECATION_WARNED: set = set()


def _warn_deprecated(name: str, use: str) -> None:
    if name in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(name)
    warnings.warn(
        f"repro.core.scala.{name} is a legacy compatibility shim; use {use} "
        "instead (the engine threads optimizers/schedules and compiles the "
        "whole round — see repro.core.engine and repro.fed)",
        DeprecationWarning, stacklevel=3)


def scala_local_step(model: SplitModel, params, batch, scala: ScalaConfig,
                     *, lr: Optional[float] = None):
    """One SCALA local iteration, materialized-logits backend.

    params: {'client': stacked (C,...), 'server': ...}; batch leaves:
    (C, B_k, ...). Returns (params, metrics).

    .. deprecated:: use :func:`repro.core.engine.make_split_step`
       (``backend="logits"``).
    """
    _warn_deprecated("scala_local_step",
                     "engine.make_split_step(backend='logits')")
    return engine.local_step(model, params, batch, scala, backend="logits",
                             lr=lr)


def scala_local_step_fused(model: SplitModel, params, batch,
                           scala: ScalaConfig, *, lr: Optional[float] = None,
                           ce_chunk: Optional[int] = None):
    """Production SCALA local iteration with the fused LACE loss.

    Identical semantics to :func:`scala_local_step`, but the classifier
    head matmul + adjusted softmax-CE are fused and chunked over tokens
    (:mod:`repro.kernels.lace`), so full-vocab logits are never
    materialized — required for the 262k-vocab archs at 1M tokens/step.

    .. deprecated:: use :func:`repro.core.engine.make_split_step`
       (``backend="lace"``).
    """
    _warn_deprecated("scala_local_step_fused",
                     "engine.make_split_step(backend='lace')")
    return engine.local_step(model, params, batch, scala, backend="lace",
                             lr=lr, ce_chunk=ce_chunk)


def scala_local_step_fused_dp(model: SplitModel, params, batch,
                              scala: ScalaConfig, mesh, batch_specs,
                              *, lr: Optional[float] = None,
                              ce_chunk: Optional[int] = None):
    """Manual-SPMD SCALA local iteration for the replicated-weight ("dp")
    profile — the whole step runs inside one ``shard_map`` and the
    per-step wire cost is exactly 2x|w_s| + 2x|w_c|, the DDP lower bound
    (EXPERIMENTS.md §Perf).

    batch_specs: PartitionSpec pytree matching ``batch`` (the same
    logical->mesh resolution the launcher uses for in_shardings).

    .. deprecated:: use :func:`repro.core.engine.make_split_step`
       (``backend="lace_dp"``).
    """
    _warn_deprecated("scala_local_step_fused_dp",
                     "engine.make_split_step(backend='lace_dp')")
    return engine.local_step(model, params, batch, scala, backend="lace_dp",
                             lr=lr, ce_chunk=ce_chunk, mesh=mesh,
                             batch_specs=batch_specs)


def scala_round(model: SplitModel, params, round_batches, scala: ScalaConfig,
                data_sizes=None, *, local_step=None):
    """T local iterations + aggregation. round_batches: leaves (T, C, Bk, ...).

    Python loop (each step separately jitted by the caller via
    ``local_step``). Prefer :func:`engine.scala_round_scan`, which fuses
    the T iterations + FedAvg into one compiled program.

    .. deprecated:: use :func:`repro.core.engine.make_round_runner`
       (sync) or :func:`repro.fed.make_async_runner` (async events).
    """
    _warn_deprecated("scala_round",
                     "engine.make_round_runner / fed.make_async_runner")
    step = local_step or (lambda p, b: scala_local_step(model, p, b, scala))
    T = jax.tree.leaves(round_batches)[0].shape[0]
    metrics = None
    for t in range(T):
        batch_t = jax.tree.map(lambda a: a[t], round_batches)
        params, metrics = step(params, batch_t)
    return scala_aggregate(params, data_sizes), metrics


# ---------------------------------------------------------------------------
# adapters
# ---------------------------------------------------------------------------


def transformer_split_model(cfg: ModelConfig, *, remat: bool = True) -> SplitModel:
    from repro.models import transformer as T

    def client_fwd(wc, batch):
        return T.client_forward(wc, batch, cfg)

    def server_fwd(ws, acts):
        return T.server_forward(ws, acts, cfg, remat=remat)

    def server_trunk(ws, acts):
        return T.server_forward(ws, acts, cfg, remat=remat, head_mode="feats")

    def head_weight(ws):
        return ws["head"]["out"]

    def head_grad_merge(d_ws, g_w):
        d_ws = dict(d_ws)
        d_ws["head"] = {"out": d_ws["head"]["out"] + g_w.astype(
            d_ws["head"]["out"].dtype)}
        return d_ws

    return SplitModel(client_fwd=client_fwd, server_fwd=server_fwd,
                      num_classes=cfg.vocab_size, server_trunk=server_trunk,
                      head_weight=head_weight, head_grad_merge=head_grad_merge,
                      dp_loss=cfg.sharding_profile == "dp")


def alexnet_split_model(split: str = "s2", num_classes: int = 10) -> SplitModel:
    from repro.models import alexnet as A

    def client_fwd(wc, batch):
        return {"x": A.client_forward_from_split(wc, batch["x"], split)}

    def server_fwd(ws, acts):
        logits = A.server_forward_from_split(ws, acts["x"], split)
        return logits, jnp.zeros((), jnp.float32)

    return SplitModel(client_fwd=client_fwd, server_fwd=server_fwd,
                      num_classes=num_classes)
