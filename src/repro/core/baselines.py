"""Baselines the paper compares against (Tables 1-6).

FL family (full model on clients, FedAvg aggregation):
  fedavg, fedprox, feddyn, feddecorr, fedlogit (eq. 15 used locally),
  fedla (FedLC-style logit calibration).

SFL family (split model):
  splitfed_v1 (per-client server copies, both halves averaged per round),
  splitfed_v2 (shared server model updated sequentially; no server avg),
  splitfed_v3 (personalized client halves, server averaged),
  sfl_localloss (auxiliary client head; no server->client gradients).

All baselines run at CPU scale (the paper's AlexNet / MLP experiments);
SCALA itself additionally scales to the production mesh via the split-step
engine (:mod:`repro.core.engine`). The split forward/loss and the
parameter updates are shared with the engine: local objectives go through
:func:`engine.split_ce` and every update is an
:class:`repro.optim.Optimizer` (plain SGD by default — the paper's
setting) with state threaded through the local-iteration scans.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional, TYPE_CHECKING

import jax
import jax.numpy as jnp

from repro.core import engine, losses
from repro.core.engine import SplitModel
from repro.core.label_stats import histogram, prior
from repro.core.split import fedavg
from repro.optim import optimizers

if TYPE_CHECKING:  # runtime import is lazy: repro.core.__init__ ->
    from repro.fed import Aggregator  # baselines -> repro.fed would cycle

FL_METHODS = ("fedavg", "fedprox", "feddyn", "feddecorr", "fedlogit", "fedla")
SFL_METHODS = ("splitfed_v1", "splitfed_v2", "splitfed_v3", "sfl_localloss")


@dataclass(frozen=True)
class FedModel:
    """Full (non-split) model adapter for the FL baselines."""

    forward: Callable[[Any, Any], Any]              # (params, x) -> logits
    num_classes: int
    # optional feature extractor for FedDecorr
    features: Optional[Callable[[Any, Any], Any]] = None


def cast_fed_model(model: FedModel, precision: str) -> FedModel:
    """The FL-baseline mirror of :func:`repro.core.engine.cast_to_compute`:
    ``"bf16"`` casts params and inputs to bfloat16 inside the wrapped
    forward (master params stay f32; the cast's transpose upcasts the
    param grads back to f32); the losses themselves already reduce in
    f32."""
    if precision not in engine.PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}; expected "
                         f"{engine.PRECISIONS}")
    if precision == "f32":
        return model
    bf16 = jnp.bfloat16

    def forward(p, x):
        return model.forward(engine.cast_floats(p, bf16),
                             engine.cast_floats(x, bf16))

    features = None
    if model.features is not None:
        def features(p, x):
            return model.features(engine.cast_floats(p, bf16),
                                  engine.cast_floats(x, bf16))

    return dataclasses.replace(model, forward=forward, features=features)


# ---------------------------------------------------------------------------
# local losses
# ---------------------------------------------------------------------------


def _decorr_loss(feats):
    """FedDecorr: squared off-diagonal correlation of normalized features."""
    f = feats.reshape(feats.shape[0], -1).astype(jnp.float32)
    f = (f - f.mean(0)) / (f.std(0) + 1e-5)
    n = f.shape[0]
    corr = (f.T @ f) / n
    d = corr.shape[0]
    off = corr - jnp.diag(jnp.diag(corr))
    return jnp.sum(off ** 2) / (d * d)


def make_local_loss(method: str, model: FedModel, *, mu: float = 0.01,
                    alpha: float = 0.01, beta: float = 0.1,
                    tau: float = 1.0):
    N = model.num_classes

    def base_ce(params, batch, ctx):
        logits = model.forward(params, batch["x"])
        return losses.softmax_xent(logits, batch["labels"])

    if method == "fedavg":
        return base_ce

    if method == "fedprox":
        def loss(params, batch, ctx):
            prox = sum(jnp.sum((a - b.astype(a.dtype)) ** 2) for a, b in zip(
                jax.tree.leaves(params), jax.tree.leaves(ctx["w_global"])))
            return base_ce(params, batch, ctx) + 0.5 * mu * prox
        return loss

    if method == "feddyn":
        def loss(params, batch, ctx):
            lin = sum(jnp.sum(a * g) for a, g in zip(
                jax.tree.leaves(params), jax.tree.leaves(ctx["h_k"])))
            prox = sum(jnp.sum((a - b.astype(a.dtype)) ** 2) for a, b in zip(
                jax.tree.leaves(params), jax.tree.leaves(ctx["w_global"])))
            return base_ce(params, batch, ctx) - lin + 0.5 * alpha * prox
        return loss

    if method == "feddecorr":
        assert model.features is not None, "feddecorr needs model.features"
        def loss(params, batch, ctx):
            logits = model.forward(params, batch["x"])
            feats = model.features(params, batch["x"])
            return (losses.softmax_xent(logits, batch["labels"])
                    + beta * _decorr_loss(feats))
        return loss

    if method == "fedlogit":
        # eq. (15) applied to purely-local FL training
        def loss(params, batch, ctx):
            logits = model.forward(params, batch["x"])
            return losses.softmax_xent(logits, batch["labels"],
                                       prior=ctx["p_k"], tau=tau)
        return loss

    if method == "fedla":
        # FedLC (Zhang et al. 2022): margin calibration by count^{-1/4}
        def loss(params, batch, ctx):
            logits = model.forward(params, batch["x"]).astype(jnp.float32)
            margin = tau * (ctx["counts_k"] + 1e-8) ** -0.25
            return losses.softmax_xent(logits - margin, batch["labels"])
        return loss

    raise ValueError(f"unknown FL method {method!r}")


# ---------------------------------------------------------------------------
# FL runner
# ---------------------------------------------------------------------------


def fl_local_round(loss_fn, w_global, batches, ctx, lr: float,
                   optimizer: Optional[optimizers.Optimizer] = None):
    """T local optimizer steps from w_global. batches leaves: (T, Bk, ...).

    ``optimizer`` is any :class:`repro.optim.Optimizer` (default: plain
    SGD, the paper's setting); its state starts fresh each round, as every
    client restarts from the aggregated model.
    """
    opt = optimizer if optimizer is not None else optimizers.sgd()

    def step(carry, batch):
        w, st = carry
        g = jax.grad(loss_fn)(w, batch, ctx)
        return opt.update(g, st, w, lr), None

    (w, _), _ = jax.lax.scan(step, (w_global, opt.init(w_global)), batches)
    return w


def _aggregate_clients(aggregator: Optional["Aggregator"], stacked,
                       data_sizes, p_k=None, p_global=None):
    """Shared FL phase: the fed-layer aggregator when given (stateless
    only — baseline rounds don't thread aggregator state), else the
    legacy data-size FedAvg."""
    if aggregator is None:
        return fedavg(stacked, data_sizes)
    from repro.fed import AggContext

    assert not aggregator.stateful, \
        "baseline rounds support stateless aggregators only"
    C = jax.tree.leaves(stacked)[0].shape[0]
    ctx = AggContext(num_clients=C, data_sizes=data_sizes, p_k=p_k,
                     p_global=p_global)
    avg, _ = aggregator.aggregate(stacked, ctx, ())
    return avg


def _aggregation_priors(num_classes: int, round_batches):
    """(P_k, P_global) over the round labels for prior-aware aggregators,
    honoring per-token 'weights' when present so zero-weight padding rows
    (loader.round_batches pads every client to bk_max) don't count."""
    from repro.fed import aggregation_priors

    return aggregation_priors(num_classes, round_batches["labels"],
                              round_batches.get("weights"), client_axis=0)


def make_fl_round(method: str, model: FedModel, lr: float,
                  optimizer: Optional[optimizers.Optimizer] = None,
                  aggregator: Optional[Aggregator] = None,
                  server_optimizer: Optional[optimizers.Optimizer] = None,
                  server_lr: float = 1.0, precision: str = "f32", **kw):
    """Returns round(w_global, round_batches, client_labels_counts, state)
    -> (w_global', state'). round_batches leaves: (C, T, Bk, ...).

    ``precision``: compute policy (:func:`cast_fed_model`) — ``"bf16"``
    runs the local forward/backward in bfloat16 against f32 master
    params; aggregation and FedOpt stay f32.

    ``aggregator``: optional stateless :mod:`repro.fed` aggregator for
    the FL phase (default: data-size FedAvg). Prior-aware aggregators
    (bias_compensated) get the per-client round priors that the local
    losses already compute.

    ``server_optimizer``: classic FedOpt (Reddi et al.): the round delta
    ``w_global - fedavg(w_k)`` is a pseudo-gradient and the server
    optimizer steps ``w_global`` against it at ``server_lr`` (momentum =
    FedAvgM, adamw = FedAdam). State lives in ``state["server_opt"]`` —
    init with ``init_fl_state(..., server_optimizer=)``. Plain SGD at
    ``server_lr=1.0`` reproduces the unmodified FedAvg round.
    """
    model = cast_fed_model(model, precision)
    loss_fn = make_local_loss(method, model, **kw)
    alpha = kw.get("alpha", 0.01)

    def round_fn(w_global, round_batches, data_sizes, state):
        C = jax.tree.leaves(round_batches)[0].shape[0]
        counts = jax.vmap(
            lambda b: histogram(b, model.num_classes))(
                round_batches["labels"].reshape(C, -1))
        p_k = jax.vmap(prior)(counts)

        def one_client(batches_k, counts_k, pk_k, h_k):
            ctx = {"w_global": w_global, "p_k": pk_k, "counts_k": counts_k,
                   "h_k": h_k}
            return fl_local_round(loss_fn, w_global, batches_k, ctx, lr,
                                  optimizer)

        if method == "feddyn":
            h = state["h"]
            w_k = jax.vmap(one_client)(round_batches, counts, p_k, h)
            # h_k <- h_k - alpha (w_k - w_global)
            new_h = jax.tree.map(
                lambda hk, wk, wg: hk - alpha * (wk - wg[None]),
                h, w_k, w_global)
            state = dict(state)
            state["h"] = new_h
        else:
            dummy_h = jax.tree.map(
                lambda a: jnp.zeros((C,) + a.shape, a.dtype), w_global)
            w_k = jax.vmap(one_client)(round_batches, counts, p_k, dummy_h)
        if aggregator is not None and aggregator.needs_priors:
            p_k_agg, p_global = _aggregation_priors(model.num_classes,
                                                    round_batches)
        else:
            p_k_agg = p_global = None
        w_avg = _aggregate_clients(aggregator, w_k, data_sizes,
                                   p_k=p_k_agg, p_global=p_global)
        if server_optimizer is not None:
            if "server_opt" not in state:
                raise ValueError("server_optimizer needs state['server_opt'] "
                                 "— init with init_fl_state(..., "
                                 "server_optimizer=)")
            delta = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                w_global, w_avg)
            w_avg, so = server_optimizer.update(delta, state["server_opt"],
                                                w_global, server_lr)
            state = dict(state)
            state["server_opt"] = so
        return w_avg, state

    return round_fn


def init_fl_state(method: str, w_global, num_clients: int,
                  server_optimizer: Optional[optimizers.Optimizer] = None):
    state = {}
    if method == "feddyn":
        state["h"] = jax.tree.map(
            lambda a: jnp.zeros((num_clients,) + a.shape, a.dtype), w_global)
    if server_optimizer is not None:
        state["server_opt"] = server_optimizer.init(w_global)
    return state


# ---------------------------------------------------------------------------
# SFL baselines (split model)
# ---------------------------------------------------------------------------


def make_sfl_round(method: str, model: SplitModel, lr: float,
                   aux_head_fwd=None,
                   optimizer: Optional[optimizers.Optimizer] = None,
                   aggregator: Optional[Aggregator] = None,
                   precision: str = "f32"):
    """SFL-family round functions.

    State layout: {'wc': stacked (C,...) or shared, 'ws': ..., 'aux': ...}.
    round_batches leaves: (C, T, Bk, ...). The local objective is the
    engine's :func:`repro.core.engine.split_ce`; updates come from
    ``optimizer`` (default plain SGD) with state threaded through the
    local scans and reset at each round boundary (clients restart from
    the aggregated model). ``aggregator``: optional stateless
    :mod:`repro.fed` aggregator for the averaged halves (default:
    data-size FedAvg). ``precision``: compute policy
    (:func:`repro.core.engine.cast_to_compute`) — ``"bf16"`` local
    compute against f32 master params.
    """
    model = engine.cast_to_compute(model, precision)
    opt = optimizer if optimizer is not None else optimizers.sgd()

    def _agg(stacked, data_sizes, round_batches):
        if aggregator is not None and aggregator.needs_priors:
            p_k, p_global = _aggregation_priors(model.num_classes,
                                                round_batches)
        else:
            p_k = p_global = None
        return _aggregate_clients(aggregator, stacked, data_sizes,
                                  p_k=p_k, p_global=p_global)

    def local_steps_pair(wc, ws, batches_k):
        def step(carry, batch):
            wc, ws, st_c, st_s = carry
            gc, gs = jax.grad(
                lambda a, b: engine.split_ce(model, a, b, batch),
                argnums=(0, 1))(wc, ws)
            wc, st_c = opt.update(gc, st_c, wc, lr)
            ws, st_s = opt.update(gs, st_s, ws, lr)
            return (wc, ws, st_c, st_s), None
        (wc, ws, _, _), _ = jax.lax.scan(
            step, (wc, ws, opt.init(wc), opt.init(ws)), batches_k)
        return wc, ws

    if method in ("splitfed_v1", "splitfed_v3"):
        def round_fn(state, round_batches, data_sizes):
            wc_stack = state["wc"]                     # (C, ...)
            ws = state["ws"]
            wc_k, ws_k = jax.vmap(
                lambda wc, b: local_steps_pair(wc, ws, b))(wc_stack, round_batches)
            new_ws = _agg(ws_k, data_sizes, round_batches)
            if method == "splitfed_v1":
                new_wc_avg = _agg(wc_k, data_sizes, round_batches)
                C = jax.tree.leaves(wc_k)[0].shape[0]
                new_wc = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (C,) + a.shape),
                    new_wc_avg)
            else:  # v3: personalized client halves
                new_wc = wc_k
            return {"wc": new_wc, "ws": new_ws}
        return round_fn

    if method == "splitfed_v2":
        # shared server model, clients processed sequentially per local step
        def round_fn(state, round_batches, data_sizes):
            wc_stack, ws = state["wc"], state["ws"]
            C = jax.tree.leaves(wc_stack)[0].shape[0]
            T = jax.tree.leaves(round_batches)[0].shape[1]

            def local_step(carry, t):
                wc_stack, ws, st_c, st_s = carry

                def per_client(carry_ws, k):
                    ws, st_s = carry_ws
                    batch = jax.tree.map(lambda a: a[k, t], round_batches)
                    wc = jax.tree.map(lambda a: a[k], wc_stack)
                    gc, gs = jax.grad(
                        lambda a, b: engine.split_ce(model, a, b, batch),
                        argnums=(0, 1))(wc, ws)
                    ws, st_s = opt.update(gs, st_s, ws, lr)
                    return (ws, st_s), gc

                (ws, st_s), gcs = jax.lax.scan(per_client, (ws, st_s),
                                               jnp.arange(C))
                wc_stack, st_c = jax.vmap(
                    lambda g, s, p: opt.update(g, s, p, lr))(
                    gcs, st_c, wc_stack)
                return (wc_stack, ws, st_c, st_s), None

            (wc_stack, ws, _, _), _ = jax.lax.scan(
                local_step,
                (wc_stack, ws, jax.vmap(opt.init)(wc_stack), opt.init(ws)),
                jnp.arange(T))
            new_wc_avg = _agg(wc_stack, data_sizes, round_batches)
            new_wc = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (C,) + a.shape), new_wc_avg)
            return {"wc": new_wc, "ws": ws}
        return round_fn

    if method == "sfl_localloss":
        assert aux_head_fwd is not None
        def round_fn(state, round_batches, data_sizes):
            wc_stack, ws, aux_stack = state["wc"], state["ws"], state["aux"]

            def one_client(wc, aux_p, batches_k):
                def step(carry, batch):
                    wc, aux_p, ws_l, st_c, st_a, st_s = carry
                    # client: local auxiliary loss only
                    def closs(wc_, aux_):
                        acts = model.client_fwd(wc_, batch)
                        lg = aux_head_fwd(aux_, acts["x"])
                        return losses.softmax_xent(lg, batch["labels"])
                    gc, ga = jax.grad(closs, argnums=(0, 1))(wc, aux_p)
                    wc, st_c = opt.update(gc, st_c, wc, lr)
                    aux_p, st_a = opt.update(ga, st_a, aux_p, lr)
                    # server: trains on (detached) activations
                    acts = model.client_fwd(wc, batch)
                    acts = jax.tree.map(jax.lax.stop_gradient, acts)
                    def sloss(ws_):
                        lg, aux = model.server_fwd(ws_, acts)
                        return losses.softmax_xent(lg, batch["labels"]) + aux
                    gs = jax.grad(sloss)(ws_l)
                    ws_l, st_s = opt.update(gs, st_s, ws_l, lr)
                    return (wc, aux_p, ws_l, st_c, st_a, st_s), None
                (wc, aux_p, ws_l, _, _, _), _ = jax.lax.scan(
                    step,
                    (wc, aux_p, ws, opt.init(wc), opt.init(aux_p),
                     opt.init(ws)),
                    batches_k)
                return wc, aux_p, ws_l

            wc_k, aux_k, ws_k = jax.vmap(one_client)(wc_stack, aux_stack,
                                                     round_batches)
            new_ws = _agg(ws_k, data_sizes, round_batches)
            new_wc_avg = _agg(wc_k, data_sizes, round_batches)
            C = jax.tree.leaves(wc_k)[0].shape[0]
            bcast = lambda a: jnp.broadcast_to(a[None], (C,) + a.shape)
            return {"wc": jax.tree.map(bcast, new_wc_avg),
                    "ws": new_ws,
                    "aux": jax.tree.map(bcast,
                                        _agg(aux_k, data_sizes,
                                             round_batches))}
        return round_fn

    raise ValueError(f"unknown SFL method {method!r}")
