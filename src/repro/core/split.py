"""Client-model stacking and FedAvg aggregation (paper eqs. 3, 10).

On the TPU mesh, "the C participating clients" are the slices of the
client-parallel axis; the per-client client-side models are a single
pytree whose leaves carry a leading ``client`` dimension.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def stack_client_params(client_params, num_clients: int):
    """Replicate one client-side pytree into (C, ...) stacked params —
    every participating client starts a round from the aggregated model
    (Alg. 1 line 7)."""
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (num_clients,) + a.shape), client_params)


def fedavg(stacked_params, data_sizes=None):
    """eq. (10): weighted average over the leading client axis."""
    if data_sizes is None:
        return jax.tree.map(lambda a: a.mean(axis=0), stacked_params)
    w = data_sizes.astype(jnp.float32)
    w = w / jnp.maximum(w.sum(), 1e-8)

    def avg(a):
        wb = w.reshape((-1,) + (1,) * (a.ndim - 1)).astype(a.dtype)
        return (a * wb).sum(axis=0)

    return jax.tree.map(avg, stacked_params)


def redistribute(stacked_params, data_sizes=None):
    """FedAvg + broadcast back to all client slots (end of round)."""
    avg = fedavg(stacked_params, data_sizes)
    C = jax.tree.leaves(stacked_params)[0].shape[0]
    return stack_client_params(avg, C)


def client_minibatch_sizes(data_sizes, server_batch: int):
    """eq. (3): B_k = |D_k| * B / sum |D_k| (integer, >=1)."""
    import numpy as np

    d = np.asarray(data_sizes, dtype=np.float64)
    b = np.maximum(1, np.floor(d * server_batch / d.sum())).astype(int)
    return b
