"""Client-model stacking and FedAvg aggregation (paper eqs. 3, 10).

On the TPU mesh, "the C participating clients" are the slices of the
client-parallel axis; the per-client client-side models are a single
pytree whose leaves carry a leading ``client`` dimension.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def stack_client_params(client_params, num_clients: int):
    """Replicate one client-side pytree into (C, ...) stacked params —
    every participating client starts a round from the aggregated model
    (Alg. 1 line 7)."""
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (num_clients,) + a.shape), client_params)


def normalize_client_weights(weights, mask=None, eps: float = 1e-8):
    """Mask-safe normalization of per-client aggregation weights.

    weights: (C,) raw non-negative weights (e.g. data sizes); mask: (C,)
    0/1 participation mask or None. Returns (C,) weights summing to 1.
    Zero-participation clients (weight 0, or masked out) are excluded
    WITHOUT producing NaNs: if the masked total is zero the weights fall
    back to uniform over the participating clients (or over all clients
    when nobody participates), never to an all-zero/NaN vector.
    """
    w = weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    total = w.sum()
    C = w.shape[0]
    if mask is None:
        fallback = jnp.full_like(w, 1.0 / C)
    else:
        m = mask.astype(jnp.float32)
        msum = m.sum()
        fallback = jnp.where(msum > 0, m / jnp.maximum(msum, 1.0),
                             jnp.full_like(w, 1.0 / C))
    return jnp.where(total > 0, w / jnp.maximum(total, eps), fallback)


def weighted_mean(stacked_params, weights):
    """Weighted sum over the leading client axis; ``weights`` (C,) must
    already be normalized (see :func:`normalize_client_weights`)."""

    def avg(a):
        wb = weights.reshape((-1,) + (1,) * (a.ndim - 1)).astype(a.dtype)
        return (a * wb).sum(axis=0)

    return jax.tree.map(avg, stacked_params)


def fedavg(stacked_params, data_sizes=None):
    """eq. (10): weighted average over the leading client axis.

    ``data_sizes`` may contain zero-participation clients (zeros); the
    normalization is mask-safe (all-zero sizes fall back to the uniform
    mean instead of an all-zero result)."""
    if data_sizes is None:
        return jax.tree.map(lambda a: a.mean(axis=0), stacked_params)
    return weighted_mean(stacked_params,
                         normalize_client_weights(data_sizes))


def redistribute(stacked_params, data_sizes=None):
    """FedAvg + broadcast back to all client slots (end of round)."""
    avg = fedavg(stacked_params, data_sizes)
    C = jax.tree.leaves(stacked_params)[0].shape[0]
    return stack_client_params(avg, C)


def client_minibatch_sizes(data_sizes, server_batch: int):
    """eq. (3): B_k = |D_k| * B / sum |D_k| (integer, >=1)."""
    import numpy as np

    d = np.asarray(data_sizes, dtype=np.float64)
    b = np.maximum(1, np.floor(d * server_batch / d.sum())).astype(int)
    return b
