"""Cross-entropy losses (plain + logit-adjusted), reference jnp path.

The Pallas fused kernel (:mod:`repro.kernels.lace`) implements the same
adjusted-CE math with blocked vocab; :func:`softmax_xent` is its oracle.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.logit_adjust import adjust_logits


def softmax_xent(logits, labels, *, weights=None, prior=None,
                 tau: float = 1.0, label_smoothing: float = 0.0,
                 prior_eps: float = 1e-8):
    """Weighted-mean softmax cross-entropy with optional logit adjustment.

    logits: (..., N); labels: (...) int; weights: (...) or None;
    prior: (N,) or broadcastable to (..., N) — eq. (14)/(15) adjustment.
    Returns scalar f32 loss.
    """
    z = logits.astype(jnp.float32)
    if prior is not None:
        z = adjust_logits(z, prior, tau, prior_eps)
    lse = jax.scipy.special.logsumexp(z, axis=-1)
    ll = jnp.take_along_axis(z, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if label_smoothing > 0.0:
        n = z.shape[-1]
        mean_z = z.mean(axis=-1)
        nll = (1 - label_smoothing) * nll + label_smoothing * (lse - mean_z)
    if weights is None:
        return nll.mean()
    w = weights.astype(jnp.float32)
    return (nll * w).sum() / jnp.maximum(w.sum(), 1e-8)


def _token_cotangent(shape, weights):
    """d loss / d nll for the weighted-mean reduction of
    :func:`softmax_xent`, with a unit loss cotangent — the op-for-op
    mirror of what autodiff produces (mean: ``1/size`` broadcast;
    weighted: ``weights / max(sum, eps)``)."""
    if weights is None:
        size = 1
        for s in shape:
            size *= s
        return jnp.broadcast_to(jnp.float32(1.0) / jnp.float32(size), shape)
    w = weights.astype(jnp.float32)
    return w * (jnp.ones((), jnp.float32) / jnp.maximum(w.sum(), 1e-8))


def _xent_side(z0, labels, prior, tau, label_smoothing, prior_eps, cw,
               weights):
    """One adjusted-CE side: (loss, d loss/d z0) in a single pass.

    Mirrors the exact op sequence autodiff emits for
    ``value_and_grad(softmax_xent)(logits)`` — including jax's
    ``logsumexp`` internals (stop-gradiented finite-max shift, ``abs``
    on the sumexp) — so values AND grads are bit-identical f32.
    """
    z = adjust_logits(z0, prior, tau, prior_eps) if prior is not None else z0
    amax = jnp.max(z, axis=-1, initial=-jnp.inf)
    amax = jax.lax.stop_gradient(
        jax.lax.select(jnp.isfinite(amax), amax, jnp.zeros_like(amax)))
    exp_a = jnp.exp(z - amax[..., None])
    sumexp = jnp.abs(jnp.sum(exp_a, axis=-1))
    lse = jnp.log(sumexp) + amax
    ll = jnp.take_along_axis(z, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    ls = label_smoothing
    if ls > 0.0:
        n = z.shape[-1]
        mean_z = z.mean(axis=-1)
        nll = (1 - ls) * nll + ls * (lse - mean_z)
    if weights is None:
        loss = nll.mean()
    else:
        w = weights.astype(jnp.float32)
        loss = (nll * w).sum() / jnp.maximum(w.sum(), 1e-8)

    d_nll = cw * (1 - ls) if ls > 0.0 else cw
    d_lse = d_nll + cw * ls if ls > 0.0 else d_nll
    g = exp_a * (d_lse / sumexp)[..., None]
    if ls > 0.0:
        g = g + jnp.broadcast_to((-(cw * ls) / n)[..., None], g.shape)
    g = g + jnp.zeros_like(g).at[
        jnp.indices(labels.shape, sparse=True) + (labels,)].add(-d_nll)
    return loss, g


def dual_adjusted_xent(logits, labels, *, weights=None, prior_s=None,
                       prior_k=None, tau: float = 1.0,
                       label_smoothing: float = 0.0, prior_eps: float = 1e-8):
    """Both SCALA losses (eq. 14 / eq. 15) AND their logit cotangents in
    one pass over shared materialized logits.

    Fused flavor of the engine's ``"logits"`` backend: instead of two
    ``value_and_grad(softmax_xent)`` evaluations (each a forward plus a
    backward over the (tokens, N) logits), the per-side softmax stats are
    computed once and reused for the value and the gradient — halving the
    loss-stage traversals. Returns ``(loss_s, loss_k, g_s, g_k)`` with
    gradients in ``logits.dtype``, bit-identical (f32) to the two-pass
    path.
    """
    z0 = logits.astype(jnp.float32)
    cw = _token_cotangent(labels.shape, weights)
    loss_s, g_s = _xent_side(z0, labels, prior_s, tau, label_smoothing,
                             prior_eps, cw, weights)
    loss_k, g_k = _xent_side(z0, labels, prior_k, tau, label_smoothing,
                             prior_eps, cw, weights)
    return loss_s, loss_k, g_s.astype(logits.dtype), g_k.astype(logits.dtype)


def accuracy(logits, labels, weights=None):
    pred = jnp.argmax(logits, axis=-1)
    correct = (pred == labels).astype(jnp.float32)
    if weights is None:
        return correct.mean()
    w = weights.astype(jnp.float32)
    return (correct * w).sum() / jnp.maximum(w.sum(), 1e-8)


def per_class_accuracy(logits, labels, num_classes: int):
    """Balanced (macro-averaged) accuracy — the paper's motivating metric."""
    pred = jnp.argmax(logits, axis=-1).reshape(-1)
    lab = labels.reshape(-1)
    correct = (pred == lab).astype(jnp.float32)
    hits = jnp.zeros((num_classes,)).at[lab].add(correct)
    counts = jnp.zeros((num_classes,)).at[lab].add(1.0)
    per_class = hits / jnp.maximum(counts, 1.0)
    present = counts > 0
    return (per_class * present).sum() / jnp.maximum(present.sum(), 1.0)
