"""Cross-entropy losses (plain + logit-adjusted), reference jnp path.

The Pallas fused kernel (:mod:`repro.kernels.lace`) implements the same
adjusted-CE math with blocked vocab; :func:`softmax_xent` is its oracle.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.logit_adjust import adjust_logits


def softmax_xent(logits, labels, *, weights=None, prior=None,
                 tau: float = 1.0, label_smoothing: float = 0.0,
                 prior_eps: float = 1e-8):
    """Weighted-mean softmax cross-entropy with optional logit adjustment.

    logits: (..., N); labels: (...) int; weights: (...) or None;
    prior: (N,) or broadcastable to (..., N) — eq. (14)/(15) adjustment.
    Returns scalar f32 loss.
    """
    z = logits.astype(jnp.float32)
    if prior is not None:
        z = adjust_logits(z, prior, tau, prior_eps)
    lse = jax.scipy.special.logsumexp(z, axis=-1)
    ll = jnp.take_along_axis(z, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if label_smoothing > 0.0:
        n = z.shape[-1]
        mean_z = z.mean(axis=-1)
        nll = (1 - label_smoothing) * nll + label_smoothing * (lse - mean_z)
    if weights is None:
        return nll.mean()
    w = weights.astype(jnp.float32)
    return (nll * w).sum() / jnp.maximum(w.sum(), 1e-8)


def accuracy(logits, labels, weights=None):
    pred = jnp.argmax(logits, axis=-1)
    correct = (pred == labels).astype(jnp.float32)
    if weights is None:
        return correct.mean()
    w = weights.astype(jnp.float32)
    return (correct * w).sum() / jnp.maximum(w.sum(), 1e-8)


def per_class_accuracy(logits, labels, num_classes: int):
    """Balanced (macro-averaged) accuracy — the paper's motivating metric."""
    pred = jnp.argmax(logits, axis=-1).reshape(-1)
    lab = labels.reshape(-1)
    correct = (pred == lab).astype(jnp.float32)
    hits = jnp.zeros((num_classes,)).at[lab].add(correct)
    counts = jnp.zeros((num_classes,)).at[lab].add(1.0)
    per_class = hits / jnp.maximum(counts, 1.0)
    present = counts > 0
    return (per_class * present).sum() / jnp.maximum(present.sum(), 1.0)
