"""The split-step engine: ONE implementation of the SCALA local iteration.

Every SCALA step — naive, fused-LACE, manual-SPMD — is the same five-stage
pipeline (paper Alg. 2 lines 9-20); this module implements it once and
parameterizes the two points where the variants actually differ:

  stage 1  label priors        P_k per client, P_s concatenated (eqs. 5-6,
                               the log-prior terms of eqs. 14-15)
  stage 2  client forward      vmap over the stacked client axis (eq. 4;
                               client-parallel on the mesh)
  stage 3  server forward+vjp  one forward of the server half (eq. 6), one
                               linearization reused by both losses
  stage 4  dual pullbacks      P_s-adjusted loss -> d w_s (eqs. 14, 7);
                               P_k-adjusted loss -> G_k -> d w_k via each
                               client's chain rule (eqs. 15, 8-9)
  stage 5  parameter update    an :class:`repro.optim.Optimizer` with lr
                               from :mod:`repro.optim.schedules` (the paper
                               uses plain SGD, eq. 7/9; the engine threads
                               any optimizer state through the params tree)

The variation points:

* **loss backend** (stage 3-4 flavor):

  - ``"logits"``  — materialize full (tokens, V) logits through
    ``model.server_fwd`` and use :func:`repro.core.losses.softmax_xent`.
    Reference semantics; fine for CIFAR-scale heads.
  - ``"lace"``    — run ``model.server_trunk`` to features and fuse
    head-matmul + adjusted CE with the chunked LACE op
    (:mod:`repro.kernels.lace`), never materializing logits; required for
    the 262k-vocab archs.
  - ``"lace_dp"`` — the replicated-weight manual-SPMD profile: the whole
    step runs inside one ``shard_map`` and the engine inserts the minimal
    collective schedule (histogram psums for the priors, two scalar loss
    psums, ONE psum of the server grad tree, one per-client grad psum over
    the inner axis), keeping the per-step wire cost at the DDP lower bound
    of 2x|w_s| + 2x|w_c|.

* **boundary flavor** (stage 3-4 pass count, :data:`BOUNDARIES`): the
  paper's dual objective evaluates the adjusted CE twice per step — once
  with the concatenated prior P_s (eq. 14) and once with the per-client
  priors P_k (eq. 15). ``boundary="dual"`` runs them as two independent
  ``value_and_grad`` evaluations; ``boundary="fused"`` (default) computes
  both NLLs and both cotangents in ONE pass over a shared
  ``features @ w_head`` product (:func:`repro.kernels.lace.ops.lace2_grads`
  for the LACE backends, :func:`repro.core.losses.dual_adjusted_xent`
  over the shared materialized logits for ``"logits"``), halving the
  loss-stage FLOPs. All gradients — hence parameter updates and the
  whole training trajectory — are bit-identical f32 to the dual path
  (test-enforced per backend). The reported LACE loss *metrics* sit
  within 1 ulp: the fused values match the plain ``lace_loss`` forward
  bitwise, while the dual baseline reads them through
  ``value_and_grad``, whose residual-saving scan compiles to slightly
  different roundings. The one dual fallback is ``"logits"`` with
  ``label_smoothing > 0``, where the mirrored backward is only
  ulp-accurate.

* **optimizer / schedule** (stage 5): any :class:`repro.optim.Optimizer`;
  client state is vmapped per client so every state leaf carries the
  stacked (C, ...) axis and shards exactly like the client params.

On top of the per-step engine, :func:`make_round_runner` /
:func:`scala_round_scan` compile T local iterations *plus* the FL phase
into a single ``lax.scan``-based XLA program — one dispatch per round
instead of T+1. The FL phase itself is pluggable via the federation
layer (:mod:`repro.fed`): an ``Aggregator`` picks the per-client
aggregation weights (FedAvg, data-size weighted, BESplit-style
bias-compensated, GAS-style staleness-decayed), a
``ParticipationScheduler`` samples the per-round client subset as a 0/1
mask over the static client axis (priors and logit adjustments are then
recomputed per subset), ``slot_gather=True`` packs that subset into a
dense ``[K_active]`` compute axis (subset-cost rounds at static
shapes), ``server_optimizer=`` adds FedOpt over the server half's round
delta, and ``opt_state_policy`` fixes what happens to client optimizer
state at the round boundary (carry | reset | average — see
:func:`make_round_runner`). Asynchronous execution — per-client
snapshots, sampled completion delays, staleness-weighted delayed
aggregation per arrival cohort — lives in :mod:`repro.fed.runtime` and
reuses the same engine step and sparse-slot gather.

The legacy entry points in :mod:`repro.core.scala` are thin wrappers over
:func:`local_step` with plain SGD.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ScalaConfig
from repro.core import losses
from repro.core.label_stats import client_and_concat_priors, histogram
from repro.core.split import redistribute, stack_client_params, weighted_mean
from repro.optim import optimizers, schedules

BACKENDS = ("logits", "lace", "lace_dp")

#: compute-precision policies for the split step. ``"f32"`` is exact
#: (legacy HLO); ``"bf16"`` runs the client forward, the concat-
#: activation server trunk, and both backward passes in bfloat16 while
#: the master params, optimizer state, label priors / logit
#: adjustments, loss reductions, and the FL aggregation stay float32
#: (the LACE kernels upcast per chunk, so the fused loss composes
#: unchanged). Halves the live activation set AND the split-boundary
#: wire traffic.
PRECISIONS = ("f32", "bf16")

#: split-boundary loss flavors. ``"dual"`` evaluates the eq. (14) and
#: eq. (15) objectives as two independent ``value_and_grad`` passes over
#: the head (the paper's literal two-loss schedule); ``"fused"``
#: (default) computes both NLLs and both feature cotangents in one pass
#: over a shared ``features @ w_head`` product — halving the loss-stage
#: matmul count. Gradients (and therefore the training trajectory) are
#: bit-identical f32 to ``"dual"`` for every backend; LACE loss metrics
#: are 1-ulp (see the module docstring). ``"logits"`` with
#: ``label_smoothing > 0`` silently falls back to the dual schedule
#: (the mirrored backward is only ulp-accurate there).
BOUNDARIES = ("dual", "fused")


# ---------------------------------------------------------------------------
# model adapter
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SplitModel:
    """Functional adapter: the two halves of a split model.

    client_fwd(wc, batch) -> acts dict with key 'x' (+ optional 'memory',
    'positions'); server_fwd(ws, acts) -> (logits, aux_loss).

    For the fused (LACE) backends, additionally:
    server_trunk(ws, acts) -> (features, aux) — everything *except* the
    classifier head — and head_weight(ws) -> (d, V) so the loss can fuse
    head-matmul + adjusted CE without materializing logits.
    """

    client_fwd: Callable[[Any, Dict[str, Any]], Dict[str, Any]]
    server_fwd: Callable[[Any, Dict[str, Any]], Any]
    num_classes: int
    server_trunk: Optional[Callable[[Any, Dict[str, Any]], Any]] = None
    head_weight: Optional[Callable[[Any], Any]] = None
    head_grad_merge: Optional[Callable[[Any, Any], Any]] = None
    # replicated-head ("dp") profile: route the fused loss through the
    # shard_map LACE so the head grad is psummed once (§Perf iteration 3)
    dp_loss: bool = False


# ---------------------------------------------------------------------------
# mixed precision
# ---------------------------------------------------------------------------


def cast_floats(tree, dtype):
    """Cast every floating leaf of a pytree to ``dtype`` (ints/keys pass
    through untouched)."""
    return jax.tree.map(
        lambda a: a.astype(dtype)
        if jnp.issubdtype(jnp.asarray(a).dtype, jnp.floating) else a, tree)


def cast_to_compute(model: SplitModel, precision: str) -> SplitModel:
    """Wrap a :class:`SplitModel` with a compute-precision policy.

    ``"f32"`` returns the model unchanged. ``"bf16"`` casts the param
    halves and float batch inputs to bfloat16 *inside* each wrapped
    forward, so activations and both backward passes run in bf16 while
    the master params stay f32 — and because the cast sits inside the
    differentiated functions, its transpose upcasts the cotangents and
    every param gradient lands back in f32. The fused-loss hooks
    (``head_weight``) hand the LACE ops a bf16 head; the ops upcast per
    chunk, so loss values and logit adjustments are still computed in
    f32 (``head_grad_merge`` receives the chunk-accumulated f32 partial
    cast to the head dtype, exactly as before).
    """
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}; expected "
                         f"{PRECISIONS}")
    if precision == "f32":
        return model
    bf16 = jnp.bfloat16

    def client_fwd(wc, batch):
        return model.client_fwd(cast_floats(wc, bf16),
                                cast_floats(batch, bf16))

    def server_fwd(ws, acts):
        return model.server_fwd(cast_floats(ws, bf16), acts)

    kw = {}
    if model.server_trunk is not None:
        kw["server_trunk"] = (
            lambda ws, acts: model.server_trunk(cast_floats(ws, bf16), acts))
    if model.head_weight is not None:
        kw["head_weight"] = (
            lambda ws: cast_floats(model.head_weight(ws), bf16))
    return dataclasses.replace(model, client_fwd=client_fwd,
                               server_fwd=server_fwd, **kw)


# ---------------------------------------------------------------------------
# small shared pieces
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshAxes:
    """Mesh-axis roles for the manual-SPMD ("lace_dp") backend: the client
    axis is sharded over ``client``, each client's batch over ``inner``."""

    client: Tuple[str, ...] = ()
    inner: Tuple[str, ...] = ()

    @property
    def all(self) -> Tuple[str, ...]:
        return self.client + self.inner


def mesh_axes(mesh) -> MeshAxes:
    names = set(mesh.axis_names)
    return MeshAxes(client=tuple(a for a in ("pod", "data") if a in names),
                    inner=tuple(a for a in ("model",) if a in names))


def _flat(a):
    return a.reshape((-1,) + a.shape[2:])


def _prior_for_tokens(p, labels_shape):
    """Broadcast a (..., N) prior against token labels (...,) -> (..., 1s, N)."""
    extra = len(labels_shape) - (p.ndim - 1)
    return p.reshape(p.shape[:-1] + (1,) * extra + (p.shape[-1],))


def default_ce_chunk(num_classes: int) -> int:
    # larger chunks -> fewer head-grad all-reduce trips in the chunked
    # CE loop (the gW partial is re-reduced every trip); cap the global
    # chunk so logits stay ~2^32 elements (§Perf iteration 3)
    return max(4096, (1 << 32) // max(1, num_classes))


def _priors(labels, weights, N, scala: ScalaConfig, axes: Optional[MeshAxes]):
    """Stage 1: (P_k (C,N), P_s (N,)) — local stats, or psummed on a mesh."""
    if axes is None:
        return client_and_concat_priors(labels, N, weights,
                                        eps=scala.prior_eps)
    # manual-SPMD: local histogram -> psums (paper eq. 14/15)
    C_l = labels.shape[0]
    hist_k = jax.vmap(lambda l, w: histogram(l, N, w))(
        labels.reshape(C_l, -1),
        (jnp.ones((C_l, labels[0].size), jnp.float32) if weights is None
         else weights.reshape(C_l, -1)))                   # (C_l, N)
    if axes.inner:
        hist_k = jax.lax.psum(hist_k, axes.inner)          # full client hist
    hist_s = jax.lax.psum(hist_k.sum(0), axes.client) \
        if axes.client else hist_k.sum(0)
    p_k = hist_k / jnp.maximum(hist_k.sum(-1, keepdims=True), 1e-8)
    p_s = hist_s / jnp.maximum(hist_s.sum(), 1e-8)
    return p_k, p_s


def _server_vjp(fwd, ws, acts):
    """Stage 3: linearize the server fn (server_fwd or server_trunk) wrt
    (w_s, x[, memory]) with positions closed over. Returns
    ((out, aux), vjp, has_mem)."""
    x = acts["x"]
    has_mem = "memory" in acts
    positions = acts["positions"][0] if "positions" in acts else None

    if has_mem:
        def f(ws, xf, memf):
            a = {"x": xf, "memory": memf}
            if positions is not None:
                a["positions"] = positions
            return fwd(ws, a)
        out, vjp = jax.vjp(f, ws, _flat(x), _flat(acts["memory"]))
    else:
        def f(ws, xf):
            a = {"x": xf}
            if positions is not None:
                a["positions"] = positions
            return fwd(ws, a)
        out, vjp = jax.vjp(f, ws, _flat(x))
    return out, vjp, has_mem


def _dual_pullbacks(vjp, g_s, g_k, aux_dtype, has_mem):
    """Stage 4a: one pullback per loss — P_s cotangent charges w_s (the aux
    loss rides with it), P_k cotangent yields the activation grads G_k."""
    one = jnp.ones((), aux_dtype)
    zero = jnp.zeros((), aux_dtype)
    if has_mem:
        d_ws, _, _ = vjp((g_s, one))
        _, g_x, g_mem = vjp((g_k, zero))
    else:
        d_ws, _ = vjp((g_s, one))
        _, g_x = vjp((g_k, zero))
        g_mem = None
    return d_ws, g_x, g_mem


def _client_pullback(model: SplitModel, wc, batch, acts, g_x, g_mem, has_mem):
    """Stage 4b (eq. 9): each client backprops its own G_k through its half."""
    g_x = g_x.reshape(acts["x"].shape)
    if g_mem is not None:
        g_mem = g_mem.reshape(acts["memory"].shape)

    def one(w, b, gx_k, gmem_k):
        def f(wk):
            a = model.client_fwd(wk, b)
            if has_mem:
                return a["x"], a["memory"]
            return a["x"]
        _, cvjp = jax.vjp(f, w)
        ct = (gx_k, gmem_k) if has_mem else gx_k
        return cvjp(ct)[0]

    if has_mem:
        return jax.vmap(one)(wc, batch, g_x, g_mem)
    return jax.vmap(lambda w, b, g: one(w, b, g, None))(wc, batch, g_x)


# ---------------------------------------------------------------------------
# the pipeline: stages 1-4 -> raw gradients
# ---------------------------------------------------------------------------


def split_step_grads(model: SplitModel, params, batch, scala: ScalaConfig, *,
                     backend: str = "logits",
                     boundary: str = "fused",
                     ce_chunk: Optional[int] = None,
                     axes: Optional[MeshAxes] = None,
                     mask=None,
                     precision: str = "f32"):
    """Stages 1-4 of the SCALA local iteration for any loss backend.

    params: {'client': stacked (C,...), 'server': ...}; batch leaves
    (C, B_k, ...). Returns (grads, metrics) with grads mirroring params —
    no parameter update applied. ``axes`` must be set iff
    ``backend == "lace_dp"`` (the caller wraps this in ``shard_map``).

    ``boundary`` (:data:`BOUNDARIES`) picks the loss-stage schedule:
    ``"fused"`` (default) evaluates eq. (14) and eq. (15) — values and
    cotangents — in one pass over a shared logits product; ``"dual"``
    keeps the literal two ``value_and_grad`` passes. Gradients are
    bit-identical f32 per backend; LACE loss metrics are 1-ulp
    (``"logits"`` falls back to dual when ``label_smoothing > 0``).

    ``precision`` (:data:`PRECISIONS`) selects the compute policy via
    :func:`cast_to_compute`: ``"bf16"`` runs stages 2-4 in bfloat16
    against the f32 master params; stage 1 (priors), the loss
    reductions, and stage 5 (updates) stay f32.

    ``mask`` is an optional (C,) 0/1 participation mask (the client count
    stays static; see :mod:`repro.fed.participation`). It folds into the
    per-token loss weights, so masked-out clients contribute zero to the
    stage-1 histograms — the concatenated prior P_s and the per-client
    priors P_k are recomputed over the participating *subset*, exactly
    the paper's partial-participation setting — zero to both losses, and
    zero gradient to their own client halves. Under ``lace_dp`` the mask
    is the *local* (C_l,) shard of the global mask.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected {BACKENDS}")
    if boundary not in BOUNDARIES:
        raise ValueError(
            f"unknown boundary {boundary!r}; expected {BOUNDARIES}")
    if (backend == "lace_dp") != (axes is not None):
        raise ValueError("backend 'lace_dp' requires mesh axes (and only it)")
    if backend != "logits" and model.server_trunk is None:
        raise ValueError(f"backend {backend!r} needs model.server_trunk/"
                         "head_weight (fused LACE path)")
    model = cast_to_compute(model, precision)

    N = model.num_classes
    labels = batch["labels"]
    weights = batch.get("weights")
    C = labels.shape[0]

    if mask is not None:
        mw = mask.astype(jnp.float32).reshape((C,) + (1,) * (labels.ndim - 1))
        base_w = (jnp.ones(labels.shape, jnp.float32) if weights is None
                  else jnp.broadcast_to(weights, labels.shape))
        weights = base_w * mw

    # --- stage 1: label statistics (clients upload Y_k with A_k) ---
    p_k, p_s = _priors(labels, weights, N, scala, axes)

    # --- stage 2: parallel client forward (client-parallel == vmap) ---
    acts = jax.vmap(lambda w, b: model.client_fwd(w, b))(params["client"],
                                                         batch)
    x = acts["x"]                                   # (C, B_k, ..., d)

    # --- stages 3-4: backend-specific dual losses over a shared vjp ---
    if backend == "logits":
        (logits, aux), vjp, has_mem = _server_vjp(model.server_fwd,
                                                  params["server"], acts)
        labels_f = _flat(labels)
        weights_f = _flat(weights) if weights is not None else None

        # both sides' priors, prepared once and shared between eq. (14)
        # and eq. (15) — the per-client prior broadcast over each
        # client's token dims
        ps_use = p_s if scala.adjust_server else None
        pk_tok = _prior_for_tokens(p_k, labels.shape)        # (C,1..,N)
        pk_flat = _flat(jnp.broadcast_to(
            pk_tok, labels.shape[:2] + (1,) * (labels.ndim - 2) + (N,)))
        pk_use = pk_flat if scala.adjust_client else None

        # the mirrored one-pass backward is bitwise only at ls == 0; the
        # smoothed objective keeps the autodiff schedule
        if boundary == "fused" and scala.label_smoothing == 0.0:
            loss_s, loss_k, g_s, g_k = losses.dual_adjusted_xent(
                logits, labels_f, weights=weights_f, prior_s=ps_use,
                prior_k=pk_use, tau=scala.tau,
                label_smoothing=scala.label_smoothing,
                prior_eps=scala.prior_eps)
        else:
            def server_loss(lg):
                return losses.softmax_xent(
                    lg, labels_f, weights=weights_f, prior=ps_use,
                    tau=scala.tau, label_smoothing=scala.label_smoothing,
                    prior_eps=scala.prior_eps)

            loss_s, g_s = jax.value_and_grad(server_loss)(logits)

            def client_loss(lg):
                return losses.softmax_xent(
                    lg, labels_f, weights=weights_f, prior=pk_use,
                    tau=scala.tau, label_smoothing=scala.label_smoothing,
                    prior_eps=scala.prior_eps)

            loss_k, g_k = jax.value_and_grad(client_loss)(logits)

        d_ws, g_x, g_mem = _dual_pullbacks(vjp, g_s, g_k, aux.dtype, has_mem)
        metrics = {"loss_server": loss_s, "loss_client": loss_k, "aux": aux,
                   "accuracy": losses.accuracy(logits, labels_f, weights_f)}
    else:
        from repro.kernels.lace.ops import (lace2_grads, lace2_grads_dp,
                                            lace_loss, lace_loss_dp,
                                            lace_nll_sum)

        if ce_chunk is None:
            ce_chunk = default_ce_chunk(N)
        (feats, aux), vjp, has_mem = _server_vjp(model.server_trunk,
                                                 params["server"], acts)
        d = feats.shape[-1]
        feats_g = feats.reshape(C, -1, d)           # (C, bk*s_out, d)
        labels_g = labels.reshape(C, -1)
        weights_g = None if weights is None else weights.reshape(C, -1)
        w_head = model.head_weight(params["server"])

        ps_rows = p_s[None] if scala.adjust_server else None
        pk_rows = p_k if scala.adjust_client else None
        pk_ids = jnp.arange(C) if scala.adjust_client else None

        if backend == "lace" and boundary == "fused":
            lace2 = lace2_grads_dp if model.dp_loss else lace2_grads
            loss_s, loss_k, gf_s, gf_k, gW_s = lace2(
                feats_g, w_head, labels_g, ps_rows, None, pk_rows, pk_ids,
                weights_g, scala.tau, scala.prior_eps, ce_chunk)[:5]
        elif backend == "lace":
            lace = lace_loss_dp if model.dp_loss else lace_loss

            # eq. (14): concatenated prior P_s for the server update
            def loss_s_fn(fg, wh):
                return lace(fg, wh, labels_g, ps_rows, None, weights_g,
                            scala.tau, scala.prior_eps, ce_chunk)

            loss_s, (gf_s, gW_s) = jax.value_and_grad(
                loss_s_fn, argnums=(0, 1))(feats_g, w_head)

            # eq. (15): per-client priors P_k for the gradients G_k
            def loss_k_fn(fg):
                return lace(fg, w_head, labels_g, pk_rows, pk_ids,
                            weights_g, scala.tau, scala.prior_eps, ce_chunk)

            loss_k, gf_k = jax.value_and_grad(loss_k_fn)(feats_g)
        else:                                        # "lace_dp"
            # differentiate LOCAL nll sums only (never through a psum: with
            # vma checking off, the psum transpose would re-reduce an
            # already-replicated cotangent and over-count by |axes|); the
            # global normalization is applied to values/grads afterwards.
            wsum_local = (jnp.sum(weights_g) if weights_g is not None
                          else jnp.float32(labels_g.size))
            w_global = jnp.maximum(jax.lax.psum(
                jnp.asarray(wsum_local, jnp.float32), axes.all), 1e-8)

            if boundary == "fused":
                nll_s, nll_k, gf_s, gf_k, gW_s, _ = lace2_grads(
                    feats_g, w_head, labels_g, ps_rows, None, pk_rows,
                    pk_ids, weights_g, scala.tau, scala.prior_eps,
                    ce_chunk, mean=False)
            else:
                def nll_s_fn(fg, wh):
                    return lace_nll_sum(fg, wh, labels_g, ps_rows, None,
                                        weights_g, scala.tau,
                                        scala.prior_eps, ce_chunk)

                nll_s, (gf_s, gW_s) = jax.value_and_grad(
                    nll_s_fn, argnums=(0, 1))(feats_g, w_head)

                def nll_k_fn(fg):
                    return lace_nll_sum(fg, w_head, labels_g, pk_rows,
                                        pk_ids, weights_g, scala.tau,
                                        scala.prior_eps, ce_chunk)

                nll_k, gf_k = jax.value_and_grad(nll_k_fn)(feats_g)

            loss_s = jax.lax.psum(nll_s, axes.all) / w_global
            gf_s = gf_s / w_global
            gW_s = gW_s / w_global
            loss_k = jax.lax.psum(nll_k, axes.all) / w_global
            gf_k = gf_k / w_global

        gf_s_t = gf_s.reshape(feats.shape).astype(feats.dtype)
        gf_k_t = gf_k.reshape(feats.shape).astype(feats.dtype)
        d_ws, g_x, g_mem = _dual_pullbacks(vjp, gf_s_t, gf_k_t, aux.dtype,
                                           has_mem)
        d_ws = model.head_grad_merge(d_ws, gW_s)
        metrics = {"loss_server": loss_s, "loss_client": loss_k, "aux": aux}

    # --- stage 4 reductions (manual-SPMD only) ---
    rdt = (jnp.dtype(scala.grad_reduce_dtype)
           if axes is not None and scala.grad_reduce_dtype else None)
    if axes is not None:
        # the ONE server-grad reduction: every leaf is a local partial
        # (the psum transpose passes the global cotangent through, so
        # grads wrt replicated weights are per-shard contributions);
        # optionally compressed to bf16 (halves the remaining wire traffic).
        if rdt is not None:
            d_ws = jax.tree.map(lambda g: g.astype(rdt), d_ws)
        d_ws = jax.lax.psum(d_ws, axes.all)

    d_wc = _client_pullback(model, params["client"], batch, acts, g_x, g_mem,
                            has_mem)
    if axes is not None and axes.inner:
        # each client's batch is itself sharded over the inner axis
        if rdt is not None:
            d_wc = jax.tree.map(lambda g: g.astype(rdt), d_wc)
        d_wc = jax.lax.psum(d_wc, axes.inner)
    if axes is not None:
        metrics["aux"] = jax.lax.pmean(metrics["aux"], axes.all)

    return {"client": d_wc, "server": d_ws}, metrics


# ---------------------------------------------------------------------------
# stage 5: updates — plain-SGD compat and real optimizers
# ---------------------------------------------------------------------------


def sgd_apply(params, grads, lr):
    """The paper's eq. (7)/(9) update, in param dtype (legacy-exact)."""
    return jax.tree.map(lambda w, g: w - lr * g.astype(w.dtype),
                        params, grads)


@dataclass(frozen=True)
class TrainState:
    """Engine state threaded through steps/rounds: params, per-half
    optimizer state (client state vmapped so every leaf carries the
    stacked (C, ...) axis), and the global step driving the lr schedule."""

    params: Any
    opt_state: Any
    step: Any


jax.tree_util.register_dataclass(
    TrainState, data_fields=("params", "opt_state", "step"), meta_fields=())


def init_train_state(params, optimizer: optimizers.Optimizer) -> TrainState:
    return TrainState(
        params=params,
        opt_state={"client": jax.vmap(optimizer.init)(params["client"]),
                   "server": optimizer.init(params["server"])},
        step=jnp.zeros((), jnp.int32))


def _apply_updates(opt: optimizers.Optimizer, state: TrainState, grads,
                   lr) -> TrainState:
    new_s, st_s = opt.update(grads["server"], state.opt_state["server"],
                             state.params["server"], lr)
    new_c, st_c = jax.vmap(lambda g, s, p: opt.update(g, s, p, lr))(
        grads["client"], state.opt_state["client"], state.params["client"])
    return TrainState(params={"client": new_c, "server": new_s},
                      opt_state={"client": st_c, "server": st_s},
                      step=state.step + 1)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def _dp_specs(mesh, axes: MeshAxes, tree):
    """Client-half leaves are sharded over the client axes, server-half
    (and scalars) replicated."""
    from jax.sharding import PartitionSpec as P

    return {"client": jax.tree.map(lambda _: P(axes.client or None),
                                   tree["client"]),
            "server": jax.tree.map(lambda _: P(), tree["server"])}


def client_shard_count(mesh) -> int:
    """How many ways the stacked client axis splits on this mesh — the
    product of the client mesh-axis sizes (:func:`mesh_axes`). 1 on a
    mesh with no client axes (pure tensor parallelism)."""
    axes = mesh_axes(mesh)
    sizes = dict(mesh.shape)
    n = 1
    for a in axes.client:
        n *= sizes[a]
    return n


def local_step(model: SplitModel, params, batch, scala: ScalaConfig, *,
               backend: str = "logits", boundary: str = "fused",
               lr: Optional[float] = None,
               ce_chunk: Optional[int] = None, mesh=None, batch_specs=None,
               precision: str = "f32"):
    """One stateless SCALA local iteration with plain SGD (eqs. 7/9) —
    the legacy-shaped entry point behind :mod:`repro.core.scala`.

    Returns (new_params, metrics). For ``backend="lace_dp"`` pass the mesh
    and a PartitionSpec pytree matching ``batch``; the whole step
    (gradients + update) then runs inside one ``shard_map``.
    """
    lr = scala.lr if lr is None else lr

    if backend == "lace_dp":
        from jax.sharding import PartitionSpec as P

        if mesh is None or batch_specs is None:
            raise ValueError("backend 'lace_dp' needs mesh and batch_specs")
        axes = mesh_axes(mesh)
        p_specs = _dp_specs(mesh, axes, params)
        m_specs = {"loss_server": P(), "loss_client": P(), "aux": P()}

        def body(p, b):
            grads, metrics = split_step_grads(model, p, b, scala,
                                              backend="lace_dp",
                                              boundary=boundary,
                                              ce_chunk=ce_chunk, axes=axes,
                                              precision=precision)
            return sgd_apply(p, grads, lr), metrics

        fn = compat.shard_map(body, mesh=mesh,
                              in_specs=(p_specs, batch_specs),
                              out_specs=(p_specs, m_specs), check_vma=False)
        return fn(params, batch)

    grads, metrics = split_step_grads(model, params, batch, scala,
                                      backend=backend, boundary=boundary,
                                      ce_chunk=ce_chunk,
                                      precision=precision)
    return sgd_apply(params, grads, lr), metrics


def make_split_step(model: SplitModel, scala: ScalaConfig, *,
                    backend: str = "lace",
                    boundary: str = "fused",
                    optimizer: Optional[optimizers.Optimizer] = None,
                    schedule: Optional[Callable] = None,
                    ce_chunk: Optional[int] = None,
                    mesh=None, batch_specs=None,
                    precision: str = "f32"):
    """Build the stateful engine step: (TrainState, batch[, mask]) ->
    (TrainState, metrics), jit/scan-compatible.

    ``optimizer`` defaults to plain SGD (the paper's eq. 7/9) and
    ``schedule`` to a constant ``scala.lr``; any combination from
    :mod:`repro.optim` works, with the lr driven by ``state.step`` (one
    increment per local iteration). ``precision`` is the compute policy
    of :func:`split_step_grads` (``"bf16"`` = bf16 forward/backward
    against f32 master params and f32 updates).

    The optional third ``mask`` argument is a (C,) 0/1 participation mask
    (see :func:`split_step_grads`); for ``lace_dp`` it is passed into the
    ``shard_map`` sharded over the client mesh axes.
    """
    opt = optimizer if optimizer is not None else optimizers.sgd()
    sched = schedule if schedule is not None else schedules.constant(scala.lr)

    if backend == "lace_dp":
        from jax.sharding import PartitionSpec as P

        if mesh is None or batch_specs is None:
            raise ValueError("backend 'lace_dp' needs mesh and batch_specs")
        axes = mesh_axes(mesh)

        def step(state: TrainState, batch, mask=None):
            p_specs = _dp_specs(mesh, axes, state.params)
            # vmapped client opt state carries the (C, ...) axis on every
            # leaf, so it shards exactly like the client params
            s_specs = TrainState(
                params=p_specs,
                opt_state=_dp_specs(mesh, axes, state.opt_state),
                step=P())
            m_specs = {"loss_server": P(), "loss_client": P(), "aux": P()}

            def body(st, b, *m):
                grads, metrics = split_step_grads(
                    model, st.params, b, scala, backend="lace_dp",
                    boundary=boundary, ce_chunk=ce_chunk, axes=axes,
                    mask=m[0] if m else None, precision=precision)
                return _apply_updates(opt, st, grads, sched(st.step)), metrics

            # the (C,) mask, when present, shards like the client axis
            args = (state, batch) if mask is None else (state, batch, mask)
            in_specs = ((s_specs, batch_specs) if mask is None
                        else (s_specs, batch_specs, P(axes.client or None)))
            fn = compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                                  out_specs=(s_specs, m_specs),
                                  check_vma=False)
            return fn(*args)

        return step

    def step(state: TrainState, batch, mask=None):
        grads, metrics = split_step_grads(model, state.params, batch, scala,
                                          backend=backend, boundary=boundary,
                                          ce_chunk=ce_chunk,
                                          mask=mask, precision=precision)
        return _apply_updates(opt, state, grads, sched(state.step)), metrics

    return step


# ---------------------------------------------------------------------------
# FL phase + the scan-compiled round
# ---------------------------------------------------------------------------


def scala_aggregate(params, data_sizes=None):
    """FL phase (eq. 10): FedAvg the client halves, redistribute.

    ``data_sizes`` may contain zero-participation clients; normalization
    is mask-safe (see :func:`repro.core.split.normalize_client_weights`).
    """
    return {"client": redistribute(params["client"], data_sizes),
            "server": params["server"]}


OPT_STATE_POLICIES = ("carry", "reset", "average")


def slot_gather_indices(mask, k_active: int):
    """Participating slot ids, ascending, from a (C,) 0/1 mask with a
    *static* subset size ``k_active`` (the sparse-slot compute path).

    Cumsum compaction, O(C) work / O(log C) depth — not the historical
    O(C log C) sort-of-a-stable-argsort: each participating slot's
    target position is its rank among the ones (prefix sum), positions
    past ``k_active`` drop. If the mask has *fewer* than ``k_active``
    ones the remaining positions fill with the lowest absent slot ids —
    they run compute but carry zero aggregation weight, which is safe
    but wasteful (every :mod:`repro.fed.participation` scheduler
    guarantees a fixed subset size, so this is the degenerate case). A
    final O(k log k) sort over the ``k_active`` survivors restores the
    global ascending order, keeping the result bit-identical to the
    sort-based compaction on EVERY mask (test-enforced on random masks
    in ``tests/test_arrival.py``).
    """
    on = mask > 0
    n_on = jnp.sum(on, dtype=jnp.int32)
    rank = jnp.cumsum(on, dtype=jnp.int32) - 1          # position if on
    fill = n_on + jnp.cumsum(~on, dtype=jnp.int32) - 1  # position if off
    pos = jnp.where(on, rank, fill)
    pos = jnp.where(pos < k_active, pos, k_active)      # OOB -> dropped
    C = mask.shape[0]
    idx = jnp.zeros((k_active,), jnp.int32).at[pos].set(
        jnp.arange(C, dtype=jnp.int32), mode="drop")
    return jnp.sort(idx)


def gather_rows(tree, idx):
    """Pack rows ``idx`` of every (C, ...) leaf into a dense leading axis."""
    return jax.tree.map(lambda a: jnp.take(a, idx, axis=0), tree)


def scatter_rows(full_tree, sub_tree, idx):
    """Write dense-axis results back into rows ``idx`` of the full leaves."""
    return jax.tree.map(lambda f, s: f.at[idx].set(s.astype(f.dtype)),
                        full_tree, sub_tree)


def _gather_clients(state: TrainState, idx) -> TrainState:
    """Pack the participating client slots into a dense [K_active] axis
    (server half shared by reference)."""
    return TrainState(
        params={"client": gather_rows(state.params["client"], idx),
                "server": state.params["server"]},
        opt_state={"client": gather_rows(state.opt_state["client"], idx),
                   "server": state.opt_state["server"]},
        step=state.step)


def _scatter_clients(state: TrainState, sub: TrainState, idx) -> TrainState:
    """Write the dense [K_active] results back into the static slots.

    Absent slots keep their params AND their optimizer state untouched
    (the masked path instead "ticks" absent slots' stateful moments with
    zero grads — see :func:`make_round_runner`)."""
    return TrainState(
        params={"client": scatter_rows(state.params["client"],
                                       sub.params["client"], idx),
                "server": sub.params["server"]},
        opt_state={"client": scatter_rows(state.opt_state["client"],
                                          sub.opt_state["client"], idx),
                   "server": sub.opt_state["server"]},
        step=sub.step)


def _round_boundary_opt_state(opt: optimizers.Optimizer, opt_state,
                              new_params, weights, policy: str):
    """Client optimizer state at the round boundary (policy semantics in
    :func:`make_round_runner`); the server half always carries."""
    if policy == "carry":
        return opt_state
    if policy == "reset":
        return {"client": jax.vmap(opt.init)(new_params["client"]),
                "server": opt_state["server"]}
    # "average": aggregate the per-client state exactly like the client
    # params, then redistribute so every slot restarts from the averaged
    # moments (computed in f32, cast back to the leaf dtype).
    def avg(a):
        wb = weights.reshape((-1,) + (1,) * (a.ndim - 1)).astype(jnp.float32)
        m = (a.astype(jnp.float32) * wb).sum(axis=0).astype(a.dtype)
        return jnp.broadcast_to(m[None], a.shape)

    return {"client": jax.tree.map(avg, opt_state["client"]),
            "server": opt_state["server"]}


def make_round_runner(model: SplitModel, scala: ScalaConfig, *,
                      backend: str = "logits",
                      boundary: str = "fused",
                      optimizer: Optional[optimizers.Optimizer] = None,
                      schedule: Optional[Callable] = None,
                      ce_chunk: Optional[int] = None,
                      aggregate: bool = True,
                      unroll=1,
                      aggregator=None,
                      participation=None,
                      opt_state_policy: str = "carry",
                      slot_gather: bool = False,
                      server_optimizer: Optional[optimizers.Optimizer] = None,
                      server_lr: float = 1.0,
                      mesh=None, batch_specs=None,
                      precision: str = "f32",
                      faults=None, guards=None):
    """Build the fused round program: T local iterations (``lax.scan``
    over the engine step) + the pluggable FL phase, all in one jittable
    fn. All backends are supported, including ``lace_dp`` (pass ``mesh``
    and ``batch_specs``): the manual-SPMD shard_map step's specs are
    step-invariant, so the whole sharded round scans into one program.

    Federation layer (:mod:`repro.fed`):

    * ``aggregator`` — an :class:`repro.fed.aggregators.Aggregator`
      deciding the per-client FL-phase weights. Default:
      ``fed.weighted()``, data-size-proportional FedAvg — exactly the
      legacy ``scala_aggregate`` behavior.
    * ``participation`` — a
      :class:`repro.fed.participation.ParticipationScheduler` sampling
      the per-round client subset as a (C,) 0/1 mask over the *static*
      stacked client axis. The mask threads through
      :func:`split_step_grads`, so priors / logit adjustments are
      recomputed over the participating subset each round, and through
      the aggregator, which excludes absent clients. ``None`` (default)
      = full participation with no masking (legacy-exact HLO).

    Client optimizer state at the round boundary (``opt_state_policy``):

    * ``"carry"``   — per-slot state persists across rounds (legacy
      behavior). After the FL phase every slot holds the same params but
      its own moments: momentum/Adam statistics act per *slot*, not per
      logical client — cheap, and the right default when slots are
      anonymous.
    * ``"reset"``   — client state re-initialized to zeros each round:
      every client restarts cold from the aggregated model, matching the
      FL/SFL baseline semantics (:mod:`repro.core.baselines`).
    * ``"average"`` — client state is aggregated with the same weights
      as the params and redistributed: moments follow the averaged model
      (FedOpt-style server-side statistics).

    The server half's optimizer state always carries — the server model
    is never averaged (only the client halves federate, eq. 10).

    Sparse-slot compute (``slot_gather=True``): the participating slots
    are gathered into a dense ``[K_active]`` axis *before* the local
    scan and scattered back afterwards, so a ``frac``-participation
    round costs ~``frac`` of the full-K compute while every shape stays
    static (``K_active`` is the scheduler's fixed subset size,
    ``participation.subset_size``). Requires a participation scheduler
    and is a no-op when the subset is the full slot set. Semantics match
    the masked round exactly for the losses, the priors (the gathered
    subset IS the participating subset), the gradients, and the FL
    phase; the one divergence is stateful-optimizer moments of *absent*
    clients under ``opt_state_policy="carry"`` — the masked round ticks
    them with zero gradients (momentum keeps decaying), the gathered
    round freezes them. On the ``lace_dp`` backend the gather happens
    *in-shard*: the whole round runs inside one ``shard_map`` and each
    shard of the client mesh axes packs its own participating slots into
    a dense local ``[K_active / n_shards]`` axis, with the FL phase as a
    local (edge) weighted partial + one psum (server fold). Requires a
    shards-balanced scheduler (``uniform:FRAC:SHARDS`` with SHARDS a
    multiple of the client shard count) and a stateless prior-free
    aggregator exposing ``shard_local`` (fedavg / weighted /
    hierarchical).

    Server-side FedOpt (``server_optimizer=``): after the round, the
    *server* half's round delta ``w_s_start - w_s_end`` is treated as a
    pseudo-gradient and ``server_optimizer`` is applied to it from
    ``w_s_start`` at ``server_lr`` (round-scale state: momentum/Adam
    moments over rounds, not local iterations). Plain SGD at
    ``server_lr=1.0`` reproduces the default (the in-round updates land
    unchanged). The optimizer's state lives in ``fed_state["server_opt"]``
    — build it with :func:`repro.fed.init_fed_state`.

    Returns ``round_fn(state, round_batches, data_sizes=None,
    fed_state=None)``; round_batches leaves (T, C, Bk, ...). With
    ``fed_state=None`` (requires stateless aggregator + scheduler and no
    server optimizer) it returns ``(TrainState, metrics)`` — the legacy
    signature. With a ``fed_state`` dict from
    :func:`repro.fed.init_fed_state` it returns
    ``(TrainState, fed_state', metrics)``, threading scheduler PRNG keys,
    aggregator round ages, and server-optimizer state across rounds.

    ``unroll`` is forwarded to ``lax.scan``. The default (1) keeps the
    HLO small — right for the deep production archs. XLA:CPU executes
    while-loop bodies with reduced parallelism, so for CPU-scale models
    pass ``unroll=True`` (full unroll): still one dispatch per round,
    no loop serialization (see benchmarks/round_loop.py).

    ``precision`` (:data:`PRECISIONS`) is the engine step's compute
    policy: ``"bf16"`` runs forward/backward in bfloat16 against f32
    master params while the priors, both loss reductions, the stage-5
    updates, and the FL-phase aggregation all stay f32.

    Fault tolerance (:mod:`repro.fed.faults` / :mod:`repro.fed.guards`):

    * ``faults`` — a :class:`repro.fed.faults.FaultModel` injecting
      deterministic failures: dropped/stalled clients leave the
      participation mask *before* the local scan (priors recompute over
      the survivors via the mask-fold path) and corrupted clients have
      their trained client-half update poisoned in transit after the
      scan. Needs ``fed_state['faults']`` (the fault PRNG key).
    * ``guards`` — a :class:`repro.fed.guards.GuardPolicy` screening
      each client's update before aggregation. If any participant is
      rejected, the local phase is *re-run* under ``lax.cond`` with the
      survivor mask, so the eq. 14/15 priors and logit adjustments match
      a round the rejected clients never joined. With zero rejections
      the guarded round is bit-identical to the unguarded one. Norm
      clipping (``clip:TAU``) additionally needs ``fed_state['guard']``.
    """
    from repro import fed as _fed
    from repro.fed import faults as _faults
    from repro.fed import guards as _guards

    if opt_state_policy not in OPT_STATE_POLICIES:
        raise ValueError(f"unknown opt_state_policy {opt_state_policy!r}; "
                         f"expected {OPT_STATE_POLICIES}")
    if slot_gather:
        if participation is None:
            raise ValueError("slot_gather needs a participation scheduler "
                             "(the static K_active comes from its "
                             "subset_size)")
        if participation.subset_size is None:
            raise ValueError(
                f"slot_gather needs a scheduler with a static subset_size; "
                f"{participation.name!r} has none — without it the gather "
                "would silently degrade to full-K masked compute")
    if faults is not None:
        faults = _faults.make_faults(faults)
    if guards is not None:
        guards = _guards.make_guards(guards)
    robust = (faults is not None) or (guards is not None)
    if robust and not aggregate:
        raise ValueError("faults/guards act on the FL phase; they need "
                         "aggregate=True")
    opt = optimizer if optimizer is not None else optimizers.sgd()
    agg = aggregator if aggregator is not None else _fed.weighted()
    stateful = _fed.is_stateful(agg, participation)
    k_active = (participation.subset_size or participation.num_clients
                if participation is not None else None)
    do_gather = (slot_gather and participation is not None
                 and k_active < participation.num_clients)
    dp_gather = do_gather and backend == "lace_dp"
    if dp_gather and robust:
        raise ValueError(
            "faults/guards are not supported with the in-shard lace_dp "
            "slot_gather round (its FL phase runs inside shard_map); use "
            "the masked lace_dp round or a sparse single-host backend")
    if dp_gather:
        # in-shard gather: each shard of the client mesh axes packs ITS
        # OWN participating slots into a dense local [K_active/n] axis,
        # inside one whole-round shard_map. Needs a shards-balanced
        # scheduler so the local subset size is static and equal.
        if mesh is None or batch_specs is None:
            raise ValueError("backend 'lace_dp' needs mesh and batch_specs")
        n_shards = client_shard_count(mesh)
        if getattr(participation, "shards", 1) % n_shards:
            raise ValueError(
                f"lace_dp slot_gather needs a shards-balanced participation "
                f"scheduler: scheduler shards "
                f"{getattr(participation, 'shards', 1)} must be a multiple "
                f"of the {n_shards} client mesh shards (use "
                f"'uniform:FRAC:{n_shards}')")
        if k_active % n_shards or participation.num_clients % n_shards:
            raise ValueError(
                f"subset size {k_active} and client count "
                f"{participation.num_clients} must divide over the "
                f"{n_shards} client shards")
        if agg.shard_local is None or agg.stateful or agg.needs_priors:
            raise ValueError(
                f"aggregator {agg.name!r} cannot run inside the sharded "
                "client axis; lace_dp slot_gather needs a stateless, "
                "prior-free, shard-decomposable aggregator (fedavg / "
                "weighted / hierarchical)")
        if opt_state_policy == "average":
            raise ValueError("opt_state_policy 'average' is not supported "
                             "with lace_dp slot_gather; use 'carry' or "
                             "'reset'")
    step = make_split_step(model, scala, backend=backend, boundary=boundary,
                           optimizer=opt, schedule=schedule,
                           ce_chunk=ce_chunk,
                           mesh=mesh, batch_specs=batch_specs,
                           precision=precision)

    if dp_gather:
        from jax.sharding import PartitionSpec as P

        from repro.sharding.logical import round_specs as _round_specs

        axes = mesh_axes(mesh)
        k_l = k_active // n_shards
        sched = (schedule if schedule is not None
                 else schedules.constant(scala.lr))
        rb_specs = _round_specs(batch_specs)
        cspec = P(axes.client or None)
        m_specs = {"loss_server": P(), "loss_client": P(), "aux": P()}

        def dp_round(state: TrainState, round_batches, mask, sizes):
            s_specs = TrainState(
                params=_dp_specs(mesh, axes, state.params),
                opt_state=_dp_specs(mesh, axes, state.opt_state),
                step=P())

            def body(st, rb, mask_l, sizes_l):
                idx = slot_gather_indices(mask_l, k_l)
                sub = _gather_clients(st, idx)
                sub_b = jax.tree.map(lambda a: jnp.take(a, idx, axis=1), rb)

                def step_body(s, b):
                    grads, mets = split_step_grads(
                        model, s.params, b, scala, backend="lace_dp",
                        boundary=boundary, ce_chunk=ce_chunk, axes=axes,
                        precision=precision)
                    return _apply_updates(opt, s, grads,
                                          sched(s.step)), mets

                sub, ms = jax.lax.scan(step_body, sub, sub_b, unroll=unroll)
                st = _scatter_clients(st, sub, idx)
                metrics = jax.tree.map(lambda a: a[-1], ms)
                if aggregate:
                    # two-tier FL phase: local weighted partial per shard
                    # (the edge fold), one psum for the server fold
                    w_l = agg.shard_local(mask_l, sizes_l, axes.client,
                                          n_shards)
                    raw = w_l * mask_l
                    denom = raw.sum()
                    if axes.client:
                        denom = jax.lax.psum(denom, axes.client)
                    w_n = raw / jnp.maximum(denom, 1e-8)
                    part = weighted_mean(st.params["client"], w_n)
                    avg = (jax.tree.map(
                        lambda a: jax.lax.psum(a, axes.client), part)
                        if axes.client else part)
                    K_l = jax.tree.leaves(
                        st.params["client"])[0].shape[0]
                    params = {"client": stack_client_params(avg, K_l),
                              "server": st.params["server"]}
                    opt_state = st.opt_state
                    if opt_state_policy == "reset":
                        opt_state = {
                            "client": jax.vmap(opt.init)(params["client"]),
                            "server": st.opt_state["server"]}
                    st = TrainState(params=params, opt_state=opt_state,
                                    step=st.step)
                return st, metrics

            fn = compat.shard_map(
                body, mesh=mesh,
                in_specs=(s_specs, rb_specs, cspec, cspec),
                out_specs=(s_specs, m_specs), check_vma=False)
            return fn(state, round_batches, mask, sizes)

    def round_fn(state: TrainState, round_batches, data_sizes=None,
                 fed_state=None):
        if fed_state is None:
            if stateful:
                raise ValueError(
                    f"aggregator {agg.name!r} / participation scheduler are "
                    "stateful; pass fed_state (repro.fed.init_fed_state)")
            if server_optimizer is not None:
                raise ValueError(
                    "server_optimizer needs fed_state — build it with "
                    "repro.fed.init_fed_state(..., server_optimizer=, "
                    "server_params=)")
            if faults is not None:
                raise ValueError(
                    "faults need fed_state['faults'] (the fault PRNG key) — "
                    "build fed_state with repro.fed.init_fed_state(..., "
                    "faults=...)")
            if guards is not None and guards.clip > 0:
                raise ValueError(
                    "guard norm clipping is stateful (running median) — "
                    "build fed_state with repro.fed.init_fed_state(..., "
                    "guards=...)")
            sched_state, agg_state, so_state = (), (), ()
            fault_key, guard_state = None, ()
        else:
            sched_state, agg_state = fed_state["sched"], fed_state["agg"]
            so_state = fed_state.get("server_opt", ())
            if server_optimizer is not None and "server_opt" not in fed_state:
                raise ValueError(
                    "server_optimizer needs fed_state['server_opt'] — build "
                    "fed_state with repro.fed.init_fed_state(..., "
                    "server_optimizer=, server_params=)")
            fault_key = fed_state.get("faults")
            if faults is not None and fault_key is None:
                raise ValueError(
                    "faults need fed_state['faults'] — build fed_state with "
                    "repro.fed.init_fed_state(..., faults=...)")
            guard_state = fed_state.get("guard", ())
            if guards is not None and guards.clip > 0 and guard_state == ():
                raise ValueError(
                    "guard norm clipping needs fed_state['guard'] — build "
                    "fed_state with repro.fed.init_fed_state(..., "
                    "guards=...)")
        ws_start = state.params["server"]
        start = state  # round-start state: guard delta / clip reference

        if participation is not None:
            mask, sched_state = participation.sample(sched_state)
        else:
            mask = None

        C_all = jax.tree.leaves(state.params["client"])[0].shape[0]
        new_fault_key = fault_key
        corrupt_m = corrupt_key = None
        if faults is not None:
            new_fault_key, k_ev = jax.random.split(fault_key)
            k_masks, corrupt_key = jax.random.split(k_ev)
            fmasks = _faults.sample_fault_masks(faults, k_masks, C_all)
            # sync semantics: dropped AND stalled clients never deliver
            # an update this round — they leave the participating subset
            # before the scan, so the eq. 14/15 priors recompute over
            # the survivors via the mask-fold path
            alive = (1.0 - fmasks["drop"]) * (1.0 - fmasks["stall"])
            mask = alive if mask is None else mask * alive
            corrupt_m = fmasks["corrupt"] * mask

        def local_phase(mask_, fold_scan_mask):
            if do_gather:
                idx = slot_gather_indices(mask_, k_active)
                sub = _gather_clients(start, idx)
                sub_batches = jax.tree.map(
                    lambda a: jnp.take(a, idx, axis=1), round_batches)
                if fold_scan_mask:
                    # faulty rounds can have fewer than K_active real
                    # participants: fill slots must not pollute priors
                    sub_mask = jnp.take(mask_, idx)
                    body = lambda s, b: step(s, b, sub_mask)
                else:
                    # no mask inside the scan: every gathered slot
                    # participates, so the stage-1 priors are the
                    # participating-subset priors
                    body = step
                sub, ms = jax.lax.scan(body, sub, sub_batches,
                                       unroll=unroll)
                st = _scatter_clients(start, sub, idx)
            else:
                body = (lambda s, b: step(s, b, mask_)) \
                    if mask_ is not None else step
                st, ms = jax.lax.scan(body, start, round_batches,
                                      unroll=unroll)
            mets = jax.tree.map(lambda a: a[-1], ms)
            if corrupt_m is not None:
                # the update is corrupted in transit, AFTER training
                cp = _faults.corrupt_update(faults, corrupt_key,
                                            st.params["client"], corrupt_m)
                st = TrainState(params={"client": cp,
                                        "server": st.params["server"]},
                                opt_state=st.opt_state, step=st.step)
            return st, mets

        if dp_gather:
            sizes = (data_sizes if data_sizes is not None
                     else jnp.ones((participation.num_clients,),
                                   jnp.float32))
            state, metrics = dp_round(state, round_batches, mask, sizes)
        else:
            state, metrics = local_phase(
                mask, fold_scan_mask=faults is not None)

        agg_mask = mask
        accept = factor = norms = rejected = None
        new_guard_state = guard_state
        if guards is not None:
            base = (mask if mask is not None
                    else jnp.ones((C_all,), jnp.float32))
            delta = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                state.params["client"], start.params["client"])
            accept, factor, norms, new_guard_state = _guards.screen(
                guards, delta, base, guard_state)
            survivor = base * accept
            rejected = base.sum() - survivor.sum()

            def recompute(_):
                # >=1 rejection: re-run the local phase over the
                # survivors so the priors / logit adjustments match a
                # round the rejected clients never joined
                return local_phase(survivor, fold_scan_mask=True)

            state, metrics = jax.lax.cond(
                rejected > 0, recompute, lambda _: (state, metrics), None)
            if guards.clip > 0:
                # re-derive the clip factors from the final (possibly
                # recomputed) updates; median state keeps pass-1 norms
                delta2 = jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32)
                                  - b.astype(jnp.float32)),
                    state.params["client"], start.params["client"])
                _, factor, _, _ = _guards.screen(guards, delta2, survivor,
                                                 guard_state)
            # survivor == base bitwise when nothing was rejected
            agg_mask = survivor

        if aggregate and not dp_gather:
            C = jax.tree.leaves(state.params["client"])[0].shape[0]
            p_k = p_global = None
            if agg.needs_priors:
                p_k, p_global = _fed.aggregation_priors(
                    model.num_classes, round_batches["labels"],
                    round_batches.get("weights"), client_axis=1)
            ctx = _fed.AggContext(num_clients=C, mask=agg_mask,
                                  data_sizes=data_sizes, p_k=p_k,
                                  p_global=p_global)
            w, agg_state = agg.client_weights(ctx, agg_state)
            pc = state.params["client"]
            if guards is not None and guards.clip > 0:
                pc = _guards.apply_clip(start.params["client"], pc, factor)
            if accept is not None:
                # 0-weight x NaN = NaN: rejected rows must be zeroed
                # out of the average, not just down-weighted
                pc = jax.tree.map(
                    lambda p: jnp.where(
                        accept.reshape((-1,) + (1,) * (p.ndim - 1)) > 0,
                        p, jnp.zeros((), p.dtype)), pc)
            new_client_avg = weighted_mean(pc, w)
            params = {"client": stack_client_params(new_client_avg, C),
                      "server": state.params["server"]}
            opt_state = _round_boundary_opt_state(opt, state.opt_state,
                                                  params, w,
                                                  opt_state_policy)
            state = TrainState(params=params, opt_state=opt_state,
                               step=state.step)

        if guards is not None:
            metrics = dict(metrics)
            metrics["guard_accept"] = accept
            metrics["guard_norm"] = norms
            metrics["guard_rejected"] = rejected

        if server_optimizer is not None:
            # FedOpt on the server half: round delta as pseudo-gradient
            delta = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                ws_start, state.params["server"])
            new_ws, so_state = server_optimizer.update(delta, so_state,
                                                       ws_start, server_lr)
            state = TrainState(params={"client": state.params["client"],
                                       "server": new_ws},
                               opt_state=state.opt_state, step=state.step)

        if fed_state is None:
            return state, metrics
        out_fed = {"sched": sched_state, "agg": agg_state}
        if "server_opt" in fed_state:
            out_fed["server_opt"] = so_state
        if "faults" in fed_state:
            out_fed["faults"] = (new_fault_key if faults is not None
                                 else fed_state["faults"])
        if "guard" in fed_state:
            out_fed["guard"] = (new_guard_state if guards is not None
                                else fed_state["guard"])
        return state, out_fed, metrics

    return round_fn


def scala_round_scan(model: SplitModel, state: TrainState, round_batches,
                     scala: ScalaConfig, data_sizes=None, *,
                     backend: str = "logits",
                     boundary: str = "fused",
                     optimizer: Optional[optimizers.Optimizer] = None,
                     schedule: Optional[Callable] = None,
                     ce_chunk: Optional[int] = None,
                     unroll=1, precision: str = "f32"):
    """One-shot convenience over :func:`make_round_runner`: T local
    iterations + aggregation as a single scanned program. For a training
    loop, build the runner once and jit it instead."""
    runner = make_round_runner(model, scala, backend=backend,
                               boundary=boundary,
                               optimizer=optimizer, schedule=schedule,
                               ce_chunk=ce_chunk, unroll=unroll,
                               precision=precision)
    return runner(state, round_batches, data_sizes)


def split_ce(model: SplitModel, wc, ws, batch):
    """Plain CE through the split — ONE client's forward into the server
    half, no concatenation and no logit adjustment. The local objective
    shared by the SFL baseline family (:mod:`repro.core.baselines`)."""
    acts = model.client_fwd(wc, batch)
    logits, aux = model.server_fwd(ws, acts)
    return losses.softmax_xent(logits, batch["labels"]) + aux


def init_scala_params(key, init_client, init_server, num_clients: int):
    """Build the stacked-client SCALA param layout from per-half inits."""
    kc, ks = jax.random.split(key)
    return {"client": stack_client_params(init_client(kc), num_clients),
            "server": init_server(ks)}
