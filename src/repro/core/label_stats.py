"""Label-distribution statistics: P_k(y) per client, P_s(y) concatenated.

The paper's server receives label sets Y_k with the activations (Alg. 1
line 12) and forms the concatenated distribution P_s (eq. 14) plus the
per-client distributions P_k (eq. 15). Histograms are scatter-adds (no
one-hot materialization — the LM archs have 262k classes).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def histogram(labels, num_classes: int, weights=None):
    """Count labels. labels: int array any shape; weights broadcastable.

    Returns float32 counts (num_classes,).
    """
    flat = labels.reshape(-1)
    if weights is None:
        w = jnp.ones_like(flat, jnp.float32)
    else:
        w = jnp.broadcast_to(weights, labels.shape).reshape(-1).astype(jnp.float32)
    valid = (flat >= 0) & (flat < num_classes)
    idx = jnp.clip(flat, 0, num_classes - 1)
    return jnp.zeros((num_classes,), jnp.float32).at[idx].add(
        jnp.where(valid, w, 0.0))


def prior(counts, eps: float = 1e-8):
    """Normalize counts -> P(y); all-zero counts give the uniform prior."""
    total = counts.sum()
    n = counts.shape[-1]
    uniform = jnp.full_like(counts, 1.0 / n)
    p = counts / jnp.maximum(total, eps)
    return jnp.where(total > 0, p, uniform)


def client_and_concat_priors(labels, num_classes: int, weights=None,
                             client_axis: int = 0, eps: float = 1e-8):
    """labels: (C, ...) per-client labels. Returns (P_k (C,N), P_s (N,)).

    P_s is the *concatenated* distribution (eq. 5-6): the histogram of the
    union batch — i.e. the sum of client histograms, NOT the mean of
    client priors (clients contribute proportionally to B_k, eq. 3).
    """
    import jax

    assert client_axis == 0
    if weights is None:
        counts = jax.vmap(lambda l: histogram(l, num_classes))(labels)
    else:
        counts = jax.vmap(lambda l, w: histogram(l, num_classes, w))(
            labels, weights)
    p_k = jax.vmap(lambda c: prior(c, eps))(counts)
    p_s = prior(counts.sum(axis=0), eps)
    return p_k, p_s
