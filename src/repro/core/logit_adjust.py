"""Logit adjustment (paper eqs. 13-15).

The balanced class-probability argmax (eq. 13) is realized by *adding*
``tau * log P(y)`` to the logits inside the softmax cross-entropy during
training (eqs. 14/15): high-frequency classes get their logits inflated
inside the loss, so the model must push them down to reduce loss —
equalizing classifier updates across frequencies (Lemma 4.3).
"""
from __future__ import annotations

import jax.numpy as jnp


def log_prior(prior, eps: float = 1e-8):
    return jnp.log(prior.astype(jnp.float32) + eps)


def adjust_logits(logits, prior, tau: float = 1.0, eps: float = 1e-8):
    """logits: (..., N); prior: broadcastable (..., N) or (N,)."""
    return logits.astype(jnp.float32) + tau * log_prior(prior, eps)


def balanced_prediction(logits, prior, tau: float = 1.0, eps: float = 1e-8):
    """Inference-time balanced argmax (eq. 13): subtract the prior."""
    return jnp.argmax(logits.astype(jnp.float32) - tau * log_prior(prior, eps),
                      axis=-1)
