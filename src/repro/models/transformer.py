"""Transformer assembly with a first-class SCALA split layout.

Params are laid out already split into the SFL halves::

    {'client': {'embed', 'projector'?, 'blocks': {'blk0', ...}},
     'server': {'prologue': {'blk0', ...},      # unrolled alignment layers
                'groups':   {'blk0', ...},      # leaves stacked (n_scan_groups, ...)
                'final_norm', 'head'}}

The server middle is a ``lax.scan`` over identical layer *groups* (one
pattern period per group) so the 72-layer archs lower to a compact
while-loop. ``split_layer`` blocks + embedding live on the client;
everything else (incl. the classifier head that SCALA's logit adjustment
targets) lives on the server.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks as B
from repro.models.common import dtype_of
from repro.models.layers import embeddings, frontends, norms
from repro.sharding.logical import constrain


# ---------------------------------------------------------------------------
# layout helpers
# ---------------------------------------------------------------------------


def _layout(cfg: ModelConfig):
    """(client_layers, prologue_layers, first_scan, n_scan_groups)."""
    gs = cfg.group_size
    split = cfg.split_layer
    r = (cfg.num_layers - split) % gs
    first_scan = split + r
    n_scan = (cfg.num_layers - first_scan) // gs
    return (list(range(split)), list(range(split, first_scan)), first_scan, n_scan)


def group_specs(cfg: ModelConfig):
    _, _, first_scan, _ = _layout(cfg)
    return [cfg.block_spec(first_scan + j) for j in range(cfg.group_size)]


# ---------------------------------------------------------------------------
# init / axes
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig):
    client_l, prologue_l, first_scan, n_scan = _layout(cfg)
    keys = jax.random.split(key, cfg.num_layers + 4)

    client = {"embed": embeddings.embedding_init(keys[-1], cfg)}
    if cfg.frontend:
        client["projector"] = frontends.projector_init(keys[-2], cfg)
    client["blocks"] = {
        f"blk{i}": B.block_init(keys[i], cfg.block_spec(l), cfg)
        for i, l in enumerate(client_l)
    }

    server = {
        "prologue": {
            f"blk{i}": B.block_init(keys[l], cfg.block_spec(l), cfg)
            for i, l in enumerate(prologue_l)
        },
        "final_norm": norms.rms_norm_init(cfg),
        "head": embeddings.head_init(keys[-3], cfg),
    }
    gspecs = group_specs(cfg)
    groups = {}
    if n_scan > 0:
        for j, spec in enumerate(gspecs):
            gkeys = jnp.stack([keys[first_scan + g * cfg.group_size + j]
                               for g in range(n_scan)])
            groups[f"blk{j}"] = jax.vmap(
                lambda k: B.block_init(k, spec, cfg))(gkeys)
    server["groups"] = groups
    return {"client": client, "server": server}


def param_axes(cfg: ModelConfig):
    client_l, prologue_l, first_scan, n_scan = _layout(cfg)
    client = {"embed": embeddings.embedding_axes(cfg)}
    if cfg.frontend:
        client["projector"] = frontends.projector_axes(cfg)
    client["blocks"] = {
        f"blk{i}": B.block_axes(cfg.block_spec(l), cfg)
        for i, l in enumerate(client_l)
    }
    server = {
        "prologue": {
            f"blk{i}": B.block_axes(cfg.block_spec(l), cfg)
            for i, l in enumerate(prologue_l)
        },
        "final_norm": norms.rms_norm_axes(cfg),
        "head": embeddings.head_axes(cfg),
        "groups": {} if n_scan == 0 else {
            f"blk{j}": jax.tree.map(
                lambda a: ("layers",) + a,
                B.block_axes(spec, cfg),
                is_leaf=lambda a: isinstance(a, tuple),
            )
            for j, spec in enumerate(group_specs(cfg))
        },
    }
    return {"client": client, "server": server}


# ---------------------------------------------------------------------------
# forward (full sequence)
# ---------------------------------------------------------------------------


def _embed_inputs(client_params, batch, cfg: ModelConfig):
    """Returns (x, positions, memory)."""
    tokens = batch["tokens"]
    memory = None
    if cfg.frontend == "vision":
        prefix = frontends.projector_apply(client_params["projector"],
                                           batch["prefix_emb"], cfg)
        total = prefix.shape[1] + tokens.shape[1]
        positions = jnp.arange(total)
        x = embeddings.embedding_apply(
            client_params["embed"], tokens, cfg,
            positions=None if cfg.pos_embed != "learned" else
            positions[prefix.shape[1]:][None, :])
        x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    elif cfg.frontend == "audio":
        positions = jnp.arange(tokens.shape[1])
        x = embeddings.embedding_apply(client_params["embed"], tokens, cfg,
                                       positions=positions[None, :])
        memory = frontends.projector_apply(client_params["projector"],
                                           batch["memory_emb"], cfg)
    else:
        positions = jnp.arange(tokens.shape[1])
        x = embeddings.embedding_apply(
            client_params["embed"], tokens, cfg,
            positions=positions[None, :] if cfg.pos_embed == "learned" else None)
    return x, positions, memory


def client_forward(client_params, batch, cfg: ModelConfig):
    """Client-side half: embedding (+frontend projector) + first blocks.

    Returns the SFL "activation upload": {'x', 'positions', 'memory'?}.
    """
    client_l, _, _, _ = _layout(cfg)
    x, positions, memory = _embed_inputs(client_params, batch, cfg)
    for i, l in enumerate(client_l):
        x, _ = B.block_apply(client_params["blocks"][f"blk{i}"], x,
                             cfg.block_spec(l), cfg, positions=positions,
                             memory=memory)
    out = {"x": x, "positions": positions}
    if memory is not None:
        out["memory"] = memory
    return out


def server_forward(server_params, acts, cfg: ModelConfig, *,
                   remat: bool = True, head_mode: str = "full"):
    """Server-side half on (possibly concatenated) activations.

    acts: {'x': (B,S,d), 'positions': (S,), 'memory'?: (B,M,d)}.
    Returns (logits, aux).
    """
    _, prologue_l, _, _ = _layout(cfg)
    x = acts["x"]
    positions = acts["positions"]
    memory = acts.get("memory")
    aux = jnp.zeros((), jnp.float32)
    # pin the concatenated batch dim to the client/data axis: XLA's
    # propagation otherwise de-shards it through the trunk (§Perf iter 1).
    # under the "dp" profile the flat batch dim (client-major x per-client)
    # spans every mesh axis (§Perf iter 2).
    batch_spec = ((("pod", "data", "model")
                   if cfg.sharding_profile in ("dp", "fsdp")
                   else ("pod", "data")), None, None)
    x = constrain(x, *batch_spec)
    for i, l in enumerate(prologue_l):
        x, a = B.block_apply(server_params["prologue"][f"blk{i}"], x,
                             cfg.block_spec(l), cfg, positions=positions,
                             memory=memory)
        x = constrain(x, *batch_spec)
        aux = aux + a

    gspecs = group_specs(cfg)

    def group_fn(carry, gp):
        x, aux = carry
        for j, spec in enumerate(gspecs):
            x, a = B.block_apply(gp[f"blk{j}"], x, spec, cfg,
                                 positions=positions, memory=memory)
            x = constrain(x, *batch_spec)
            aux = aux + a
        return (x, aux), None

    if server_params["groups"]:
        fn = jax.checkpoint(group_fn) if remat else group_fn
        (x, aux), _ = jax.lax.scan(fn, (x, aux), server_params["groups"])

    if head_mode == "last":
        x = x[:, -1:]
    x = norms.rms_norm_apply(server_params["final_norm"], x, cfg.norm_eps)
    if head_mode == "feats":
        return x, aux
    logits = embeddings.head_apply(server_params["head"], x, cfg)
    return logits, aux


def forward(params, batch, cfg: ModelConfig, *, remat: bool = True,
            head_mode: str = "full"):
    """Merged (non-split) forward — used by serving and FL baselines."""
    acts = client_forward(params["client"], batch, cfg)
    return server_forward(params["server"], acts, cfg, remat=remat,
                          head_mode=head_mode)


def forward_prefill(params, batch, cfg: ModelConfig):
    """Serving prefill: full trunk, next-token logits only (B, 1, V)."""
    logits, _ = forward(params, batch, cfg, remat=False, head_mode="last")
    return logits


def forward_prefill_cached(params, batch, cfg: ModelConfig, max_len: int,
                           cache_dtype=None):
    """Fused serving prefill: one trunk pass over the whole prompt that
    also scatters the full KV/SSM decode cache — replaces the P-dispatch
    token-by-token prefill loop with a single dispatch.

    Returns (logits (B, 1, V), cache): logits at the last prompt
    position (the distribution over the first generated token) and a
    cache structured exactly like :func:`init_decode_cache`
    ``(cfg, B, max_len)`` so :func:`decode_step` continues from it
    unchanged. Vision frontends are unsupported (the image prefix would
    shift cached positions relative to the token index decode uses);
    audio cross-attention memory is recomputed from the batch each
    decode step, so nothing needs caching for it.
    """
    if cfg.frontend == "vision":
        raise NotImplementedError(
            "forward_prefill_cached does not support vision prefixes")
    dtype = cache_dtype or dtype_of(cfg.dtype)
    client_l, prologue_l, _, n_scan = _layout(cfg)
    client_params = params["client"]
    x, positions, memory = _embed_inputs(client_params, batch, cfg)

    cache = {"client": {}, "prologue": {}}
    for i, l in enumerate(client_l):
        x, c = B.block_prefill(client_params["blocks"][f"blk{i}"], x,
                               cfg.block_spec(l), cfg, positions=positions,
                               max_len=max_len, cache_dtype=dtype,
                               memory=memory)
        cache["client"][f"blk{i}"] = c
    for i, l in enumerate(prologue_l):
        x, c = B.block_prefill(params["server"]["prologue"][f"blk{i}"], x,
                               cfg.block_spec(l), cfg, positions=positions,
                               max_len=max_len, cache_dtype=dtype,
                               memory=memory)
        cache["prologue"][f"blk{i}"] = c

    gspecs = group_specs(cfg)

    def gpre(x, gp):
        cs = {}
        for j, spec in enumerate(gspecs):
            x, c = B.block_prefill(gp[f"blk{j}"], x, spec, cfg,
                                   positions=positions, max_len=max_len,
                                   cache_dtype=dtype, memory=memory)
            cs[f"blk{j}"] = c
        return x, cs

    if params["server"]["groups"]:
        x, group_cache = jax.lax.scan(gpre, x, params["server"]["groups"])
        cache["groups"] = group_cache
    else:
        cache["groups"] = {}

    x = x[:, -1:]
    x = norms.rms_norm_apply(params["server"]["final_norm"], x, cfg.norm_eps)
    logits = embeddings.head_apply(params["server"]["head"], x, cfg)
    return logits, cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=None):
    dtype = dtype or dtype_of(cfg.dtype)
    client_l, prologue_l, first_scan, n_scan = _layout(cfg)

    def stacked(spec):
        c = B.block_cache_init(spec, cfg, batch, max_len, dtype)
        return jax.tree.map(
            lambda a: jnp.zeros((n_scan,) + a.shape, a.dtype), c)

    return {
        "client": {f"blk{i}": B.block_cache_init(cfg.block_spec(l), cfg,
                                                 batch, max_len, dtype)
                   for i, l in enumerate(client_l)},
        "prologue": {f"blk{i}": B.block_cache_init(cfg.block_spec(l), cfg,
                                                   batch, max_len, dtype)
                     for i, l in enumerate(prologue_l)},
        "groups": {} if n_scan == 0 else {
            f"blk{j}": stacked(spec)
            for j, spec in enumerate(group_specs(cfg))},
    }


def cache_axes(cfg: ModelConfig):
    client_l, prologue_l, _, n_scan = _layout(cfg)

    def stacked_axes(spec):
        return jax.tree.map(lambda a: ("layers",) + a,
                            B.block_cache_axes(spec),
                            is_leaf=lambda a: isinstance(a, tuple))

    return {
        "client": {f"blk{i}": B.block_cache_axes(cfg.block_spec(l))
                   for i, l in enumerate(client_l)},
        "prologue": {f"blk{i}": B.block_cache_axes(cfg.block_spec(l))
                     for i, l in enumerate(prologue_l)},
        "groups": {} if n_scan == 0 else {
            f"blk{j}": stacked_axes(spec)
            for j, spec in enumerate(group_specs(cfg))},
    }


def decode_step(params, batch, cache, index, cfg: ModelConfig):
    """One-token decode on the merged model.

    batch: {'tokens': (B,1), 'memory_emb'?: (B,M,fd)}; index: () int32 =
    position of the new token. Returns (logits (B,1,V), new_cache).
    """
    client_l, prologue_l, _, _ = _layout(cfg)
    client_params = params["client"]
    tokens = batch["tokens"]
    memory = None
    if cfg.frontend == "audio":
        memory = frontends.projector_apply(client_params["projector"],
                                           batch["memory_emb"], cfg)
    pos = jnp.full((1, 1), index, jnp.int32)
    x = embeddings.embedding_apply(
        client_params["embed"], tokens, cfg,
        positions=pos if cfg.pos_embed == "learned" else None)

    new_cache = {"client": {}, "prologue": {}}
    for i, l in enumerate(client_l):
        x, nc = B.block_decode(client_params["blocks"][f"blk{i}"], x,
                               cache["client"][f"blk{i}"], index,
                               cfg.block_spec(l), cfg, memory=memory)
        new_cache["client"][f"blk{i}"] = nc
    for i, l in enumerate(prologue_l):
        x, nc = B.block_decode(params["server"]["prologue"][f"blk{i}"], x,
                               cache["prologue"][f"blk{i}"], index,
                               cfg.block_spec(l), cfg, memory=memory)
        new_cache["prologue"][f"blk{i}"] = nc

    gspecs = group_specs(cfg)

    def gdec(x, inp):
        gp, gc = inp
        ncs = {}
        for j, spec in enumerate(gspecs):
            x, nc = B.block_decode(gp[f"blk{j}"], x, gc[f"blk{j}"], index,
                                   spec, cfg, memory=memory)
            ncs[f"blk{j}"] = nc
        return x, ncs

    if params["server"]["groups"]:
        x, group_cache = jax.lax.scan(
            gdec, x, (params["server"]["groups"], cache["groups"]))
        new_cache["groups"] = group_cache
    else:
        new_cache["groups"] = {}

    x = norms.rms_norm_apply(params["server"]["final_norm"], x, cfg.norm_eps)
    logits = embeddings.head_apply(params["server"]["head"], x, cfg)
    return logits, new_cache
