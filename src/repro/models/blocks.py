"""Residual block assembly: one BlockSpec -> params/apply/axes/cache.

A block is: pre-norm -> mixer (+residual) [-> pre-norm -> cross-attn
(+residual)] [-> pre-norm -> ffn (+residual)]. xLSTM blocks carry their
FFN inside the mixer (ffn == 'none').
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import BlockSpec, ModelConfig
from repro.models.layers import attention, mamba, mlp, moe, norms, xlstm


# ---------------------------------------------------------------------------
# init / axes
# ---------------------------------------------------------------------------

_MIXER_INIT = {
    "attn": lambda k, cfg: attention.attn_init(k, cfg),
    "mamba": lambda k, cfg: mamba.mamba_init(k, cfg),
    "mlstm": lambda k, cfg: xlstm.mlstm_init(k, cfg),
    "slstm": lambda k, cfg: xlstm.slstm_init(k, cfg),
}

_MIXER_AXES = {
    "attn": lambda cfg: attention.attn_axes(cfg),
    "mamba": lambda cfg: mamba.mamba_axes(cfg),
    "mlstm": lambda cfg: xlstm.mlstm_axes(cfg),
    "slstm": lambda cfg: xlstm.slstm_axes(cfg),
}


def block_init(key, spec: BlockSpec, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p = {
        "norm1": norms.rms_norm_init(cfg),
        "mixer": _MIXER_INIT[spec.mixer](ks[0], cfg),
    }
    if spec.cross_attn:
        p["norm_cross"] = norms.rms_norm_init(cfg)
        p["cross"] = attention.attn_init(ks[1], cfg, cross=True)
    if spec.ffn == "dense":
        p["norm2"] = norms.rms_norm_init(cfg)
        p["ffn"] = mlp.mlp_init(ks[2], cfg)
    elif spec.ffn == "moe":
        p["norm2"] = norms.rms_norm_init(cfg)
        p["ffn"] = moe.moe_init(ks[3], cfg)
    return p


def block_axes(spec: BlockSpec, cfg: ModelConfig):
    a = {
        "norm1": norms.rms_norm_axes(cfg),
        "mixer": _MIXER_AXES[spec.mixer](cfg),
    }
    if spec.cross_attn:
        a["norm_cross"] = norms.rms_norm_axes(cfg)
        a["cross"] = attention.attn_axes(cfg, cross=True)
    if spec.ffn == "dense":
        a["norm2"] = norms.rms_norm_axes(cfg)
        a["ffn"] = mlp.mlp_axes(cfg)
    elif spec.ffn == "moe":
        a["norm2"] = norms.rms_norm_axes(cfg)
        a["ffn"] = moe.moe_axes(cfg)
    return a


# ---------------------------------------------------------------------------
# forward (full sequence)
# ---------------------------------------------------------------------------


def _use_chunked(seq_len: int, window: Optional[int]) -> bool:
    if seq_len > 8192:
        return True
    return window is not None and window * 2 <= seq_len


def block_apply(params, x, spec: BlockSpec, cfg: ModelConfig, *,
                positions, memory=None):
    """Full-sequence forward. Returns (y, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = norms.rms_norm_apply(params["norm1"], x, cfg.norm_eps)
    if spec.mixer == "attn":
        h = attention.attn_apply(
            params["mixer"], h, cfg, positions=positions, window=spec.window,
            chunked=_use_chunked(x.shape[1], spec.window))
    elif spec.mixer == "mamba":
        h = mamba.mamba_apply(params["mixer"], h, cfg)
    elif spec.mixer == "mlstm":
        h = xlstm.mlstm_apply(params["mixer"], h, cfg)
    elif spec.mixer == "slstm":
        h = xlstm.slstm_apply(params["mixer"], h, cfg)
    x = x + h

    if spec.cross_attn:
        h = norms.rms_norm_apply(params["norm_cross"], x, cfg.norm_eps)
        h = attention.cross_attn_apply(params["cross"], h, memory, cfg)
        x = x + h

    if spec.ffn == "dense":
        h = norms.rms_norm_apply(params["norm2"], x, cfg.norm_eps)
        x = x + mlp.mlp_apply(params["ffn"], h, cfg)
    elif spec.ffn == "moe":
        h = norms.rms_norm_apply(params["norm2"], x, cfg.norm_eps)
        y, aux = moe.moe_apply(params["ffn"], h, cfg)
        x = x + y
    return x, aux


def block_prefill(params, x, spec: BlockSpec, cfg: ModelConfig, *,
                  positions, max_len: int, cache_dtype, memory=None):
    """Full-sequence forward that also emits this block's decode cache.

    Same math as :func:`block_apply` (router aux dropped — serving does
    not train), but the mixer pass additionally scatters the state a
    subsequent :func:`block_decode` needs: roped k/v rows into a fresh
    ring/dense KV cache for attention, the final conv/SSM carry for the
    recurrent mixers. The returned cache is structured exactly like
    :func:`block_cache_init` so decode can continue from it unchanged.

    Returns (y, cache).
    """
    h = norms.rms_norm_apply(params["norm1"], x, cfg.norm_eps)
    if spec.mixer == "attn":
        length = max_len if spec.window is None else min(max_len, spec.window)
        h, (k, v) = attention.attn_apply(
            params["mixer"], h, cfg, positions=positions, window=spec.window,
            chunked=_use_chunked(x.shape[1], spec.window), return_kv=True)
        cache = attention.prefill_cache(k, v, positions, length, cache_dtype)
    elif spec.mixer == "mamba":
        h, cache = mamba.mamba_prefill(params["mixer"], h, cfg, cache_dtype)
    elif spec.mixer == "mlstm":
        h, cache = xlstm.mlstm_prefill(params["mixer"], h, cfg, cache_dtype)
    elif spec.mixer == "slstm":
        h, cache = xlstm.slstm_prefill(params["mixer"], h, cfg)
    else:
        raise ValueError(spec.mixer)
    x = x + h

    if spec.cross_attn:
        h = norms.rms_norm_apply(params["norm_cross"], x, cfg.norm_eps)
        x = x + attention.cross_attn_apply(params["cross"], h, memory, cfg)

    if spec.ffn == "dense":
        h = norms.rms_norm_apply(params["norm2"], x, cfg.norm_eps)
        x = x + mlp.mlp_apply(params["ffn"], h, cfg)
    elif spec.ffn == "moe":
        h = norms.rms_norm_apply(params["norm2"], x, cfg.norm_eps)
        # decode parity: one-token decode routes each token alone, so
        # the capacity gate (the only cross-token coupling in the MoE)
        # never drops there. Prefill must route drop-free too, or the
        # fused pass diverges from the token-by-token path it replaces.
        dropless = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
        y, _ = moe.moe_apply(params["ffn"], h, dropless)
        x = x + y
    return x, cache


# ---------------------------------------------------------------------------
# decode (one token, stateful caches)
# ---------------------------------------------------------------------------


def block_cache_init(spec: BlockSpec, cfg: ModelConfig, batch: int,
                     max_len: int, dtype):
    if spec.mixer == "attn":
        # windowed layers only need a window-sized cache ring; we keep the
        # full length for layout uniformity unless the window is smaller.
        length = max_len if spec.window is None else min(max_len, spec.window)
        return attention.init_cache(cfg, batch, length, dtype)
    if spec.mixer == "mamba":
        return mamba.init_cache(cfg, batch, dtype)
    if spec.mixer == "mlstm":
        return xlstm.mlstm_init_cache(cfg, batch, dtype)
    if spec.mixer == "slstm":
        return xlstm.slstm_init_cache(cfg, batch, dtype)
    raise ValueError(spec.mixer)


def block_cache_axes(spec: BlockSpec):
    if spec.mixer == "attn":
        return attention.cache_axes()
    if spec.mixer == "mamba":
        return mamba.cache_axes()
    if spec.mixer == "mlstm":
        return xlstm.mlstm_cache_axes()
    if spec.mixer == "slstm":
        return xlstm.slstm_cache_axes()
    raise ValueError(spec.mixer)


def block_decode(params, x, cache, index, spec: BlockSpec, cfg: ModelConfig,
                 *, memory=None):
    """One-token decode. Returns (y, new_cache)."""
    h = norms.rms_norm_apply(params["norm1"], x, cfg.norm_eps)
    if spec.mixer == "attn":
        cache_len = cache["k"].shape[1]
        # windowed ring cache: write at index % cache_len
        widx = jnp.remainder(index, cache_len) if spec.window is not None else index
        if spec.window is not None:
            h, new_cache = _decode_ring(params["mixer"], h, cache, index,
                                        widx, cfg, spec.window)
        else:
            h, new_cache = attention.attn_decode(params["mixer"], h, cache,
                                                 index, cfg, window=None)
    elif spec.mixer == "mamba":
        h, new_cache = mamba.mamba_decode(params["mixer"], h, cache, cfg)
    elif spec.mixer == "mlstm":
        h, new_cache = xlstm.mlstm_decode(params["mixer"], h, cache, cfg)
    elif spec.mixer == "slstm":
        h, new_cache = xlstm.slstm_decode(params["mixer"], h, cache, cfg)
    x = x + h

    if spec.cross_attn:
        h = norms.rms_norm_apply(params["norm_cross"], x, cfg.norm_eps)
        x = x + attention.cross_attn_apply(params["cross"], h, memory, cfg)

    if spec.ffn == "dense":
        h = norms.rms_norm_apply(params["norm2"], x, cfg.norm_eps)
        x = x + mlp.mlp_apply(params["ffn"], h, cfg)
    elif spec.ffn == "moe":
        h = norms.rms_norm_apply(params["norm2"], x, cfg.norm_eps)
        y, _ = moe.moe_apply(params["ffn"], h, cfg)
        x = x + y
    return x, new_cache


def _decode_ring(params, x, cache, index, widx, cfg, window):
    """Decode against a ring buffer of size <= window (SWA layers).

    Positions of ring slots are reconstructed from the write index so the
    relative-window mask stays exact.
    """
    from repro.models.layers.attention import (_mask, _project_kv, _project_q,
                                               _repeat_kv, attend_dense)
    from repro.models.layers.rope import apply_rope

    cache_len = cache["k"].shape[1]
    q = _project_q(params, x, cfg)
    k_new, v_new = _project_kv(params, x, cfg)
    pos = jnp.full((1,), index, jnp.int32)
    if cfg.pos_embed == "rope":
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), widx, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), widx, axis=1)
    # slot i holds position: the largest p <= index with p % cache_len == i
    slots = jnp.arange(cache_len)
    delta = jnp.remainder(widx - slots, cache_len)
    kv_pos = index - delta
    kv_pos = jnp.where(kv_pos >= 0, kv_pos, -1)
    kf = _repeat_kv(k.astype(x.dtype), cfg.num_heads)
    vf = _repeat_kv(v.astype(x.dtype), cfg.num_heads)
    out = attend_dense(q, kf, vf, pos, kv_pos, causal=True, window=window)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, {"k": k, "v": v}
