from repro.models import alexnet, blocks, transformer  # noqa: F401
