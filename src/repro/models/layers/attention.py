"""Multi-head attention: GQA + RoPE + sliding window + KV cache + cross-attn.

Three execution paths:

* ``dense``   — full (Sq, Skv) score matrix; used for short sequences.
* ``chunked`` — lax.scan over query chunks; with a sliding window the KV is
  dynamically sliced to ``window + chunk`` so compute/memory are O(S·w),
  not O(S²). Used for long prefill and windowed training.
* ``decode``  — one query token against a (possibly windowed) KV cache.

The dense/chunked paths are the pure-jnp reference; the Pallas flash
kernel in :mod:`repro.kernels.flash_attn` implements the same math for
TPU and is validated against :func:`attend_dense` in the kernel tests.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, dtype_of
from repro.models.layers import norms
from repro.models.layers.rope import apply_rope

NEG_INF = -1e30
DEFAULT_CHUNK = 512


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def attn_init(key, cfg, *, cross: bool = False):
    pd = dtype_of(cfg.param_dtype)
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), d, pd),
        "wk": dense_init(ks[1], (d, kv, hd), d, pd),
        "wv": dense_init(ks[2], (d, kv, hd), d, pd),
        "wo": dense_init(ks[3], (h, hd, d), h * hd, pd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), pd)
        p["bk"] = jnp.zeros((kv, hd), pd)
        p["bv"] = jnp.zeros((kv, hd), pd)
    if cfg.qk_norm and not cross:
        p["q_norm"] = norms.head_norm_init(hd)
        p["k_norm"] = norms.head_norm_init(hd)
    return p


def attn_axes(cfg, *, cross: bool = False):
    a = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qkv_bias:
        a["bq"] = ("heads", "head_dim")
        a["bk"] = ("kv_heads", "head_dim")
        a["bv"] = ("kv_heads", "head_dim")
    if cfg.qk_norm and not cross:
        a["q_norm"] = norms.head_norm_axes()
        a["k_norm"] = norms.head_norm_axes()
    return a


# ---------------------------------------------------------------------------
# core attends (q/k/v already projected & roped; k/v have full head count)
# ---------------------------------------------------------------------------


def _mask(q_pos, kv_pos, *, causal: bool, window: Optional[int]):
    """Boolean mask [..., Sq, Skv]; True = attend."""
    q = q_pos[..., :, None]
    k = kv_pos[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    if causal:
        m &= k <= q
    if window is not None:
        m &= (q - k) < window
    m &= k >= 0  # kv_pos < 0 marks invalid/unwritten cache slots
    return m


def attend_dense(q, k, v, q_pos, kv_pos, *, causal: bool, window: Optional[int]):
    """q: (B,Sq,H,hd); k/v: (B,Skv,H,hd); positions: (B?,S) or (S,)."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    mask = _mask(q_pos, kv_pos, causal=causal, window=window)
    if mask.ndim == 2:
        mask = mask[None, None]
    else:
        mask = mask[:, None]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attend_chunked(q, k, v, q_pos, kv_pos, *, causal: bool,
                   window: Optional[int], chunk: int = DEFAULT_CHUNK):
    """Query-chunked attention. With a window the KV is sliced per chunk.

    Positions must be 1-D (shared across batch) for the chunked path.
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    if Sq % chunk != 0:
        return attend_dense(q, k, v, q_pos, kv_pos, causal=causal, window=window)

    nchunks = Sq // chunk
    qc = q.reshape(B, nchunks, chunk, H, hd).swapaxes(0, 1)  # (n, B, c, H, hd)
    qp = q_pos.reshape(nchunks, chunk)

    windowed = window is not None and (window + chunk) < Skv

    def body(_, inp):
        qi, qpi, idx = inp
        if windowed:
            span = window + chunk
            start = jnp.clip(idx * chunk - window, 0, Skv - span)
            ki = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            kpi = jax.lax.dynamic_slice_in_dim(kv_pos, start, span, axis=0)
        else:
            ki, vi, kpi = k, v, kv_pos
        out = attend_dense(qi, ki, vi, qpi, kpi, causal=causal, window=window)
        return None, out

    _, outs = jax.lax.scan(body, None,
                           (qc, qp, jnp.arange(nchunks)))
    return outs.swapaxes(0, 1).reshape(B, Sq, H, hd)


# ---------------------------------------------------------------------------
# full layer apply
# ---------------------------------------------------------------------------


def _project_q(params, x, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
    if "q_norm" in params:
        q = norms.head_norm_apply(params["q_norm"], q, cfg.norm_eps)
    return q


def _project_kv(params, x, cfg):
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if "bk" in params:
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if "k_norm" in params:
        k = norms.head_norm_apply(params["k_norm"], k, cfg.norm_eps)
    return k, v


def _repeat_kv(k, num_heads):
    reps = num_heads // k.shape[2]
    return jnp.repeat(k, reps, axis=2) if reps > 1 else k


def attn_apply(params, x, cfg, *, positions, window=None,
               chunked: bool = False, return_kv: bool = False):
    """Self-attention over a full sequence (train / prefill).

    ``return_kv=True`` additionally returns the post-rope, pre-GQA-repeat
    ``(k, v)`` — exactly what the decode cache stores — so the fused
    serving prefill can scatter the cache from the same projections it
    attends with instead of re-projecting per token.
    """
    q = _project_q(params, x, cfg)
    k, v = _project_kv(params, x, cfg)
    if cfg.pos_embed == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    kv = (k, v)
    k = _repeat_kv(k, cfg.num_heads)
    v = _repeat_kv(v, cfg.num_heads)
    attend = attend_chunked if chunked else attend_dense
    out = attend(q, k, v, positions, positions, causal=True, window=window)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return (y, kv) if return_kv else y


def prefill_cache(k, v, positions, cache_len: int, dtype):
    """Scatter a prompt's roped k/v (B, P, kv, hd) into a fresh decode
    cache of length ``cache_len``.

    Position ``p`` lands in slot ``p % cache_len`` — the ring layout
    :func:`repro.models.blocks._decode_ring` reads for windowed layers
    (for global layers ``cache_len >= P`` so the modulo is the
    identity). Only the last ``min(P, cache_len)`` tokens are kept: a
    ring holds exactly that many, and earlier positions would be
    overwritten by the scatter anyway.
    """
    P = k.shape[1]
    n = min(P, cache_len)
    slots = positions[P - n:] % cache_len
    kc = jnp.zeros((k.shape[0], cache_len) + k.shape[2:], dtype)
    vc = jnp.zeros_like(kc)
    kc = kc.at[:, slots].set(k[:, P - n:].astype(dtype))
    vc = vc.at[:, slots].set(v[:, P - n:].astype(dtype))
    return {"k": kc, "v": vc}


def cross_attn_apply(params, x, memory, cfg):
    """Cross-attention: queries from x, keys/values from encoder memory."""
    q = _project_q(params, x, cfg)
    k, v = _project_kv(params, memory, cfg)
    k = _repeat_kv(k, cfg.num_heads)
    v = _repeat_kv(v, cfg.num_heads)
    Sq, Skv = x.shape[1], memory.shape[1]
    out = attend_dense(q, k, v, jnp.arange(Sq), jnp.arange(Skv),
                       causal=False, window=None)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# decode with KV cache
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, max_len: int, dtype):
    hd, kv = cfg.head_dim, cfg.num_kv_heads
    return {
        "k": jnp.zeros((batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, kv, hd), dtype),
    }


def cache_axes():
    return {
        "k": ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
        "v": ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
    }


def attn_decode(params, x, cache, index, cfg, *, window=None):
    """One-token decode. x: (B,1,d); cache k/v: (B,Smax,kv,hd); index: ()
    = number of tokens already in the cache (the new token's position).

    Returns (y, new_cache).
    """
    B = x.shape[0]
    q = _project_q(params, x, cfg)            # (B,1,H,hd)
    k_new, v_new = _project_kv(params, x, cfg)  # (B,1,kv,hd)
    pos = jnp.full((1,), index, jnp.int32)
    if cfg.pos_embed == "rope":
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)

    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), index, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), index, axis=1)
    new_cache = {"k": k, "v": v}

    kf = _repeat_kv(k.astype(x.dtype), cfg.num_heads)
    vf = _repeat_kv(v.astype(x.dtype), cfg.num_heads)
    Smax = k.shape[1]
    kv_pos = jnp.arange(Smax)
    # slots beyond `index` are unwritten: mark invalid with pos = -1
    kv_pos = jnp.where(kv_pos <= index, kv_pos, -1)
    out = attend_dense(q, kf, vf, pos, kv_pos, causal=True, window=window)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, new_cache
