from repro.models.layers import (  # noqa: F401
    attention,
    embeddings,
    frontends,
    mamba,
    mlp,
    moe,
    norms,
    rope,
    xlstm,
)
