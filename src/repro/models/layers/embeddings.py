"""Token embeddings, learned positions, output head."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, dtype_of, embed_init


def embedding_init(key, cfg):
    p = {"tok": embed_init(key, (cfg.vocab_size, cfg.d_model), dtype_of(cfg.param_dtype))}
    if cfg.pos_embed == "learned":
        p["pos"] = embed_init(
            jax.random.fold_in(key, 1),
            (cfg.max_position, cfg.d_model),
            dtype_of(cfg.param_dtype),
        )
    return p


def embedding_axes(cfg):
    a = {"tok": ("vocab", "embed")}
    if cfg.pos_embed == "learned":
        a["pos"] = ("position", "embed")
    return a


def embedding_apply(params, tokens, cfg, positions=None):
    x = jnp.take(params["tok"], tokens, axis=0).astype(dtype_of(cfg.dtype))
    if cfg.pos_embed == "learned":
        assert positions is not None
        x = x + jnp.take(params["pos"], positions, axis=0).astype(x.dtype)
    return x


def head_init(key, cfg):
    # NOTE: tied embeddings are deliberately *untied* in this framework:
    # SCALA places the embedding on clients and the classifier head on the
    # server; a tie would cross the split privacy boundary (see DESIGN.md).
    return {"out": dense_init(key, (cfg.d_model, cfg.vocab_size), cfg.d_model,
                              dtype_of(cfg.param_dtype))}


def head_axes(cfg):
    return {"out": ("embed", "vocab")}


def head_apply(params, x, cfg):
    return jnp.einsum("...d,dv->...v", x, params["out"].astype(x.dtype))
