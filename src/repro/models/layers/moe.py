"""Mixture-of-experts FFN with sort-based capacity dispatch.

TPU adaptation notes (vs. the usual GPU grouped-GEMM):

* Dispatch is **sort-based** (argsort by expert id + scatter into a
  (groups, experts, capacity, d_model) buffer) instead of the one-hot
  einsum dispatch — the one-hot (tokens, E, cap) tensor is quadratically
  larger and does not fit VMEM-friendly tiles at 128 experts.
* Each batch row is a dispatch *group*, so capacity is computed per-row
  and the buffer shards cleanly: group -> data axis, experts -> model
  axis (expert parallelism). The expert GEMM is a plain batched einsum
  on the MXU.
* Dropped tokens (capacity overflow) fall into a dump slot and
  contribute zero output — standard Switch semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ACTIVATIONS, dense_init, dtype_of
from repro.sharding.logical import constrain


def moe_init(key, cfg):
    m = cfg.moe
    pd = dtype_of(cfg.param_dtype)
    d, e, f = cfg.d_model, m.num_experts, m.d_expert
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), d, jnp.float32),
        "gate": dense_init(ks[1], (e, d, f), d, pd),
        "up": dense_init(ks[2], (e, d, f), d, pd),
        "down": dense_init(ks[3], (e, f, d), f, pd),
    }


def moe_axes(cfg):
    return {
        "router": ("embed", "experts_router"),
        "gate": ("experts", "embed", "expert_ffn"),
        "up": ("experts", "embed", "expert_ffn"),
        "down": ("experts", "expert_ffn", "embed"),
    }


def capacity(tokens_per_group: int, m) -> int:
    cap = int(tokens_per_group * m.top_k * m.capacity_factor / m.num_experts)
    return max(1, cap)


def moe_apply(params, x, cfg):
    """x: (..., seq, d_model). Returns (y, aux_loss)."""
    m = cfg.moe
    act = ACTIVATIONS[cfg.act]
    orig_shape = x.shape
    d = orig_shape[-1]
    n = orig_shape[-2]                      # tokens per group (= seq)
    x = x.reshape(-1, n, d)                 # (G, n, d)
    G = x.shape[0]
    E, K = m.num_experts, m.top_k
    cap = capacity(n, m)

    # --- routing (float32) ---
    logits = jnp.einsum("gnd,de->gne", x.astype(jnp.float32),
                        params["router"])                       # (G,n,E)
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(gates, K)                       # (G,n,K)
    top_w = top_w / jnp.clip(top_w.sum(-1, keepdims=True), 1e-9)

    # --- load-balance aux (Switch): E * <gates_e> . <frac_routed_e> ---
    me = gates.mean(axis=(0, 1))                                 # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(
        jnp.ones((G * n * K,), jnp.float32)) / (G * n * K)
    aux = m.router_aux_weight * E * jnp.sum(me * ce)

    # --- sort-based dispatch ---
    flat_e = top_i.reshape(G, n * K)                             # expert ids
    flat_w = top_w.reshape(G, n * K)
    flat_t = jnp.repeat(jnp.arange(n)[None, :, None], K, axis=2).reshape(1, n * K)
    flat_t = jnp.broadcast_to(flat_t, (G, n * K))                # token ids

    order = jnp.argsort(flat_e, axis=-1, stable=True)            # (G, nK)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    sorted_t = jnp.take_along_axis(flat_t, order, axis=-1)

    counts = jax.nn.one_hot(flat_e, E, dtype=jnp.int32).sum(axis=1)  # (G,E)
    starts = jnp.cumsum(counts, axis=-1) - counts                    # exclusive
    pos = jnp.arange(n * K)[None, :] - jnp.take_along_axis(starts, sorted_e, -1)
    keep = pos < cap
    slot = jnp.where(keep, sorted_e * cap + pos, E * cap)            # dump slot

    # gather tokens in sorted order and scatter into the expert buffer.
    # the scatter is where GSPMD loses the batch sharding (the gidx
    # indices look global), so pin the group dim on both sides — without
    # this every device materializes the FULL global (G,E,cap,d) f32
    # buffer and all-reduces it (§Perf jamba iteration 3).
    grp = (("pod", "data", "model") if cfg.sharding_profile in ("dp", "fsdp")
           else ("pod", "data"))
    xs = jnp.take_along_axis(x, sorted_t[..., None], axis=1)         # (G,nK,d)
    xs = constrain(xs, grp, None, None)

    # vmapped per-group scatter: keeping G a *batched* dim (instead of
    # flattening it into the scatter index space) keeps the scatter local
    # to each group's shard — a flat global-index scatter makes GSPMD
    # emit a partial scatter + full-buffer all-reduce (§Perf jamba
    # iteration 4).
    def _scatter_group(xg, sg):
        return jnp.zeros((E * cap + 1, d), x.dtype).at[sg].set(
            xg, mode="drop")

    buf = jax.vmap(_scatter_group)(xs, slot)        # (G, E*cap+1, d)
    buf = constrain(buf, grp, None, None)
    buf = buf[:, : E * cap].reshape(G, E, cap, d)
    buf = constrain(buf, grp, None, None, None)

    # --- expert FFN (gated) ---
    # NOTE (§Perf jamba, refuted hypothesis): constraining the expert
    # weights to (experts->model, d/f replicated) at the use site — to
    # make XLA all-gather bf16 weights instead of all-reducing the f32
    # dispatched-activation buffers — measured 1.6x WORSE (380.9s ->
    # 603.1s collective, 8x HLO flops): the per-trip weight gather inside
    # the remat'd layer scan forced additional rematerialization. Kept on
    # the default GSPMD resolution instead.
    h_g = jnp.einsum("gecd,edf->gecf", buf, params["gate"].astype(x.dtype))
    h_u = jnp.einsum("gecd,edf->gecf", buf, params["up"].astype(x.dtype))
    out = jnp.einsum("gecf,efd->gecd", act(h_g) * h_u,
                     params["down"].astype(x.dtype))

    # --- gather back, unsort, weighted combine ---
    out = constrain(out, grp, None, None, None)
    out_flat = jnp.concatenate(
        [out.reshape(G, E * cap, d), jnp.zeros((G, 1, d), x.dtype)], axis=1)
    y_sorted = jnp.take_along_axis(out_flat, slot[..., None], axis=1)  # (G,nK,d)
    inv = jnp.argsort(order, axis=-1, stable=True)
    y = jnp.take_along_axis(y_sorted, inv[..., None], axis=1)
    y = (y.reshape(G, n, K, d) * flat_w.reshape(G, n, K)[..., None].astype(x.dtype)
         ).sum(axis=2)
    return y.reshape(orig_shape), aux
