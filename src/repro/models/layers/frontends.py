"""Modality frontends (STUBBED per the brief).

The VLM vision encoder (InternViT) and the audio conv/mel encoder
(Whisper) are NOT implemented — ``input_specs`` supplies pre-computed
patch / frame embeddings. What IS implemented is the part that belongs to
the language backbone: the projector that maps frontend embeddings into
d_model (and, for whisper, the cross-attention memory path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, dtype_of
from repro.models.layers import norms


def projector_init(key, cfg):
    """Two-layer MLP projector (InternVL-style) frontend_dim -> d_model."""
    pd = dtype_of(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    return {
        "norm": norms.layer_norm_init(cfg.frontend_dim),
        "fc1": dense_init(k1, (cfg.frontend_dim, cfg.d_model), cfg.frontend_dim, pd),
        "fc2": dense_init(k2, (cfg.d_model, cfg.d_model), cfg.d_model, pd),
    }


def projector_axes(cfg):
    return {
        "norm": norms.layer_norm_axes(),
        "fc1": ("frontend", "embed"),
        "fc2": ("embed", "embed_alt"),
    }


def projector_apply(params, emb, cfg):
    """emb: (B, P, frontend_dim) -> (B, P, d_model)."""
    x = norms.layer_norm_apply(params["norm"], emb.astype(jnp.float32))
    x = x.astype(dtype_of(cfg.dtype))
    x = jnp.einsum("bpf,fd->bpd", x, params["fc1"].astype(x.dtype))
    x = jax.nn.gelu(x, approximate=True)
    return jnp.einsum("bpd,de->bpe", x, params["fc2"].astype(x.dtype))
