"""Mamba-1 selective SSM mixer (Jamba-style), chunkwise-parallel training.

TPU adaptation: instead of the CUDA selective-scan kernel, training uses a
``lax.scan`` over sequence chunks with a ``lax.associative_scan`` inside
each chunk — the (B, chunk, d_inner, d_state) working set stays VMEM-sized
once d_inner is sharded over the model axis, and the HLO remains a compact
while-loop for the 72-layer dry-runs. Decode is the exact single-step
recurrence with a (conv window, ssm state) cache.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, dtype_of

CHUNK = 64


def _dims(cfg):
    di = cfg.mamba.d_inner(cfg.d_model)
    dt_rank = math.ceil(cfg.d_model / 16)
    return di, dt_rank, cfg.mamba.d_state, cfg.mamba.d_conv


def mamba_init(key, cfg):
    pd = dtype_of(cfg.param_dtype)
    d = cfg.d_model
    di, dt_rank, N, dc = _dims(cfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))
    dt = jnp.exp(
        jax.random.uniform(ks[5], (di,)) * (math.log(0.1) - math.log(0.001))
        + math.log(0.001)
    )
    dt_bias = dt + jnp.log1p(-jnp.exp(-dt))  # inverse softplus
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), d, pd),
        "conv_w": dense_init(ks[1], (dc, di), dc, jnp.float32),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": dense_init(ks[2], (di, dt_rank + 2 * N), di, pd),
        "dt_proj": dense_init(ks[3], (dt_rank, di), dt_rank, jnp.float32),
        "dt_bias": dt_bias,
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d), di, pd),
    }


def mamba_axes(cfg):
    return {
        "in_proj": ("embed", "inner"),
        "conv_w": ("conv_k", "inner"),
        "conv_b": ("inner",),
        "x_proj": ("inner", "lowrank"),
        "dt_proj": ("lowrank", "inner"),
        "dt_bias": ("inner",),
        "A_log": ("inner", "state"),
        "D": ("inner",),
        "out_proj": ("inner", "embed"),
    }


def _causal_conv(x, w, b, prev=None):
    """Depthwise causal conv. x: (B,S,di); w: (dc,di); prev: (B,dc-1,di)."""
    dc = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    y = sum(xp[:, k : k + S, :] * w[k].astype(x.dtype) for k in range(dc))
    return y + b.astype(x.dtype), xp[:, -(dc - 1) :, :]


def _ssm_inputs(params, xc, cfg):
    """From conv output xc (B,S,di): dt (B,S,di), Bm/Cm (B,S,N)."""
    di, dt_rank, N, _ = _dims(cfg)
    proj = jnp.einsum("bsd,dr->bsr", xc, params["x_proj"].astype(xc.dtype))
    dt_low, Bm, Cm = jnp.split(proj.astype(jnp.float32), [dt_rank, dt_rank + N], -1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_low, params["dt_proj"]) + params["dt_bias"])
    return dt, Bm, Cm


def _scan_chunked(a, b, h0):
    """h_t = a_t * h_{t-1} + b_t over axis 1, chunked.

    a, b: (B, S, di, N) f32; h0: (B, di, N). Returns (h_all (B,S,di,N), h_S).
    """
    B, S, di, N = a.shape
    L = min(CHUNK, S)
    while S % L:
        L //= 2
    nchunks = S // L
    a = a.reshape(B, nchunks, L, di, N).transpose(1, 0, 2, 3, 4)
    b = b.reshape(B, nchunks, L, di, N).transpose(1, 0, 2, 3, 4)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    def step(h, ab):
        ai, bi = ab
        Acum, Bcum = jax.lax.associative_scan(combine, (ai, bi), axis=1)
        h_all = Acum * h[:, None] + Bcum
        return h_all[:, -1], h_all

    h_last, h_chunks = jax.lax.scan(step, h0, (a, b))
    h_all = h_chunks.transpose(1, 0, 2, 3, 4).reshape(B, S, di, N)
    return h_all, h_last


def mamba_apply(params, x, cfg):
    """Full-sequence forward. x: (B,S,d) -> (B,S,d)."""
    di, _, N, _ = _dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, _ = _causal_conv(xin, params["conv_w"], params["conv_b"])
    xc = jax.nn.silu(xc)
    dt, Bm, Cm = _ssm_inputs(params, xc, cfg)
    A = -jnp.exp(params["A_log"])                                  # (di,N)
    a = jnp.exp(dt[..., None] * A)                                 # (B,S,di,N)
    b = (dt * xc.astype(jnp.float32))[..., None] * Bm[:, :, None, :]
    h0 = jnp.zeros((x.shape[0], di, N), jnp.float32)
    h_all, _ = _scan_chunked(a, b, h0)
    y = jnp.einsum("bsdn,bsn->bsd", h_all, Cm)
    y = y + params["D"] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    return jnp.einsum("bsd,de->bse", y, params["out_proj"].astype(x.dtype))


def mamba_prefill(params, x, cfg, cache_dtype):
    """Full-sequence forward that also returns the decode cache.

    Identical math to :func:`mamba_apply`; the conv tail and final SSM
    state that ``mamba_apply`` discards become the serving cache, so a
    prompt is absorbed in one dispatch instead of one per token.
    """
    di, _, N, _ = _dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(xin, params["conv_w"], params["conv_b"])
    xc = jax.nn.silu(xc)
    dt, Bm, Cm = _ssm_inputs(params, xc, cfg)
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt[..., None] * A)
    b = (dt * xc.astype(jnp.float32))[..., None] * Bm[:, :, None, :]
    h0 = jnp.zeros((x.shape[0], di, N), jnp.float32)
    h_all, h_last = _scan_chunked(a, b, h0)
    y = jnp.einsum("bsdn,bsn->bsd", h_all, Cm)
    y = y + params["D"] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    y = jnp.einsum("bsd,de->bse", y, params["out_proj"].astype(x.dtype))
    return y, {"conv": conv_state.astype(cache_dtype), "h": h_last}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, dtype):
    di, _, N, dc = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, dc - 1, di), dtype),
        "h": jnp.zeros((batch, di, N), jnp.float32),
    }


def cache_axes():
    return {
        "conv": ("cache_batch", "conv_k", "inner"),
        "h": ("cache_batch", "inner", "state"),
    }


def mamba_decode(params, x, cache, cfg):
    """One-token step. x: (B,1,d). Returns (y, new_cache)."""
    di, _, N, _ = _dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(xin, params["conv_w"], params["conv_b"],
                                  prev=cache["conv"])
    xc = jax.nn.silu(xc)
    dt, Bm, Cm = _ssm_inputs(params, xc, cfg)
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt[:, 0, :, None] * A)                             # (B,di,N)
    b = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0, None, :]
    h = a * cache["h"] + b
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])
    y = y + params["D"] * xc[:, 0].astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z[:, 0]))[:, None, :]
    y = jnp.einsum("bsd,de->bse", y, params["out_proj"].astype(x.dtype))
    return y, {"conv": conv_state.astype(cache["conv"].dtype), "h": h}
