"""Rotary position embeddings (applied per-call from integer positions)."""
from __future__ import annotations

import jax.numpy as jnp


def _freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def rope_angles(positions, head_dim: int, theta: float):
    """positions: integer array [...]; returns (cos, sin) of shape [..., half]."""
    inv = _freqs(head_dim, theta)
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float):
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq].

    Rotates pairs (x[..., :half], x[..., half:]) — the "GPT-NeoX" layout.
    Odd head_dims (e.g. danube's 120 is even, fine) require even head_dim.
    """
    head_dim = x.shape[-1]
    assert head_dim % 2 == 0, "rope requires even head_dim"
    cos, sin = rope_angles(positions, head_dim, theta)  # [..., seq, half]
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)
