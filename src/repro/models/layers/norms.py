"""RMSNorm / LayerNorm / QK head-norm (functional)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.common import ones, zeros


def rms_norm_init(cfg):
    return {"scale": ones((cfg.d_model,), jnp.float32)}


def rms_norm_axes(cfg):
    return {"scale": ("embed",)}


def rms_norm_apply(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * (var + eps) ** -0.5 * params["scale"]).astype(dtype)


def layer_norm_init(dim):
    return {"scale": ones((dim,), jnp.float32), "bias": zeros((dim,), jnp.float32)}


def layer_norm_axes():
    return {"scale": ("embed",), "bias": ("embed",)}


def layer_norm_apply(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * (var + eps) ** -0.5
    return (y * params["scale"] + params["bias"]).astype(dtype)


# head-dim norm for QK-norm (qwen3 / gemma3)
def head_norm_init(head_dim):
    return {"scale": ones((head_dim,), jnp.float32)}


def head_norm_axes():
    return {"scale": ("head_dim",)}


def head_norm_apply(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * (var + eps) ** -0.5 * params["scale"]).astype(dtype)
