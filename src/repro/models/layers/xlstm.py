"""xLSTM mixers: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, true recurrence).

The mLSTM chunkwise math mirrors the stabilized formulation of
arXiv:2405.04517: per head, with log-sigmoid forget gates ``f`` and raw
input gates ``i``,

    m_t = max(f_t + m_{t-1}, i_t)
    C_t = e^{f_t + m_{t-1} - m_t} C_{t-1} + e^{i_t - m_t} k_t v_t^T
    n_t = e^{f_t + m_{t-1} - m_t} n_{t-1} + e^{i_t - m_t} k_t
    h_t = (q_t C_t) / max(|q_t n_t|, e^{-m_t})

Training evaluates this chunkwise: intra-chunk via an (L, L) decay matrix,
inter-chunk via the carried (C, n, m) state — the same decomposition the
Pallas kernel in :mod:`repro.kernels.mlstm` tiles for VMEM. The per-step
recurrence (used for decode) is the oracle the chunkwise path is tested
against.

sLSTM has a hidden-to-hidden recurrence (block-diagonal R per head), so it
is inherently sequential: a ``lax.scan`` over time both for training and
decode — this is the TPU-honest statement of its cost.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, dtype_of
from repro.models.layers import norms


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def _mlstm_dims(cfg):
    di = int(cfg.xlstm.proj_factor_mlstm * cfg.d_model)
    H = cfg.num_heads
    return di, H, di // H


def mlstm_init(key, cfg):
    pd = dtype_of(cfg.param_dtype)
    d = cfg.d_model
    di, H, hd = _mlstm_dims(cfg)
    dc = cfg.xlstm.conv_kernel
    ks = jax.random.split(key, 8)
    return {
        "up": dense_init(ks[0], (d, 2 * di), d, pd),
        "conv_w": dense_init(ks[1], (dc, di), dc, jnp.float32),
        "conv_b": jnp.zeros((di,), jnp.float32),
        # per-head block-diagonal projections (xLSTM paper §4: "block-
        # diagonal projection matrices with NH blocks") — a dense (di, di)
        # here would nearly triple total params (3.6B vs the cited 1.3B)
        "wq": dense_init(ks[2], (H, hd, hd), hd, pd),
        "wk": dense_init(ks[3], (H, hd, hd), hd, pd),
        "wv": dense_init(ks[4], (H, hd, hd), hd, pd),
        "w_gates": dense_init(ks[5], (di, 2 * H), di, jnp.float32),
        "b_gates": jnp.concatenate(
            [jnp.zeros((H,)), jnp.linspace(3.0, 6.0, H)]).astype(jnp.float32),
        "out_norm": {"scale": jnp.ones((di,), jnp.float32)},
        "down": dense_init(ks[6], (di, d), di, pd),
    }


def mlstm_axes(cfg):
    return {
        "up": ("embed", "inner"),
        "conv_w": ("conv_k", "inner"),
        "conv_b": ("inner",),
        "wq": ("heads", "head_dim", "head_dim_alt"),
        "wk": ("heads", "head_dim", "head_dim_alt"),
        "wv": ("heads", "head_dim", "head_dim_alt"),
        "w_gates": ("inner", "gates"),
        "b_gates": ("gates",),
        "out_norm": {"scale": ("inner",)},
        "down": ("inner", "embed"),
    }


def _mlstm_qkvg(params, x, cfg, conv_prev=None):
    """x: (B,S,d) -> q,k,v (B,S,H,hd), i,f (B,S,H) f32, z (B,S,di), conv_state."""
    from repro.models.layers.mamba import _causal_conv

    di, H, hd = _mlstm_dims(cfg)
    up = jnp.einsum("bsd,de->bse", x, params["up"].astype(x.dtype))
    xin, z = jnp.split(up, 2, axis=-1)
    xc, conv_state = _causal_conv(xin, params["conv_w"], params["conv_b"],
                                  prev=conv_prev)
    xc = jax.nn.silu(xc)
    B, S = x.shape[:2]
    xch = xc.reshape(B, S, H, hd)
    xinh = xin.reshape(B, S, H, hd)
    q = jnp.einsum("bshd,hde->bshe", xch, params["wq"].astype(x.dtype))
    k = jnp.einsum("bshd,hde->bshe", xch, params["wk"].astype(x.dtype))
    v = jnp.einsum("bshd,hde->bshe", xinh, params["wv"].astype(x.dtype))
    gates = jnp.einsum("bse,eg->bsg", xc.astype(jnp.float32),
                       params["w_gates"]) + params["b_gates"]
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)                   # (B,S,H)
    f_log = jax.nn.log_sigmoid(f_raw)
    q = q * (hd ** -0.5)
    return q, k, v, i_raw, f_log, z, conv_state


def mlstm_chunk(q, k, v, i_raw, f_log, state, chunk: int):
    """Chunkwise mLSTM core. q,k,v: (B,S,H,hd); gates (B,S,H) f32.

    state: (C (B,H,hd,hd), n (B,H,hd), m (B,H)). Returns (h (B,S,H,hd), state').
    """
    B, S, H, hd = q.shape
    L = min(chunk, S)
    while S % L:
        L //= 2
    nc = S // L

    def resh(t, trailing):
        return t.reshape((B, nc, L) + trailing).transpose((1, 0, 2) + tuple(
            range(3, 3 + len(trailing))))

    qs, ks, vs = (resh(t, (H, hd)) for t in (q, k, v))
    is_, fs = (resh(t, (H,)) for t in (i_raw, f_log))

    # sqrt-remat over the chunk scan: autodiff saves the (C,n,m) carry at
    # every chunk boundary, which at 4k/64-token chunks is ~268MB/layer of
    # f32 matrix memory. Segment the scan (outer saves ~sqrt(nc)
    # boundaries; inner recomputes within a segment on the backward pass)
    # -> ~8x less live state for one extra inner forward.
    seg = 1
    for cand in range(int(np.sqrt(nc)), 0, -1):
        if nc % cand == 0:
            seg = cand
            break

    if seg > 1:
        n_seg = nc // seg

        def seg_resh(t):
            return t.reshape((n_seg, seg) + t.shape[1:])

        xs_seg = tuple(seg_resh(t) for t in (qs, ks, vs, is_, fs))

        @jax.checkpoint
        def seg_step(carry, inp):
            new_carry, hs_seg = jax.lax.scan(_mlstm_chunk_step, carry, inp)
            return new_carry, hs_seg

        (C, n, m), hs = jax.lax.scan(seg_step, state, xs_seg)
        hs = hs.reshape((nc,) + hs.shape[2:])
    else:
        (C, n, m), hs = jax.lax.scan(_mlstm_chunk_step, state,
                                     (qs, ks, vs, is_, fs))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    return h, (C, n, m)


def _mlstm_chunk_step(carry, inp):
    C0, n0, m0 = carry
    qi, ki, vi, ii, fi = inp
    B, L, H, hd = qi.shape
    b = jnp.cumsum(fi, axis=1)
    a = ii - b
    a_max = jax.lax.cummax(a, axis=1)
    m_t = jnp.maximum(m0[:, None] + b, b + a_max)
    w0 = jnp.exp(m0[:, None] + b - m_t)
    h_inter = jnp.einsum("blhd,bhde->blhe", qi, C0) * w0[..., None]
    d_inter = jnp.einsum("blhd,bhd->blh", qi, n0) * w0
    Dlog = b[:, :, None] - b[:, None, :] + ii[:, None, :, :]
    mask = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
    Dlog = jnp.where(mask, Dlog - m_t[:, :, None], -jnp.inf)
    D = jnp.exp(Dlog)
    scores = jnp.einsum("blhd,bshd->blsh", qi, ki) * D
    h_intra = jnp.einsum("blsh,bshd->blhd", scores, vi)
    d_intra = scores.sum(axis=2)
    denom = jnp.maximum(jnp.abs(d_inter + d_intra), jnp.exp(-m_t))
    h = (h_inter + h_intra) / denom[..., None]
    F = b[:, -1]
    m_new = jnp.maximum(m0 + F, F + a_max[:, -1])
    wC0 = jnp.exp(m0 + F - m_new)
    wks = jnp.exp(F[:, None] - b + ii - m_new[:, None])
    C_new = C0 * wC0[..., None, None] + jnp.einsum(
        "blhd,blhe->bhde", ki * wks[..., None], vi)
    n_new = n0 * wC0[..., None] + (ki * wks[..., None]).sum(axis=1)
    return (C_new, n_new, m_new), h


def mlstm_step(q, k, v, i_raw, f_log, state):
    """Exact per-step recurrence (decode + oracle). q,k,v: (B,H,hd)."""
    C0, n0, m0 = state
    m_t = jnp.maximum(f_log + m0, i_raw)
    wf = jnp.exp(f_log + m0 - m_t)
    wi = jnp.exp(i_raw - m_t)
    C = C0 * wf[..., None, None] + wi[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n = n0 * wf[..., None] + wi[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), jnp.exp(-m_t))
    return num / den[..., None], (C, n, m_t)


def _mlstm_out(params, h, z, cfg, dtype):
    B, S = h.shape[:2]
    di, H, hd = _mlstm_dims(cfg)
    h = h.reshape(B, S, di)
    # per-head group norm
    h = h.reshape(B, S, H, hd)
    mu = h.mean(-1, keepdims=True)
    var = h.var(-1, keepdims=True)
    h = ((h - mu) * (var + 1e-6) ** -0.5).reshape(B, S, di)
    h = h * params["out_norm"]["scale"]
    y = h.astype(dtype) * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, params["down"].astype(dtype))


def mlstm_apply(params, x, cfg):
    q, k, v, i_raw, f_log, z, _ = _mlstm_qkvg(params, x, cfg)
    B = x.shape[0]
    di, H, hd = _mlstm_dims(cfg)
    state = (jnp.zeros((B, H, hd, hd), jnp.float32),
             jnp.zeros((B, H, hd), jnp.float32),
             jnp.zeros((B, H), jnp.float32))
    h, _ = mlstm_chunk(q.astype(jnp.float32), k.astype(jnp.float32),
                       v.astype(jnp.float32), i_raw, f_log, state,
                       cfg.xlstm.chunk_size)
    return _mlstm_out(params, h, z, cfg, x.dtype)


def mlstm_prefill(params, x, cfg, cache_dtype):
    """Full-sequence forward that also returns the decode cache: the
    conv tail and the chunkwise-carried (C, n, m) state that
    :func:`mlstm_apply` discards."""
    q, k, v, i_raw, f_log, z, conv_state = _mlstm_qkvg(params, x, cfg)
    B = x.shape[0]
    di, H, hd = _mlstm_dims(cfg)
    state = (jnp.zeros((B, H, hd, hd), jnp.float32),
             jnp.zeros((B, H, hd), jnp.float32),
             jnp.zeros((B, H), jnp.float32))
    h, (C, n, m) = mlstm_chunk(q.astype(jnp.float32), k.astype(jnp.float32),
                               v.astype(jnp.float32), i_raw, f_log, state,
                               cfg.xlstm.chunk_size)
    y = _mlstm_out(params, h, z, cfg, x.dtype)
    return y, {"conv": conv_state.astype(cache_dtype), "C": C, "n": n, "m": m}


def mlstm_init_cache(cfg, batch: int, dtype):
    di, H, hd = _mlstm_dims(cfg)
    dc = cfg.xlstm.conv_kernel
    return {
        "conv": jnp.zeros((batch, dc - 1, di), dtype),
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
    }


def mlstm_cache_axes():
    return {
        "conv": ("cache_batch", "conv_k", "inner"),
        "C": ("cache_batch", "heads", "head_dim", "head_dim_alt"),
        "n": ("cache_batch", "heads", "head_dim"),
        "m": ("cache_batch", "heads"),
    }


def mlstm_decode(params, x, cache, cfg):
    q, k, v, i_raw, f_log, z, conv_state = _mlstm_qkvg(
        params, x, cfg, conv_prev=cache["conv"])
    state = (cache["C"], cache["n"], cache["m"])
    h, (C, n, m) = mlstm_step(
        q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
        v[:, 0].astype(jnp.float32), i_raw[:, 0], f_log[:, 0], state)
    y = _mlstm_out(params, h[:, None], z, cfg, x.dtype)
    return y, {"conv": conv_state.astype(cache["conv"].dtype),
               "C": C, "n": n, "m": m}


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------


def _slstm_dims(cfg):
    H = cfg.num_heads
    return cfg.d_model, H, cfg.d_model // H


def slstm_init(key, cfg):
    pd = dtype_of(cfg.param_dtype)
    d, H, hd = _slstm_dims(cfg)
    df = int(cfg.xlstm.proj_factor_slstm * d)
    ks = jax.random.split(key, 7)
    return {
        "w_gates": dense_init(ks[0], (d, 4 * d), d, jnp.float32),
        "r_gates": dense_init(ks[1], (4, H, hd, hd), hd, jnp.float32),
        "b_gates": jnp.concatenate(
            [jnp.zeros((d,)), jnp.linspace(3.0, 6.0, d),
             jnp.zeros((2 * d,))]).astype(jnp.float32),
        "out_norm": {"scale": jnp.ones((d,), jnp.float32)},
        "ffn_up": dense_init(ks[2], (d, df), d, pd),
        "ffn_gate": dense_init(ks[3], (d, df), d, pd),
        "ffn_down": dense_init(ks[4], (df, d), df, pd),
    }


def slstm_axes(cfg):
    return {
        "w_gates": ("embed", "gates"),
        "r_gates": ("gate_kind", "heads", "head_dim", "head_dim_alt"),
        "b_gates": ("gates",),
        "out_norm": {"scale": ("embed",)},
        "ffn_up": ("embed", "ffn"),
        "ffn_gate": ("embed", "ffn"),
        "ffn_down": ("ffn", "embed"),
    }


def slstm_cell(gx, state, r_gates):
    """One sLSTM step. gx: (B, 4d) pre-activations from input path.

    state: (c, n, m, h) each (B, d). Block-diagonal recurrent mixing per head.
    """
    c0, n0, m0, h0 = state
    B, d = c0.shape
    _, H, hd, _ = r_gates.shape
    hh = h0.reshape(B, H, hd)
    rec = jnp.einsum("bhk,ghkl->gbhl", hh, r_gates).reshape(4, B, d)
    gi, gf, gz, go = jnp.split(gx, 4, axis=-1)
    gi = gi + rec[0]
    gf = gf + rec[1]
    gz = gz + rec[2]
    go = go + rec[3]
    f_log = jax.nn.log_sigmoid(gf)
    m_t = jnp.maximum(f_log + m0, gi)
    wf = jnp.exp(f_log + m0 - m_t)
    wi = jnp.exp(gi - m_t)
    c = wf * c0 + wi * jnp.tanh(gz)
    n = wf * n0 + wi
    h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6)
    return (c, n, m_t, h)


def slstm_scan(params, x32):
    """x32: (B,S,d) f32 -> h (B,S,d), final state."""
    B, S, d = x32.shape
    gx = jnp.einsum("bsd,de->bse", x32, params["w_gates"]) + params["b_gates"]
    state0 = tuple(jnp.zeros((B, d), jnp.float32) for _ in range(4))

    def step(state, g_t):
        new_state = slstm_cell(g_t, state, params["r_gates"])
        return new_state, new_state[3]

    state, hs = jax.lax.scan(step, state0, gx.swapaxes(0, 1))
    return hs.swapaxes(0, 1), state


def _slstm_out(params, h, x, cfg):
    d, H, hd = _slstm_dims(cfg)
    B, S = h.shape[:2]
    hh = h.reshape(B, S, H, hd)
    mu = hh.mean(-1, keepdims=True)
    var = hh.var(-1, keepdims=True)
    h = ((hh - mu) * (var + 1e-6) ** -0.5).reshape(B, S, d)
    h = (h * params["out_norm"]["scale"]).astype(x.dtype)
    up = jnp.einsum("bsd,df->bsf", h, params["ffn_up"].astype(x.dtype))
    gate = jnp.einsum("bsd,df->bsf", h, params["ffn_gate"].astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(gate) * up,
                      params["ffn_down"].astype(x.dtype))


def slstm_apply(params, x, cfg):
    h, _ = slstm_scan(params, x.astype(jnp.float32))
    return _slstm_out(params, h, x, cfg)


def slstm_prefill(params, x, cfg):
    """Full-sequence forward that also returns the decode cache (the
    final (c, n, m, h) carry of the exact recurrence)."""
    h, (c, n, m, hf) = slstm_scan(params, x.astype(jnp.float32))
    y = _slstm_out(params, h, x, cfg)
    return y, {"c": c, "n": n, "m": m, "h": hf}


def slstm_init_cache(cfg, batch: int, dtype):
    d = cfg.d_model
    return {k: jnp.zeros((batch, d), jnp.float32) for k in ("c", "n", "m", "h")}


def slstm_cache_axes():
    return {k: ("cache_batch", "embed") for k in ("c", "n", "m", "h")}


def slstm_decode(params, x, cache, cfg):
    x32 = x.astype(jnp.float32)
    gx = jnp.einsum("bsd,de->bse", x32, params["w_gates"]) + params["b_gates"]
    state = (cache["c"], cache["n"], cache["m"], cache["h"])
    c, n, m, h = slstm_cell(gx[:, 0], state, params["r_gates"])
    y = _slstm_out(params, h[:, None], x, cfg)
    return y, {"c": c, "n": n, "m": m, "h": h}
