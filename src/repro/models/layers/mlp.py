"""Dense FFN: gated (SwiGLU / GeGLU) or plain two-layer MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ACTIVATIONS, dense_init, dtype_of


def _gated(cfg) -> bool:
    return cfg.act in ("silu", "gelu")


def mlp_init(key, cfg):
    pd = dtype_of(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "up": dense_init(k1, (cfg.d_model, cfg.d_ff), cfg.d_model, pd),
        "down": dense_init(k2, (cfg.d_ff, cfg.d_model), cfg.d_ff, pd),
    }
    if _gated(cfg):
        p["gate"] = dense_init(k3, (cfg.d_model, cfg.d_ff), cfg.d_model, pd)
    return p


def mlp_axes(cfg):
    a = {"up": ("embed", "ffn"), "down": ("ffn", "embed")}
    if _gated(cfg):
        a["gate"] = ("embed", "ffn")
    return a


def mlp_apply(params, x, cfg):
    act = ACTIVATIONS[cfg.act]
    up = jnp.einsum("...d,df->...f", x, params["up"].astype(x.dtype))
    if _gated(cfg):
        gate = jnp.einsum("...d,df->...f", x, params["gate"].astype(x.dtype))
        h = act(gate) * up
    else:
        h = act(up)
    return jnp.einsum("...f,fd->...d", h, params["down"].astype(x.dtype))
