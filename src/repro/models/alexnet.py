"""AlexNet adapted for 32x32 inputs — the paper's own model (Appendix E).

Conv stack (5 convs + pools) + 2 FC layers + classifier. The split point
``s1..s5`` (Appendix H) selects how many conv layers stay on the client;
the paper's default (§5.1, "first 6 layers client / last 8 server")
corresponds to s2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.alexnet_cifar import CONV_CHANNELS, FC_WIDTHS, SPLIT_POINTS

# (kernel, stride, pool_after) per conv layer; pools are 2x2 max.
_CONV_SPECS = [(3, 1, True), (3, 1, True), (3, 1, False), (3, 1, False), (3, 1, True)]


def _conv_init(key, k, cin, cout):
    scale = 1.0 / jnp.sqrt(k * k * cin)
    return {
        "w": jax.random.truncated_normal(key, -2, 2, (k, k, cin, cout)) * scale,
        "b": jnp.zeros((cout,)),
    }


def _fc_init(key, din, dout):
    scale = 1.0 / jnp.sqrt(din)
    return {
        "w": jax.random.truncated_normal(key, -2, 2, (din, dout)) * scale,
        "b": jnp.zeros((dout,)),
    }


def _flat_dim(channels, in_hw: int = 32) -> int:
    hw = in_hw
    for _, _, pool in _CONV_SPECS:
        if pool:
            hw //= 2
    return hw * hw * channels[-1]


def init_params(key, num_classes: int = 10, in_channels: int = 3,
                width: float = 1.0):
    """width < 1 scales channels/FC widths for CPU-scale benchmark runs;
    the paper's exact architecture is width=1.0."""
    channels = [max(8, int(c * width)) for c in CONV_CHANNELS]
    fc_widths = [max(32, int(f * width)) for f in FC_WIDTHS]
    keys = jax.random.split(key, len(channels) + len(fc_widths) + 1)
    convs = []
    cin = in_channels
    for i, cout in enumerate(channels):
        convs.append(_conv_init(keys[i], _CONV_SPECS[i][0], cin, cout))
        cin = cout
    fcs = []
    din = _flat_dim(channels)
    for j, w in enumerate(fc_widths):
        fcs.append(_fc_init(keys[len(channels) + j], din, w))
        din = w
    head = _fc_init(keys[-1], din, num_classes)
    return {"convs": convs, "fcs": fcs, "head": head}


def _conv_apply(p, x, pool):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = jax.nn.relu(y + p["b"])
    if pool:
        y = jax.lax.reduce_window(
            y, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    return y


def client_forward(params, x, split: str = "s2"):
    """x: (B,32,32,3) -> activations after `split` conv layers."""
    n = SPLIT_POINTS[split]
    for i in range(n):
        x = _conv_apply(params["convs"][i], x, _CONV_SPECS[i][2])
    return x


def server_forward(params, acts, split: str = "s2"):
    """Remaining convs + FCs + classifier. Returns logits (B, classes)."""
    n = SPLIT_POINTS[split]
    x = acts
    for i in range(n, len(params["convs"])):
        x = _conv_apply(params["convs"][i], x, _CONV_SPECS[i][2])
    x = x.reshape(x.shape[0], -1)
    for fc in params["fcs"]:
        x = jax.nn.relu(x @ fc["w"] + fc["b"])
    return x @ params["head"]["w"] + params["head"]["b"]


def forward(params, x, split: str = "s2"):
    return server_forward(params, client_forward(params, x, split), split)


def features(params, x):
    """Representation before the classifier head (last FC activation) —
    used by FedDecorr's dimensional-collapse regularizer."""
    for i, p in enumerate(params["convs"]):
        x = _conv_apply(p, x, _CONV_SPECS[i][2])
    x = x.reshape(x.shape[0], -1)
    for fc in params["fcs"]:
        x = jax.nn.relu(x @ fc["w"] + fc["b"])
    return x


def split_params(params, split: str = "s2"):
    """Partition the pytree into (client_side, server_side)."""
    n = SPLIT_POINTS[split]
    client = {"convs": params["convs"][:n]}
    server = {"convs": params["convs"][n:], "fcs": params["fcs"],
              "head": params["head"]}
    return client, server


def merge_params(client, server):
    return {"convs": client["convs"] + server["convs"],
            "fcs": server["fcs"], "head": server["head"]}


def client_forward_from_split(client_params, x, split: str = "s2"):
    """Forward through the client half only (params already partitioned)."""
    for i, p in enumerate(client_params["convs"]):
        x = _conv_apply(p, x, _CONV_SPECS[i][2])
    return x


def server_forward_from_split(server_params, acts, split: str = "s2"):
    offset = SPLIT_POINTS[split]
    x = acts
    for i, p in enumerate(server_params["convs"]):
        x = _conv_apply(p, x, _CONV_SPECS[offset + i][2])
    x = x.reshape(x.shape[0], -1)
    for fc in server_params["fcs"]:
        x = jax.nn.relu(x @ fc["w"] + fc["b"])
    return x @ server_params["head"]["w"] + server_params["head"]["b"]
