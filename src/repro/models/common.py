"""Shared helpers for the functional model library.

Every layer module follows the same convention:

``init(key, cfg, ...) -> params``        pure, eval_shape-friendly
``apply(params, x, ...) -> y``           pure
``axes(cfg, ...) -> pytree``             same structure as params, leaves are
                                          tuples of *logical axis names*

Logical axis names are resolved to mesh axes by :mod:`repro.sharding`.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Axes = Tuple[str, ...]

DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "float16": jnp.float16,
    "int32": jnp.int32,
}


def dtype_of(name: str):
    return DTYPES[name]


def dense_init(key, shape, in_axis_size, dtype) -> jax.Array:
    """Truncated-normal fan-in initializer (LeCun-style)."""
    scale = 1.0 / np.sqrt(max(1, in_axis_size))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key, shape, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def zeros(shape, dtype) -> jax.Array:
    return jnp.zeros(shape, dtype)


def ones(shape, dtype) -> jax.Array:
    return jnp.ones(shape, dtype)


def split_keys(key, n: int):
    return jax.random.split(key, n)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": gelu,
    "gelu_mlp": gelu,
    "relu": jax.nn.relu,
}
