"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun.

  PYTHONPATH=src python -m repro.perf.report [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.perf.roofline import PEAK_FLOPS

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirname):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt(x, digits=3):
    if x is None:
        return "-"
    return f"{x:.{digits}e}"


def dryrun_table(recs, mesh):
    rows = [r for r in recs if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    out = [f"| arch | shape | status | compile s | peak GB/dev | "
           f"args GB/dev | HLO GFLOPs/dev | collectives (loop-aware) |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            reason = r.get("reason", r.get("error", ""))[:60]
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']} |  |  |"
                       f"  |  | {reason} |")
            continue
        mem = r["memory"]
        coll = r.get("collectives_scoped", r["collectives"])
        cdesc = ", ".join(
            f"{k}:{int(v['count'])}"
            for k, v in coll.items()
            if isinstance(v, dict) and v.get("count"))
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']} | "
            f"{(mem['peak_bytes'] or 0)/1e9:.2f} | "
            f"{(mem['argument_bytes'] or 0)/1e9:.2f} | "
            f"{r['flops_per_device']/1e9:.1f} | {cdesc} |")
    return "\n".join(out)


def roofline_table(recs, mesh="16x16"):
    rows = [r for r in recs if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    out = ["| arch | shape | t_compute (HLO) | t_compute (model) | "
           "t_mem (HLO) | t_mem (min) | t_coll (loop-aware) | bottleneck | "
           "MODEL/HLO flops | one-line fix |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | skip "
                       f"| | | | | | | {r.get('reason','')[:50]} |")
            continue
        t = r.get("roofline_scoped", r["roofline"])
        tca = r["model_flops_per_device"] / PEAK_FLOPS
        cand = {"compute": max(t["t_compute_s"], tca),
                "memory": t["t_memory_min_s"],
                "collective": t["t_collective_s"]}
        bott = max(cand, key=cand.get)
        ufr = r.get("useful_flops_ratio")
        fix = {
            "collective": "shrink dominant collective (see §Perf)",
            "memory": "fuse/reuse HBM traffic; bigger blocks",
            "compute": "at roofline: raise MXU util (layout/fusion)",
        }[bott]
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt(t['t_compute_s'])} | "
            f"{fmt(tca)} | {fmt(t['t_memory_s'])} | "
            f"{fmt(t['t_memory_min_s'])} | {fmt(t['t_collective_s'])} | "
            f"{bott} | {'' if ufr is None else f'{ufr:.2f}'} | {fix} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline"])
    args = ap.parse_args()
    recs = load(args.dir)
    if args.section in ("all", "dryrun"):
        print("### Dry-run — single pod 16x16 (256 chips)\n")
        print(dryrun_table(recs, "16x16"))
        print("\n### Dry-run — multi-pod 2x16x16 (512 chips)\n")
        print(dryrun_table(recs, "2x16x16"))
        print()
    if args.section in ("all", "roofline"):
        print("### Roofline terms (single pod, per device, "
              "seconds per step)\n")
        print(roofline_table(recs))


if __name__ == "__main__":
    main()
