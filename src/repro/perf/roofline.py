"""Roofline-term derivation from compiled dry-run artifacts.

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16 per chip, 819 GB/s
HBM, ~50 GB/s/link ICI.

Collective bytes are parsed from the post-SPMD HLO (``compiled.as_text()``
is the per-device partitioned module), with the accounting conventions:

  all-gather          result size        (bytes landing per device)
  reduce-scatter      first-operand size (bytes leaving per device)
  all-reduce          2 x result size    (ring RS + AG)
  all-to-all          result size
  collective-permute  result size

``cost_analysis()`` FLOPs/bytes on a partitioned module are per-device;
terms below are therefore per-device seconds (= step seconds under
perfect overlap-free execution), which is what the §Roofline table
reports.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

_TYPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# the result type may be a tuple containing /*index=N*/ comments — match
# lazily up to the op name rather than excluding '='
_LINE_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[.*?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, dict]:
    """Per-op-kind {count, bytes} from post-SPMD HLO text."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        result_type, op, _ = m.groups()
        if op == "reduce-scatter":
            # charge the input (first operand inside the parens)
            paren = line[m.end():]
            om = _TYPE_RE.search(paren)
            size = _type_bytes(om.group(0)) if om else _type_bytes(result_type)
        elif op == "all-reduce":
            size = 2 * _type_bytes(result_type)
        else:
            size = _type_bytes(result_type)
        out[op]["count"] += 1
        out[op]["bytes"] += size
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


_WHILE_BODY_RE = re.compile(r"\bbody=%?([\w.\-]+)")
_TRIP_RE = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")
_REF_RE = re.compile(r"(?:calls|to_apply|condition|branch_computations)="
                     r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def parse_collectives_scoped(hlo_text: str) -> Dict[str, dict]:
    """Loop-aware collective accounting.

    XLA prints each ``while`` body once; a layer-scan over G groups
    therefore under-counts its collectives by G in :func:`parse_collectives`.
    This variant splits the module into computations, walks the call graph
    from ENTRY, and multiplies each ``while`` body's collective bytes by
    the loop's ``known_trip_count`` from its backend_config (falling back
    to the condition's s32 constant, then 1).
    """
    # --- split into computations (headers are unindented "%name (...) {") ---
    comps: Dict[str, list] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        if line.rstrip().endswith("{") and (
                line.startswith("%") or line.startswith("ENTRY")):
            name = line.split()[1] if line.startswith("ENTRY") \
                else line.split()[0]
            cur = name.lstrip("%")
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
        elif cur is not None:
            comps[cur].append(line)

    def comp_const_max(name: str) -> int:
        best = 0
        for line in comps.get(name, ()):
            for c in _CONST_RE.findall(line):
                best = max(best, int(c))
        return best

    from functools import lru_cache

    @lru_cache(maxsize=None)
    def comp_cost(name: str):
        """(bytes{op}, count{op}) for ONE execution of computation."""
        out = {k: 0.0 for k in _COLL_OPS}
        cnt = {k: 0.0 for k in _COLL_OPS}
        for line in comps.get(name, ()):
            m = _LINE_RE.search(line)
            if m:
                result_type, op, _ = m.groups()
                if op == "reduce-scatter":
                    paren = line[m.end():]
                    om = _TYPE_RE.search(paren)
                    size = _type_bytes(om.group(0)) if om \
                        else _type_bytes(result_type)
                elif op == "all-reduce":
                    size = 2 * _type_bytes(result_type)
                else:
                    size = _type_bytes(result_type)
                out[op] += size
                cnt[op] += 1
            if " while(" in line:
                bm = _WHILE_BODY_RE.search(line)
                if bm and bm.group(1) in comps and bm.group(1) != name:
                    tm = _TRIP_RE.search(line)
                    trip = int(tm.group(1)) if tm else 0
                    if not trip:
                        cm = re.search(r"condition=%?([\w.\-]+)", line)
                        trip = comp_const_max(cm.group(1)) if cm else 0
                    trip = max(1, trip)
                    sub, sub_c = comp_cost(bm.group(1))
                    for k in _COLL_OPS:
                        out[k] += trip * sub[k]
                        cnt[k] += trip * sub_c[k]
                continue
            for rm in _REF_RE.finditer(line):
                for ref in rm.group(1).split(","):
                    ref = ref.strip().lstrip("%")
                    if ref in comps and ref != name:
                        sub, sub_c = comp_cost(ref)
                        for k in _COLL_OPS:
                            out[k] += sub[k]
                            cnt[k] += sub_c[k]
        return out, cnt

    if entry is None:
        flat = parse_collectives(hlo_text)
        flat["loop_aware"] = False
        return flat
    cost, counts = comp_cost(entry)
    res = {k: {"count": counts[k], "bytes": cost[k]} for k in _COLL_OPS}
    res["total_bytes"] = sum(cost.values())
    res["loop_aware"] = True
    return res


def collective_breakdown(hlo_text: str, top: int = 15):
    """Loop-aware per-op collective ranking: [(bytes, op, shape, mult,
    op_name)] sorted by total bytes — the §Perf profiling view."""
    comps: Dict[str, list] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        if line.rstrip().endswith("{") and (
                line.startswith("%") or line.startswith("ENTRY")):
            name = line.split()[1] if line.startswith("ENTRY") \
                else line.split()[0]
            cur = name.lstrip("%")
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
        elif cur is not None:
            comps[cur].append(line)

    items = []

    def walk(name, mult, seen=()):
        if name in seen:
            return
        for line in comps.get(name, ()):
            m = _LINE_RE.search(line)
            if m:
                rt, op, _ = m.groups()
                size = _type_bytes(rt) * (2 if op == "all-reduce" else 1)
                md = re.search(r'op_name="([^"]+)"', line)
                items.append((size * mult, op, rt[:70], mult,
                              (md.group(1) if md else "?")[-90:]))
            if " while(" in line:
                bm = _WHILE_BODY_RE.search(line)
                tm = _TRIP_RE.search(line)
                if bm and bm.group(1) in comps:
                    walk(bm.group(1),
                         mult * (int(tm.group(1)) if tm else 1),
                         seen + (name,))
                continue
            for rm in _REF_RE.finditer(line):
                for ref in rm.group(1).split(","):
                    ref = ref.strip().lstrip("%")
                    if ref in comps and ref != name:
                        walk(ref, mult, seen + (name,))

    if entry:
        walk(entry, 1)
    items.sort(key=lambda x: -x[0])
    return items[:top], sum(i[0] for i in items)


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   min_bytes: float = 0.0) -> dict:
    """min_bytes: liveness-aware lower bound on real HBM traffic
    (arguments + outputs + peak temp) — the CPU backend's unfused
    ``bytes accessed`` over-counts every intermediate, so the honest
    memory term lies in [t_memory_min, t_memory]."""
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm_bytes / HBM_BW
    t_coll = coll_bytes / ICI_BW
    terms = {"t_compute_s": t_compute, "t_memory_s": t_memory,
             "t_collective_s": t_coll,
             "t_memory_min_s": min_bytes / HBM_BW}
    # bottleneck classification uses the conservative (lower-bound) memory
    cand = {"compute": t_compute, "memory": terms["t_memory_min_s"],
            "collective": t_coll}
    terms["bottleneck"] = max(cand, key=cand.get)
    cand_hlo = {"compute": t_compute, "memory": t_memory,
                "collective": t_coll}
    terms["bottleneck_hlo_bytes"] = max(cand_hlo, key=cand_hlo.get)
    return terms


def model_flops(param_count_active: float, tokens: float,
                mode: str) -> float:
    """6·N·D for training, 2·N·D for inference forward."""
    mult = 6.0 if mode == "train" else 2.0
    return mult * param_count_active * tokens


def count_params(shapes_tree, axes_tree, top_k: int = 0,
                 num_experts: int = 0) -> dict:
    """Total and active param counts; expert leaves scaled by top_k/E."""
    import jax

    from repro.sharding.logical import is_axes

    shapes = jax.tree.leaves(shapes_tree)
    axes = jax.tree.leaves(axes_tree, is_leaf=is_axes)
    total = 0
    active = 0.0
    for s, a in zip(shapes, axes):
        n = 1
        for d in s.shape:
            n *= d
        # SCALA-stacked client params: one client's copy is the model
        if a and a[0] == "client":
            n //= s.shape[0]
        total += n
        if "experts" in a and num_experts:
            active += n * (top_k / num_experts)
        else:
            active += n
    return {"total": total, "active": active}
