from repro.perf.roofline import (  # noqa: F401
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    count_params,
    model_flops,
    parse_collectives,
    roofline_terms,
)
