"""Pytree checkpointing: host-gathered ``.npz`` + a JSON treedef.

Sharded arrays are gathered to host before save (fine at the scales this
container trains; a production deployment would swap in tensorstore /
orbax-style per-shard IO behind the same ``save``/``restore`` API).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(directory: str, step: int, tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    arrays = _flatten_with_paths(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    np.savez(tmp, **arrays)
    os.replace(tmp, path)
    treedef = jax.tree_util.tree_structure(tree)
    with open(os.path.join(directory, "treedef.json"), "w") as f:
        json.dump({"treedef": str(treedef), "step": step}, f)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.match(r"ckpt_(\d+)\.npz$", name)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(directory: str, template: Any, step: Optional[int] = None) -> Any:
    """Restore into the structure of ``template`` (shapes must match)."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with np.load(path) as data:
        arrays = dict(data)
    keys = list(_flatten_with_paths(template))
    leaves, treedef = jax.tree_util.tree_flatten(template)
    assert len(keys) == len(leaves)
    new_leaves = []
    for key, leaf in zip(keys, leaves):
        a = arrays[key]
        assert a.shape == leaf.shape, (key, a.shape, leaf.shape)
        new_leaves.append(a.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
