"""Pytree checkpointing: host-gathered ``.npz`` + a JSON treedef.

Sharded arrays are gathered to host before save (fine at the scales this
container trains; a production deployment would swap in tensorstore /
orbax-style per-shard IO behind the same ``save``/``restore`` API).

Crash safety: every file is written to a temp path in the same
directory, fsync'd, then atomically renamed over the target
(``os.replace``), so a checkpoint is either fully present or absent —
never truncated. ``restore`` treats an unreadable latest checkpoint
(killed mid-rename on filesystems without atomic replace, bit rot) as
absent and falls back to the next-older step unless ``step`` was pinned
explicitly.
"""
from __future__ import annotations

import json
import os
import re
import zipfile
from typing import Any, Optional

import jax
import numpy as np

# Failure modes of np.load on a torn/corrupt .npz: truncated zip central
# directory (BadZipFile), short reads / missing members (OSError,
# KeyError), and mangled array headers (ValueError).
CORRUPT_ERRORS = (zipfile.BadZipFile, OSError, KeyError, ValueError,
                  EOFError)
_CORRUPT_ERRORS = CORRUPT_ERRORS


def _flatten_with_paths(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _atomic_replace(tmp: str, path: str) -> None:
    """fsync the temp file, rename over the target, fsync the directory."""
    with open(tmp, "rb") as f:
        os.fsync(f.fileno())
    os.replace(tmp, path)
    dir_fd = os.open(os.path.dirname(path) or ".", os.O_RDONLY)
    try:
        os.fsync(dir_fd)
    finally:
        os.close(dir_fd)


def write_json_atomic(path: str, payload: Any) -> None:
    """Serialize ``payload`` to ``path`` via write-temp-fsync-rename."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def save(directory: str, step: int, tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    arrays = _flatten_with_paths(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    np.savez(tmp, **arrays)
    _atomic_replace(tmp, path)
    treedef = jax.tree_util.tree_structure(tree)
    write_json_atomic(os.path.join(directory, "treedef.json"),
                      {"treedef": str(treedef), "step": step})
    return path


def all_steps(directory: str) -> list:
    """Checkpoint steps present in ``directory``, ascending."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        m = re.match(r"ckpt_(\d+)\.npz$", name)
        if m:
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def _load_arrays(path: str) -> dict:
    with np.load(path) as data:
        return dict(data)


def restore(directory: str, template: Any, step: Optional[int] = None,
            key_prefix: str = "") -> Any:
    """Restore into the structure of ``template`` (shapes must match).

    With ``step=None`` the newest readable checkpoint wins: a corrupt
    latest file (torn write from a crash) is skipped with a warning and
    the next-older step is tried. An explicitly pinned ``step`` is never
    substituted — corruption there raises.

    ``key_prefix`` restores a *subtree* of a larger saved pytree: each
    template leaf key is looked up as ``key_prefix + key`` (e.g.
    ``".inner/.params/"`` pulls just the params out of a full
    ``Trainer.save`` ProgramState checkpoint).
    """
    candidates = [step] if step is not None else all_steps(directory)[::-1]
    if not candidates:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    arrays = None
    errors = []
    for s in candidates:
        path = os.path.join(directory, f"ckpt_{s:08d}.npz")
        try:
            arrays = _load_arrays(path)
            break
        except _CORRUPT_ERRORS as e:
            if step is not None:
                raise
            errors.append((s, e))
            import warnings
            warnings.warn(f"checkpoint step {s} unreadable "
                          f"({type(e).__name__}: {e}); falling back to the "
                          f"previous step", stacklevel=2)
    if arrays is None:
        raise FileNotFoundError(
            f"no readable checkpoint in {directory}; "
            f"tried steps {[s for s, _ in errors]}")
    keys = list(_flatten_with_paths(template))
    leaves, treedef = jax.tree_util.tree_flatten(template)
    assert len(keys) == len(leaves)
    new_leaves = []
    for key, leaf in zip(keys, leaves):
        a = arrays[key_prefix + key]
        assert a.shape == leaf.shape, (key, a.shape, leaf.shape)
        new_leaves.append(a.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
