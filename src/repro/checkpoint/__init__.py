from repro.checkpoint.checkpoint import (  # noqa: F401
    CORRUPT_ERRORS,
    all_steps,
    latest_step,
    restore,
    save,
    write_json_atomic,
)
