"""Minimal functional optimizers (optax-style) — SGD is the paper default
(§5.1: plain SGD, η=0.01), which also keeps the 398B dry-run free of
optimizer-state memory. Momentum/AdamW provided for the framework layer.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    # update(grads, state, params, lr) -> (new_params, new_state)


def sgd(weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        def step(p, g):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * g).astype(p.dtype)
        return jax.tree.map(step, params, grads), state

    return Optimizer(init, update)


def momentum(beta: float = 0.9, weight_decay: float = 0.0,
             nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params, lr):
        def step(p, g, m):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            m_new = beta * m + g
            d = g + beta * m_new if nesterov else m_new
            return (p.astype(jnp.float32) - lr * d).astype(p.dtype), m_new
        flat = jax.tree.map(step, params, grads, state)
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_state = jax.tree.map(lambda t: t[1], flat,
                                 is_leaf=lambda t: isinstance(t, tuple))
        return new_params, new_state

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"mu": jax.tree.map(z, params),
                "nu": jax.tree.map(z, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def moments(g, mu, nu):
            g = g.astype(jnp.float32)
            return b1 * mu + (1 - b1) * g, b2 * nu + (1 - b2) * g * g

        flat = jax.tree.map(moments, grads, state["mu"], state["nu"])
        mu = jax.tree.map(lambda t: t[0], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
        nu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda t: isinstance(t, tuple))

        def step(p, m, v):
            upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

        new_params = jax.tree.map(step, params, mu, nu)
        return new_params, {"mu": mu, "nu": nu, "count": count}

    return Optimizer(init, update)


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(weight_decay=kw.get("weight_decay", 0.0))
    if name == "momentum":
        return momentum(beta=kw.get("momentum", 0.9),
                        weight_decay=kw.get("weight_decay", 0.0))
    if name == "adamw":
        return adamw(weight_decay=kw.get("weight_decay", 0.0))
    raise ValueError(f"unknown optimizer {name!r}")
