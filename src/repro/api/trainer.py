"""``api.Trainer`` — the thin host-side driver every entry point shares.

The trainer owns the *host* side of an experiment: synthesizing the
dataset from :class:`repro.api.DataSpec`, assembling per-round batches
(eq. 3 sizing via :mod:`repro.data.loader`), threading the
:class:`repro.api.build.ProgramState` through the built
:class:`repro.api.build.RoundProgram`, and evaluation. Everything
jit-compiled lives in the program; everything numpy lives here.

    spec = api.ExperimentSpec(...)           # declarative, serializable
    trainer = api.Trainer(spec)              # build(spec) + data + state
    history = trainer.run()                  # spec.rounds rounds/events
    print(trainer.evaluate())

Host-side RNG choreography is kept exactly as the pre-API drivers'
(``numpy.default_rng(seed + 7)`` for image data / client sampling as in
``benchmarks/common.run_experiment``; ``default_rng(seed)`` for the LM
driver as in ``launch/train.py``), so existing results reproduce.

Batch-budget parity across modes follows each driver's convention too:
for ``image_synthetic`` the in-program sync modes (masked/sparse) split
``server_batch / participation`` over all K slots so the participating
subset sees ~``server_batch`` samples (eq. 3 parity with subset mode);
for ``lm_synthetic`` the budget is never rescaled (scale
``server_batch`` by 1/FRAC yourself for parity — the historical
``train.py`` semantics).
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.api.build import RoundProgram, build
from repro.api.specs import ExperimentSpec


# ---------------------------------------------------------------------------
# dataset synthesis (host side)
# ---------------------------------------------------------------------------


def build_lm_data(cfg, num_clients: int, docs_per_client: int, seq: int,
                  seed: int) -> List[np.ndarray]:
    """Domain-skewed synthetic token docs: client k prefers domain k % D."""
    from repro.data.synthetic import token_stream

    docs, domains = token_stream(
        n_docs=num_clients * docs_per_client, doc_len=seq + 1,
        vocab=cfg.vocab_size, num_domains=max(2, num_clients // 2), seed=seed)
    rng = np.random.default_rng(seed + 1)
    by_client = []
    D = domains.max() + 1
    for k in range(num_clients):
        pref = k % D
        p = np.where(domains == pref, 8.0, 1.0)
        p = p / p.sum()
        idx = rng.choice(len(docs), size=docs_per_client, replace=False, p=p)
        by_client.append(docs[idx])
    return by_client


def build_image_data(spec: ExperimentSpec):
    """CIFAR-shaped gaussian images, label-skew partitioned per DataSpec.

    Returns (FederatedData, (x_test, y_test))."""
    from repro.data.loader import FederatedData
    from repro.data.partition import partition
    from repro.data.synthetic import gaussian_images

    d = spec.data
    x, y = gaussian_images(d.n_train + d.n_test, num_classes=d.num_classes,
                           seed=spec.seed)
    x_train, y_train = x[:d.n_train], y[:d.n_train]
    parts = partition(y_train, spec.scala.num_clients, alpha=d.alpha,
                      beta=d.beta, num_classes=d.num_classes, seed=spec.seed)
    return (FederatedData.from_partition(x_train, y_train, parts),
            (x[d.n_train:], y[d.n_train:]))


# ---------------------------------------------------------------------------
# the trainer
# ---------------------------------------------------------------------------


class Trainer:
    """Run a built experiment round by round.

    ``program`` defaults to :func:`repro.api.build` on the spec;
    pass one explicitly to reuse a compiled program across trainers
    (sweeps over data seeds) or to inject ``mesh``/``batch_specs`` for
    the ``lace_dp`` backend.
    """

    def __init__(self, spec: ExperimentSpec, *,
                 program: Optional[RoundProgram] = None,
                 mesh=None, batch_specs=None):
        self.spec = spec.validate()
        self.program = program if program is not None else build(
            spec, mesh=mesh, batch_specs=batch_specs)
        self.state = self.program.init()
        self.history: List[Dict[str, float]] = []
        self.round = 0
        self._rpc = self.program.metadata.get("rounds_per_call", 1)
        self._cfg = spec.model_config()
        if spec.data.kind == "image_synthetic":
            self._data, self._test = build_image_data(spec)
            self._rng = np.random.default_rng(spec.seed + 7)
        else:
            self._data = build_lm_data(self._cfg, spec.scala.num_clients,
                                       spec.data.docs_per_client,
                                       spec.data.seq, spec.seed)
            self._test = None
            self._rng = np.random.default_rng(spec.seed)

    # ------------------------------------------------------------------

    def _next_round_batches(self):
        from repro.data.loader import (lm_round_batches, round_batches,
                                       sample_clients)

        spec, sc = self.spec, self.spec.scala
        K = sc.num_clients
        if spec.execution.in_program:
            selected = np.arange(K)        # all slots; subset in-program
        else:
            selected = sample_clients(K, sc.clients_per_round, self._rng)
        if spec.data.kind == "image_synthetic":
            budget = (round(sc.server_batch / sc.participation)
                      if spec.execution.mode in ("masked", "sparse")
                      else sc.server_batch)
            rb = round_batches(self._data, selected, budget, sc.local_iters,
                               self._rng)
        else:
            rb = lm_round_batches(self._data, selected, sc.server_batch,
                                  sc.local_iters, self._rng)
        sizes = jnp.asarray(rb.pop("sizes"))
        return {k: jnp.asarray(v) for k, v in rb.items()}, sizes

    # ------------------------------------------------------------------

    def step(self, rounds: Optional[int] = None):
        """One program dispatch: assemble batches, advance state.

        With ``execution.rounds_per_call = 1`` (the default) this is one
        round (or async event). With ``R > 1`` one dispatch executes
        ``min(R, rounds)`` whole rounds fused into a single XLA program:
        the per-round batches are assembled host-side in exactly the
        order the unfused path would draw them (same RNG stream), stacked
        along a leading round axis, and the stacked metrics are pulled to
        host once. One history entry is appended per *round* either way.

        Returns the last executed round's scalar metrics as floats."""
        n = self._rpc if rounds is None else min(rounds, self._rpc)
        if self._rpc == 1:
            batches, sizes = self._next_round_batches()
            self.state, metrics = self.program.step(self.state, batches,
                                                    sizes)
            scalars = {k: float(v) for k, v in metrics.items()
                       if jnp.ndim(v) == 0}
            self.history.append(scalars)
            self.round += 1
            return scalars
        per_round = [self._next_round_batches() for _ in range(n)]
        batches = {k: jnp.stack([b[k] for b, _ in per_round])
                   for k in per_round[0][0]}
        sizes = jnp.stack([s for _, s in per_round])
        self.state, metrics = self.program.step(self.state, batches, sizes)
        # per-round scalars carry the leading (n,) round axis now; ONE
        # device-to-host pull per metric for the whole chunk
        stacked = {k: np.asarray(v) for k, v in metrics.items()
                   if jnp.ndim(v) == 1}
        scalars = None
        for r in range(n):
            scalars = {k: float(v[r]) for k, v in stacked.items()}
            self.history.append(scalars)
        self.round += n
        return scalars

    def run(self, rounds: Optional[int] = None, *,
            on_round: Optional[Callable[[int, Dict[str, float], float],
                                        Any]] = None):
        """Run ``rounds`` rounds (default ``spec.rounds``); returns the
        full metric history (one dict of floats per round so far).
        ``on_round(index, metrics, seconds)`` is called for every round;
        under ``rounds_per_call`` fusion it fires once per round after
        each chunk, with ``seconds`` amortized over the chunk (fuse less
        if you need a true per-round host callback). A trailing
        remainder chunk (``rounds % rounds_per_call``) just recompiles
        the step once for the smaller leading axis."""
        n = self.spec.rounds if rounds is None else rounds
        done = 0
        while done < n:
            k = min(self._rpc, n - done)
            t0 = time.time()
            self.step(k)
            dt = time.time() - t0
            done += k
            if on_round is not None:
                for j in range(k):
                    on_round(self.round - k + j,
                             self.history[len(self.history) - k + j],
                             dt / k)
        return self.history

    # ------------------------------------------------------------------
    # crash recovery
    # ------------------------------------------------------------------

    def save(self, directory: str) -> str:
        """Checkpoint the FULL run: program state + host driver state.

        The ``.npz`` carries the whole :class:`ProgramState` pytree —
        params, optimizer moments, and the fed state (sync dict or
        :class:`AsyncFedState` including ring/delta snapshots, finish
        times, versions, retries, fault keys, guard medians). A
        ``meta_{round}.json`` sidecar carries the host side: round
        counter, metric history, and the numpy bit-generator state that
        drives batch assembly. Both writes are atomic
        (write-temp-fsync-rename), so a crash mid-save never corrupts an
        existing checkpoint. :meth:`resume` from the pair is bit-identical
        to never having stopped."""
        if self.program.metadata.get("host_paged"):
            raise ValueError(
                "save/resume with opt_paging='host' is unsupported: the "
                "paged optimizer moments live in the host pager, outside "
                "ProgramState; keep optimizer state on device to "
                "checkpoint")
        from repro import checkpoint as C

        path = C.save(directory, self.round, self.state)
        C.write_json_atomic(
            os.path.join(directory, f"meta_{self.round:08d}.json"),
            {"round": self.round, "history": self.history,
             "rng_state": self._rng.bit_generator.state})
        return path

    def resume(self, directory: str, step: Optional[int] = None) -> int:
        """Restore the newest complete checkpoint; returns its round.

        A checkpoint counts only when both its ``.npz`` and its
        ``meta_{round}.json`` sidecar are readable — a torn pair from a
        crash mid-save is skipped and the next-older step is tried
        (unless ``step`` pins one explicitly, which raises instead).
        After resume, :meth:`run`/:meth:`step` continue the interrupted
        RNG stream and program state exactly."""
        from repro import checkpoint as C

        candidates = [step] if step is not None else C.all_steps(directory)[::-1]
        if not candidates:
            raise FileNotFoundError(f"no checkpoints in {directory}")
        for s in candidates:
            meta_path = os.path.join(directory, f"meta_{s:08d}.json")
            try:
                with open(meta_path) as f:
                    meta = json.load(f)
                state = C.restore(directory, self.state, step=s)
            except C.CORRUPT_ERRORS + (json.JSONDecodeError,
                                       AssertionError):
                if step is not None:
                    raise
                continue
            break
        else:
            raise FileNotFoundError(
                f"no complete (npz + meta) checkpoint in {directory}")
        self.state = state
        self.round = int(meta["round"])
        self.history = list(meta["history"])
        self._rng.bit_generator.state = meta["rng_state"]
        return self.round

    # ------------------------------------------------------------------

    def evaluate(self) -> Dict[str, float]:
        """Evaluate the current global model.

        image_synthetic — held-out accuracy + class-balanced accuracy
        (the paper-table metrics); lm_synthetic — next-token loss and
        accuracy on a held-out document stream (seeded off the
        experiment seed)."""
        from repro.core.losses import (accuracy, per_class_accuracy,
                                       softmax_xent)

        spec = self.spec
        if spec.data.kind == "image_synthetic":
            x_test, y_test = self._test
            logits = self.program.predict(self.state,
                                          {"x": jnp.asarray(x_test)})
            y = jnp.asarray(y_test)
            return {"acc": float(accuracy(logits, y)),
                    "balanced_acc": float(per_class_accuracy(
                        logits, y, spec.data.num_classes))}
        from repro.data.synthetic import token_stream

        docs, _ = token_stream(
            n_docs=32, doc_len=spec.data.seq + 1,
            vocab=self._cfg.vocab_size,
            num_domains=max(2, spec.scala.num_clients // 2),
            seed=spec.seed + 9973)
        toks = jnp.asarray(docs[:, :-1])
        labels = jnp.asarray(docs[:, 1:])
        logits = self.program.predict(self.state, {"tokens": toks})
        return {"eval_loss": float(softmax_xent(logits, labels)),
                "eval_accuracy": float(accuracy(logits, labels))}
