"""The declarative experiment spec tree.

One experiment = one :class:`ExperimentSpec` — a frozen, typed,
JSON-serializable description of *everything* the run needs: the model
(by registry name, composing the existing :class:`repro.configs.base.
ModelConfig`), the SCALA protocol (:class:`repro.configs.base.
ScalaConfig`), the local optimizer (:class:`OptimSpec`), the federation
layer (:class:`FedSpec` — aggregator + participation + opt-state
policy), the execution mode (:class:`ExecutionSpec` — masked / sparse /
async / the legacy host-side ``subset`` sampling, plus the async and
server-FedOpt knobs), and the dataset (:class:`DataSpec`).

Every sub-spec parses from the compact strings the CLI already uses
(``"dirichlet:0.3:0.25"``, ``"lognormal:1:1"``, ``"fedadam:0.01"``) and
the whole tree round-trips losslessly through :meth:`ExperimentSpec.
to_dict` / :meth:`ExperimentSpec.from_dict` JSON — the unit a sweep
manifest stores and ``launch/train.py --config/--dump-config`` consume.

Validation happens at *spec* time (:meth:`ExperimentSpec.validate`,
called by :func:`repro.api.build`): incoherent combinations — e.g. a
stateful aggregator without stable client identities, async execution
with a participation scheduler, delta snapshots with a stateful local
optimizer, a non-shard-decomposable aggregator on the sharded
``lace_dp`` sparse/async paths — are rejected with a targeted error
instead of failing deep inside jit.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

from repro.configs import ScalaConfig, get_config
from repro.configs.base import ModelConfig

#: the four round programs an experiment can execute as.
#: "subset" is the legacy host-side mode: each round stacks only the
#: C = r*K sampled clients (no in-program scheduler); the other three
#: keep all K slots static — see repro.core.engine / repro.fed.runtime.
EXECUTION_MODES = ("subset", "masked", "sparse", "async")

#: local-optimizer registry names plus the FedOpt aliases the server
#: side uses (``fedavgm`` -> momentum, ``fedadam`` -> adamw).
OPTIMIZERS = ("sgd", "momentum", "adamw")
OPTIMIZER_ALIASES = {"fedavgm": "momentum", "fedadam": "adamw"}


def _parse_err(kind: str, spec: str, usage: str) -> ValueError:
    return ValueError(f"bad {kind} spec {spec!r}; usage: {usage}")


# ---------------------------------------------------------------------------
# OptimSpec
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class OptimSpec:
    """A :mod:`repro.optim` optimizer + lr schedule, declaratively.

    Compact form: ``"sgd[:LR]"`` | ``"momentum[:LR[:BETA]]"`` |
    ``"adamw[:LR[:WD]]"`` (plus the FedOpt aliases ``fedavgm`` /
    ``fedadam``, which canonicalize to momentum / adamw). The schedule
    fields are not part of the compact form — set them on the dataclass
    (``schedule="cosine"``, ``warmup=N``).

    ``lr=None`` (the default) defers to the experiment's
    ``scala.lr`` — there is exactly ONE base learning rate per spec
    unless you explicitly override it here.
    """

    name: str = "sgd"
    lr: Optional[float] = None
    momentum: float = 0.9
    weight_decay: float = 0.0
    schedule: str = "constant"         # constant | cosine
    warmup: int = 0                    # warmup steps for schedule="cosine"

    def __post_init__(self):
        if self.name not in OPTIMIZERS:
            raise ValueError(f"unknown optimizer {self.name!r}; expected "
                             f"{OPTIMIZERS} (aliases: "
                             f"{sorted(OPTIMIZER_ALIASES)})")
        if self.schedule not in ("constant", "cosine"):
            raise ValueError(f"unknown schedule {self.schedule!r}; "
                             "expected ('constant', 'cosine')")

    @classmethod
    def parse(cls, spec: str, *, default_lr: Optional[float] = None,
              **overrides) -> "OptimSpec":
        usage = "NAME[:LR[:ARG]] with NAME in " + repr(
            OPTIMIZERS + tuple(sorted(OPTIMIZER_ALIASES)))
        parts = spec.split(":")
        name = OPTIMIZER_ALIASES.get(parts[0], parts[0])
        if name not in OPTIMIZERS or len(parts) > 3:
            raise _parse_err("optimizer", spec, usage)
        kw: Dict[str, Any] = dict(name=name, lr=default_lr)
        try:
            if len(parts) >= 2:
                kw["lr"] = float(parts[1])
            if len(parts) == 3:
                if name == "momentum":
                    kw["momentum"] = float(parts[2])
                elif name == "adamw":
                    kw["weight_decay"] = float(parts[2])
                else:
                    raise _parse_err("optimizer", spec, usage)
        except ValueError as e:
            if "bad optimizer spec" in str(e):
                raise
            raise _parse_err("optimizer", spec, usage) from None
        kw.update(overrides)
        return cls(**kw)

    @property
    def spec(self) -> str:
        """The canonical compact string (lossy: schedule fields excluded;
        an unset lr renders as the bare name)."""
        if self.lr is None:
            return self.name
        if self.name == "momentum":
            return f"momentum:{self.lr}:{self.momentum}"
        if self.name == "adamw":
            return f"adamw:{self.lr}:{self.weight_decay}"
        return f"sgd:{self.lr}"

    def resolve_lr(self, default_lr: float) -> float:
        """The effective base lr (``scala.lr`` unless overridden here)."""
        return default_lr if self.lr is None else self.lr

    def make(self):
        """Build the :class:`repro.optim.Optimizer`."""
        from repro.optim import make_optimizer

        return make_optimizer(self.name, momentum=self.momentum,
                              weight_decay=self.weight_decay)

    def make_schedule(self, total_steps: int, *,
                      default_lr: Optional[float] = None):
        """Build the lr schedule (driven by the engine's global step)."""
        from repro.optim import schedules

        lr = self.lr if self.lr is not None else default_lr
        if lr is None:
            raise ValueError("OptimSpec.lr is unset and no default_lr "
                             "(scala.lr) was provided")
        if self.schedule == "cosine":
            return schedules.linear_warmup_cosine(lr, self.warmup,
                                                  total_steps)
        return schedules.constant(lr)


# ---------------------------------------------------------------------------
# FedSpec
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FedSpec:
    """The federation layer: aggregation + participation + opt-state policy.

    ``aggregator`` and ``participation`` are the registries' compact
    strings (kept verbatim, so the round-trip is lossless):

    * aggregator — ``"fedavg"`` | ``"weighted"`` |
      ``"bias_compensated[:GAMMA]"`` | ``"staleness_weighted[:DECAY]"`` |
      ``"hierarchical:EDGES[:EDGE[:TOP]]"``
      (:func:`repro.fed.make_aggregator`);
    * participation — ``None`` (full participation / legacy subset
      sampling) or ``"full"`` | ``"uniform:FRAC[:SHARDS]"`` |
      ``"dirichlet:FRAC[:ALPHA]"`` (:func:`repro.fed.make_participation`;
      SHARDS balances the subset over contiguous slot blocks — required
      on the sharded ``lace_dp`` sparse path).

    ``opt_state_policy`` is the client optimizer state's round-boundary
    behavior (``carry | reset | average`` — see
    :func:`repro.core.engine.make_round_runner`).

    Fault tolerance (chaos runs are spec-level JSON like everything
    else):

    * ``faults`` — failure-injection spec
      (:func:`repro.fed.make_faults`):
      ``"drop:P[,corrupt:P[:MODE[:SCALE]]][,stall:P[:FACTOR]]"``, e.g.
      ``"drop:0.1,corrupt:0.05:nan,stall:0.02"``. ``None`` = no faults.
    * ``guards`` — guarded-aggregation spec
      (:func:`repro.fed.make_guards`): ``"nonfinite"`` rejects NaN/Inf
      updates, ``"nonfinite,clip:TAU[:BETA]"`` additionally clips
      update norms against a running median. Rejected clients shrink
      the effective cohort AND the eq. 14/15 priors (the local phase is
      re-run over the survivors). ``None`` = unguarded (legacy-exact).
    """

    aggregator: str = "weighted"
    participation: Optional[str] = None
    opt_state_policy: str = "carry"
    faults: Optional[str] = None
    guards: Optional[str] = None

    def __post_init__(self):
        from repro.core.engine import OPT_STATE_POLICIES

        self.make_aggregator()                       # structural validation
        if self.participation is not None:
            self.make_participation(2)               # structural validation
        if self.opt_state_policy not in OPT_STATE_POLICIES:
            raise ValueError(
                f"unknown opt_state_policy {self.opt_state_policy!r}; "
                f"expected {OPT_STATE_POLICIES}")
        self.make_faults()                           # structural validation
        self.make_guards()                           # structural validation

    def make_aggregator(self):
        from repro.fed import make_aggregator

        return make_aggregator(self.aggregator)

    def make_participation(self, num_clients: int):
        from repro.fed import make_participation

        if self.participation is None:
            return None
        return make_participation(self.participation, num_clients)

    def make_faults(self):
        from repro.fed import make_faults

        if self.faults is None:
            return None
        return make_faults(self.faults)

    def make_guards(self):
        from repro.fed import make_guards

        if self.guards is None:
            return None
        return make_guards(self.guards)


# ---------------------------------------------------------------------------
# ExecutionSpec
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ExecutionSpec:
    """How the round program executes.

    ``mode`` is THE mode vocabulary — ``launch/train.py``, the
    benchmarks, and every other driver build from it, so they cannot
    disagree on names:

    * ``"subset"`` — legacy host-side sampling: C = r*K clients are
      re-stacked each round (no in-program scheduler);
    * ``"masked"`` — all K slots stay stacked; the scheduler's 0/1 mask
      folds into the loss weights (full-K compute);
    * ``"sparse"`` — the scheduler's fixed-size subset is gathered into
      a dense axis before the local scan (subset-cost compute,
      ``engine.make_round_runner(slot_gather=True)``);
    * ``"async"`` — the event runtime (:mod:`repro.fed.runtime`):
      sampled completion delays, arrival cohorts, staleness-weighted
      delayed aggregation.

    ``backend`` is the engine loss backend (``logits | lace | lace_dp``);
    ``boundary`` is the split-boundary loss schedule
    (:data:`repro.core.engine.BOUNDARIES`): ``"fused"`` (default)
    computes the eq. 14/15 pair — values and cotangents — in one pass
    over a shared logits product (gradient-bitwise vs. ``"dual"``, half
    the loss-stage matmuls); ``"dual"`` keeps the literal two
    ``value_and_grad`` passes.
    ``delay`` / ``cohort`` / ``staleness_decay`` / ``mix_rate`` apply to
    mode ``"async"`` only (``cohort=0`` = K//4, min 1).
    ``server_optimizer`` is the optional server-half FedOpt
    (:class:`OptimSpec`; its ``lr`` is the server lr — parse
    ``"fedadam:0.01"`` with ``OptimSpec.parse(s, default_lr=1.0)``).
    ``unroll``: scan unroll factor — ``-1`` auto (full on CPU),
    ``0`` full, ``N`` factor.

    Dispatch-efficiency knobs (benchmarked in
    ``benchmarks/BENCH_dispatch.json``):

    * ``precision`` — engine compute policy
      (:data:`repro.core.engine.PRECISIONS`): ``"f32"`` exact (default),
      ``"bf16"`` bf16 forward/backward against f32 master params (the
      priors, loss reductions, updates, and aggregation stay f32).
    * ``rounds_per_call`` — how many whole rounds (or async events) one
      jitted ``RoundProgram.step`` dispatch executes, as an outer
      ``lax.scan`` over the per-round program. Batches/sizes gain a
      leading ``(R,)`` axis and metrics come back stacked;
      :class:`repro.api.Trainer` chunks transparently (remainder rounds
      recompile once for the smaller leading axis). Keep it at 1 while
      debugging or when a host callback must run every round.
    * ``donate`` — donate the program-state argument's buffers to the
      jitted step (``donate_argnums``), updating the round state in
      place instead of copying the stacked client params + optimizer
      moments every dispatch. On by default; a donated state must not
      be reused after stepping it.

    Client-axis scaling knobs (mode ``"async"``; benchmarked in
    ``benchmarks/BENCH_scale.json``):

    * ``snapshots`` — the :class:`repro.fed.runtime.AsyncFedState`
      storage layout (:data:`repro.fed.SNAPSHOT_MODES`): ``"dense"``
      materializes one client-half snapshot per slot (O(K) memory, the
      legacy layout); ``"delta"`` stores a ``ring_size``-deep ring of
      recent global client halves instead — O(cohort + ring) resident,
      bit-identical to dense while every arrival's staleness stays
      below ``ring_size`` (bounded-staleness eviction past it). Delta
      needs a stateless local optimizer (sgd) or
      ``fed.opt_state_policy="reset"``.
    * ``ring_size`` — the delta ring depth (max reconstructable
      staleness).
    * ``lr_scale`` — per-arrival lr scaling
      (:data:`repro.fed.LR_SCALES`): ``"cohort"`` multiplies the lr
      schedule by ``cohort / num_clients`` so per-event aggregate
      movement matches the sync round's per-participant scale;
      ``"none"`` (default) is the historical behavior. At
      ``cohort == num_clients`` the two are bit-identical.
    * ``arrival`` — the event scheduler's cohort-pop algorithm
      (:data:`repro.fed.ARRIVALS`): ``"sort"`` is the legacy per-event
      O(K log K) lexsort over (finish_time, version); ``"topk"``
      replaces it with an O(K)-work / O(log K)-depth blocked-tournament
      selection (``jax.lax.top_k`` over a composite float32 key) that
      is bit-identical to the lexsort, FIFO tie-break included;
      ``"topk:sharded"`` additionally runs the pop per client-mesh
      shard (local top-cohort + one O(cohort·shards) merge) so no
      device ever materializes (K,) schedule work — requires a mesh
      with a client axis at build time.
    * ``opt_paging`` — per-client optimizer-moment residency:
      ``"none"`` keeps moments wherever ``fed.opt_state_policy`` puts
      them; ``"host"`` pages them to a host-memory store
      (:class:`repro.fed.HostOptPager`) and gathers only the arrival
      cohort's slots per event, which *lifts* the delta-snapshot
      restriction to stateless optimizers — ``snapshots='delta'`` +
      ``opt_state_policy='carry'`` now runs with any optimizer without
      a dense (K, ...) moment stack on device. Host paging requires
      mode 'async', snapshots 'delta', opt_state_policy 'carry', and
      ``rounds_per_call == 1`` (the pop/gather/scatter round-trip is
      one host step per event).
    """

    mode: str = "masked"
    backend: str = "logits"
    boundary: str = "fused"
    delay: str = "lognormal:1:1"
    cohort: int = 0
    staleness_decay: float = 0.5
    mix_rate: float = 1.0
    server_optimizer: Optional[OptimSpec] = None
    unroll: int = -1
    precision: str = "f32"
    rounds_per_call: int = 1
    donate: bool = True
    snapshots: str = "dense"
    ring_size: int = 64
    lr_scale: str = "none"
    arrival: str = "sort"
    opt_paging: str = "none"
    #: async cohort-barrier deadline: the event fires at min(cohort-th
    #: finish, first finish + deadline); misses are excluded from the
    #: event and requeued with exponential backoff. None = unbounded
    #: wait (legacy).
    deadline: Optional[float] = None
    backoff: float = 2.0

    def __post_init__(self):
        from repro.core.engine import BACKENDS, BOUNDARIES, PRECISIONS
        from repro.fed import (ARRIVALS, LR_SCALES, SNAPSHOT_MODES,
                               make_delays)

        if self.mode not in EXECUTION_MODES:
            raise ValueError(f"unknown execution mode {self.mode!r}; "
                             f"expected {EXECUTION_MODES}")
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"expected {BACKENDS}")
        if self.boundary not in BOUNDARIES:
            raise ValueError(f"unknown boundary {self.boundary!r}; "
                             f"expected {BOUNDARIES}")
        if self.precision not in PRECISIONS:
            raise ValueError(f"unknown precision {self.precision!r}; "
                             f"expected {PRECISIONS}")
        if self.rounds_per_call < 1:
            raise ValueError(f"rounds_per_call must be >= 1, got "
                             f"{self.rounds_per_call}")
        make_delays(self.delay)                      # structural validation
        if self.cohort < 0:
            raise ValueError(f"cohort must be >= 0, got {self.cohort}")
        if self.snapshots not in SNAPSHOT_MODES:
            raise ValueError(f"unknown snapshots mode {self.snapshots!r}; "
                             f"expected {SNAPSHOT_MODES}")
        if self.ring_size < 1:
            raise ValueError(f"ring_size must be >= 1, got {self.ring_size}")
        if self.lr_scale not in LR_SCALES:
            raise ValueError(f"unknown lr_scale {self.lr_scale!r}; "
                             f"expected {LR_SCALES}")
        if self.arrival not in ARRIVALS:
            raise ValueError(f"unknown arrival {self.arrival!r}; "
                             f"expected {ARRIVALS}")
        if self.opt_paging not in ("none", "host"):
            raise ValueError(f"unknown opt_paging {self.opt_paging!r}; "
                             f"expected ('none', 'host')")
        if self.deadline is not None and self.deadline <= 0:
            raise ValueError(f"deadline must be > 0, got {self.deadline}")
        if self.backoff < 1.0:
            raise ValueError(f"backoff must be >= 1, got {self.backoff}")

    @property
    def in_program(self) -> bool:
        """True iff participation is decided inside the compiled program."""
        return self.mode in ("masked", "sparse", "async")

    def make_delays(self):
        from repro.fed import make_delays

        return make_delays(self.delay)

    def resolve_cohort(self, num_clients: int) -> int:
        return self.cohort if self.cohort > 0 else max(1, num_clients // 4)

    def resolve_unroll(self):
        import jax

        if self.unroll == -1:
            return True if jax.default_backend() == "cpu" else 1
        return True if self.unroll == 0 else self.unroll


# ---------------------------------------------------------------------------
# DataSpec
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DataSpec:
    """The dataset recipe (host-side synthesis; seeded by the
    experiment's top-level ``seed``).

    * ``"lm_synthetic"`` — domain-skewed synthetic token documents
      (:func:`repro.data.synthetic.token_stream`): client k prefers
      domain k % D; next-token prediction at length ``seq``. The LM
      driver's data.
    * ``"image_synthetic"`` — CIFAR-shaped gaussian class images
      (:func:`repro.data.synthetic.gaussian_images`) partitioned by
      quantity skew (``alpha`` = classes per client) or Dirichlet label
      skew (``beta``). The paper-table benchmark data.
    """

    kind: str = "lm_synthetic"
    # --- lm_synthetic ---
    seq: int = 128
    docs_per_client: int = 32
    # --- image_synthetic ---
    n_train: int = 2000
    n_test: int = 1000
    num_classes: int = 10
    alpha: Optional[int] = None        # quantity skew: classes per client
    beta: Optional[float] = None       # Dirichlet label-skew concentration

    def __post_init__(self):
        if self.kind not in ("lm_synthetic", "image_synthetic"):
            raise ValueError(f"unknown data kind {self.kind!r}; expected "
                             "('lm_synthetic', 'image_synthetic')")


# ---------------------------------------------------------------------------
# ExperimentSpec
# ---------------------------------------------------------------------------

#: methods the builder dispatches over (the SCALA engine + every baseline).
SCALA_METHODS = ("scala", "scala_noadj")
FL_METHODS = ("fedavg", "fedprox", "feddyn", "feddecorr", "fedlogit", "fedla")
SFL_METHODS = ("splitfed_v1", "splitfed_v2", "splitfed_v3", "sfl_localloss")
METHODS = SCALA_METHODS + FL_METHODS + SFL_METHODS


@dataclass(frozen=True)
class ExperimentSpec:
    """The whole experiment, declaratively.

    ``arch`` names a :mod:`repro.configs` registry entry (``reduced``
    applies :meth:`ModelConfig.reduced`); ``split`` / ``width`` apply to
    the CNN (AlexNet) family only. ``method`` selects SCALA or one of
    the paper's FL/SFL baselines. ``scala`` is the existing
    :class:`ScalaConfig` verbatim (``method="scala_noadj"`` overrides
    its adjust flags off at build time).
    """

    arch: str = "qwen1.5-0.5b"
    reduced: bool = False
    split: str = "s2"                  # CNN family: client/server boundary
    width: float = 0.125               # CNN family: width multiplier
    method: str = "scala"
    rounds: int = 20
    seed: int = 0
    scala: ScalaConfig = field(default_factory=ScalaConfig)
    optim: OptimSpec = field(default_factory=OptimSpec)
    fed: FedSpec = field(default_factory=FedSpec)
    execution: ExecutionSpec = field(default_factory=ExecutionSpec)
    data: DataSpec = field(default_factory=DataSpec)

    # ------------------------------------------------------------------
    # composition with the model registry
    # ------------------------------------------------------------------

    def model_config(self) -> ModelConfig:
        cfg = get_config(self.arch)
        return cfg.reduced() if self.reduced else cfg

    @property
    def num_clients(self) -> int:
        return self.scala.num_clients

    @property
    def slots(self) -> int:
        """The static stacked-client slot count of the compiled program."""
        if self.execution.in_program:
            return self.scala.num_clients
        return self.scala.clients_per_round

    # ------------------------------------------------------------------
    # coherence validation (spec time, not jit time)
    # ------------------------------------------------------------------

    def validate(self) -> "ExperimentSpec":
        """Reject incoherent spec combinations with targeted errors.

        Sub-spec ``__post_init__`` already guarantees each field parses;
        this checks the *cross-spec* constraints. Returns self so it
        chains: ``build(spec.validate())``.
        """
        ex, fd, sc = self.execution, self.fed, self.scala
        cfg = self.model_config()
        agg = fd.make_aggregator()

        if self.method not in METHODS:
            raise ValueError(f"unknown method {self.method!r}; expected "
                             f"{METHODS}")

        # --- backend coherence ---
        if ex.backend == "lace_dp" and ex.mode in ("sparse", "async"):
            # sparse/async run whole-event/whole-round inside one
            # shard_map: the aggregation must decompose per client shard
            # (local edge fold + psum), which rules out stateful /
            # prior-dependent aggregators and the cross-slot "average"
            # opt-state policy. Mesh-dependent divisibility (cohort,
            # subset size, scheduler shards vs the client shard count)
            # is checked at build time when the mesh is known.
            if agg.shard_local is None or agg.stateful or agg.needs_priors:
                raise ValueError(
                    f"backend 'lace_dp' with mode {ex.mode!r} needs a "
                    "stateless, prior-free, shard-decomposable aggregator "
                    "(fedavg / weighted / hierarchical); got "
                    f"{agg.name!r}")
            if fd.opt_state_policy == "average":
                raise ValueError(
                    "backend 'lace_dp' with mode 'sparse'/'async' does not "
                    "support opt_state_policy 'average'; use 'carry' or "
                    "'reset'")
        if ex.backend != "logits" and cfg.family == "cnn":
            raise ValueError(
                f"backend {ex.backend!r} needs a trunk/head split; the CNN "
                "(AlexNet) family only supports backend 'logits'")

        # --- participation / mode coherence ---
        if ex.mode == "sparse" and fd.participation is None:
            raise ValueError(
                "mode 'sparse' needs a participation spec (the static "
                "K_active comes from the scheduler's subset_size); set "
                "fed.participation to 'uniform:FRAC' or "
                "'dirichlet:FRAC[:ALPHA]'")
        if ex.mode == "async" and fd.participation is not None:
            raise ValueError(
                "mode 'async' replaces participation scheduling (the "
                "arrival cohort IS the participating subset); drop "
                "fed.participation")
        if ex.mode == "subset" and fd.participation is not None:
            raise ValueError(
                "mode 'subset' samples clients host-side; a participation "
                "spec needs an in-program mode ('masked' or 'sparse')")

        # --- stateful aggregators need stable client identities ---
        if agg.stateful:
            if ex.mode == "async":
                raise ValueError(
                    f"aggregator {agg.name!r} double-decays under mode "
                    "'async' (the runtime applies staleness_decay itself); "
                    "use a stateless aggregator")
            if ex.mode == "subset" or fd.participation is None:
                raise ValueError(
                    f"aggregator {agg.name!r} is stateful and needs stable "
                    "client identities: use mode 'masked'/'sparse' with a "
                    "participation spec (host-side subset re-stacking has "
                    "no slot -> client correspondence)")

        # --- async knobs ---
        if ex.mode == "async" and ex.cohort > sc.num_clients:
            raise ValueError(f"cohort {ex.cohort} exceeds the "
                             f"{sc.num_clients} client slots")
        if ex.snapshots == "delta":
            if ex.mode != "async":
                raise ValueError(
                    "snapshots='delta' is an async-runtime storage layout; "
                    f"mode {ex.mode!r} has no per-client snapshots")
            if fd.opt_state_policy == "average":
                raise ValueError(
                    "snapshots='delta' stores no per-client optimizer "
                    "state to average; use opt_state_policy 'reset' (or "
                    "'carry' with a stateless optimizer)")
            if fd.opt_state_policy == "carry" and self.optim.name != "sgd" \
                    and ex.opt_paging != "host":
                raise ValueError(
                    f"snapshots='delta' cannot carry {self.optim.name!r} "
                    "per-client moments (no per-client state is stored); "
                    "use optim 'sgd', fed.opt_state_policy='reset', or "
                    "execution.opt_paging='host' (host-paged moment store)")
        if ex.lr_scale != "none" and ex.mode != "async":
            raise ValueError("lr_scale applies to mode 'async' only (the "
                             "cohort/K factor is an event-schedule knob)")
        if ex.arrival != "sort" and ex.mode != "async":
            raise ValueError(
                f"arrival {ex.arrival!r} applies to mode 'async' only (the "
                "cohort pop is an event-schedule op); mode "
                f"{ex.mode!r} has no arrival schedule")
        if ex.arrival == "topk:sharded" and ex.backend == "lace_dp":
            raise ValueError(
                "arrival 'topk:sharded' is redundant under backend "
                "'lace_dp': the shard_map event already pops per client "
                "shard; use arrival 'topk' (applied per shard)")
        if ex.opt_paging == "host":
            if ex.mode != "async":
                raise ValueError(
                    "opt_paging='host' pages the async runtime's per-client "
                    f"moments; mode {ex.mode!r} has none")
            if ex.snapshots != "delta" or fd.opt_state_policy != "carry":
                raise ValueError(
                    "opt_paging='host' exists to carry per-client moments "
                    "outside the delta snapshot state; it requires "
                    "snapshots='delta' and fed.opt_state_policy='carry' "
                    f"(got snapshots={ex.snapshots!r}, "
                    f"opt_state_policy={fd.opt_state_policy!r})")
            if ex.rounds_per_call != 1:
                raise ValueError(
                    "opt_paging='host' steps one event per host "
                    "pop/gather/scatter round-trip; rounds_per_call must "
                    f"be 1, got {ex.rounds_per_call}")
            if ex.backend == "lace_dp":
                raise ValueError(
                    "opt_paging='host' predicts the arrival pop outside the "
                    "compiled event; backend 'lace_dp' pops per shard "
                    "inside its shard_map and is not supported")

        # --- fault tolerance ---
        robust = (fd.faults is not None) or (fd.guards is not None)
        if ex.deadline is not None and ex.mode != "async":
            raise ValueError(
                "deadline bounds the async cohort barrier; mode "
                f"{ex.mode!r} has no arrival schedule")
        if robust and ex.mode == "subset":
            raise ValueError(
                "faults/guards are in-program federation features; mode "
                "'subset' re-stacks clients host-side — use 'masked', "
                "'sparse', or 'async'")
        if robust or ex.deadline is not None:
            if ex.backend == "lace_dp" and (ex.mode in ("sparse", "async")):
                raise ValueError(
                    "faults/guards/deadline are not supported on the "
                    "in-shard lace_dp sparse/async programs (their FL "
                    "phase runs inside shard_map); use backend "
                    "'logits'/'lace', or lace_dp with mode 'masked'")
            if ex.opt_paging == "host":
                raise ValueError(
                    "faults/guards/deadline are not supported with "
                    "opt_paging='host' (the pager's arrival prediction "
                    "does not model partial cohorts)")

        # --- baselines ---
        if self.method not in SCALA_METHODS:
            if ex.mode != "subset":
                raise ValueError(
                    f"method {self.method!r} (a baseline) only supports "
                    "mode 'subset' (host-side sampling); the in-program "
                    "modes are SCALA engine programs")
            if cfg.family != "cnn":
                raise ValueError(
                    f"method {self.method!r} needs the CNN (AlexNet) "
                    f"family; arch {self.arch!r} is {cfg.family!r}")
            if self.method in SFL_METHODS and ex.server_optimizer is not None:
                raise ValueError(
                    "server_optimizer (FedOpt) is not supported by the SFL "
                    "baselines; use an FL method or SCALA")

        # --- data / model coherence ---
        if self.data.kind == "image_synthetic" and cfg.family != "cnn":
            raise ValueError(
                f"data kind 'image_synthetic' needs the CNN family; arch "
                f"{self.arch!r} is {cfg.family!r}")
        if self.data.kind == "lm_synthetic" and (cfg.family == "cnn"
                                                 or cfg.frontend is not None):
            raise ValueError(
                f"data kind 'lm_synthetic' needs a text arch; "
                f"{self.arch!r} has family {cfg.family!r} / frontend "
                f"{cfg.frontend!r}")
        if self.data.kind == "image_synthetic" \
                and self.data.alpha is not None and self.data.beta is not None:
            raise ValueError("set at most one of data.alpha (quantity skew) "
                             "and data.beta (Dirichlet skew)")
        return self

    # ------------------------------------------------------------------
    # lossless serialization (sweep manifests, --config/--dump-config)
    # ------------------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExperimentSpec":
        d = dict(d)
        if "scala" in d and isinstance(d["scala"], dict):
            d["scala"] = ScalaConfig(**d["scala"])
        if "optim" in d and isinstance(d["optim"], dict):
            d["optim"] = OptimSpec(**d["optim"])
        if "fed" in d and isinstance(d["fed"], dict):
            d["fed"] = FedSpec(**d["fed"])
        if "execution" in d and isinstance(d["execution"], dict):
            ex = dict(d["execution"])
            if isinstance(ex.get("server_optimizer"), dict):
                ex["server_optimizer"] = OptimSpec(**ex["server_optimizer"])
            d["execution"] = ExecutionSpec(**ex)
        if "data" in d and isinstance(d["data"], dict):
            d["data"] = DataSpec(**d["data"])
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))
