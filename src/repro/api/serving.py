"""ServeSpec — declarative serving of a trained SCALA global model.

The serving counterpart of :class:`repro.api.ExperimentSpec`: a frozen,
JSON-round-trippable description of *what* to serve (arch + federated
training checkpoint) and *how* (slots, paged-cache budget, max length,
sampling). :func:`build_serve` restores the training checkpoint via
:func:`repro.checkpoint.restore`, merges the slot-0 client half with the
server half into the served global model (the same merge
:class:`repro.api.RoundProgram` ``predict`` evaluates — eq. 10's
aggregated client half is broadcast to every slot at round boundaries,
so slot 0 IS the global client half), and returns a
:class:`ServeProgram` wrapping a ready
:class:`repro.serve.ServeEngine`::

    from repro import api

    spec = api.ServeSpec(arch="qwen1.5-0.5b", reduced=True,
                         checkpoint_dir="ckpts/run0", slots=8,
                         max_len=256, pages=64, page_size=16)
    program = api.build_serve(spec)
    out = program.engine.generate(prompts, max_new=32)

With ``checkpoint_dir=""`` the model is freshly initialised from
``seed`` — the smoke/benchmark path.
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig

ADMISSION_MODES = ("continuous", "static")


@dataclass(frozen=True)
class ServeSpec:
    """Everything one serving deployment needs, declaratively.

    ``pages == 0`` serves from a dense ``slots x max_len`` cache;
    ``pages > 0`` serves from a paged pool of that many pages
    (bit-identical output, memory becomes a pool budget instead of a
    dense per-slot allocation). ``temperature == 0`` is greedy.
    """

    arch: str = "qwen1.5-0.5b"
    reduced: bool = False
    checkpoint_dir: str = ""           # "" = fresh init from `seed`
    checkpoint_step: Optional[int] = None
    slots: int = 4
    max_len: int = 256
    pages: int = 0                     # 0 = dense cache
    page_size: int = 16
    temperature: float = 0.0
    seed: int = 0
    admission: str = "continuous"

    def __post_init__(self):
        cfg = self.model_config()
        if not cfg.is_decoder:
            raise ValueError(f"arch {self.arch!r} is not a decoder; "
                             "ServeSpec serves autoregressive text models")
        if cfg.frontend is not None:
            raise ValueError(f"arch {self.arch!r} has frontend "
                             f"{cfg.frontend!r}; ServeSpec serves text-only "
                             "archs")
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {self.slots}")
        if self.max_len < 2:
            raise ValueError(f"max_len must be >= 2, got {self.max_len}")
        if self.pages < 0:
            raise ValueError(f"pages must be >= 0, got {self.pages}")
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.temperature < 0:
            raise ValueError(
                f"temperature must be >= 0, got {self.temperature}")
        if self.admission not in ADMISSION_MODES:
            raise ValueError(f"unknown admission {self.admission!r}; "
                             f"expected {ADMISSION_MODES}")

    def model_config(self) -> ModelConfig:
        cfg = get_config(self.arch)
        return cfg.reduced() if self.reduced else cfg

    # -- lossless serialization -------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ServeSpec":
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "ServeSpec":
        return cls.from_dict(json.loads(s))


@dataclass
class ServeProgram:
    """A built serving deployment.

    * ``prefill(tokens)`` — fused prompt absorption: one trunk dispatch
      returning (last-position logits, full decode cache);
    * ``admit(request)`` — prefill a request into a free engine slot
      (False when no capacity);
    * ``step()`` — advance every active slot one token;
    * ``predict(batch)`` — full-sequence logits of the served global
      model (parity surface with ``RoundProgram.predict``);
    * ``engine`` — the underlying :class:`repro.serve.ServeEngine`
      (``serve`` / ``generate`` / ``take_finished``).
    """

    spec: ServeSpec
    cfg: ModelConfig
    params: Any
    engine: Any
    prefill: Callable
    admit: Callable
    step: Callable
    predict: Callable


def restore_global_params(cfg: ModelConfig, directory: str,
                          step: Optional[int] = None):
    """Restore a federated training checkpoint and merge it into the
    served global model.

    ``launch/train.py`` checkpoints ``state.inner.params`` =
    ``{'client': (K, ...) stacked, 'server': ...}``. The stacked client
    count K is inferred from the saved arrays (restore needs an
    exact-shape template), slot 0 of the client half is merged with the
    server half, and the result matches
    :func:`repro.models.transformer.init_params` layout. An unstacked
    (already-merged) checkpoint restores as-is, and a *full-state*
    checkpoint (``Trainer.save`` — the whole ProgramState under
    ``.inner/.params/...`` keys) serves too: the params subtree is
    pulled out by key prefix and everything else ignored.
    """
    from repro import checkpoint
    from repro.models import transformer as T

    shapes = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))

    step = checkpoint.latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory!r}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes["client"])
    probe_path, probe = flat[0]
    key = "client/" + "/".join(
        str(getattr(p, "key", getattr(p, "idx", p))) for p in probe_path)
    with np.load(path) as data:
        prefix = "" if key in data.files else ".inner/.params/"
        if prefix + key not in data.files:
            raise ValueError(
                f"checkpoint {path!r} has neither {key!r} nor "
                f"'.inner/.params/{key}' — not a params or full-state "
                f"training checkpoint")
        saved_shape = data[prefix + key].shape

    if saved_shape == probe.shape:
        k_slots = 0                                    # already merged
        client_tpl = jax.tree.map(
            lambda s: np.zeros(s.shape, s.dtype), shapes["client"])
    elif saved_shape[1:] == probe.shape:
        k_slots = saved_shape[0]                       # (K, ...) stacked
        client_tpl = jax.tree.map(
            lambda s: np.zeros((k_slots,) + s.shape, s.dtype),
            shapes["client"])
    else:
        raise ValueError(
            f"checkpoint leaf {key!r} has shape {saved_shape}, expected "
            f"{probe.shape} or (K,)+{probe.shape}")

    template = {
        "client": client_tpl,
        "server": jax.tree.map(lambda s: np.zeros(s.shape, s.dtype),
                               shapes["server"]),
    }
    restored = checkpoint.restore(directory, template, step,
                                  key_prefix=prefix)
    merge = (lambda a: jnp.asarray(a[0])) if k_slots else jnp.asarray
    return {"client": jax.tree.map(merge, restored["client"]),
            "server": jax.tree.map(jnp.asarray, restored["server"])}


def build_serve(spec: ServeSpec) -> ServeProgram:
    """Spec -> running deployment (restore + merge + engine)."""
    from repro.models import transformer as T
    from repro.serve import ServeEngine

    cfg = spec.model_config()
    if spec.checkpoint_dir:
        params = restore_global_params(cfg, spec.checkpoint_dir,
                                       spec.checkpoint_step)
    else:
        params = T.init_params(jax.random.PRNGKey(spec.seed), cfg)

    engine = ServeEngine(
        params, cfg, slots=spec.slots, max_len=spec.max_len,
        pages=spec.pages, page_size=spec.page_size,
        temperature=spec.temperature, seed=spec.seed,
        admission=spec.admission)

    prefill = jax.jit(lambda tokens: T.forward_prefill_cached(
        params, {"tokens": tokens}, cfg, spec.max_len))
    predict = jax.jit(lambda batch: T.forward(
        params, batch, cfg, remat=False)[0])

    return ServeProgram(spec=spec, cfg=cfg, params=params, engine=engine,
                        prefill=prefill, admit=engine.admit,
                        step=engine.step, predict=predict)
