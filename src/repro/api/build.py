"""``build(spec)`` — one builder for every round program.

Dispatches an :class:`repro.api.ExperimentSpec` across the masked /
sparse-slot / async SCALA rounds *and* the FL/SFL baselines, returning a
:class:`RoundProgram`: an ``init()`` for the full program state (params,
optimizer state, federation/async state), one jitted ``step`` with a
*uniform* signature regardless of mode, and a jitted ``predict`` for
evaluation. The old constructors (``engine.make_round_runner``,
``fed.make_async_runner``, ``baselines.make_fl_round`` /
``make_sfl_round``) remain the internal layer this builder calls — the
program it builds is bit-identical to direct construction with the same
keys (test-enforced in ``tests/test_api.py``).

PRNG choreography (kept exactly as the pre-API drivers', so existing
benchmark numbers and examples reproduce):

* params init — ``PRNGKey(seed)`` (CNN: ``A.init_params`` then split;
  text: per-half ``T.init_params`` via ``engine.init_scala_params``);
* federation / async state — ``PRNGKey(seed + 1)`` for
  ``lm_synthetic`` (the ``launch/train.py`` convention),
  ``fold_in(PRNGKey(seed), 11)`` for ``image_synthetic`` (the
  ``benchmarks/common.run_experiment`` convention).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.api.specs import (SCALA_METHODS, SFL_METHODS, ExperimentSpec)


@dataclass(frozen=True)
class ProgramState:
    """The full state one :class:`RoundProgram` step threads: ``inner``
    is the method's own state (engine :class:`TrainState`, an FL
    baseline's global params, an SFL state dict) and ``fed`` the
    federation carry (sync fed-state dict, :class:`AsyncFedState`, FL
    baseline state, or ``()``)."""

    inner: Any
    fed: Any = ()


jax.tree_util.register_dataclass(ProgramState,
                                 data_fields=("inner", "fed"),
                                 meta_fields=())


@dataclass(frozen=True)
class RoundProgram:
    """A built experiment: state factory + jitted step + metadata.

    * ``init() -> ProgramState`` — params, optimizer state, and
      federation/async state from the spec's seed;
    * ``step(state, batches, sizes) -> (state, metrics)`` — ONE
      dispatch: one round (or async event) by default; with
      ``execution.rounds_per_call = R > 1`` the step is an outer
      ``lax.scan`` over R whole rounds — ``batches``/``sizes`` leaves
      gain a leading (R,) axis and metrics come back stacked. With
      ``execution.donate`` (the default) the ``state`` argument's
      buffers are donated: the state you pass in is dead after the
      call — keep only the returned state;
    * ``predict(state, batch) -> logits`` — the current global model's
      forward (slot-0 client half + server half for split methods;
      always full f32, independent of ``execution.precision``);
    * ``metadata`` — static facts a driver wants without re-deriving:
      ``mode``, ``slots``, ``thread_fed``, ``backend``, ``method``,
      ``precision``, ``rounds_per_call``, ``donate``.
    """

    spec: ExperimentSpec
    model: Any
    init: Callable[[], ProgramState]
    step: Callable[..., Any]
    predict: Callable[..., Any]
    metadata: Dict[str, Any]


def donated_jit(fn, donate: bool = True):
    """jit a round/step function with its state argument (argnum 0)
    donated, so the stacked client params, optimizer moments, and
    federation state update in place instead of being copied every
    dispatch. The one rule donation imposes: the state you pass in is
    dead after the call — keep only the returned state. This wrapper is
    THE jit every driver should use for a step; the legacy
    ``launch/train.py --no-scan`` branch shares it too.
    """
    return jax.jit(fn, donate_argnums=(0,)) if donate else jax.jit(fn)


def _fuse_rounds(step, unroll):
    """Fuse ``R = rounds_per_call`` whole rounds into one XLA program:
    an outer ``lax.scan`` over the per-round step. ``batches`` / ``sizes``
    leaves carry a leading (R,) axis (R is read from the shapes, so one
    jitted program handles full chunks and the remainder chunk alike via
    shape-specialized recompilation); metrics come back stacked (R, ...)
    and are pulled to host once per chunk by the Trainer."""

    def fused(state, batches, sizes):
        R = jax.tree.leaves(batches)[0].shape[0]
        if unroll is True or R == 1:
            # trace-time unroll: a plain round chain with static slices.
            # Structurally identical to R sequential dispatches (lax.scan
            # — even fully unrolled — compiles the body a hair
            # differently: one-ulp conv drift and an extra carry copy),
            # so the fused chunk stays bit-identical to sequential
            # rounds and XLA updates the round state in place.
            ms = []
            for r in range(R):
                state, m = step(state,
                                jax.tree.map(lambda a: a[r], batches),
                                jax.tree.map(lambda a: a[r], sizes))
                ms.append(m)
            return state, jax.tree.map(lambda *xs: jnp.stack(xs), *ms)

        def body(st, inp):
            b, sz = inp
            return step(st, b, sz)

        return jax.lax.scan(body, state, (batches, sizes), unroll=unroll)

    return fused


def _fed_key(spec: ExperimentSpec):
    key = jax.random.PRNGKey(spec.seed)
    if spec.data.kind == "image_synthetic":
        return jax.random.fold_in(key, 11)
    return jax.random.PRNGKey(spec.seed + 1)


def _broadcast_slots(tree, slots: int):
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (slots,) + a.shape), tree)


def _server_optimizer(spec: ExperimentSpec):
    so = spec.execution.server_optimizer
    return (None, 1.0) if so is None else (so.make(), so.lr)


# ---------------------------------------------------------------------------
# per-family model + params
# ---------------------------------------------------------------------------


def _cnn_split_init(spec: ExperimentSpec):
    from repro.core.scala import alexnet_split_model
    from repro.models import alexnet as A

    model = alexnet_split_model(spec.split,
                                num_classes=spec.data.num_classes)
    key = jax.random.PRNGKey(spec.seed)
    full = A.init_params(key, num_classes=spec.data.num_classes,
                         width=spec.width)
    wc, ws = A.split_params(full, spec.split)
    return model, wc, ws, full, key


def text_split_init(spec: ExperimentSpec, slots: int):
    from repro.core import engine
    from repro.core.scala import transformer_split_model
    from repro.models import transformer as T

    cfg = spec.model_config()
    model = transformer_split_model(cfg)
    params = engine.init_scala_params(
        jax.random.PRNGKey(spec.seed),
        lambda k: T.init_params(k, cfg)["client"],
        lambda k: T.init_params(k, cfg)["server"],
        slots)
    return model, params


# ---------------------------------------------------------------------------
# the builder
# ---------------------------------------------------------------------------


def build(spec: ExperimentSpec, *, mesh=None, batch_specs=None,
          jit: bool = True) -> RoundProgram:
    """Validate ``spec`` and build its :class:`RoundProgram`.

    ``mesh`` / ``batch_specs`` are required iff
    ``spec.execution.backend == "lace_dp"`` (forwarded to the engine's
    manual-SPMD round). ``jit=False`` returns the un-jitted step
    (HLO inspection, nesting inside an outer jit).
    """
    spec.validate()
    ex = spec.execution
    if ex.backend == "lace_dp" and (mesh is None or batch_specs is None):
        raise ValueError("backend 'lace_dp' needs build(spec, mesh=, "
                         "batch_specs=)")
    if ex.arrival == "topk:sharded" and mesh is None:
        raise ValueError("arrival 'topk:sharded' pops per client-mesh "
                         "shard; it needs build(spec, mesh=)")

    if spec.method in SCALA_METHODS:
        program = _build_scala(spec, mesh=mesh, batch_specs=batch_specs)
    elif spec.method in SFL_METHODS:
        program = _build_sfl(spec)
    else:
        program = _build_fl(spec)

    program.metadata.update(precision=ex.precision,
                            boundary=ex.boundary,
                            rounds_per_call=ex.rounds_per_call,
                            donate=ex.donate)
    if program.metadata.get("host_paged"):
        # the host-paged step is a two-phase host loop (predict the pop,
        # gather the cohort's moments from the host store, run the jitted
        # event, scatter back) — it jits and donates its event internally
        # and cannot be wrapped in an outer jit or fused across rounds.
        if not jit:
            raise ValueError("opt_paging='host' builds a host-side "
                             "two-phase step (its event is jitted "
                             "internally); jit=False is not supported")
        init = program.init
        if ex.donate:
            # same donation-safety copy as the jitted path below: the
            # paged event donates the state, so every init() must hand
            # out fresh buffers.
            _raw_init = program.init
            init = lambda: jax.tree.map(jnp.copy, _raw_init())
        return dataclasses.replace(program, init=init,
                                   predict=jax.jit(program.predict))
    step = program.step
    if ex.rounds_per_call > 1:
        step = _fuse_rounds(step, ex.resolve_unroll())
    if program.metadata.get("client_major"):
        # the FL/SFL baselines consume client-major (C, T, ...) batches;
        # the driver-facing layout stays iteration-major (T, C, ...), so
        # transpose once per dispatch HERE — outside the fused-rounds
        # scan — instead of re-transposing every round inside the chunk
        inner_step = step
        a0, a1 = (1, 2) if ex.rounds_per_call > 1 else (0, 1)
        step = lambda st, b, s: inner_step(
            st, jax.tree.map(lambda a: jnp.swapaxes(a, a0, a1), b), s)
    if jit:
        step = donated_jit(step, donate=ex.donate)
        init = program.init
        if ex.donate:
            # the un-donated init closes over the built param buffers, so
            # two init() calls would share them — and a donated step may
            # neither receive the same buffer twice (the async snapshots
            # alias the stacked client half) nor consume a buffer a
            # previous init() handed out. A leaf-wise copy makes every
            # init() a fresh, donation-safe state.
            _raw_init = program.init
            init = lambda: jax.tree.map(jnp.copy, _raw_init())
        program = dataclasses.replace(program, step=step, init=init,
                                      predict=jax.jit(program.predict))
    elif step is not program.step:
        program = dataclasses.replace(program, step=step)
    return program


def _build_scala(spec: ExperimentSpec, *, mesh=None,
                 batch_specs=None) -> RoundProgram:
    from repro import fed
    from repro.core import engine

    ex, fd, sc = spec.execution, spec.fed, spec.scala
    if spec.method == "scala_noadj":
        sc = dataclasses.replace(sc, adjust_server=False, adjust_client=False)
    slots = spec.slots
    cfg = spec.model_config()

    opt = spec.optim.make()
    sched = spec.optim.make_schedule(spec.rounds * sc.local_iters,
                                     default_lr=sc.lr)
    agg = fd.make_aggregator()
    scheduler = (fd.make_participation(slots)
                 if ex.mode in ("masked", "sparse") and fd.participation
                 else None)
    server_opt, server_lr = _server_optimizer(spec)
    faults = fd.make_faults()
    guards = fd.make_guards()
    unroll = ex.resolve_unroll()

    # delta snapshots carry the global client half over ONE param slot
    # (the ring replaces the per-client stacking) — the logical client
    # count stays `slots` everywhere else (versions, delays, batches)
    delta = ex.mode == "async" and ex.snapshots == "delta"
    param_slots = 1 if delta else slots
    if cfg.family == "cnn":
        model, wc, ws, _, _ = _cnn_split_init(spec)
        params = {"client": _broadcast_slots(wc, param_slots), "server": ws}
    else:
        model, params = text_split_init(spec, param_slots)

    if ex.mode == "async":
        delays = ex.make_delays()
        cohort = ex.resolve_cohort(slots)
        paged = ex.opt_paging == "host"
        round_fn = fed.make_async_runner(
            model, sc, backend=ex.backend, boundary=ex.boundary,
            optimizer=opt, schedule=sched,
            delays=delays, cohort=cohort,
            staleness_decay=ex.staleness_decay, mix_rate=ex.mix_rate,
            aggregator=agg, server_optimizer=server_opt,
            server_lr=server_lr, opt_state_policy=fd.opt_state_policy,
            unroll=unroll, precision=ex.precision,
            snapshots=ex.snapshots, ring_size=ex.ring_size,
            lr_scale=ex.lr_scale, num_clients=slots,
            arrival=ex.arrival, paged_opt=paged,
            mesh=mesh, batch_specs=batch_specs,
            deadline=ex.deadline, backoff=ex.backoff,
            faults=faults, guards=guards)
        pager = (fed.HostOptPager(
            opt, jax.tree.map(lambda a: a[0], params["client"]), slots)
            if paged else None)
        sched_mesh = mesh if ex.arrival == "topk:sharded" else None

        def init() -> ProgramState:
            afed = fed.init_async_state(
                _fed_key(spec), params["client"], delays, aggregator=agg,
                server_optimizer=server_opt, server_params=params["server"],
                snapshots=ex.snapshots, ring_size=ex.ring_size,
                num_clients=slots, mesh=sched_mesh, guards=guards)
            if pager is not None:
                pager.reset()
            return ProgramState(inner=engine.init_train_state(params, opt),
                                fed=afed)

        if paged:
            import numpy as np

            # predict the arrival pop OUTSIDE the event with the same
            # deterministic pop the event applies internally, so the
            # host gather/scatter indices match the event's cohort
            # exactly. np.asarray blocks until the pop has consumed the
            # schedule scalars, making the event's donation safe.
            pop = jax.jit(fed.make_arrival_pop(cohort, ex.arrival,
                                               mesh=sched_mesh))

            def ev_fn(state: ProgramState, batches, sizes, cohort_opt):
                inner, afed, metrics, new_co = round_fn(
                    state.inner, state.fed, batches, sizes, cohort_opt)
                return ProgramState(inner=inner, fed=afed), metrics, new_co

            ev = donated_jit(ev_fn, donate=ex.donate)

            def step(state: ProgramState, batches, sizes):
                idx = np.asarray(
                    pop(state.fed.finish_time, state.fed.version)[0])
                cohort_opt = pager.gather(idx)
                new_state, metrics, new_co = ev(state, batches, sizes,
                                                cohort_opt)
                pager.scatter(idx, new_co)
                return new_state, metrics
        else:
            def step(state: ProgramState, batches, sizes):
                inner, afed, metrics = round_fn(state.inner, state.fed,
                                                batches, sizes)
                return ProgramState(inner=inner, fed=afed), metrics

        thread_fed = True
    else:
        round_fn = engine.make_round_runner(
            model, sc, backend=ex.backend, boundary=ex.boundary,
            optimizer=opt, schedule=sched,
            unroll=unroll, aggregator=agg, participation=scheduler,
            opt_state_policy=fd.opt_state_policy,
            slot_gather=ex.mode == "sparse", server_optimizer=server_opt,
            server_lr=server_lr, mesh=mesh, batch_specs=batch_specs,
            precision=ex.precision, faults=faults, guards=guards)
        thread_fed = (scheduler is not None or agg.stateful
                      or server_opt is not None or faults is not None
                      or (guards is not None and guards.stateful))

        def init() -> ProgramState:
            fed_state = (fed.init_fed_state(_fed_key(spec), agg, scheduler,
                                            num_clients=slots,
                                            server_optimizer=server_opt,
                                            server_params=params["server"],
                                            faults=faults, guards=guards)
                         if thread_fed else ())
            return ProgramState(inner=engine.init_train_state(params, opt),
                                fed=fed_state)

        if thread_fed:
            def step(state: ProgramState, batches, sizes):
                inner, fed_state, metrics = round_fn(state.inner, batches,
                                                     sizes, state.fed)
                return ProgramState(inner=inner, fed=fed_state), metrics
        else:
            def step(state: ProgramState, batches, sizes):
                inner, metrics = round_fn(state.inner, batches, sizes)
                return ProgramState(inner=inner, fed=state.fed), metrics

    def predict(state: ProgramState, batch):
        wc0 = jax.tree.map(lambda a: a[0], state.inner.params["client"])
        acts = model.client_fwd(wc0, batch)
        logits, _ = model.server_fwd(state.inner.params["server"], acts)
        return logits

    return RoundProgram(
        spec=spec, model=model, init=init, step=step, predict=predict,
        metadata=dict(method=spec.method, mode=ex.mode, slots=slots,
                      backend=ex.backend, thread_fed=thread_fed,
                      snapshots=ex.snapshots, arrival=ex.arrival,
                      host_paged=ex.opt_paging == "host"))


def _build_fl(spec: ExperimentSpec) -> RoundProgram:
    from repro.core import baselines as B
    from repro.models import alexnet as A

    fd = spec.fed
    slots = spec.slots
    agg = fd.make_aggregator() if fd.aggregator != "weighted" \
        else None
    server_opt, server_lr = _server_optimizer(spec)

    def fwd(p, x):
        return A.forward(p, x, spec.split)

    def feats(p, x):
        return A.features(p, x)

    model = B.FedModel(forward=fwd, num_classes=spec.data.num_classes,
                       features=feats)
    key = jax.random.PRNGKey(spec.seed)
    w0 = A.init_params(key, num_classes=spec.data.num_classes,
                       width=spec.width)
    round_fn = B.make_fl_round(spec.method, model,
                               lr=spec.optim.resolve_lr(spec.scala.lr),
                               aggregator=agg, server_optimizer=server_opt,
                               server_lr=server_lr,
                               precision=spec.execution.precision)

    def init() -> ProgramState:
        return ProgramState(
            inner=w0,
            fed=B.init_fl_state(spec.method, w0, slots,
                                server_optimizer=server_opt))

    # client-major step: batches arrive (C, T, ...) — the (T, C) -> (C, T)
    # transpose is hoisted into build()'s dispatch wrapper, so a fused
    # rounds_per_call chunk transposes ONCE instead of once per round
    def step(state: ProgramState, batches, sizes):
        w, fl_state = round_fn(state.inner, batches, sizes, state.fed)
        return ProgramState(inner=w, fed=fl_state), {}

    def predict(state: ProgramState, batch):
        return model.forward(state.inner, batch["x"])

    return RoundProgram(
        spec=spec, model=model, init=init, step=step, predict=predict,
        metadata=dict(method=spec.method, mode="subset", slots=slots,
                      backend="logits", thread_fed=True, client_major=True))


def _build_sfl(spec: ExperimentSpec) -> RoundProgram:
    import numpy as np

    from repro.core import baselines as B
    from repro.models import alexnet as A

    fd = spec.fed
    slots = spec.slots
    agg = fd.make_aggregator() if fd.aggregator != "weighted" \
        else None
    model, wc, ws, _, key = _cnn_split_init(spec)

    state0 = {"wc": _broadcast_slots(wc, slots), "ws": ws}
    aux_head_fwd = None
    if spec.method == "sfl_localloss":
        probe = A.client_forward_from_split(
            wc, jnp.zeros((1, 32, 32, 3)), spec.split)
        feat_dim = int(np.prod(probe.shape[1:]))
        aux0 = {"w": jax.random.normal(
            key, (feat_dim, spec.data.num_classes)) * 0.05}
        state0["aux"] = _broadcast_slots(aux0, slots)

        def aux_head_fwd(p, feats):
            return feats.reshape(feats.shape[0], -1) @ p["w"]

    round_fn = B.make_sfl_round(spec.method, model,
                                lr=spec.optim.resolve_lr(spec.scala.lr),
                                aux_head_fwd=aux_head_fwd, aggregator=agg,
                                precision=spec.execution.precision)

    def init() -> ProgramState:
        return ProgramState(inner=state0, fed=())

    # client-major step — see _build_fl: the batch transpose is hoisted
    # into build()'s dispatch wrapper
    def step(state: ProgramState, batches, sizes):
        return ProgramState(inner=round_fn(state.inner, batches, sizes),
                            fed=state.fed), {}

    def predict(state: ProgramState, batch):
        wc0 = jax.tree.map(lambda a: a[0], state.inner["wc"])
        acts = model.client_fwd(wc0, batch)
        logits, _ = model.server_fwd(state.inner["ws"], acts)
        return logits

    return RoundProgram(
        spec=spec, model=model, init=init, step=step, predict=predict,
        metadata=dict(method=spec.method, mode="subset", slots=slots,
                      backend="logits", thread_fed=False,
                      client_major=True))
