"""Once-per-process deprecation warnings (the ``core/scala.py``
convention, shared so every compat shim warns the same way)."""
from __future__ import annotations

import warnings

# names that already warned this process (warn once each)
_WARNED: set = set()


def warn_once(name: str, use: str, *, stacklevel: int = 3) -> None:
    """Emit one ``DeprecationWarning`` per process for ``name``,
    pointing at its ``repro.api``-era replacement ``use``."""
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(
        f"{name} is a legacy kwarg-style helper; use {use} instead "
        "(the declarative spec layer — see repro.api)",
        DeprecationWarning, stacklevel=stacklevel)
