"""repro.api — the declarative experiment layer.

One typed, frozen, JSON-serializable :class:`ExperimentSpec` describes
an entire run — model (by registry name), SCALA protocol, optimizer +
schedule, federation (aggregator / participation / opt-state policy),
execution mode (subset | masked | sparse | async, plus the async and
server-FedOpt knobs), and dataset. :func:`build` turns a spec into a
:class:`RoundProgram` (state factory + ONE jitted step + predict),
dispatching across the SCALA engine rounds and the FL/SFL baselines and
rejecting incoherent combinations at spec time; :class:`Trainer` is the
thin host loop every driver (``launch/train.py``, ``benchmarks``,
``examples``) runs on.

    from repro import api

    spec = api.ExperimentSpec(arch="qwen1.5-0.5b", reduced=True, rounds=5)
    trainer = api.Trainer(spec)
    trainer.run(); print(trainer.evaluate())

Sub-specs parse from the compact CLI strings (``"uniform:0.25"``,
``"dirichlet:0.3:0.25"``, ``"lognormal:1:1"``, ``"fedadam:0.01"``) and
the whole tree round-trips through ``to_dict()/from_dict()`` JSON — the
unit sweep manifests store and ``train.py --config/--dump-config``
exchange. The kwarg-style constructors (``engine.make_round_runner``,
``fed.make_async_runner``, ``baselines.make_fl_round``) remain the
internal layer the builder calls.

:class:`ServeSpec` / :func:`build_serve` are the serving counterparts:
they restore a federated training checkpoint, merge it into the global
model, and return a :class:`ServeProgram` around the
continuous-batching :class:`repro.serve.ServeEngine`.
"""
from repro.api.build import (  # noqa: F401
    ProgramState,
    RoundProgram,
    build,
    donated_jit,
)
from repro.api.deprecation import warn_once  # noqa: F401
from repro.api.serving import (  # noqa: F401
    ADMISSION_MODES,
    ServeProgram,
    ServeSpec,
    build_serve,
    restore_global_params,
)
from repro.api.specs import (  # noqa: F401
    EXECUTION_MODES,
    FL_METHODS,
    METHODS,
    OPTIMIZER_ALIASES,
    OPTIMIZERS,
    SCALA_METHODS,
    SFL_METHODS,
    DataSpec,
    ExecutionSpec,
    ExperimentSpec,
    FedSpec,
    OptimSpec,
)
from repro.api.trainer import (  # noqa: F401
    Trainer,
    build_image_data,
    build_lm_data,
)

__all__ = [
    "ADMISSION_MODES", "EXECUTION_MODES", "FL_METHODS", "METHODS",
    "OPTIMIZER_ALIASES", "OPTIMIZERS", "SCALA_METHODS", "SFL_METHODS",
    "DataSpec", "ExecutionSpec", "ExperimentSpec", "FedSpec", "OptimSpec",
    "ProgramState", "RoundProgram", "ServeProgram", "ServeSpec", "Trainer",
    "build", "build_image_data", "build_lm_data", "build_serve",
    "donated_jit", "restore_global_params", "warn_once",
]
