"""Gemma3-12B — 5:1 local:global attention, 128k context, 262k vocab.

[hf:google/gemma-3-1b-pt family, scaled to the 12B variant]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    source="hf:google/gemma-3-12b-pt",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    # 5 local (1024-token sliding window) : 1 global
    window_pattern=(1024, 1024, 1024, 1024, 1024, None),
    qk_norm=True,
    rope_theta=1_000_000.0,
    act="gelu",
    tied_embeddings=True,
    split_layer=2,
    param_dtype="bfloat16",
    # 12B: ZeRO/FSDP over all chips beats TP on the collective
    # roofline term (EXPERIMENTS.md §Perf-beyond)
    sharding_profile="fsdp",
)
