"""Jamba-1.5-Large (398B) — Mamba+attention 1:7 interleave with MoE 16e top-2.

[arXiv:2403.19887] — period-8 layer pattern: one attention layer per 7
Mamba layers; every second layer uses the 16-expert MoE FFN.
"""
from repro.configs.base import MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887 (Jamba-1.5-Large)",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    # attn at layer 4 of each 8-layer period (matches Jamba's placement)
    mixer_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    # MoE every other layer
    ffn_pattern=("dense", "moe"),
    pos_embed="none",                # Jamba uses no explicit positional encoding
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=24576),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    split_layer=2,
    param_dtype="bfloat16",
)
