"""DBRX-base (132B) — fine-grained 16-expert top-4 MoE.

[hf:databricks/dbrx-base]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    source="hf:databricks/dbrx-base",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    rope_theta=500_000.0,
    ffn_pattern=("moe",),
    moe=MoEConfig(num_experts=16, top_k=4, d_expert=10752),
    split_layer=2,
    param_dtype="bfloat16",
    # 132B MoE: "fsdp" measured 1.3x better on collectives but the
    # per-layer gathered expert weights blow HBM (peak 30.5GB) — stays on
    # TP+FSDP (EXPERIMENTS.md §Perf-beyond)
)
