"""xLSTM-1.3B — sLSTM + mLSTM block stack (attention-free).

[arXiv:2405.04517] — xLSTM[7:1]: one sLSTM block per 7 mLSTM blocks.
d_ff=0: all FFN capacity lives inside the block up/down projections.
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    source="arXiv:2405.04517 (xLSTM 1.3B)",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    mixer_pattern=("mlstm", "mlstm", "mlstm", "slstm",
                   "mlstm", "mlstm", "mlstm", "mlstm"),
    ffn_pattern=("none",),
    pos_embed="none",
    xlstm=XLSTMConfig(),
    split_layer=2,
    # 1.3B params replicate comfortably; the mLSTM chunkwise scan emits
    # thousands of tiny TP collectives under the "tp" profile (25k+ ARs
    # per step) — pure client/data parallelism removes all of them
    # (EXPERIMENTS.md §Perf)
    sharding_profile="dp",
)
