"""Granite-3.0-8B — dense GQA decoder.

[hf:ibm-granite/granite-3.0-8b-base (family card: granite-3.0-2b-base)]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    source="hf:ibm-granite/granite-3.0-8b-base",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    rope_theta=10_000.0,
    tied_embeddings=True,
    split_layer=2,
    # 8B does not replicate (32GB f32) but ZeRO/FSDP over all 256 chips
    # removes every TP activation collective (EXPERIMENTS.md §Perf-beyond)
    sharding_profile="fsdp",
)
