"""AlexNet adapted for CIFAR-shaped 32x32 inputs — the paper's own model.

[SCALA paper, Appendix E, Figure 6] — 6 client-side layers / 8 server-side
layers at the default split (paper §5.1), with the Appendix H split points
s1..s5 selectable.

This config is consumed by :mod:`repro.models.alexnet` (a CNN, not the
transformer assembler); it reuses :class:`ModelConfig` fields loosely:
``d_model`` is the classifier width and ``vocab_size`` the class count.
"""
from repro.configs.base import ModelConfig

# Conv stack (paper Fig. 6, CIFAR variant): channels per conv layer.
CONV_CHANNELS = (64, 192, 384, 256, 256)
FC_WIDTHS = (4096, 4096)

# Appendix H split points: number of *conv* layers kept on the client.
SPLIT_POINTS = {"s1": 1, "s2": 2, "s3": 3, "s4": 4, "s5": 5}

CONFIG = ModelConfig(
    name="alexnet-cifar",
    family="cnn",
    source="SCALA (2024) Appendix E Fig.6",
    num_layers=len(CONV_CHANNELS) + len(FC_WIDTHS) + 1,
    d_model=FC_WIDTHS[0],
    num_heads=1,
    num_kv_heads=1,
    head_dim=1,
    d_ff=FC_WIDTHS[0],
    vocab_size=10,                  # num classes (CIFAR10 default)
    mixer_pattern=("attn",),        # unused by the CNN path
    split_layer=2,                  # paper default == s2
    dtype="float32",
    param_dtype="float32",
)
