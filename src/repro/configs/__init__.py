"""Config registry: ``get_config(name)`` / ``list_configs()``.

The 10 assigned architectures + the paper's own AlexNet.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (
    INPUT_SHAPES,
    BlockSpec,
    InputShape,
    MambaConfig,
    ModelConfig,
    MoEConfig,
    ScalaConfig,
    TrainConfig,
    XLSTMConfig,
)

from repro.configs import (  # noqa: E402
    alexnet_cifar,
    dbrx_132b,
    gemma3_12b,
    granite_3_8b,
    h2o_danube_3_4b,
    internvl2_26b,
    jamba_1_5_large_398b,
    qwen1_5_0_5b,
    qwen3_moe_30b_a3b,
    whisper_tiny,
    xlstm_1_3b,
)

_REGISTRY: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        internvl2_26b,
        qwen3_moe_30b_a3b,
        qwen1_5_0_5b,
        jamba_1_5_large_398b,
        whisper_tiny,
        h2o_danube_3_4b,
        gemma3_12b,
        dbrx_132b,
        xlstm_1_3b,
        granite_3_8b,
        alexnet_cifar,
    )
}

ASSIGNED_ARCHS: List[str] = [n for n in _REGISTRY if n != "alexnet-cifar"]


def get_config(name: str) -> ModelConfig:
    try:
        cfg = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown architecture {name!r}; available: {sorted(_REGISTRY)}"
        ) from None
    cfg.validate()
    return cfg


def list_configs() -> List[str]:
    return sorted(_REGISTRY)


def get_shape(name: str) -> InputShape:
    try:
        return INPUT_SHAPES[name]
    except KeyError:
        raise KeyError(
            f"unknown input shape {name!r}; available: {sorted(INPUT_SHAPES)}"
        ) from None


__all__ = [
    "ASSIGNED_ARCHS",
    "BlockSpec",
    "INPUT_SHAPES",
    "InputShape",
    "MambaConfig",
    "ModelConfig",
    "MoEConfig",
    "ScalaConfig",
    "TrainConfig",
    "XLSTMConfig",
    "get_config",
    "get_shape",
    "list_configs",
]
