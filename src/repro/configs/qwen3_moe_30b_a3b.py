"""Qwen3-30B-A3B — 128-expert top-8 MoE, GQA kv=4, QK-norm.

[hf:Qwen/Qwen3-30B-A3B]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,                       # per-expert hidden dim
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    ffn_pattern=("moe",),
    moe=MoEConfig(num_experts=128, top_k=8, d_expert=768),
    split_layer=2,
    param_dtype="bfloat16",
    # 30B MoE: ZeRO/FSDP over all chips beats TP on the collective
    # roofline term (EXPERIMENTS.md §Perf-beyond)
    sharding_profile="fsdp",
)
