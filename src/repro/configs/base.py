"""Config dataclasses for the SCALA framework.

Every assigned architecture is expressed as a :class:`ModelConfig`; the
per-layer structure (attention vs. SSM mixers, dense vs. MoE FFNs,
sliding-window patterns) is described by cyclic patterns that the model
assembler expands into per-layer :class:`BlockSpec`s and groups into a
scan-friendly super-block.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration."""

    num_experts: int
    top_k: int
    d_expert: int                      # hidden dim of each expert FFN
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01    # load-balance auxiliary loss weight
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MambaConfig:
    """Mamba-1 selective SSM mixer configuration (Jamba-style)."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block configuration (mLSTM matrix memory / sLSTM scalar)."""

    proj_factor_mlstm: float = 2.0     # up-projection inside mLSTM blocks
    proj_factor_slstm: float = 4.0 / 3.0
    conv_kernel: int = 4
    chunk_size: int = 64               # chunkwise-parallel training chunk


@dataclass(frozen=True)
class BlockSpec:
    """Fully-resolved structure of one layer of the stack."""

    mixer: str                 # 'attn' | 'mamba' | 'mlstm' | 'slstm'
    ffn: str                   # 'dense' | 'moe' | 'none'
    window: Optional[int]      # sliding-window size for attn (None = global)
    cross_attn: bool = False   # insert a cross-attention sublayer (whisper)


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense|moe|hybrid|ssm|vlm|audio|cnn
    source: str                        # citation for the config numbers

    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- layer structure patterns (cycled over layer index) ---
    mixer_pattern: Tuple[str, ...] = ("attn",)
    ffn_pattern: Tuple[str, ...] = ("dense",)
    window_pattern: Tuple[Optional[int], ...] = (None,)
    cross_attn: bool = False           # every attn layer also cross-attends

    # --- attention details ---
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    pos_embed: str = "rope"            # rope|learned|none
    max_position: int = 524_288
    attn_logit_softcap: Optional[float] = None

    # --- embeddings / head ---
    tied_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"                  # mlp activation: silu (gated) | gelu

    # --- sub-configs ---
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # --- modality frontend (stubbed per the brief) ---
    frontend: Optional[str] = None     # None | 'vision' | 'audio'
    num_prefix_tokens: int = 0         # patch tokens (vlm) / enc memory (audio)
    frontend_dim: int = 0              # raw embedding dim before projector

    # --- SCALA split ---
    split_layer: int = 2               # client-side = embed + blocks[:split_layer]

    # --- distribution policy (§Perf iteration 2) ---
    # "tp": weights tensor/expert-parallel over `model`, FSDP over `data`.
    # "dp": weights replicated, batch over every mesh axis (client over
    #       data, per-client batch over model) — zero activation
    #       collectives; right when params fit per-chip HBM.
    sharding_profile: str = "tp"

    # --- numerics ---
    dtype: str = "bfloat16"            # activation/compute dtype
    param_dtype: str = "float32"       # parameter storage dtype

    # ------------------------------------------------------------------
    def block_spec(self, layer: int) -> BlockSpec:
        mixer = self.mixer_pattern[layer % len(self.mixer_pattern)]
        ffn = self.ffn_pattern[layer % len(self.ffn_pattern)]
        window = self.window_pattern[layer % len(self.window_pattern)]
        return BlockSpec(
            mixer=mixer,
            ffn=ffn,
            window=window if mixer == "attn" else None,
            cross_attn=self.cross_attn and mixer == "attn",
        )

    @property
    def block_specs(self) -> Tuple[BlockSpec, ...]:
        return tuple(self.block_spec(l) for l in range(self.num_layers))

    @property
    def group_size(self) -> int:
        """Smallest period of the layer pattern that divides num_layers.

        The transformer assembler stacks params of one *group* of layers
        and scans over ``num_layers // group_size`` groups, keeping the
        HLO small for the 48-72 layer archs.
        """
        period = math.lcm(
            len(self.mixer_pattern), len(self.ffn_pattern), len(self.window_pattern)
        )
        while self.num_layers % period != 0:
            period += period
            if period > self.num_layers:
                return self.num_layers
        return period

    @property
    def num_groups(self) -> int:
        return self.num_layers // self.group_size

    @property
    def uses_attention(self) -> bool:
        return any(s.mixer == "attn" for s in self.block_specs)

    @property
    def pure_full_attention(self) -> bool:
        """True iff every mixer is global (non-windowed) attention."""
        return all(s.mixer == "attn" and s.window is None for s in self.block_specs)

    @property
    def supports_long_decode(self) -> bool:
        """long_500k eligibility per the brief: SSM / hybrid / windowed."""
        return not self.pure_full_attention and self.family != "audio"

    @property
    def is_decoder(self) -> bool:
        return self.family != "cnn"

    def validate(self) -> None:
        assert self.num_heads % self.num_kv_heads == 0, self.name
        assert 0 < self.split_layer < self.num_layers, self.name
        if "moe" in self.ffn_pattern:
            assert self.moe is not None, self.name
        if "mamba" in self.mixer_pattern:
            assert self.mamba is not None, self.name
        if {"mlstm", "slstm"} & set(self.mixer_pattern):
            assert self.xlstm is not None, self.name

    def reduced(self, **overrides) -> "ModelConfig":
        """A CPU-smoke-test variant of the same family (<=2 groups,
        d_model<=512, <=4 experts)."""
        gs = self.group_size
        num_layers = min(self.num_layers, 4 if gs == 1 else 2 * gs)
        d_model = min(self.d_model, 256)
        num_heads = min(self.num_heads, 4)
        num_kv = max(1, min(self.num_kv_heads, num_heads, 2))
        while num_heads % num_kv:
            num_kv -= 1
        head_dim = max(8, d_model // num_heads)
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                num_experts=min(4, self.moe.num_experts),
                top_k=min(2, self.moe.top_k),
                d_expert=min(128, self.moe.d_expert),
            )
        window = tuple(
            (None if w is None else min(w, 64)) for w in self.window_pattern
        )
        base = dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=num_layers,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            moe=moe,
            window_pattern=window,
            num_prefix_tokens=min(self.num_prefix_tokens, 8),
            frontend_dim=min(self.frontend_dim, 64) if self.frontend_dim else 0,
            split_layer=max(1, min(self.split_layer, num_layers - 1)),
            param_dtype="float32",
            dtype="float32",
        )
        return dataclasses.replace(base, **overrides) if overrides else base


# ---------------------------------------------------------------------------
# Input shapes (assigned grid)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                          # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# SCALA / training configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ScalaConfig:
    """Hyper-parameters of the SCALA algorithm (paper §5.1 defaults)."""

    num_clients: int = 100             # K, total client population
    participation: float = 0.10        # r, fraction sampled per round
    local_iters: int = 5               # T
    server_batch: int = 320            # B (concatenated minibatch size)
    lr: float = 0.01                   # eta (plain SGD, paper default)
    tau: float = 1.0                   # logit-adjustment temperature
    adjust_server: bool = True         # eq. (14)
    adjust_client: bool = True         # eq. (15)
    label_smoothing: float = 0.0
    prior_eps: float = 1e-8            # numerical floor for log P(y)
    # dtype for cross-device gradient reductions in the manual-SPMD ("dp")
    # step; bf16 halves the only remaining wire traffic (and its buffers)
    # at the usual DDP-compression numerics cost. None = reduce in the
    # gradient's native dtype (exact).
    grad_reduce_dtype: Optional[str] = "bfloat16"

    @property
    def clients_per_round(self) -> int:
        return max(1, round(self.num_clients * self.participation))


@dataclass(frozen=True)
class TrainConfig:
    """End-to-end training-run config (examples / benchmarks scale)."""

    rounds: int = 50                   # I, global iterations
    seed: int = 0
    optimizer: str = "sgd"             # sgd | momentum | adamw
    momentum: float = 0.0
    weight_decay: float = 0.0
    eval_every: int = 10
    log_every: int = 10
    checkpoint_every: int = 0          # 0 = disabled
    checkpoint_dir: str = ""
