"""InternVL2-26B language backbone (InternLM2-20B) + stub InternViT frontend.

[arXiv:2404.16821] — vision encoder (InternViT-6B) and MLP projector are
stubbed per the brief: ``input_specs`` supplies pre-computed patch
embeddings of shape (batch, num_prefix_tokens, frontend_dim) which the
projector maps into d_model.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    source="arXiv:2404.16821 (InternVL2-26B; InternLM2-20B backbone)",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    rope_theta=1_000_000.0,
    act="silu",
    frontend="vision",
    num_prefix_tokens=256,       # 256 patch tokens per image tile
    frontend_dim=3200,           # InternViT-6B output width
    split_layer=2,
    param_dtype="bfloat16",
    # 26B: ZeRO/FSDP over all chips beats TP on the collective
    # roofline term (EXPERIMENTS.md §Perf-beyond)
    sharding_profile="fsdp",
)
