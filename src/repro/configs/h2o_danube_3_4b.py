"""H2O-Danube-3-4B — llama/mistral-style dense decoder with sliding-window.

[arXiv:2401.16818]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    source="arXiv:2401.16818 (H2O-Danube 4B)",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    window_pattern=(4096,),          # mistral-style SWA on every layer
    rope_theta=10_000.0,
    split_layer=2,
    # 4B: ZeRO/FSDP over all chips beats TP on the collective
    # roofline term (EXPERIMENTS.md §Perf-beyond)
    sharding_profile="fsdp",
)
