"""Whisper-tiny decoder backbone with stub audio-encoder memory.

[arXiv:2212.04356] — the mel-spectrogram + conv frontend and the audio
encoder are stubbed per the brief: ``input_specs`` supplies encoder
memory embeddings (batch, num_prefix_tokens=1500, d_model) which every
decoder layer cross-attends.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    source="arXiv:2212.04356 (Whisper tiny, decoder)",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    cross_attn=True,
    pos_embed="learned",
    max_position=32768,
    act="gelu",
    tied_embeddings=True,
    frontend="audio",
    num_prefix_tokens=1500,      # encoder output frames (30s @ 50Hz)
    frontend_dim=384,
    split_layer=1,
    # 39M params: tensor-parallelism is pure overhead at this size — pure
    # client/data parallelism (see EXPERIMENTS.md §Perf)
    sharding_profile="dp",
)
