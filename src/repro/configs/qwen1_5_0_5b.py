"""Qwen1.5-0.5B — small dense decoder with QKV bias and tied embeddings.

[hf:Qwen/Qwen1.5-0.5B]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    tied_embeddings=True,
    rope_theta=1_000_000.0,
    split_layer=2,
    # 0.5B params fit per-chip HBM with room to spare: pure client/data
    # parallelism beats 16-way TP by ~40x on the collective roofline term
    # (EXPERIMENTS.md §Perf iteration 2)
    sharding_profile="dp",
)
