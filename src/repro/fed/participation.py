"""Participation scheduling: which clients join each round.

SCALA's partial-participation setting (paper §5, Table 2) changes the
label distribution of the participating subset every round, so the
engine must recompute priors / logit adjustments per subset. The
schedulers here realize that as *jittable, scan-compatible* ops: the
client count C is static (the stacked (C, ...) param layout never
changes shape) and participation is a per-round boolean mask (stored as
0/1 float32) threaded through :func:`repro.core.engine.split_step_grads`
— masked-out clients contribute zero weight to the priors, the losses,
and the aggregation.

  =================  =====================================================
  scheduler          per-round subset
  =================  =====================================================
  :func:`full`       everyone, every round (mask of ones; stateless)
  :func:`uniform`    ``m = max(1, round(frac * C))`` clients uniformly
                     without replacement (random permutation prefix)
  :func:`dirichlet`  availability skew: per-round client-availability
                     probabilities ~ Dirichlet(alpha·1), then m clients
                     without replacement via Gumbel-top-k on those
                     probabilities (small alpha => a few clients dominate
                     round after round — the heterogeneous-availability
                     regime)
  =================  =====================================================

Scheduler state is a pytree (the PRNG key for the random schedulers)
threaded through rounds by the runner; ``init(key)`` builds it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

SCHEDULERS = ("full", "uniform", "dirichlet")


@dataclass(frozen=True)
class ParticipationScheduler:
    """``sample(state) -> (mask (C,) float32 0/1, new_state)``.

    ``subset_size`` is the *static* per-round participant count (every
    scheduler samples exactly this many ones) — the engine's sparse-slot
    path (``make_round_runner(slot_gather=True)``) sizes its dense
    ``[K_active]`` compute axis from it. ``None`` means full
    participation (``num_clients``).
    """

    name: str
    num_clients: int
    init: Callable[[Any], Any]
    sample: Callable[[Any], Tuple[Any, Any]]
    stateful: bool = True
    subset_size: Optional[int] = None
    #: per-round ones are balanced over this many contiguous slot blocks
    #: (``subset_size / shards`` per block). 1 = unconstrained sampling.
    #: The sharded (lace_dp) sparse round requires a scheduler whose
    #: ``shards`` is a multiple of the client-axis shard count, so every
    #: shard's local gather has the same static size.
    shards: int = 1


def _subset_size(num_clients: int, frac: float) -> int:
    m = max(1, round(num_clients * frac))
    return min(m, num_clients)


def full(num_clients: int) -> ParticipationScheduler:
    """Full participation — the legacy engine behavior, as a scheduler."""

    def init(key):
        return ()

    def sample(state):
        return jnp.ones((num_clients,), jnp.float32), state

    return ParticipationScheduler(name="full", num_clients=num_clients,
                                  init=init, sample=sample, stateful=False,
                                  subset_size=num_clients)


def uniform(num_clients: int, frac: float,
            shards: int = 1) -> ParticipationScheduler:
    """Uniform-without-replacement sampling of round(frac*C) clients.

    ``shards > 1`` balances the subset over ``shards`` contiguous slot
    blocks: ``m / shards`` clients sampled uniformly within each block of
    ``C / shards`` slots (m is rounded up to a multiple of ``shards``).
    This is the sharded-client-axis sampler — each mesh shard owning a
    block gathers exactly its share of the subset, so the in-shard
    sparse gather has a static local size — and doubles as a per-region
    quota (every edge of a matching :func:`repro.fed.aggregators.hierarchical`
    setup contributes equally many participants).
    """
    if shards < 1 or num_clients % shards:
        raise ValueError(f"{num_clients} clients do not divide into "
                         f"{shards} shards")
    m = _subset_size(num_clients, frac)
    m = min(num_clients, ((m + shards - 1) // shards) * shards)
    block = num_clients // shards
    m_l = m // shards

    def init(key):
        return {"key": key}

    def sample(state):
        key, sub = jax.random.split(state["key"])
        if shards == 1:
            perm = jax.random.permutation(sub, num_clients)
            mask = jnp.zeros((num_clients,),
                             jnp.float32).at[perm[:m]].set(1.0)
        else:
            perms = jax.vmap(
                lambda k: jax.random.permutation(k, block))(
                    jax.random.split(sub, shards))
            picks = (perms[:, :m_l]
                     + (jnp.arange(shards) * block)[:, None]).reshape(-1)
            mask = jnp.zeros((num_clients,),
                             jnp.float32).at[picks].set(1.0)
        return mask, {"key": key}

    return ParticipationScheduler(name="uniform", num_clients=num_clients,
                                  init=init, sample=sample, subset_size=m,
                                  shards=shards)


def dirichlet(num_clients: int, frac: float,
              alpha: float = 0.3) -> ParticipationScheduler:
    """Dirichlet-skewed availability: p ~ Dir(alpha·1) per round, then m
    clients without replacement ∝ p (Gumbel-top-k)."""
    m = _subset_size(num_clients, frac)

    def init(key):
        return {"key": key}

    def sample(state):
        key, k_avail, k_gumbel = jax.random.split(state["key"], 3)
        g = jax.random.gamma(k_avail, jnp.float32(alpha), (num_clients,))
        avail = g / jnp.maximum(g.sum(), 1e-8)
        score = jnp.log(avail + 1e-20) + jax.random.gumbel(
            k_gumbel, (num_clients,))
        # lax.top_k == the stable descending argsort's first m entries:
        # both take the m largest scores, equal scores to the lower
        # slot id — selection-identical without the O(K log K) full
        # sort (test-enforced in tests/test_arrival.py)
        _, top = jax.lax.top_k(score, m)
        mask = jnp.zeros((num_clients,), jnp.float32).at[top].set(1.0)
        return mask, {"key": key}

    return ParticipationScheduler(name="dirichlet", num_clients=num_clients,
                                  init=init, sample=sample, subset_size=m)


def make_participation(spec: str, num_clients: int) -> ParticipationScheduler:
    """Parse a launcher-flag spec into a scheduler.

    ``"full"`` | ``"uniform:FRAC[:SHARDS]"`` |
    ``"dirichlet:FRAC[:ALPHA]"``.
    """
    parts = spec.split(":")
    name = parts[0]
    if name == "full":
        return full(num_clients)
    if name == "uniform":
        if len(parts) not in (2, 3):
            raise ValueError("uniform spec is 'uniform:FRAC[:SHARDS]'")
        shards = int(parts[2]) if len(parts) == 3 else 1
        return uniform(num_clients, float(parts[1]), shards=shards)
    if name == "dirichlet":
        if len(parts) not in (2, 3):
            raise ValueError("dirichlet spec is 'dirichlet:FRAC[:ALPHA]'")
        alpha = float(parts[2]) if len(parts) == 3 else 0.3
        return dirichlet(num_clients, float(parts[1]), alpha=alpha)
    raise ValueError(f"unknown participation scheduler {name!r}; "
                     f"expected {SCHEDULERS}")
