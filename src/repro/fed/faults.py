"""Deterministic failure injection for federated rounds.

Real fleets decide "participation" through failures, not schedulers:
clients crash mid-round, return NaN/Inf or garbage updates, or straggle
forever.  This module provides a PRNG-keyed fault model that composes
with every execution mode (masked / sparse / async / delta snapshots)
so chaos runs are spec-level JSON like everything else.

Spec grammar (comma-joined clauses, mirroring ``make_delays``)::

    drop:P              # client never arrives this round (prob P)
    corrupt:P[:MODE[:SCALE]]
                        # update corrupted in transit; MODE in
                        # {nan, inf, noise}, SCALE only for noise
    stall:P[:FACTOR]    # finish time inflated by FACTOR (async) /
                        # client treated as absent (sync)

e.g. ``"drop:0.1,corrupt:0.05:nan,stall:0.02"``.  All randomness flows
from a dedicated fault key threaded through the fed state, so a chaos
run is exactly reproducible from its seed.

Semantics per execution mode:

- **sync** (masked / sparse): ``drop`` and ``stall`` fold into the
  participation mask *before* the local scan — the eq. 14/15 priors and
  logit adjustments recompute over the reduced subset automatically via
  the mask-fold path in ``split_step_grads``.  ``corrupt`` is applied to
  the trained client-half params *after* the scan (the update is
  corrupted in transit; in-round server training is not poisoned).
- **async**: ``drop`` removes an arrival from the event's contribution
  mask, ``corrupt`` poisons the arriving update, ``stall`` multiplies
  the re-dispatch delay by ``stall_factor`` (later rescued by the
  deadline/backoff machinery in :mod:`repro.fed.runtime`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

CORRUPT_MODES = ("nan", "inf", "noise")


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Per-round/per-arrival fault probabilities (all independent)."""

    drop: float = 0.0
    corrupt: float = 0.0
    corrupt_mode: str = "nan"
    noise_scale: float = 10.0
    stall: float = 0.0
    stall_factor: float = 1000.0
    spec: str = ""

    @property
    def any_faults(self) -> bool:
        return (self.drop > 0) or (self.corrupt > 0) or (self.stall > 0)


def make_faults(spec: Optional[str]) -> Optional[FaultModel]:
    """Parse a fault spec string (see module docstring for grammar).
    ``None`` and already-parsed :class:`FaultModel`s pass through."""
    if spec is None or isinstance(spec, FaultModel):
        return spec
    kw: Dict[str, Any] = {"spec": spec}
    for clause in str(spec).split(","):
        clause = clause.strip()
        if not clause:
            continue
        parts = clause.split(":")
        name = parts[0].strip().lower()
        if name == "drop":
            if len(parts) != 2:
                raise ValueError(f"drop clause needs one probability: {clause!r}")
            kw["drop"] = float(parts[1])
        elif name == "corrupt":
            if len(parts) < 2 or len(parts) > 4:
                raise ValueError(
                    f"corrupt clause is corrupt:P[:MODE[:SCALE]]: {clause!r}")
            kw["corrupt"] = float(parts[1])
            if len(parts) >= 3:
                mode = parts[2].strip().lower()
                if mode not in CORRUPT_MODES:
                    raise ValueError(
                        f"corrupt mode {mode!r} not in {CORRUPT_MODES}")
                kw["corrupt_mode"] = mode
            if len(parts) == 4:
                kw["noise_scale"] = float(parts[3])
        elif name == "stall":
            if len(parts) < 2 or len(parts) > 3:
                raise ValueError(f"stall clause is stall:P[:FACTOR]: {clause!r}")
            kw["stall"] = float(parts[1])
            if len(parts) == 3:
                kw["stall_factor"] = float(parts[2])
        else:
            raise ValueError(
                f"unknown fault clause {name!r} (want drop/corrupt/stall)")
    if len(kw) == 1:                        # only the spec echo: no clauses
        raise ValueError(f"empty fault spec {spec!r}; want comma-joined "
                         "drop:P | corrupt:P[:MODE[:SCALE]] | "
                         "stall:P[:FACTOR]")
    fm = FaultModel(**kw)
    for p in (fm.drop, fm.corrupt, fm.stall):
        if not 0.0 <= p <= 1.0:
            raise ValueError(f"fault probabilities must be in [0,1]: {spec!r}")
    if fm.stall_factor < 1.0:
        raise ValueError("stall factor must be >= 1")
    return fm


def sample_fault_masks(fm: FaultModel, key, n: int) -> Dict[str, jnp.ndarray]:
    """Draw independent 0/1 fault masks of shape (n,) for one event.

    Returns float32 masks ``{"drop", "corrupt", "stall"}`` where 1 means
    the fault fires for that client/arrival.  Always consumes the key
    the same way regardless of which probabilities are zero, so a spec
    change never silently reshuffles the other fault streams.
    """
    kd, kc, ks = jax.random.split(key, 3)

    def bern(k, p):
        return jax.random.bernoulli(k, p, (n,)).astype(jnp.float32)

    return {
        "drop": bern(kd, fm.drop),
        "corrupt": bern(kc, fm.corrupt),
        "stall": bern(ks, fm.stall),
    }


def corrupt_update(fm: FaultModel, key, stacked_params, corrupt_mask):
    """Corrupt rows (leading client axis) of a stacked param tree.

    ``corrupt_mask`` is (C,) 0/1; rows where it fires are overwritten
    with NaN / Inf, or perturbed with scaled Gaussian noise, depending
    on ``fm.corrupt_mode``.  Deterministic in ``key``.
    """
    leaves, treedef = jax.tree.flatten(stacked_params)
    out = []
    for i, leaf in enumerate(leaves):
        m = corrupt_mask.reshape((-1,) + (1,) * (leaf.ndim - 1)) > 0
        if fm.corrupt_mode == "nan":
            bad = jnp.full_like(leaf, jnp.nan)
        elif fm.corrupt_mode == "inf":
            bad = jnp.full_like(leaf, jnp.inf)
        else:  # noise
            kn = jax.random.fold_in(key, i)
            noise = fm.noise_scale * jax.random.normal(
                kn, leaf.shape, dtype=jnp.float32)
            bad = leaf + noise.astype(leaf.dtype)
        out.append(jnp.where(m, bad, leaf))
    return jax.tree.unflatten(treedef, out)
