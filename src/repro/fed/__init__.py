"""repro.fed — the pluggable federation layer on top of the split-step
engine: client-model aggregation (:mod:`repro.fed.aggregators`) and
participation scheduling (:mod:`repro.fed.participation`), composed by
:func:`repro.core.engine.make_round_runner`.

The round-level state the runner threads (scheduler PRNG key, aggregator
round ages, ...) lives in a plain dict ``{"sched": ..., "agg": ...}``
built by :func:`init_fed_state`.
"""
from __future__ import annotations

from typing import Any, Optional

from repro.fed.aggregators import (  # noqa: F401
    AGGREGATORS,
    AggContext,
    Aggregator,
    aggregation_priors,
    bias_compensated,
    fedavg,
    make_aggregator,
    staleness_weighted,
    weighted,
)
from repro.fed.participation import (  # noqa: F401
    SCHEDULERS,
    ParticipationScheduler,
    dirichlet,
    full,
    make_participation,
    uniform,
)


def is_stateful(aggregator: Optional[Aggregator],
                participation: Optional[ParticipationScheduler]) -> bool:
    """True iff the runner must thread a fed-state pytree across rounds."""
    return ((aggregator is not None and aggregator.stateful)
            or (participation is not None and participation.stateful))


def init_fed_state(key, aggregator: Optional[Aggregator] = None,
                   participation: Optional[ParticipationScheduler] = None,
                   num_clients: Optional[int] = None) -> dict:
    """Build the federation-state pytree threaded through rounds."""
    if num_clients is None:
        if participation is None:
            raise ValueError("init_fed_state needs num_clients when no "
                             "participation scheduler is given")
        num_clients = participation.num_clients
    sched: Any = participation.init(key) if participation is not None else ()
    agg: Any = aggregator.init(num_clients) if aggregator is not None else ()
    return {"sched": sched, "agg": agg}
