"""repro.fed — the pluggable federation layer on top of the split-step
engine: client-model aggregation (:mod:`repro.fed.aggregators`),
participation scheduling (:mod:`repro.fed.participation`), completion
delays (:mod:`repro.fed.delays`), and the asynchronous event runtime
(:mod:`repro.fed.runtime`), composed by
:func:`repro.core.engine.make_round_runner` /
:func:`repro.fed.runtime.make_async_runner`.

The round-level state the sync runner threads (scheduler PRNG key,
aggregator round ages, server-optimizer state, ...) lives in a plain
dict ``{"sched": ..., "agg": ...[, "server_opt": ...]}`` built by
:func:`init_fed_state`; the async runner's per-client dispatch state is
the :class:`repro.fed.runtime.AsyncFedState` pytree built by
:func:`repro.fed.runtime.init_async_state`.
"""
from __future__ import annotations

from typing import Any, Optional

from repro.fed import delays  # noqa: F401  (module: fed.delays.uniform etc.)
from repro.fed.aggregators import (  # noqa: F401
    AGGREGATORS,
    AggContext,
    Aggregator,
    aggregation_priors,
    bias_compensated,
    fedavg,
    hierarchical,
    make_aggregator,
    staleness_weighted,
    weighted,
)
from repro.fed.delays import (  # noqa: F401
    DELAY_MODELS,
    DelayModel,
    make_delays,
)
from repro.fed.faults import (  # noqa: F401
    CORRUPT_MODES,
    FaultModel,
    make_faults,
)
from repro.fed.guards import (  # noqa: F401
    GuardPolicy,
    make_guards,
)
from repro.fed.participation import (  # noqa: F401
    SCHEDULERS,
    ParticipationScheduler,
    dirichlet,
    full,
    make_participation,
    uniform,
)
from repro.fed.runtime import (  # noqa: F401
    ARRIVALS,
    LR_SCALES,
    SNAPSHOT_MODES,
    AsyncFedState,
    HostOptPager,
    arrival_cohort,
    async_state_bytes,
    init_async_state,
    make_arrival_pop,
    make_async_runner,
    ring_lookup,
    sharded_arrival_cohort,
)


def is_stateful(aggregator: Optional[Aggregator],
                participation: Optional[ParticipationScheduler]) -> bool:
    """True iff the runner must thread a fed-state pytree across rounds."""
    return ((aggregator is not None and aggregator.stateful)
            or (participation is not None and participation.stateful))


def init_fed_state(key, aggregator: Optional[Aggregator] = None,
                   participation: Optional[ParticipationScheduler] = None,
                   num_clients: Optional[int] = None,
                   server_optimizer=None, server_params=None,
                   faults=None, guards=None) -> dict:
    """Build the federation-state pytree threaded through sync rounds.

    ``server_optimizer`` / ``server_params``: when the round runner was
    built with a server-side FedOpt optimizer, its state is initialized
    here (under ``"server_opt"``) from the server half's param shapes.

    ``faults``: a :class:`repro.fed.faults.FaultModel` (or spec string)
    — seeds the dedicated fault-injection PRNG key under ``"faults"``.
    ``guards``: a :class:`repro.fed.guards.GuardPolicy` (or spec string)
    — seeds the running-median clip state under ``"guard"`` when the
    policy is stateful (``clip:TAU``).
    """
    import jax as _jax

    from repro.fed import faults as _faults_mod
    from repro.fed import guards as _guards_mod

    if num_clients is None:
        if participation is not None:
            num_clients = participation.num_clients
        elif aggregator is not None:
            raise ValueError("init_fed_state needs num_clients when no "
                             "participation scheduler is given")
    sched: Any = participation.init(key) if participation is not None else ()
    agg: Any = aggregator.init(num_clients) if aggregator is not None else ()
    state = {"sched": sched, "agg": agg}
    if server_optimizer is not None:
        if server_params is None:
            raise ValueError("init_fed_state needs server_params when a "
                             "server_optimizer is given")
        state["server_opt"] = server_optimizer.init(server_params)
    if faults is not None:
        _faults_mod.make_faults(faults)  # validate the spec
        state["faults"] = _jax.random.fold_in(key, 0x5FA17)
    if guards is not None:
        gp = _guards_mod.make_guards(guards)
        state["guard"] = _guards_mod.init_state() if gp.stateful else ()
    return state
