"""Guarded aggregation: per-client update screening before FedAvg.

One poisoned client update (NaN/Inf, or a huge-norm outlier) would
otherwise propagate straight into the eq. 10 average and destroy the
global model.  Guards screen each client's *update* (trained client
half minus the round-start client half) and shrink the effective
cohort:

- **non-finite rejection** — any NaN/Inf leaf entry rejects the client;
- **norm clipping** — the update's global L2 norm is clipped against a
  multiple of a running median of accepted norms (EMA-tracked state).

The SCALA-specific part lives in the callers (``engine.make_round_runner``
and ``fed.make_async_runner``): a rejected client does not merely get
weight zero — the round's local phase is re-run with the survivor mask
so the eq. 14/15 priors and logit adjustments are recomputed over the
surviving subset, exactly as if the rejected client had never
participated.

Spec grammar (comma-joined clauses)::

    nonfinite           # reject NaN/Inf updates (default on)
    clip:TAU[:BETA]     # clip norms above TAU x running median;
                        # BETA = median EMA rate (default 0.5)

``make_guards("nonfinite")`` is the stateless default; clipping needs a
``{"med", "n"}`` state threaded through the fed state.  Non-finite
rejection with zero faults injected is a bit-exact no-op (enforced by
tests/test_faults.py).  Norm clipping, when it actually triggers, is
deliberately NOT bit-preserving — it rescales real updates.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GuardPolicy:
    nonfinite: bool = True
    clip: float = 0.0   # multiple of the running median; 0 disables
    beta: float = 0.5   # EMA rate for the running median
    spec: str = "nonfinite"

    @property
    def stateful(self) -> bool:
        return self.clip > 0


def make_guards(spec: Optional[str]) -> Optional[GuardPolicy]:
    """Parse a guard spec string (see module docstring for grammar).
    ``None`` and already-parsed :class:`GuardPolicy`s pass through."""
    if spec is None or isinstance(spec, GuardPolicy):
        return spec
    kw = {"spec": spec, "nonfinite": False}
    saw_any = False
    for clause in str(spec).split(","):
        clause = clause.strip()
        if not clause:
            continue
        saw_any = True
        parts = clause.split(":")
        name = parts[0].strip().lower()
        if name == "nonfinite":
            if len(parts) != 1:
                raise ValueError(f"nonfinite clause takes no args: {clause!r}")
            kw["nonfinite"] = True
        elif name == "clip":
            if len(parts) < 2 or len(parts) > 3:
                raise ValueError(f"clip clause is clip:TAU[:BETA]: {clause!r}")
            kw["clip"] = float(parts[1])
            if len(parts) == 3:
                kw["beta"] = float(parts[2])
        else:
            raise ValueError(
                f"unknown guard clause {name!r} (want nonfinite/clip)")
    if not saw_any:
        raise ValueError(f"empty guard spec: {spec!r}")
    gp = GuardPolicy(**kw)
    if gp.clip < 0:
        raise ValueError("clip multiple must be >= 0")
    if not 0.0 < gp.beta <= 1.0:
        raise ValueError("median EMA rate must be in (0, 1]")
    if not gp.nonfinite and gp.clip == 0:
        raise ValueError(f"guard spec enables nothing: {spec!r}")
    return gp


def init_state():
    """Running-median state for norm clipping ({"med", "n"})."""
    return {"med": jnp.zeros((), jnp.float32), "n": jnp.zeros((), jnp.int32)}


def update_norms(delta_tree) -> jnp.ndarray:
    """Global L2 norm of each client's update: (C,) float32 over all
    leaves of a (C, ...)-stacked delta tree."""
    sq = [
        jnp.sum(
            (leaf.astype(jnp.float32) ** 2).reshape(leaf.shape[0], -1), axis=1)
        for leaf in jax.tree.leaves(delta_tree)
    ]
    return jnp.sqrt(sum(sq))


def finite_rows(delta_tree) -> jnp.ndarray:
    """(C,) float32 0/1: 1 where every leaf entry of the row is finite."""
    ok = None
    for leaf in jax.tree.leaves(delta_tree):
        row_ok = jnp.all(
            jnp.isfinite(leaf.astype(jnp.float32)).reshape(leaf.shape[0], -1),
            axis=1)
        ok = row_ok if ok is None else (ok & row_ok)
    return ok.astype(jnp.float32)


def screen(policy: GuardPolicy, delta_tree, mask,
           state) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, Any]:
    """Screen per-client updates.

    delta_tree: (C, ...)-stacked update (trained minus round-start,
    f32); mask: (C,) 0/1 participation (screening only considers
    participants); state: ``init_state()`` dict or ``()`` when clipping
    is off.

    Returns ``(accept, clip_factor, norms, new_state)``:
    ``accept`` (C,) 0/1 (non-participants are accepted — they carry no
    update), ``clip_factor`` (C,) multiplicative factor in (0, 1] to
    apply to each update (1 everywhere when clipping is off or
    untriggered), ``norms`` (C,) update L2 norms, and the advanced
    median state (``()`` in, ``()`` out).
    """
    m = mask.astype(jnp.float32)
    norms = update_norms(delta_tree)
    if policy.nonfinite:
        fin = finite_rows(delta_tree)
        # non-participants carry no update: always accepted
        accept = jnp.where(m > 0, fin, 1.0)
    else:
        accept = jnp.ones_like(m)
    factor = jnp.ones_like(norms)
    new_state = state
    if policy.clip > 0:
        if state == ():
            raise ValueError(
                "guard clip needs a running-median state — seed it via "
                "init_fed_state(..., guards=...) / init_async_state(..., "
                "guards=...)")
        part = m * accept  # participating, finite
        # median of this event's accepted norms (NaN-safe: masked-out
        # entries become NaN and are ignored by nanmedian)
        ev_med = jnp.nanmedian(jnp.where(part > 0, norms, jnp.nan))
        have = part.sum() > 0
        ev_med = jnp.where(jnp.isfinite(ev_med), ev_med, state["med"])
        first = state["n"] == 0
        med = jnp.where(
            have,
            jnp.where(first, ev_med,
                      (1.0 - policy.beta) * state["med"] + policy.beta * ev_med),
            state["med"])
        new_state = {"med": med,
                     "n": state["n"] + jnp.where(have, 1, 0).astype(jnp.int32)}
        limit = policy.clip * med
        trig = (part > 0) & (med > 0) & (norms > limit)
        factor = jnp.where(trig, limit / jnp.maximum(norms, 1e-30), 1.0)
    return accept, factor, norms, new_state


def apply_clip(start_params, trained_params, factor):
    """Rescale each client's update by ``factor`` (C,).

    Bit-exact no-op for rows where factor == 1: the original trained
    params pass through a ``where`` untouched instead of being
    reconstructed as ``start + 1.0 * delta``.
    """

    def clip_leaf(s, p):
        fb = factor.reshape((-1,) + (1,) * (p.ndim - 1))
        clipped = (s.astype(jnp.float32)
                   + fb * (p.astype(jnp.float32) - s.astype(jnp.float32))
                   ).astype(p.dtype)
        return jnp.where(fb < 1.0, clipped, p)

    return jax.tree.map(clip_leaf, start_params, trained_params)
