"""Client completion-delay models for the asynchronous execution layer.

Real client fleets are heterogeneous: a round's stragglers are set by
device speed, network, and availability, and the *shape* of the delay
distribution decides how asynchronous execution behaves (GAS,
arXiv:2409.01251 — staleness grows with the delay tail). The async
runtime (:mod:`repro.fed.runtime`) samples one completion delay per
dispatched client from a :class:`DelayModel`; the event scheduler then
pops arrival cohorts in finish-time order.

  =================  =====================================================
  model              delay of one dispatched client
  =================  =====================================================
  :func:`constant`   ``d`` exactly (``d=0`` degenerates to the fully
                     synchronous barrier — every client arrives at once)
  :func:`uniform`    ``U[lo, hi]`` — bounded jitter, thin tail
  :func:`lognormal`  ``median * exp(sigma * z)``, ``z ~ N(0,1)`` — the
                     heavy-tailed regime (a few clients straggle for much
                     longer than the median; sigma controls the tail)
  =================  =====================================================

Every model is a pure-jax op: ``sample(key, shape) -> float32 delays``
(non-negative), jittable and scan-compatible, so delay sampling lives
*inside* the compiled async event program.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

DELAY_MODELS = ("constant", "uniform", "lognormal")


@dataclass(frozen=True)
class DelayModel:
    """``sample(key, shape) -> (shape,) float32 non-negative delays``."""

    name: str
    sample: Callable[[Any, Tuple[int, ...]], Any]

    def sample_sharded(self, key, n: int, mesh):
        """Sample ``(n,)`` delays laid out over ``mesh``'s client axes.

        The client-mesh-sharded schedule path (``init_async_state(mesh=
        ...)``, ``arrival="topk:sharded"``): the sampling program
        compiles with the sharded output layout
        (:func:`repro.sharding.logical.client_scalar_spec`), so XLA
        partitions the counter-based threefry draw across the shards
        instead of materializing the (K,) vector on one device and
        re-laying it out. Threefry is value-deterministic, so the
        result is bit-identical to ``sample(key, (n,))``.
        """
        from jax.sharding import NamedSharding

        from repro.sharding.logical import client_scalar_spec

        sharding = NamedSharding(mesh, client_scalar_spec(mesh, n))
        fn = jax.jit(lambda k: self.sample(k, (n,)).astype(jnp.float32),
                     out_shardings=sharding)
        return fn(key)


def constant(d: float = 1.0) -> DelayModel:
    """Every client takes exactly ``d`` time units. ``d=0`` makes the
    async runner a barrier-synchronized round (the sync special case)."""
    if d < 0:
        raise ValueError(f"constant delay must be >= 0, got {d}")

    def sample(key, shape):
        return jnp.full(shape, d, jnp.float32)

    return DelayModel(name="constant", sample=sample)


def uniform(lo: float, hi: float) -> DelayModel:
    """Bounded jitter: delays ~ U[lo, hi]."""
    if not 0 <= lo <= hi:
        raise ValueError(f"uniform delay needs 0 <= lo <= hi, got [{lo}, {hi}]")

    def sample(key, shape):
        return jax.random.uniform(key, shape, jnp.float32, lo, hi)

    return DelayModel(name="uniform", sample=sample)


def lognormal(median: float = 1.0, sigma: float = 1.0) -> DelayModel:
    """Heavy-tailed delays: ``median * exp(sigma * N(0,1))``.

    The straggler regime — most clients finish near the median but the
    tail is unbounded; larger ``sigma`` means older arrivals and higher
    staleness under a fixed cohort size.
    """
    if median <= 0 or sigma < 0:
        raise ValueError(f"lognormal needs median > 0, sigma >= 0, got "
                         f"({median}, {sigma})")

    def sample(key, shape):
        z = jax.random.normal(key, shape, jnp.float32)
        return jnp.float32(median) * jnp.exp(jnp.float32(sigma) * z)

    return DelayModel(name="lognormal", sample=sample)


def make_delays(spec: str) -> DelayModel:
    """Parse a launcher-flag spec into a delay model.

    ``"zero"`` | ``"constant[:D]"`` | ``"uniform:LO:HI"`` |
    ``"lognormal[:MEDIAN[:SIGMA]]"``.
    """
    parts = spec.split(":")
    name = parts[0]
    if name == "zero":
        if len(parts) != 1:
            raise ValueError("zero spec takes no arguments")
        return constant(0.0)
    if name == "constant":
        if len(parts) > 2:
            raise ValueError("constant spec is 'constant[:D]'")
        return constant(float(parts[1]) if len(parts) == 2 else 1.0)
    if name == "uniform":
        if len(parts) != 3:
            raise ValueError("uniform spec is 'uniform:LO:HI'")
        return uniform(float(parts[1]), float(parts[2]))
    if name == "lognormal":
        if len(parts) > 3:
            raise ValueError("lognormal spec is 'lognormal[:MEDIAN[:SIGMA]]'")
        median = float(parts[1]) if len(parts) >= 2 else 1.0
        sigma = float(parts[2]) if len(parts) == 3 else 1.0
        return lognormal(median, sigma)
    raise ValueError(f"unknown delay model {name!r}; expected "
                     f"{('zero',) + DELAY_MODELS}")
