"""Pluggable client-model aggregation (the FL phase of the round).

The engine's round runner (:func:`repro.core.engine.make_round_runner`)
used to hard-code FedAvg (eq. 10). Under *partial participation* — the
setting SCALA's claims are about — the right aggregation weights change
per round (which clients showed up, how much data they hold, how biased
or how stale their updates are), so the FL phase is factored out into
an :class:`Aggregator` the round runner composes with:

  ============================  ============================================
  aggregator                    per-client weight (before normalization)
  ============================  ============================================
  :func:`fedavg`                ``mask_k``                (uniform over the
                                participating subset)
  :func:`weighted`              ``mask_k * n_k``          (eq. 10, data-size
                                proportional — the engine's legacy default)
  :func:`bias_compensated`      ``mask_k * n_k * exp(-gamma * TV(P_k, P))``
                                (BESplit-style: clients whose round label
                                distribution P_k diverges from the global
                                prior P push a biased update; their weight
                                decays with the total-variation distance)
  :func:`staleness_weighted`    ``mask_k * n_k * decay^age_k``  (GAS-style:
                                age_k = rounds since client k last
                                participated, tracked in aggregator state)
  :func:`hierarchical`          ``within_edge_k * top_e`` (two-tier: edges
                                fold their own cohort first, the server
                                folds the edge results)
  ============================  ============================================

All weights go through the mask-safe
:func:`repro.core.split.normalize_client_weights`, so zero-participation
clients (mask 0 or data size 0) are excluded without NaNs.

Every aggregator is a pure-jax, jittable/scan-compatible op over the
stacked ``(C, ...)`` client-param layout: ``aggregate`` returns the
*averaged* (unstacked) client model plus new aggregator state; callers
that need the stacked layout broadcast it back with
:func:`repro.core.split.stack_client_params`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.label_stats import client_and_concat_priors
from repro.core.split import normalize_client_weights, weighted_mean

AGGREGATORS = ("fedavg", "weighted", "bias_compensated", "staleness_weighted",
               "hierarchical")


def aggregation_priors(num_classes: int, labels, weights=None,
                       client_axis: int = 0):
    """(P_k (C,N), P_global (N,)) over one round's labels for the
    prior-aware aggregators. ``labels``/``weights`` carry the client
    dimension at ``client_axis`` (engine round batches: axis 1; baseline
    batches: axis 0); zero-weight entries (padding rows, masked-out
    clients) are excluded from the histograms."""
    C = labels.shape[client_axis]
    lab = jnp.moveaxis(labels, client_axis, 0).reshape(C, -1)
    w = (None if weights is None
         else jnp.moveaxis(weights, client_axis, 0).reshape(C, -1))
    return client_and_concat_priors(lab, num_classes, w)


@dataclass(frozen=True)
class AggContext:
    """Per-round inputs an aggregator may consume.

    num_clients: C, the static stacked-slot count;
    mask: (C,) 0/1 participation mask (None = full participation);
    data_sizes: (C,) per-client dataset sizes (None = uniform);
    p_k: (C, N) per-client label priors over the round's batches;
    p_global: (N,) global (population) label prior — the concatenated
    histogram over ALL clients, unmasked (``core.label_stats``).
    ``p_k``/``p_global`` are only materialized when the aggregator
    declares ``needs_priors`` (the round runner skips the histograms
    otherwise, keeping the default path's HLO unchanged).
    """

    num_clients: int = 0
    mask: Optional[Any] = None
    data_sizes: Optional[Any] = None
    p_k: Optional[Any] = None
    p_global: Optional[Any] = None

    @property
    def C(self) -> int:
        if self.num_clients:
            return self.num_clients
        for a in (self.mask, self.data_sizes, self.p_k):
            if a is not None:
                return a.shape[0]
        raise ValueError("AggContext cannot resolve the client count; set "
                         "num_clients")

    def base_weights(self):
        """data_sizes with a uniform fallback when None."""
        if self.data_sizes is not None:
            return self.data_sizes.astype(jnp.float32)
        return jnp.ones((self.C,), jnp.float32)


@dataclass(frozen=True)
class Aggregator:
    """The FL-phase protocol: per-round client weights + optional state.

    ``init(num_clients) -> state`` builds the (possibly empty) carry;
    ``client_weights(ctx, state) -> (weights (C,), state)`` returns
    *normalized* aggregation weights — the single variation point. The
    engine's round runner consumes ``client_weights`` directly (it needs
    the weights again for the ``"average"`` opt-state policy);
    ``aggregate`` is the packaged weighted-mean FL phase for callers that
    only want the averaged model (baselines, tests).
    """

    name: str
    init: Callable[[int], Any]
    client_weights: Callable[[AggContext, Any], Tuple[Any, Any]]
    needs_priors: bool = False
    stateful: bool = False
    #: shard-decomposable weight kernel for the manual-SPMD (shard_map)
    #: execution paths: ``shard_local(mask_l, sizes_l, client_axes,
    #: n_shards=1) -> (C_l,) raw weights`` over one shard's *local* slot
    #: block, such that the caller's global renormalization of
    #: ``raw * decay * mask`` (psum over ``client_axes``) reproduces the
    #: flat ``client_weights`` path up to float association. None means
    #: the aggregator cannot run inside a sharded client axis.
    shard_local: Optional[Callable] = None

    def aggregate(self, stacked_params, ctx: AggContext, state=()):
        """(stacked (C,...) client params, ctx, state) ->
        (averaged client params, new state)."""
        w, state = self.client_weights(ctx, state)
        return weighted_mean(stacked_params, w), state


def _stateless_init(num_clients: int):
    return ()


def fedavg() -> Aggregator:
    """Uniform average over the participating subset (classic FedAvg
    with equal client weights)."""

    def client_weights(ctx: AggContext, state):
        w = jnp.ones((ctx.C,), jnp.float32)
        return normalize_client_weights(w, ctx.mask), state

    def shard_local(mask_l, sizes_l, client_axes, n_shards: int = 1):
        return jnp.ones_like(mask_l, dtype=jnp.float32)

    return Aggregator(name="fedavg", init=_stateless_init,
                      client_weights=client_weights,
                      shard_local=shard_local)


def weighted() -> Aggregator:
    """Data-size-proportional FedAvg (paper eq. 10) — the engine's
    legacy aggregation; reduces to :func:`fedavg` when no sizes given."""

    def client_weights(ctx: AggContext, state):
        w = ctx.base_weights()
        return normalize_client_weights(w, ctx.mask), state

    def shard_local(mask_l, sizes_l, client_axes, n_shards: int = 1):
        return sizes_l.astype(jnp.float32)

    return Aggregator(name="weighted", init=_stateless_init,
                      client_weights=client_weights,
                      shard_local=shard_local)


def bias_compensated(gamma: float = 2.0) -> Aggregator:
    """BESplit-style bias-compensated FedAvg.

    Client k's round update is biased toward its own label distribution
    P_k; the compensation decays its aggregation weight with the
    total-variation distance to the *global* prior P (from
    :mod:`repro.core.label_stats` over the full population):

        w_k  ∝  mask_k * n_k * exp(-gamma * TV(P_k, P))

    gamma=0 recovers :func:`weighted`.
    """

    def client_weights(ctx: AggContext, state):
        if ctx.p_k is None or ctx.p_global is None:
            raise ValueError("bias_compensated needs ctx.p_k/p_global "
                             "(round label priors)")
        tv = 0.5 * jnp.abs(ctx.p_k.astype(jnp.float32)
                           - ctx.p_global.astype(jnp.float32)[None]).sum(-1)
        w = ctx.base_weights() * jnp.exp(-gamma * tv)
        return normalize_client_weights(w, ctx.mask), state

    return Aggregator(name="bias_compensated", init=_stateless_init,
                      client_weights=client_weights, needs_priors=True)


def staleness_weighted(decay: float = 0.5) -> Aggregator:
    """GAS-style staleness decay on per-client round age.

    State carries ``age`` (C,) — rounds since each client last
    participated. A returning client's contribution is decayed by
    ``decay**age`` (age 0 = participated last round too, full weight),
    modeling the staleness discount of asynchronous aggregation inside
    the synchronous scanned round. Ages update per round: participants
    reset to 0, absentees increment.

    Only meaningful with a participation scheduler over *stable* client
    identities (the fed layer's static-slot masking): under full
    participation every age stays 0 and this reduces to
    :func:`weighted`, and host-side subset re-stacking has no slot ->
    client correspondence for the ages to track.
    """

    def init(num_clients: int):
        return {"age": jnp.zeros((num_clients,), jnp.float32)}

    def client_weights(ctx: AggContext, state):
        age = state["age"]
        w = ctx.base_weights() * jnp.power(jnp.float32(decay), age)
        w = normalize_client_weights(w, ctx.mask)
        mask = (ctx.mask if ctx.mask is not None
                else jnp.ones((age.shape[0],), jnp.float32))
        new_age = jnp.where(mask > 0, 0.0, age + 1.0)
        return w, {"age": new_age}

    return Aggregator(name="staleness_weighted", init=init,
                      client_weights=client_weights, stateful=True)


def hierarchical(edges: int, edge: str = "weighted",
                 top: str = "weighted") -> Aggregator:
    """Two-tier (edge -> server) aggregation over contiguous slot blocks.

    The C static slots split into ``edges`` contiguous blocks ("edge
    aggregators" — a geo region, a silo, or one shard of the sharded
    client mesh axis). Each edge folds its own participating clients
    first with the ``edge`` rule (``"weighted"``: data-size proportional,
    eq. 10 within the edge; ``"fedavg"``: uniform), then the server folds
    the edge results with the ``top`` rule (``"weighted"``: by the edge's
    participating data mass; ``"fedavg"``: uniform over non-empty edges).
    The composition is expressed as one flat (C,) weight vector

        w_k  =  within_edge(k) * top(edge_of(k)),

    so the engine/runtime consume it like any other aggregator and the
    model average is a single :func:`weighted_mean` — the two-tier
    *communication* shape materializes on the sharded backends, where
    ``shard_local`` computes each shard's edges locally and only the
    O(params) edge partials cross shards (a psum). Priors / logit
    adjustments are orthogonal: they are recomputed per participating
    subset by the round program, not per edge.

    ``edge="weighted", top="weighted"`` is exactly flat :func:`weighted`
    (w_k ∝ mask_k n_k — test-enforced); differing tiers change the
    geometry, e.g. ``top="fedavg"`` gives every region equal say
    regardless of its data mass. An edge with no participants gets zero
    weight; a round with no participants at all falls back to the flat
    mask-safe normalization.

    C must divide by ``edges``; on a sharded client axis ``edges`` must
    divide by the shard count so every edge lives whole on one shard.
    """
    if edge not in ("fedavg", "weighted") or top not in ("fedavg",
                                                         "weighted"):
        raise ValueError(f"hierarchical tiers must be 'fedavg' or "
                         f"'weighted', got edge={edge!r} top={top!r}")
    if edges < 1:
        raise ValueError(f"edges must be >= 1, got {edges}")

    def _tiers(mask, sizes, n_edges: int):
        """-> (within-edge weights (C,), edge masses (E,))."""
        C = mask.shape[0]
        if C % n_edges:
            raise ValueError(f"{C} client slots do not divide into "
                             f"{n_edges} edges")
        base = sizes if edge == "weighted" else jnp.ones_like(sizes)
        raw = (base * mask).reshape(n_edges, C // n_edges)
        S = raw.sum(axis=1)
        within = (raw / jnp.maximum(S, 1e-8)[:, None]).reshape(C)
        T = jnp.where(S > 0, S if top == "weighted" else 1.0, 0.0)
        return within, T

    def client_weights(ctx: AggContext, state):
        C = ctx.C
        mask = (ctx.mask.astype(jnp.float32) if ctx.mask is not None
                else jnp.ones((C,), jnp.float32))
        within, T = _tiers(mask, ctx.base_weights(), edges)
        tot = T.sum()
        w = within * jnp.repeat(T / jnp.maximum(tot, 1e-8), C // edges)
        fallback = normalize_client_weights(jnp.ones((C,), jnp.float32),
                                            ctx.mask)
        return jnp.where(tot > 0, w, fallback), state

    def shard_local(mask_l, sizes_l, client_axes, n_shards: int = 1):
        if edges % n_shards:
            raise ValueError(f"hierarchical edges={edges} must divide over "
                             f"the {n_shards} client shards")
        edges_l = edges // n_shards
        within, T = _tiers(mask_l.astype(jnp.float32),
                           sizes_l.astype(jnp.float32), edges_l)
        tot = T.sum()
        if client_axes:
            tot = jax.lax.psum(tot, client_axes)
        C_l = mask_l.shape[0]
        return within * jnp.repeat(T / jnp.maximum(tot, 1e-8),
                                   C_l // edges_l)

    return Aggregator(name="hierarchical", init=_stateless_init,
                      client_weights=client_weights,
                      shard_local=shard_local)


def make_aggregator(spec: str, **kw) -> Aggregator:
    """Registry: build an aggregator from a compact spec string.

    ``"fedavg"`` | ``"weighted"`` | ``"bias_compensated[:GAMMA]"`` |
    ``"staleness_weighted[:DECAY]"`` | ``"hierarchical:EDGES[:EDGE[:TOP]]"``
    (keyword overrides still accepted for the parameterized aggregators).
    """
    parts = spec.split(":")
    name, args = parts[0], parts[1:]
    if name in ("fedavg", "weighted") and args:
        raise ValueError(f"aggregator {name!r} takes no spec arguments, "
                         f"got {spec!r}")
    if name == "fedavg":
        return fedavg()
    if name == "weighted":
        return weighted()
    if name == "bias_compensated":
        if len(args) > 1:
            raise ValueError("bias_compensated spec is "
                             "'bias_compensated[:GAMMA]'")
        gamma = float(args[0]) if args else kw.get("gamma", 2.0)
        return bias_compensated(gamma=gamma)
    if name == "hierarchical":
        if not args or len(args) > 3:
            raise ValueError("hierarchical spec is "
                             "'hierarchical:EDGES[:EDGE[:TOP]]'")
        return hierarchical(edges=int(args[0]),
                            edge=args[1] if len(args) > 1 else "weighted",
                            top=args[2] if len(args) > 2 else "weighted")
    if name in ("staleness_weighted", "staleness"):
        if len(args) > 1:
            raise ValueError("staleness_weighted spec is "
                             "'staleness_weighted[:DECAY]'")
        decay = float(args[0]) if args else kw.get("decay", 0.5)
        return staleness_weighted(decay=decay)
    raise ValueError(f"unknown aggregator {name!r}; expected {AGGREGATORS}")
