"""Asynchronous split-federated execution on top of the split-step engine.

The synchronous round (:func:`repro.core.engine.make_round_runner`) is a
barrier: every participating client runs T local iterations from the same
aggregated model, then the FL phase averages. Real fleets are
asynchronous — clients finish at different times and their updates were
computed against *older* server params. GAS (arXiv:2409.01251) shows the
workable recipe is staleness-aware delayed aggregation; this module
implements it as a *jit-compatible event schedule*:

1. Every client holds a **snapshot** of the global client half (the
   params it trains from) tagged with the server **version** it was taken
   at, plus a sampled **finish time** (:mod:`repro.fed.delays`).
2. One call of the async runner is one **event**: the ``cohort`` earliest
   finishers arrive. Their T local iterations run on a dense sparse-slot
   axis (gathered from the static K slots, exactly the engine's
   ``slot_gather`` path), with label priors and logit adjustments
   recomputed over the *arrival cohort* — the same per-subset semantics
   the sync path applies per participating subset.
3. The arrivals' trained client halves are folded into the global model
   with **staleness-weighted delayed aggregation** (FedAsync/GAS-style
   model mixing): per-arrival weights are the aggregator's weights
   decayed by ``staleness_decay ** age`` (age = server versions elapsed
   since the snapshot), renormalized over the cohort, and the global
   client half moves ``mix_rate`` of the way to the cohort average. The
   server half trains in-scan as always (it is never averaged) with an
   optional FedOpt ``server_optimizer`` over its event delta.
4. The cohort re-snapshots the new global model at the new version,
   samples fresh delays, and the event clock advances to the cohort's
   latest arrival. Busy clients keep their snapshots and finish times.

Everything — cohort selection, gather/scatter, delay sampling, the
staleness weights — is pure jax inside one compiled program per event.

**The sync round is the zero-delay special case**: with
``delays=constant(0)`` and ``cohort=K`` every client arrives at every
event with staleness 0, the cohort average is the full FedAvg, and
``mix_rate=1`` replaces the global model with it — bit-for-bit the
synchronous round runner (test-enforced at fp32 tolerance in
``tests/test_async.py``).

Snapshot storage (``snapshots=``):

* ``"dense"`` — the legacy layout: ``client_params`` materializes one
  client-half snapshot *per slot*, O(K x |w_c|) memory.
* ``"delta"`` — the million-client layout. The state invariant below
  says ``client_params[k]`` IS the global client half as of
  ``version[k]``: the per-client delta against the tagged server
  version is **identically zero**, so nothing per-client needs storing.
  A fixed-size **ring** of the ``ring_size`` most recent global client
  halves (slot ``v % ring_size`` holds global@v) plus the existing
  (K,) ``version`` tags reconstruct any snapshot on gather:
  ``ring[max(version_k, server_version - ring_size + 1) % ring_size]``.
  Resident snapshot memory is O(ring_size x |w_c| + cohort) — flat in
  K — and the path is **bit-identical** to dense storage while every
  arrival's staleness is < ``ring_size`` (test-enforced). A snapshot
  whose base version aged out of the ring is clamped to the oldest
  retained version — bounded-staleness eviction: the straggler trains
  from a slightly newer global model than it was dispatched with,
  which only *reduces* its effective staleness. Per-client optimizer
  state is not stored either, so ``"delta"`` requires a stateless
  local optimizer (plain SGD — the paper's setting) or
  ``opt_state_policy="reset"``.

:class:`AsyncFedState` invariants (maintained by :func:`init_async_state`
and every runner call; rely on them, don't re-derive):

* ``version[k] <= server_version`` elementwise; ``server_version``
  increments by exactly 1 per event.
* ``client_params[k]`` is the global client half as of ``version[k]`` —
  slots with ``version[k] == server_version`` hold the *current* global
  model. (``snapshots="delta"`` stores this redundancy-free: the ring
  holds one entry per recent version instead of one per client.)
* ``finish_time[k] >= now`` for busy clients; arrivals satisfy
  ``finish_time[k] <= new now`` at the event that pops them and are
  re-armed strictly into the future (for nonzero delays).
* ``server_version - version`` is the per-client staleness age — under a
  full-barrier schedule it reproduces the sync
  :func:`repro.fed.aggregators.staleness_weighted` age bookkeeping.

The manual-SPMD backend (``backend="lace_dp"``, pass ``mesh`` and
``batch_specs``) runs the whole event inside one ``shard_map``: each
shard of the client mesh axes pops ``cohort / n_shards`` of *its own*
earliest finishers (a balanced two-tier schedule — the shard is the
"edge", the psum across shards is the server fold), gathers them from
its local slots (or the replicated ring), and the cohort-weight
normalization / cohort average / event clock are combined with psums.
The per-shard pop is the one scheduling difference vs the single-program
runner: arrivals are balanced per shard rather than popped globally
(with zero delays and ``cohort=K`` the two schedules coincide).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ScalaConfig
from repro.core import engine
from repro.core.split import (normalize_client_weights, stack_client_params,
                              weighted_mean)
from repro.fed import aggregators as _agg
from repro.fed.delays import DelayModel
from repro.optim import optimizers, schedules

#: snapshot storage layouts for :class:`AsyncFedState`.
SNAPSHOT_MODES = ("dense", "delta")

#: per-arrival lr scaling policies (see :func:`make_async_runner`).
LR_SCALES = ("none", "cohort")

#: ring_versions tag for a slot that has never been written.
_NO_VERSION = jnp.int32(-(2 ** 30))


@dataclass(frozen=True)
class AsyncFedState:
    """Per-client dispatch state threaded through async events.

    client_params: (K, ...) stacked per-client snapshots of the global
    client half (what each client is training from) — ``()`` under
    ``snapshots="delta"``, where the ring replaces it;
    version: (K,) int32 server version each snapshot was taken at;
    server_version: () int32 global version (events applied so far);
    finish_time: (K,) float32 simulated completion time per client;
    now: () float32 event clock (the last cohort's latest arrival);
    key: PRNG key driving delay sampling;
    agg_state: aggregator carry (e.g. staleness ages) — usually () since
    the runtime tracks ages itself via ``version``;
    server_opt: server-side FedOpt optimizer state (or ());
    ring: (ring_size, ...) recent global client halves, slot
    ``v % ring_size`` holding global@v (``snapshots="delta"`` only);
    ring_versions: (ring_size,) int32 version tag per ring slot
    (un-written slots carry a large negative sentinel).
    """

    client_params: Any
    version: Any
    server_version: Any
    finish_time: Any
    now: Any
    key: Any
    agg_state: Any = ()
    server_opt: Any = ()
    ring: Any = ()
    ring_versions: Any = ()


jax.tree_util.register_dataclass(
    AsyncFedState,
    data_fields=("client_params", "version", "server_version", "finish_time",
                 "now", "key", "agg_state", "server_opt", "ring",
                 "ring_versions"),
    meta_fields=())


def init_async_state(key, client_params, delays: DelayModel, *,
                     aggregator=None,
                     server_optimizer: Optional[optimizers.Optimizer] = None,
                     server_params=None,
                     snapshots: str = "dense",
                     ring_size: int = 64,
                     num_clients: Optional[int] = None) -> AsyncFedState:
    """Dispatch all K clients at version 0.

    ``client_params`` is the stacked client half (every slot holds the
    same init — :func:`repro.core.split.stack_client_params`); each
    client's first completion delay is sampled immediately, so the first
    event pops the cohort of earliest finishers. Pass the same
    ``aggregator`` / ``server_optimizer`` the runner was built with so
    their state is initialized to matching shapes.

    With ``snapshots="delta"`` the per-client snapshots are NOT
    materialized: pass the global client half stacked over a single slot
    (or any stacked layout — row 0 is taken) plus ``num_clients=K``, and
    the state carries a ``ring_size``-deep ring of recent global client
    halves instead — O(ring_size), not O(K). ``ring_size`` bounds the
    reconstructable staleness (see the module docstring's eviction
    semantics).
    """
    if snapshots not in SNAPSHOT_MODES:
        raise ValueError(f"unknown snapshots mode {snapshots!r}; expected "
                         f"{SNAPSHOT_MODES}")
    lead = jax.tree.leaves(client_params)[0].shape[0]
    K = lead if num_clients is None else num_clients
    if snapshots == "dense" and num_clients is not None and lead != K:
        raise ValueError(f"dense snapshots need client_params stacked over "
                         f"all {K} clients, got {lead} slots")
    k_delay, k_carry = jax.random.split(jnp.asarray(key))
    if server_optimizer is not None and server_params is None:
        raise ValueError("init_async_state needs server_params when a "
                         "server_optimizer is given")
    if snapshots == "delta":
        if ring_size < 1:
            raise ValueError(f"ring_size must be >= 1, got {ring_size}")
        global_c = jax.tree.map(lambda a: a[0], client_params)
        snap = ()
        ring = jax.tree.map(
            lambda g: jnp.broadcast_to(g[None], (ring_size,) + g.shape),
            global_c)
        ring_versions = jnp.full((ring_size,), _NO_VERSION,
                                 jnp.int32).at[0].set(0)
    else:
        snap, ring, ring_versions = client_params, (), ()
    return AsyncFedState(
        client_params=snap,
        version=jnp.zeros((K,), jnp.int32),
        server_version=jnp.zeros((), jnp.int32),
        finish_time=delays.sample(k_delay, (K,)).astype(jnp.float32),
        now=jnp.zeros((), jnp.float32),
        key=k_carry,
        agg_state=aggregator.init(K) if aggregator is not None else (),
        server_opt=(server_optimizer.init(server_params)
                    if server_optimizer is not None else ()),
        ring=ring,
        ring_versions=ring_versions)


def arrival_cohort(finish_time, cohort: int, version=None):
    """The event schedule's pop: the ``cohort`` earliest finishers.

    Returns (idx (cohort,) ascending slot ids, mask (K,) 0/1 float32,
    t_event — the cohort's latest finish time, i.e. the new clock).
    Ties (equal finish times) break by snapshot ``version`` — the
    longest-waiting client goes first (FIFO) — then by slot id (lexsort
    is stable). Without the version key, degenerate schedules (zero or
    constant-tied delays with ``cohort < K``) would re-arm the lowest
    slot ids at the same finish time and starve every other slot; with
    it, zero delays pop slots round-robin in blocks of ``cohort``.
    """
    if version is None:
        order = jnp.argsort(finish_time)
    else:
        order = jnp.lexsort((version, finish_time))
    idx = jnp.sort(order[:cohort])
    K = finish_time.shape[0]
    mask = jnp.zeros((K,), jnp.float32).at[idx].set(1.0)
    t_event = jnp.max(jnp.take(finish_time, idx))
    return idx, mask, t_event


def ring_lookup(ring, versions, server_version, ring_size: int):
    """Reconstruct dense snapshots for slots ``versions`` from the ring.

    ``versions`` (m,) int32 snapshot tags; returns (snapshots with a
    leading (m,) axis, effective versions (m,)). A version older than
    the ring depth is clamped to the oldest retained version
    ``server_version - ring_size + 1`` (bounded-staleness eviction);
    otherwise the lookup is exact — ring slot ``v % ring_size`` holds
    the global client half written at version ``v``, and any v within
    the last ``ring_size`` versions is the slot's latest write.
    """
    eff = jnp.maximum(versions,
                      server_version - jnp.int32(ring_size - 1))
    slot = eff % ring_size
    return jax.tree.map(lambda r: jnp.take(r, slot, axis=0), ring), eff


def async_state_bytes(afed: AsyncFedState) -> dict:
    """Resident-memory accounting of an :class:`AsyncFedState`.

    ``snapshot_bytes`` is the param-sized component — O(K x |w_c|) for
    dense snapshots, O(ring_size x |w_c|) for the delta ring — and
    ``per_client_scalar_bytes`` the unavoidable (K,) tags (version +
    finish_time, ~8 bytes/client). The O(cohort + ring) scaling claim
    (BENCH_scale.json) is about the param-sized component.
    """

    def nbytes(tree) -> int:
        return int(sum(int(np.prod(l.shape)) * l.dtype.itemsize
                       for l in jax.tree.leaves(tree)))

    snap = nbytes(afed.client_params) + nbytes(afed.ring)
    per_client = nbytes(afed.version) + nbytes(afed.finish_time)
    other = nbytes((afed.ring_versions, afed.server_version, afed.now,
                    afed.key, afed.agg_state, afed.server_opt))
    return {"snapshot_bytes": snap,
            "per_client_scalar_bytes": per_client,
            "other_bytes": other,
            "total_bytes": snap + per_client + other}


def _resolve_schedule(schedule, scala: ScalaConfig, lr_scale: str,
                      cohort: int, num_clients: Optional[int]):
    """The event schedule's lr policy (``lr_scale``).

    The global ``step`` counter advances once per *local iteration* of
    whichever cohort arrived — with ``cohort < K`` the schedule ticks
    K/cohort times faster per unit of fleet-wide work than the sync
    round's, and each event moves the global model a full ``mix_rate``
    step from a cohort-sized sample. ``"cohort"`` scales the lr by
    ``cohort / K`` so per-event aggregate movement matches the sync
    round's per-participant scale; at ``cohort == K`` the factor is
    exactly 1.0 and the schedule is bit-identical to ``"none"``
    (test-enforced sync-equivalence).
    """
    if lr_scale not in LR_SCALES:
        raise ValueError(f"unknown lr_scale {lr_scale!r}; expected "
                         f"{LR_SCALES}")
    sched = schedule if schedule is not None else schedules.constant(scala.lr)
    if lr_scale == "none":
        return sched
    if num_clients is None:
        raise ValueError("lr_scale='cohort' needs num_clients= (the factor "
                         "is cohort / K)")
    factor = jnp.float32(cohort / num_clients)
    base = sched
    return lambda step: base(step) * factor


def make_async_runner(model: engine.SplitModel, scala: ScalaConfig, *,
                      delays: DelayModel,
                      cohort: int,
                      backend: str = "logits",
                      optimizer: Optional[optimizers.Optimizer] = None,
                      schedule: Optional[Callable] = None,
                      ce_chunk: Optional[int] = None,
                      staleness_decay: float = 0.5,
                      mix_rate: float = 1.0,
                      aggregator=None,
                      server_optimizer: Optional[optimizers.Optimizer] = None,
                      server_lr: float = 1.0,
                      opt_state_policy: str = "carry",
                      unroll=1,
                      precision: str = "f32",
                      snapshots: str = "dense",
                      ring_size: int = 64,
                      lr_scale: str = "none",
                      num_clients: Optional[int] = None,
                      emit_client_metrics: bool = True,
                      mesh=None, batch_specs=None):
    """Build the async event program: ``async_fn(state, afed,
    round_batches, data_sizes=None) -> (state, afed, metrics)``.

    ``round_batches`` leaves are (T, K, Bk, ...) — one local-iteration
    schedule for every static slot; only the arrival cohort's columns are
    computed (sparse-slot gather), so the per-event cost is
    ~``cohort / K`` of a full sync round. Alternatively the leaves may be
    (T, cohort, Bk, ...) — *cohort-sized* batches consumed by the
    arrivals directly, skipping the O(K) batch materialization entirely
    (the million-client path; requires a prior-free aggregator since the
    (K,)-indexed aggregation priors cannot be derived from them).

    * ``delays`` / ``cohort`` — the event schedule: completion delays per
      dispatch, and how many arrivals each event waits for
      (``cohort=K`` is a full barrier; ``cohort=1`` is fully async).
    * ``staleness_decay`` / ``mix_rate`` — delayed-aggregation knobs: an
      arrival whose snapshot is ``a`` versions old is decayed by
      ``staleness_decay ** a`` inside the cohort weights, and the global
      client half moves ``mix_rate`` toward the cohort average
      (FedAsync-style mixing; ``mix_rate=1`` replaces it).
    * ``aggregator`` — base per-arrival weights before the staleness
      decay (default: data-size :func:`repro.fed.aggregators.weighted`,
      matching the sync runner's default). Stateful aggregators thread
      their carry through ``afed.agg_state``; note the runtime already
      tracks ages via ``version``, so :func:`staleness_weighted` here
      would double-decay.
    * ``server_optimizer`` / ``server_lr`` — optional FedOpt on the
      server half's event delta (state in ``afed.server_opt``), the same
      semantics as the sync runner's.
    * ``opt_state_policy`` — the cohort's client optimizer state at the
      event boundary: ``carry`` scatters the cohort's updated moments
      back to their slots (busy clients' moments are untouched),
      ``reset`` zeroes the cohort's, ``average`` redistributes the
      cohort-weighted mean over the cohort slots.
    * ``precision`` — the engine step's compute policy
      (:data:`repro.core.engine.PRECISIONS`): ``"bf16"`` runs the
      cohort's local forward/backward in bfloat16 against f32 master
      params; the staleness weights, priors, and delayed aggregation
      stay f32.
    * ``snapshots`` / ``ring_size`` — the :class:`AsyncFedState`
      storage layout (module docstring): ``"delta"`` replaces the
      (K, ...) per-client snapshots with a ``ring_size``-deep ring of
      recent global client halves, bit-identical to ``"dense"`` while
      staleness stays below ``ring_size`` and O(cohort + ring) resident
      otherwise. Requires a stateless optimizer or
      ``opt_state_policy="reset"`` (no per-client moments are stored)
      and builds ``state.params["client"]`` over ONE slot.
    * ``lr_scale`` — per-arrival lr scaling (:data:`LR_SCALES`):
      ``"cohort"`` multiplies the schedule by ``cohort / num_clients``
      (pass ``num_clients=``); ``"none"`` is the historical behavior.
    * ``emit_client_metrics`` — include the (K,) ``arrival_mask`` /
      ``staleness`` vectors in the metrics (default). Disable at large K
      so the per-event host transfer stays O(cohort).
    * ``mesh`` / ``batch_specs`` — required iff ``backend="lace_dp"``:
      the whole event runs inside one ``shard_map`` with the client axis
      sharded over the mesh's client axes; each shard pops
      ``cohort / n_shards`` of its local finishers (balanced two-tier
      schedule, module docstring). Requires cohort and K divisible by
      the client-shard count and a shard-decomposable aggregator
      (``Aggregator.shard_local``).

    ``state.params["client"]`` always holds the *current* global client
    half broadcast over the K slots (checkpoint/eval-compatible with the
    sync runner) — over a single slot under ``snapshots="delta"``; the
    per-client training snapshots live in ``afed.client_params`` (dense)
    or ``afed.ring`` (delta).

    Metrics extend the engine's with the async observables:
    ``arrival_mask`` (K,), ``staleness`` (K,) pre-event ages (both
    gated on ``emit_client_metrics``), ``staleness_mean`` over the
    cohort, ``t_event``, and ``server_version`` post-event.
    """
    if opt_state_policy not in engine.OPT_STATE_POLICIES:
        raise ValueError(f"unknown opt_state_policy {opt_state_policy!r}; "
                         f"expected {engine.OPT_STATE_POLICIES}")
    if snapshots not in SNAPSHOT_MODES:
        raise ValueError(f"unknown snapshots mode {snapshots!r}; expected "
                         f"{SNAPSHOT_MODES}")
    if snapshots == "delta" and opt_state_policy == "average":
        raise ValueError(
            "snapshots='delta' stores no per-client optimizer state to "
            "average; use opt_state_policy 'reset' (or 'carry' with a "
            "stateless optimizer)")
    if cohort < 1:
        raise ValueError(f"cohort must be >= 1, got {cohort}")
    delta = snapshots == "delta"
    opt = optimizer if optimizer is not None else optimizers.sgd()
    agg = aggregator if aggregator is not None else _agg.weighted()
    sched = _resolve_schedule(schedule, scala, lr_scale, cohort, num_clients)

    if backend == "lace_dp":
        return _make_async_runner_dp(
            model, scala, delays=delays, cohort=cohort, opt=opt, sched=sched,
            ce_chunk=ce_chunk, staleness_decay=staleness_decay,
            mix_rate=mix_rate, agg=agg, server_optimizer=server_optimizer,
            server_lr=server_lr, opt_state_policy=opt_state_policy,
            unroll=unroll, precision=precision, delta=delta,
            ring_size=ring_size, emit_client_metrics=emit_client_metrics,
            mesh=mesh, batch_specs=batch_specs)

    step = engine.make_split_step(model, scala, backend=backend,
                                  optimizer=opt, schedule=sched,
                                  ce_chunk=ce_chunk, precision=precision)

    def async_fn(state: engine.TrainState, afed: AsyncFedState,
                 round_batches, data_sizes=None):
        K = afed.version.shape[0]
        if cohort > K:
            raise ValueError(f"cohort {cohort} exceeds the {K} client slots")
        if delta and opt_state_policy == "carry" \
                and jax.tree.leaves(state.opt_state["client"]):
            raise ValueError(
                "snapshots='delta' cannot carry per-client optimizer "
                "moments (none are stored); use a stateless optimizer "
                "(plain sgd) or opt_state_policy='reset'")

        # --- event pop: who arrives, and when ---
        idx, arrival_mask, t_event = arrival_cohort(afed.finish_time, cohort,
                                                    afed.version)
        staleness = (afed.server_version - afed.version).astype(jnp.float32)

        # --- sparse-slot local compute from the per-client snapshots:
        # the engine's gather, sourced from the snapshots (dense) or
        # reconstructed from the version ring (delta) ---
        if delta:
            snap_c, _ = ring_lookup(afed.ring, jnp.take(afed.version, idx),
                                    afed.server_version, ring_size)
            sub = engine.TrainState(
                params={"client": snap_c, "server": state.params["server"]},
                opt_state={"client": jax.vmap(opt.init)(snap_c),
                           "server": state.opt_state["server"]},
                step=state.step)
        else:
            sub = engine._gather_clients(
                engine.TrainState(
                    params={"client": afed.client_params,
                            "server": state.params["server"]},
                    opt_state=state.opt_state, step=state.step), idx)
        b_lead = jax.tree.leaves(round_batches)[0].shape[1]
        if b_lead == K:
            sub_batches = jax.tree.map(lambda a: jnp.take(a, idx, axis=1),
                                       round_batches)
        elif b_lead == cohort:
            if agg.needs_priors:
                raise ValueError(
                    f"aggregator {agg.name!r} needs (K,)-indexed aggregation "
                    "priors, which cohort-sized round_batches cannot "
                    "provide; pass full (T, K, ...) batches")
            sub_batches = round_batches
        else:
            raise ValueError(
                f"round_batches client axis is {b_lead}; expected the {K} "
                f"static slots or the {cohort}-sized arrival cohort")
        # priors / logit adjustments recompute over the arrival cohort:
        # the gathered batch IS the cohort's concatenated batch
        sub, ms = jax.lax.scan(step, sub, sub_batches, unroll=unroll)
        metrics = jax.tree.map(lambda a: a[-1], ms)

        # --- staleness-weighted delayed aggregation (GAS / FedAsync) ---
        p_k = p_global = None
        if agg.needs_priors:
            p_k, p_global = _agg.aggregation_priors(
                model.num_classes, round_batches["labels"],
                round_batches.get("weights"), client_axis=1)
        ctx = _agg.AggContext(num_clients=K, mask=arrival_mask,
                              data_sizes=data_sizes, p_k=p_k,
                              p_global=p_global)
        w_base, agg_state = agg.client_weights(ctx, afed.agg_state)
        decay = jnp.power(jnp.float32(staleness_decay), staleness)
        r_hat = normalize_client_weights(w_base * decay, arrival_mask)
        cohort_avg = weighted_mean(sub.params["client"],
                                   jnp.take(r_hat, idx))
        mu = jnp.float32(mix_rate)
        global_c = jax.tree.map(lambda a: a[0], state.params["client"])
        new_global = jax.tree.map(
            lambda g, c: ((1.0 - mu) * g.astype(jnp.float32)
                          + mu * c.astype(jnp.float32)).astype(g.dtype),
            global_c, cohort_avg)

        # --- server half: in-scan updates (+ optional FedOpt on delta) ---
        new_ws = sub.params["server"]
        server_opt_state = afed.server_opt
        if server_optimizer is not None:
            ws_delta = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                state.params["server"], new_ws)
            new_ws, server_opt_state = server_optimizer.update(
                ws_delta, server_opt_state, state.params["server"], server_lr)

        # --- cohort opt-state at the event boundary ---
        if delta:
            new_client = stack_client_params(new_global, 1)
            opt_c = jax.vmap(opt.init)(new_client)
        else:
            sub_opt_c = sub.opt_state["client"]
            if opt_state_policy == "reset":
                sub_opt_c = jax.vmap(opt.init)(sub.params["client"])
            elif opt_state_policy == "average":
                r_sub = jnp.take(r_hat, idx)

                def avg(a):
                    wb = r_sub.reshape((-1,) + (1,) * (a.ndim - 1))
                    m = (a.astype(jnp.float32) * wb).sum(axis=0) \
                        .astype(a.dtype)
                    return jnp.broadcast_to(m[None], a.shape)

                sub_opt_c = jax.tree.map(avg, sub_opt_c)
            opt_c = engine.scatter_rows(state.opt_state["client"], sub_opt_c,
                                        idx)
            new_client = stack_client_params(new_global, K)

        # --- re-dispatch the cohort at the new version ---
        new_version = afed.server_version + 1
        k_delay, k_carry = jax.random.split(afed.key)
        new_delays = delays.sample(k_delay, (cohort,)).astype(jnp.float32)
        if delta:
            slot = new_version % ring_size
            snap = afed.client_params
            ring = jax.tree.map(
                lambda r, g: r.at[slot].set(g.astype(r.dtype)),
                afed.ring, new_global)
            ring_versions = afed.ring_versions.at[slot].set(new_version)
        else:
            snap = engine.scatter_rows(
                afed.client_params, stack_client_params(new_global, cohort),
                idx)
            ring, ring_versions = afed.ring, afed.ring_versions
        new_afed = AsyncFedState(
            client_params=snap,
            version=afed.version.at[idx].set(new_version),
            server_version=new_version,
            finish_time=afed.finish_time.at[idx].set(t_event + new_delays),
            now=t_event,
            key=k_carry,
            agg_state=agg_state,
            server_opt=server_opt_state,
            ring=ring,
            ring_versions=ring_versions)
        new_state = engine.TrainState(
            params={"client": new_client, "server": new_ws},
            opt_state={"client": opt_c, "server": sub.opt_state["server"]},
            step=sub.step)
        metrics = dict(metrics)
        if emit_client_metrics:
            metrics.update(
                arrival_mask=arrival_mask, staleness=staleness,
                staleness_mean=(staleness * arrival_mask).sum()
                / jnp.maximum(arrival_mask.sum(), 1.0))
        else:
            metrics.update(staleness_mean=jnp.take(staleness, idx).mean())
        metrics.update(t_event=t_event, server_version=new_version)
        return new_state, new_afed, metrics

    return async_fn


# ---------------------------------------------------------------------------
# the manual-SPMD ("lace_dp") event program
# ---------------------------------------------------------------------------


def _half_specs(tree, client_spec):
    """{'client','server'} pytree -> PartitionSpecs: client leaves on
    ``client_spec``, server leaves replicated."""
    from jax.sharding import PartitionSpec as P

    return {"client": jax.tree.map(lambda _: client_spec, tree["client"]),
            "server": jax.tree.map(lambda _: P(), tree["server"])}


def _make_async_runner_dp(model, scala, *, delays, cohort, opt, sched,
                          ce_chunk, staleness_decay, mix_rate, agg,
                          server_optimizer, server_lr, opt_state_policy,
                          unroll, precision, delta, ring_size,
                          emit_client_metrics, mesh, batch_specs):
    """The whole async event inside one ``shard_map`` (backend lace_dp).

    See :func:`make_async_runner` — this builds the same
    ``async_fn(state, afed, round_batches, data_sizes=None)`` with the
    client axis sharded over the mesh's client axes and a *per-shard*
    cohort pop (each shard waits for ``cohort / n_shards`` of its local
    finishers — the balanced two-tier schedule).
    """
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.sharding.logical import round_specs

    if mesh is None or batch_specs is None:
        raise ValueError("backend 'lace_dp' needs mesh= and batch_specs=")
    axes = engine.mesh_axes(mesh)
    n_shards = engine.client_shard_count(mesh)
    if cohort % n_shards:
        raise ValueError(f"cohort {cohort} must divide over the {n_shards} "
                         "client shards (per-shard balanced pop)")
    if agg.shard_local is None:
        raise ValueError(
            f"aggregator {agg.name!r} is not shard-decomposable "
            "(Aggregator.shard_local is None); the lace_dp event needs "
            "fedavg / weighted / hierarchical")
    if agg.stateful:
        raise ValueError(f"aggregator {agg.name!r} is stateful; the lace_dp "
                         "async event supports stateless aggregators only")
    if opt_state_policy == "average":
        raise ValueError("opt_state_policy 'average' is not supported on "
                         "the lace_dp async event; use 'carry' or 'reset'")
    cohort_l = cohort // n_shards
    cspec = P(axes.client or None)
    rb_specs = round_specs(batch_specs)
    m_specs = {"loss_server": P(), "loss_client": P(), "aux": P(),
               "staleness_mean": P(), "t_event": P(), "server_version": P()}
    if emit_client_metrics:
        m_specs.update(arrival_mask=cspec, staleness=cspec)

    def async_fn(state: engine.TrainState, afed: AsyncFedState,
                 round_batches, data_sizes=None):
        K = afed.version.shape[0]
        if K % n_shards:
            raise ValueError(f"{K} client slots must divide over the "
                             f"{n_shards} client shards")
        if delta and opt_state_policy == "carry" \
                and jax.tree.leaves(state.opt_state["client"]):
            raise ValueError(
                "snapshots='delta' cannot carry per-client optimizer "
                "moments; use a stateless optimizer or "
                "opt_state_policy='reset'")
        if jax.tree.leaves(round_batches)[0].shape[1] != K:
            raise ValueError("the lace_dp async event needs full (T, K, ...)"
                             " round_batches (sharded over the client axes)")
        if data_sizes is None:
            data_sizes = jnp.ones((K,), jnp.float32)

        pspec = P() if delta else cspec
        s_specs = engine.TrainState(
            params=_half_specs(state.params, pspec),
            opt_state=_half_specs(state.opt_state, pspec),
            step=P())
        a_specs = AsyncFedState(
            client_params=jax.tree.map(lambda _: cspec, afed.client_params),
            version=cspec, server_version=P(), finish_time=cspec, now=P(),
            key=P(),
            agg_state=jax.tree.map(lambda _: P(), afed.agg_state),
            server_opt=jax.tree.map(lambda _: P(), afed.server_opt),
            ring=jax.tree.map(lambda _: P(), afed.ring),
            ring_versions=P() if delta else ())

        def body(st, af, rb, sizes_l):
            # --- per-shard pop of the local cohort ---
            idx, a_mask_l, t_l = arrival_cohort(af.finish_time, cohort_l,
                                                af.version)
            t_event = (jax.lax.pmax(t_l, axes.client) if axes.client
                       else t_l)
            stal_l = (af.server_version - af.version).astype(jnp.float32)

            # --- gather the local arrivals' snapshots ---
            if delta:
                snap_c, _ = ring_lookup(af.ring, jnp.take(af.version, idx),
                                        af.server_version, ring_size)
                sub = engine.TrainState(
                    params={"client": snap_c,
                            "server": st.params["server"]},
                    opt_state={"client": jax.vmap(opt.init)(snap_c),
                               "server": st.opt_state["server"]},
                    step=st.step)
            else:
                sub = engine._gather_clients(
                    engine.TrainState(
                        params={"client": af.client_params,
                                "server": st.params["server"]},
                        opt_state=st.opt_state, step=st.step), idx)
            sub_b = jax.tree.map(lambda a: jnp.take(a, idx, axis=1), rb)

            def step_body(s, b):
                grads, mets = engine.split_step_grads(
                    model, s.params, b, scala, backend="lace_dp",
                    ce_chunk=ce_chunk, axes=axes, precision=precision)
                return engine._apply_updates(opt, s, grads,
                                             sched(s.step)), mets

            sub, ms = jax.lax.scan(step_body, sub, sub_b, unroll=unroll)
            metrics = dict(jax.tree.map(lambda a: a[-1], ms))

            # --- two-tier delayed aggregation: each shard (edge) folds
            # its cohort locally, the psum folds the edges ---
            w_base_l = agg.shard_local(a_mask_l, sizes_l, axes.client,
                                       n_shards)
            decay_l = jnp.power(jnp.float32(staleness_decay), stal_l)
            raw_l = w_base_l * decay_l * a_mask_l
            denom = raw_l.sum()
            if axes.client:
                denom = jax.lax.psum(denom, axes.client)
            r_l = raw_l / jnp.maximum(denom, 1e-8)
            part = weighted_mean(sub.params["client"], jnp.take(r_l, idx))
            cohort_avg = (jax.tree.map(
                lambda a: jax.lax.psum(a, axes.client), part)
                if axes.client else part)
            mu = jnp.float32(mix_rate)
            global_c = jax.tree.map(lambda a: a[0], st.params["client"])
            new_global = jax.tree.map(
                lambda g, c: ((1.0 - mu) * g.astype(jnp.float32)
                              + mu * c.astype(jnp.float32)).astype(g.dtype),
                global_c, cohort_avg)

            # --- server half (replicated; identical on every shard) ---
            new_ws = sub.params["server"]
            so_state = af.server_opt
            if server_optimizer is not None:
                ws_delta = jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32)
                                  - b.astype(jnp.float32)),
                    st.params["server"], new_ws)
                new_ws, so_state = server_optimizer.update(
                    ws_delta, so_state, st.params["server"], server_lr)

            # --- opt state / re-dispatch (local slots) ---
            new_version = af.server_version + 1
            k_delay, k_carry = jax.random.split(af.key)
            shard_ix = jnp.int32(0)
            for a in axes.client:
                shard_ix = shard_ix * dict(mesh.shape)[a] \
                    + jax.lax.axis_index(a)
            new_delays = delays.sample(
                jax.random.fold_in(k_delay, shard_ix),
                (cohort_l,)).astype(jnp.float32)
            if delta:
                new_client = stack_client_params(new_global, 1)
                opt_c = jax.vmap(opt.init)(new_client)
                slot = new_version % ring_size
                snap = af.client_params
                ring = jax.tree.map(
                    lambda r, g: r.at[slot].set(g.astype(r.dtype)),
                    af.ring, new_global)
                ring_versions = af.ring_versions.at[slot].set(new_version)
            else:
                sub_opt_c = sub.opt_state["client"]
                if opt_state_policy == "reset":
                    sub_opt_c = jax.vmap(opt.init)(sub.params["client"])
                opt_c = engine.scatter_rows(st.opt_state["client"],
                                            sub_opt_c, idx)
                new_client = stack_client_params(new_global,
                                                 af.version.shape[0])
                snap = engine.scatter_rows(
                    af.client_params,
                    stack_client_params(new_global, cohort_l), idx)
                ring, ring_versions = af.ring, af.ring_versions
            new_af = AsyncFedState(
                client_params=snap,
                version=af.version.at[idx].set(new_version),
                server_version=new_version,
                finish_time=af.finish_time.at[idx].set(t_event + new_delays),
                now=t_event,
                key=k_carry,
                agg_state=af.agg_state,
                server_opt=so_state,
                ring=ring,
                ring_versions=ring_versions)
            new_st = engine.TrainState(
                params={"client": new_client, "server": new_ws},
                opt_state={"client": opt_c,
                           "server": sub.opt_state["server"]},
                step=sub.step)
            s_sum = (stal_l * a_mask_l).sum()
            s_cnt = a_mask_l.sum()
            if axes.client:
                s_sum = jax.lax.psum(s_sum, axes.client)
                s_cnt = jax.lax.psum(s_cnt, axes.client)
            if emit_client_metrics:
                metrics.update(arrival_mask=a_mask_l, staleness=stal_l)
            metrics.update(staleness_mean=s_sum / jnp.maximum(s_cnt, 1.0),
                           t_event=t_event, server_version=new_version)
            return new_st, new_af, metrics

        fn = compat.shard_map(
            body, mesh=mesh,
            in_specs=(s_specs, a_specs, rb_specs, cspec),
            out_specs=(s_specs, a_specs, m_specs), check_vma=False)
        return fn(state, afed, round_batches, data_sizes)

    return async_fn
