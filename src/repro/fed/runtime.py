"""Asynchronous split-federated execution on top of the split-step engine.

The synchronous round (:func:`repro.core.engine.make_round_runner`) is a
barrier: every participating client runs T local iterations from the same
aggregated model, then the FL phase averages. Real fleets are
asynchronous — clients finish at different times and their updates were
computed against *older* server params. GAS (arXiv:2409.01251) shows the
workable recipe is staleness-aware delayed aggregation; this module
implements it as a *jit-compatible event schedule*:

1. Every client holds a **snapshot** of the global client half (the
   params it trains from) tagged with the server **version** it was taken
   at, plus a sampled **finish time** (:mod:`repro.fed.delays`).
2. One call of the async runner is one **event**: the ``cohort`` earliest
   finishers arrive. Their T local iterations run on a dense sparse-slot
   axis (gathered from the static K slots, exactly the engine's
   ``slot_gather`` path), with label priors and logit adjustments
   recomputed over the *arrival cohort* — the same per-subset semantics
   the sync path applies per participating subset.
3. The arrivals' trained client halves are folded into the global model
   with **staleness-weighted delayed aggregation** (FedAsync/GAS-style
   model mixing): per-arrival weights are the aggregator's weights
   decayed by ``staleness_decay ** age`` (age = server versions elapsed
   since the snapshot), renormalized over the cohort, and the global
   client half moves ``mix_rate`` of the way to the cohort average. The
   server half trains in-scan as always (it is never averaged) with an
   optional FedOpt ``server_optimizer`` over its event delta.
4. The cohort re-snapshots the new global model at the new version,
   samples fresh delays, and the event clock advances to the cohort's
   latest arrival. Busy clients keep their snapshots and finish times.

Everything — cohort selection, gather/scatter, delay sampling, the
staleness weights — is pure jax inside one compiled program per event.

**The sync round is the zero-delay special case**: with
``delays=constant(0)`` and ``cohort=K`` every client arrives at every
event with staleness 0, the cohort average is the full FedAvg, and
``mix_rate=1`` replaces the global model with it — bit-for-bit the
synchronous round runner (test-enforced at fp32 tolerance in
``tests/test_async.py``).

Snapshot storage (``snapshots=``):

* ``"dense"`` — the legacy layout: ``client_params`` materializes one
  client-half snapshot *per slot*, O(K x |w_c|) memory.
* ``"delta"`` — the million-client layout. The state invariant below
  says ``client_params[k]`` IS the global client half as of
  ``version[k]``: the per-client delta against the tagged server
  version is **identically zero**, so nothing per-client needs storing.
  A fixed-size **ring** of the ``ring_size`` most recent global client
  halves (slot ``v % ring_size`` holds global@v) plus the existing
  (K,) ``version`` tags reconstruct any snapshot on gather:
  ``ring[max(version_k, server_version - ring_size + 1) % ring_size]``.
  Resident snapshot memory is O(ring_size x |w_c| + cohort) — flat in
  K — and the path is **bit-identical** to dense storage while every
  arrival's staleness is < ``ring_size`` (test-enforced). A snapshot
  whose base version aged out of the ring is clamped to the oldest
  retained version — bounded-staleness eviction: the straggler trains
  from a slightly newer global model than it was dispatched with,
  which only *reduces* its effective staleness. Per-client optimizer
  state is not stored on device either, so ``"delta"`` requires a
  stateless local optimizer (plain SGD — the paper's setting),
  ``opt_state_policy="reset"``, or the **host-paged moment store**
  (``paged_opt=True`` + :class:`HostOptPager`): the cold (K, ...)
  moment stack lives in host memory and only the arrival cohort's rows
  page to the device per event.

The arrival pop itself has three implementations (:data:`ARRIVALS`,
``arrival=``): the legacy O(K log K) lexsort, an O(K)-work composite-key
``lax.top_k`` pop (bit-identical, including ties), and a client-mesh-
sharded pop (per-shard top-k + O(cohort x shards) merge) that keeps the
(K,) ``version``/``finish_time`` scalars sharded — at K=1e6 the lexsort
IS the event cost, see ``benchmarks/BENCH_scale.json``.

:class:`AsyncFedState` invariants (maintained by :func:`init_async_state`
and every runner call; rely on them, don't re-derive):

* ``version[k] <= server_version`` elementwise; ``server_version``
  increments by exactly 1 per event.
* ``client_params[k]`` is the global client half as of ``version[k]`` —
  slots with ``version[k] == server_version`` hold the *current* global
  model. (``snapshots="delta"`` stores this redundancy-free: the ring
  holds one entry per recent version instead of one per client.)
* ``finish_time[k] >= now`` for busy clients; arrivals satisfy
  ``finish_time[k] <= new now`` at the event that pops them and are
  re-armed strictly into the future (for nonzero delays).
* ``server_version - version`` is the per-client staleness age — under a
  full-barrier schedule it reproduces the sync
  :func:`repro.fed.aggregators.staleness_weighted` age bookkeeping.

The manual-SPMD backend (``backend="lace_dp"``, pass ``mesh`` and
``batch_specs``) runs the whole event inside one ``shard_map``: each
shard of the client mesh axes pops ``cohort / n_shards`` of *its own*
earliest finishers (a balanced two-tier schedule — the shard is the
"edge", the psum across shards is the server fold), gathers them from
its local slots (or the replicated ring), and the cohort-weight
normalization / cohort average / event clock are combined with psums.
The per-shard pop is the one scheduling difference vs the single-program
runner: arrivals are balanced per shard rather than popped globally
(with zero delays and ``cohort=K`` the two schedules coincide).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ScalaConfig
from repro.core import engine
from repro.core.split import (normalize_client_weights, stack_client_params,
                              weighted_mean)
from repro.fed import aggregators as _agg
from repro.fed.delays import DelayModel
from repro.optim import optimizers, schedules

#: snapshot storage layouts for :class:`AsyncFedState`.
SNAPSHOT_MODES = ("dense", "delta")

#: arrival-pop implementations for the event schedule (see
#: :func:`arrival_cohort` / :func:`sharded_arrival_cohort`): ``"sort"``
#: is the legacy O(K log K) lexsort, ``"topk"`` the O(K)-work composite
#: -key ``lax.top_k`` pop (bit-identical), ``"topk:sharded"`` the
#: client-mesh-sharded pop (per-shard local top-k + one O(cohort x
#: shards) merge, bit-identical to the single-device pop).
ARRIVALS = ("sort", "topk", "topk:sharded")

#: per-arrival lr scaling policies (see :func:`make_async_runner`).
LR_SCALES = ("none", "cohort")

#: ring_versions tag for a slot that has never been written.
_NO_VERSION = jnp.int32(-(2 ** 30))


@dataclass(frozen=True)
class AsyncFedState:
    """Per-client dispatch state threaded through async events.

    client_params: (K, ...) stacked per-client snapshots of the global
    client half (what each client is training from) — ``()`` under
    ``snapshots="delta"``, where the ring replaces it;
    version: (K,) int32 server version each snapshot was taken at;
    server_version: () int32 global version (events applied so far);
    finish_time: (K,) float32 simulated completion time per client;
    now: () float32 event clock (the last cohort's latest arrival);
    key: PRNG key driving delay sampling;
    agg_state: aggregator carry (e.g. staleness ages) — usually () since
    the runtime tracks ages itself via ``version``;
    server_opt: server-side FedOpt optimizer state (or ());
    ring: (ring_size, ...) recent global client halves, slot
    ``v % ring_size`` holding global@v (``snapshots="delta"`` only);
    ring_versions: (ring_size,) int32 version tag per ring slot
    (un-written slots carry a large negative sentinel);
    retries: (K,) int32 consecutive deadline misses per client (drives
    the exponential re-dispatch backoff; ``()`` on legacy states);
    guard: running-median state for guarded aggregation's norm clip
    (:func:`repro.fed.guards.init_state`, or ``()``).
    """

    client_params: Any
    version: Any
    server_version: Any
    finish_time: Any
    now: Any
    key: Any
    agg_state: Any = ()
    server_opt: Any = ()
    ring: Any = ()
    ring_versions: Any = ()
    retries: Any = ()
    guard: Any = ()


jax.tree_util.register_dataclass(
    AsyncFedState,
    data_fields=("client_params", "version", "server_version", "finish_time",
                 "now", "key", "agg_state", "server_opt", "ring",
                 "ring_versions", "retries", "guard"),
    meta_fields=())


def init_async_state(key, client_params, delays: DelayModel, *,
                     aggregator=None,
                     server_optimizer: Optional[optimizers.Optimizer] = None,
                     server_params=None,
                     snapshots: str = "dense",
                     ring_size: int = 64,
                     num_clients: Optional[int] = None,
                     mesh=None, guards=None) -> AsyncFedState:
    """Dispatch all K clients at version 0.

    ``client_params`` is the stacked client half (every slot holds the
    same init — :func:`repro.core.split.stack_client_params`); each
    client's first completion delay is sampled immediately, so the first
    event pops the cohort of earliest finishers. Pass the same
    ``aggregator`` / ``server_optimizer`` the runner was built with so
    their state is initialized to matching shapes.

    With ``snapshots="delta"`` the per-client snapshots are NOT
    materialized: pass the global client half stacked over a single slot
    (or any stacked layout — row 0 is taken) plus ``num_clients=K``, and
    the state carries a ``ring_size``-deep ring of recent global client
    halves instead — O(ring_size), not O(K). ``ring_size`` bounds the
    reconstructable staleness (see the module docstring's eviction
    semantics).

    With ``mesh=`` the (K,) schedule scalars — ``version`` and
    ``finish_time`` — are laid out sharded over the mesh's client axes
    (:func:`repro.sharding.logical.client_scalar_spec`), and the initial
    delay sampling compiles with that output sharding
    (:meth:`repro.fed.delays.DelayModel.sample_sharded` — threefry is
    value-deterministic, so the sharded init is bit-identical to the
    unsharded one). Pair with ``make_async_runner(arrival=
    "topk:sharded", mesh=...)`` so no event materializes the (K,)
    scalars on one device.
    """
    if snapshots not in SNAPSHOT_MODES:
        raise ValueError(f"unknown snapshots mode {snapshots!r}; expected "
                         f"{SNAPSHOT_MODES}")
    lead = jax.tree.leaves(client_params)[0].shape[0]
    K = lead if num_clients is None else num_clients
    if snapshots == "dense" and num_clients is not None and lead != K:
        raise ValueError(f"dense snapshots need client_params stacked over "
                         f"all {K} clients, got {lead} slots")
    k_delay, k_carry = jax.random.split(jnp.asarray(key))
    if server_optimizer is not None and server_params is None:
        raise ValueError("init_async_state needs server_params when a "
                         "server_optimizer is given")
    if snapshots == "delta":
        if ring_size < 1:
            raise ValueError(f"ring_size must be >= 1, got {ring_size}")
        global_c = jax.tree.map(lambda a: a[0], client_params)
        snap = ()
        ring = jax.tree.map(
            lambda g: jnp.broadcast_to(g[None], (ring_size,) + g.shape),
            global_c)
        ring_versions = jnp.full((ring_size,), _NO_VERSION,
                                 jnp.int32).at[0].set(0)
    else:
        snap, ring, ring_versions = client_params, (), ()
    version = jnp.zeros((K,), jnp.int32)
    retries = jnp.zeros((K,), jnp.int32)
    finish_time = delays.sample(k_delay, (K,)).astype(jnp.float32)
    if mesh is not None:
        from jax.sharding import NamedSharding

        from repro.sharding.logical import client_scalar_spec

        spec = client_scalar_spec(mesh, K)
        version = jax.device_put(version, NamedSharding(mesh, spec))
        retries = jax.device_put(retries, NamedSharding(mesh, spec))
        finish_time = delays.sample_sharded(k_delay, K, mesh)
    guard = ()
    if guards is not None:
        from repro.fed import guards as _guards_mod

        gp = _guards_mod.make_guards(guards)
        guard = _guards_mod.init_state() if gp.stateful else ()
    return AsyncFedState(
        client_params=snap,
        version=version,
        server_version=jnp.zeros((), jnp.int32),
        finish_time=finish_time,
        now=jnp.zeros((), jnp.float32),
        key=k_carry,
        agg_state=aggregator.init(K) if aggregator is not None else (),
        server_opt=(server_optimizer.init(server_params)
                    if server_optimizer is not None else ()),
        ring=ring,
        ring_versions=ring_versions,
        retries=retries,
        guard=guard)


def _pop_topk(finish_time, version, cohort: int):
    """O(K)-work selection of the ``cohort`` minima under the composite
    lexicographic key (finish_time, version, slot).

    The composite key never materializes as one word — no available
    dtype holds an exact (f32, i32, i32) pack — so the selection runs as
    a short ladder of **float32** ``lax.top_k`` stages, one per key
    component, each refining the boundary tie set of the previous one:

    1. ``finish_time`` (native f32): one top-k gives the boundary value
       ``b`` (the cohort-th earliest finish); everything strictly
       earlier is selected, the ties at ``b`` continue.
    2. ``version`` split into its 16-bit halves (``v >> 16`` and
       ``v & 0xffff`` — two's-complement floor decomposition, each half
       exactly representable in f32, lexicographically monotone in
       ``v``): two more masked top-k passes over the tie set.
    3. slot id: ``lax.top_k`` breaks equal values by *lower index
       first*, so one final top-k over the residual tie mask pops the
       remaining slots in ascending id order.

    Every stage is O(K) work / O(log K) depth and stays on XLA's fast
    f32 TopK path — int32 ``top_k`` would do stage 2 in one pass but
    lowers to a full O(K log K) sort on CPU, which is the cost this
    function exists to remove. Bit-identical to the lexsort pop
    (test-enforced in ``tests/test_arrival.py``), including the FIFO
    tie-break that prevents slot starvation.
    """
    K = finish_time.shape[0]
    stages = [finish_time]
    if version is not None:
        v = version.astype(jnp.int32)
        stages += [(v >> 16).astype(jnp.float32),
                   (v & 0xFFFF).astype(jnp.float32)]
    selected = jnp.zeros((K,), jnp.bool_)
    eligible = jnp.ones((K,), jnp.bool_)
    need = jnp.int32(cohort)            # stays >= 1: strictly-below-the-
    for k in stages:                    # boundary counts are < need
        kk = jnp.where(eligible, k.astype(jnp.float32), jnp.inf)
        # the barrier keeps XLA from constant-folding a static slice of
        # the top_k output into its sort-based rewrite (a full O(K log K)
        # sort on CPU — the exact cost this pop exists to remove); with
        # it the fast O(K) TopK custom call survives even on the first
        # stage, where `need` is still the trace-time constant `cohort`
        vals = jax.lax.optimization_barrier(jax.lax.top_k(-kk, cohort)[0])
        b = -jnp.take(vals, need - 1)   # need-th smallest eligible key
        strict = eligible & (kk < b)
        selected |= strict
        need -= strict.sum(dtype=jnp.int32)
        eligible &= kk == b
    # the residual ties differ only in slot id: top_k's lower-index-
    # first rule pops the `need` lowest ids (the lexsort's stability)
    tvals, tidx = jax.lax.top_k(eligible.astype(jnp.float32), cohort)
    take = (jnp.arange(cohort, dtype=jnp.int32) < need) & (tvals > 0)
    selected |= jnp.zeros((K,), jnp.bool_).at[tidx].set(take, mode="drop")
    # ascending idx: all selected values are equal, ties -> index order
    _, idx = jax.lax.top_k(selected.astype(jnp.float32), cohort)
    mask = selected.astype(jnp.float32)
    t_event = jnp.max(jnp.take(finish_time, idx))
    return idx, mask, t_event


def arrival_cohort(finish_time, cohort: int, version=None,
                   method: str = "sort"):
    """The event schedule's pop: the ``cohort`` earliest finishers.

    Returns (idx (cohort,) ascending slot ids, mask (K,) 0/1 float32,
    t_event — the cohort's latest finish time, i.e. the new clock).
    Ties (equal finish times) break by snapshot ``version`` — the
    longest-waiting client goes first (FIFO) — then by slot id (lexsort
    is stable). Without the version key, degenerate schedules (zero or
    constant-tied delays with ``cohort < K``) would re-arm the lowest
    slot ids at the same finish time and starve every other slot; with
    it, zero delays pop slots round-robin in blocks of ``cohort``.

    ``method`` picks the implementation (:data:`ARRIVALS`): ``"sort"``
    is the O(K log K) lexsort, ``"topk"`` the O(K)-work composite-key
    :func:`_pop_topk` — **bit-identical** outputs, including every tie
    case (test-enforced). The mesh-sharded pop is
    :func:`sharded_arrival_cohort`.
    """
    if method == "topk":
        return _pop_topk(finish_time, version, cohort)
    if method != "sort":
        raise ValueError(f"unknown arrival method {method!r}; expected "
                         "'sort' or 'topk' (use sharded_arrival_cohort "
                         "for 'topk:sharded')")
    if version is None:
        order = jnp.argsort(finish_time)
    else:
        order = jnp.lexsort((version, finish_time))
    idx = jnp.sort(order[:cohort])
    K = finish_time.shape[0]
    mask = jnp.zeros((K,), jnp.float32).at[idx].set(1.0)
    t_event = jnp.max(jnp.take(finish_time, idx))
    return idx, mask, t_event


def sharded_arrival_cohort(finish_time, cohort: int, version, *, mesh):
    """The pop with the (K,) schedule scalars sharded over the client
    mesh axes: per-shard local top-``cohort`` candidates + one
    O(cohort x shards) merge. Bit-identical to the single-device pop.

    Each shard runs :func:`_pop_topk` on its local (K/S,) slice under
    the SAME composite (finish_time, version, slot) order — the global
    top-``cohort`` is contained in the union of per-shard top-cohorts,
    because any globally selected slot has fewer than ``cohort``
    predecessors globally, hence fewer within its own shard. The
    all-gathered ``S x min(cohort, K/S)`` candidate triples are merged
    with one small lexsort (slot id as the final key makes the merge
    deterministic and exact). No step materializes a (K,) array on one
    device: the inputs stay sharded, the merge is O(cohort x shards),
    and the returned ``mask`` is sharded like the inputs.

    Returns (idx (cohort,) global slot ids ascending — replicated,
    mask (K,) float32 sharded over the client axes, t_event —
    replicated).
    """
    from jax.sharding import PartitionSpec as P

    from repro import compat

    axes = engine.mesh_axes(mesh)
    n_shards = engine.client_shard_count(mesh)
    K = finish_time.shape[0]
    if K % n_shards:
        raise ValueError(f"{K} client slots must divide over the "
                         f"{n_shards} client shards for the sharded pop")
    K_l = K // n_shards
    c_l = min(cohort, K_l)
    cspec = P(axes.client or None)

    def body(ft_l, v_l):
        li, _, _ = _pop_topk(ft_l, v_l, c_l)
        shard_ix = jnp.int32(0)
        for a in axes.client:
            shard_ix = shard_ix * dict(mesh.shape)[a] + jax.lax.axis_index(a)
        cand = (jnp.take(ft_l, li), jnp.take(v_l, li), li + shard_ix * K_l)
        if axes.client:
            cand = tuple(jax.lax.all_gather(c, axes.client, tiled=True)
                         for c in cand)
        ft_c, v_c, g_c = cand
        # O(cohort x shards) merge under the composite order; global
        # slot ids are distinct so the order is total and exact
        order = jnp.lexsort((g_c, v_c, ft_c))[:cohort]
        idx = jnp.sort(jnp.take(g_c, order))
        t_event = jnp.max(jnp.take(ft_c, order))
        loc = idx - shard_ix * K_l
        loc = jnp.where((loc >= 0) & (loc < K_l), loc, K_l)
        mask_l = jnp.zeros((K_l,), jnp.float32).at[loc].set(1.0, mode="drop")
        return idx, mask_l, t_event

    fn = compat.shard_map(body, mesh=mesh, in_specs=(cspec, cspec),
                          out_specs=(P(), cspec, P()), check_vma=False)
    return fn(finish_time, version)


def make_arrival_pop(cohort: int, arrival: str = "sort", *, mesh=None):
    """The configured pop as one function ``pop(finish_time, version) ->
    (idx, mask, t_event)`` (:data:`ARRIVALS` vocabulary).

    The async runner builds its in-event pop through this, and the
    host-paged optimizer path (:class:`HostOptPager`) uses the SAME
    constructor for its pre-event idx prediction — the two pops are the
    same deterministic function of the same state, so the host gather
    always addresses the slots the event actually pops.
    """
    if arrival not in ARRIVALS:
        raise ValueError(f"unknown arrival {arrival!r}; expected {ARRIVALS}")
    if arrival == "topk:sharded":
        if mesh is None:
            raise ValueError("arrival='topk:sharded' needs mesh= (the "
                             "client axes the schedule scalars shard over)")
        return lambda ft, v: sharded_arrival_cohort(ft, cohort, v, mesh=mesh)
    return lambda ft, v: arrival_cohort(ft, cohort, v, method=arrival)


def ring_lookup(ring, versions, server_version, ring_size: int):
    """Reconstruct dense snapshots for slots ``versions`` from the ring.

    ``versions`` (m,) int32 snapshot tags; returns (snapshots with a
    leading (m,) axis, effective versions (m,)). A version older than
    the ring depth is clamped to the oldest retained version
    ``server_version - ring_size + 1`` (bounded-staleness eviction);
    otherwise the lookup is exact — ring slot ``v % ring_size`` holds
    the global client half written at version ``v``, and any v within
    the last ``ring_size`` versions is the slot's latest write.
    """
    eff = jnp.maximum(versions,
                      server_version - jnp.int32(ring_size - 1))
    slot = eff % ring_size
    return jax.tree.map(lambda r: jnp.take(r, slot, axis=0), ring), eff


def async_state_bytes(afed: AsyncFedState) -> dict:
    """Resident-memory accounting of an :class:`AsyncFedState`.

    ``snapshot_bytes`` is the param-sized component — O(K x |w_c|) for
    dense snapshots, O(ring_size x |w_c|) for the delta ring — and
    ``per_client_scalar_bytes`` the unavoidable (K,) tags (version +
    finish_time, ~8 bytes/client). The O(cohort + ring) scaling claim
    (BENCH_scale.json) is about the param-sized component.
    """

    def nbytes(tree) -> int:
        return int(sum(int(np.prod(l.shape)) * l.dtype.itemsize
                       for l in jax.tree.leaves(tree)))

    snap = nbytes(afed.client_params) + nbytes(afed.ring)
    per_client = nbytes(afed.version) + nbytes(afed.finish_time)
    other = nbytes((afed.ring_versions, afed.server_version, afed.now,
                    afed.key, afed.agg_state, afed.server_opt,
                    afed.retries, afed.guard))
    return {"snapshot_bytes": snap,
            "per_client_scalar_bytes": per_client,
            "other_bytes": other,
            "total_bytes": snap + per_client + other}


class HostOptPager:
    """Host-paged per-client optimizer moments for ``opt_state_policy=
    "carry"`` at large K.

    ``snapshots="delta"`` keeps the param-sized async state O(cohort +
    ring) but stores no per-client optimizer state, which restricted it
    to stateless sgd or ``opt_state_policy="reset"``. The pager lifts
    that restriction without re-growing device memory: the cold (K, ...)
    moment stack lives in **host memory** (numpy buffers, paged to the
    device on demand), and each event gathers only the arrival cohort's
    ``(cohort, ...)`` rows to the device, feeds them through the local
    scan as the cohort's carried moments, and scatters the updated rows
    back. Device-resident optimizer state stays O(cohort); host state is
    O(K x |moments|) where it is cheap.

    Choreography (what :func:`repro.api.build` wires up for
    ``ExecutionSpec.opt_paging="host"``):

    1. ``pop = make_arrival_pop(cohort, arrival, ...)`` predicts the
       event's arrival ``idx`` from ``afed`` — the same deterministic
       function the event program applies internally, so the prediction
       is exact.
    2. ``cohort_opt = pager.gather(idx)`` pages the cohort's moments in.
    3. the paged event (``make_async_runner(paged_opt=True)``) consumes
       ``cohort_opt`` and returns the post-scan moments as a fourth
       output.
    4. ``pager.scatter(idx, new_cohort_opt)`` pages them back out.

    One pager backs one live training state (it is mutable host
    memory); call :meth:`reset` when re-initializing the state.
    """

    def __init__(self, opt: optimizers.Optimizer, client_template,
                 num_clients: int):
        """``client_template`` is ONE client's (unstacked) client half;
        the store is ``num_clients`` stacked rows of
        ``opt.init(client_template)``'s shapes (zero-initialized,
        exactly ``vmap(opt.init)`` over identical snapshots)."""
        proto = jax.eval_shape(opt.init, client_template)
        self.num_clients = num_clients
        self._store = jax.tree.map(
            lambda s: np.zeros((num_clients,) + tuple(s.shape), s.dtype),
            proto)

    def reset(self):
        """Zero every moment row (a fresh ``opt.init`` for all K)."""
        jax.tree.map(lambda a: a.fill(0), self._store)

    def gather(self, idx):
        """Page rows ``idx`` in: host (K, ...) -> device (cohort, ...)."""
        idx = np.asarray(idx)
        return jax.tree.map(lambda a: jnp.asarray(a[idx]), self._store)

    def scatter(self, idx, cohort_opt):
        """Page the cohort's updated moments back out to rows ``idx``."""
        idx = np.asarray(idx)

        def put(a, s):
            a[idx] = np.asarray(s).astype(a.dtype, copy=False)
            return a

        jax.tree.map(put, self._store, cohort_opt)

    def nbytes(self) -> int:
        """Host-resident bytes of the cold moment stack."""
        return int(sum(a.nbytes for a in jax.tree.leaves(self._store)))


def _resolve_schedule(schedule, scala: ScalaConfig, lr_scale: str,
                      cohort: int, num_clients: Optional[int]):
    """The event schedule's lr policy (``lr_scale``).

    The global ``step`` counter advances once per *local iteration* of
    whichever cohort arrived — with ``cohort < K`` the schedule ticks
    K/cohort times faster per unit of fleet-wide work than the sync
    round's, and each event moves the global model a full ``mix_rate``
    step from a cohort-sized sample. ``"cohort"`` scales the lr by
    ``cohort / K`` so per-event aggregate movement matches the sync
    round's per-participant scale; at ``cohort == K`` the factor is
    exactly 1.0 and the schedule is bit-identical to ``"none"``
    (test-enforced sync-equivalence).
    """
    if lr_scale not in LR_SCALES:
        raise ValueError(f"unknown lr_scale {lr_scale!r}; expected "
                         f"{LR_SCALES}")
    sched = schedule if schedule is not None else schedules.constant(scala.lr)
    if lr_scale == "none":
        return sched
    if num_clients is None:
        raise ValueError("lr_scale='cohort' needs num_clients= (the factor "
                         "is cohort / K)")
    factor = jnp.float32(cohort / num_clients)
    base = sched
    return lambda step: base(step) * factor


def make_async_runner(model: engine.SplitModel, scala: ScalaConfig, *,
                      delays: DelayModel,
                      cohort: int,
                      backend: str = "logits",
                      boundary: str = "fused",
                      optimizer: Optional[optimizers.Optimizer] = None,
                      schedule: Optional[Callable] = None,
                      ce_chunk: Optional[int] = None,
                      staleness_decay: float = 0.5,
                      mix_rate: float = 1.0,
                      aggregator=None,
                      server_optimizer: Optional[optimizers.Optimizer] = None,
                      server_lr: float = 1.0,
                      opt_state_policy: str = "carry",
                      unroll=1,
                      precision: str = "f32",
                      snapshots: str = "dense",
                      ring_size: int = 64,
                      lr_scale: str = "none",
                      num_clients: Optional[int] = None,
                      emit_client_metrics: bool = True,
                      arrival: str = "sort",
                      paged_opt: bool = False,
                      mesh=None, batch_specs=None,
                      deadline: Optional[float] = None,
                      backoff: float = 2.0,
                      faults=None, guards=None):
    """Build the async event program: ``async_fn(state, afed,
    round_batches, data_sizes=None) -> (state, afed, metrics)``.

    ``round_batches`` leaves are (T, K, Bk, ...) — one local-iteration
    schedule for every static slot; only the arrival cohort's columns are
    computed (sparse-slot gather), so the per-event cost is
    ~``cohort / K`` of a full sync round. Alternatively the leaves may be
    (T, cohort, Bk, ...) — *cohort-sized* batches consumed by the
    arrivals directly, skipping the O(K) batch materialization entirely
    (the million-client path; requires a prior-free aggregator since the
    (K,)-indexed aggregation priors cannot be derived from them).

    * ``delays`` / ``cohort`` — the event schedule: completion delays per
      dispatch, and how many arrivals each event waits for
      (``cohort=K`` is a full barrier; ``cohort=1`` is fully async).
    * ``staleness_decay`` / ``mix_rate`` — delayed-aggregation knobs: an
      arrival whose snapshot is ``a`` versions old is decayed by
      ``staleness_decay ** a`` inside the cohort weights, and the global
      client half moves ``mix_rate`` toward the cohort average
      (FedAsync-style mixing; ``mix_rate=1`` replaces it).
    * ``aggregator`` — base per-arrival weights before the staleness
      decay (default: data-size :func:`repro.fed.aggregators.weighted`,
      matching the sync runner's default). Stateful aggregators thread
      their carry through ``afed.agg_state``; note the runtime already
      tracks ages via ``version``, so :func:`staleness_weighted` here
      would double-decay.
    * ``server_optimizer`` / ``server_lr`` — optional FedOpt on the
      server half's event delta (state in ``afed.server_opt``), the same
      semantics as the sync runner's.
    * ``opt_state_policy`` — the cohort's client optimizer state at the
      event boundary: ``carry`` scatters the cohort's updated moments
      back to their slots (busy clients' moments are untouched),
      ``reset`` zeroes the cohort's, ``average`` redistributes the
      cohort-weighted mean over the cohort slots.
    * ``precision`` — the engine step's compute policy
      (:data:`repro.core.engine.PRECISIONS`): ``"bf16"`` runs the
      cohort's local forward/backward in bfloat16 against f32 master
      params; the staleness weights, priors, and delayed aggregation
      stay f32.
    * ``snapshots`` / ``ring_size`` — the :class:`AsyncFedState`
      storage layout (module docstring): ``"delta"`` replaces the
      (K, ...) per-client snapshots with a ``ring_size``-deep ring of
      recent global client halves, bit-identical to ``"dense"`` while
      staleness stays below ``ring_size`` and O(cohort + ring) resident
      otherwise. Requires a stateless optimizer or
      ``opt_state_policy="reset"`` (no per-client moments are stored)
      and builds ``state.params["client"]`` over ONE slot.
    * ``lr_scale`` — per-arrival lr scaling (:data:`LR_SCALES`):
      ``"cohort"`` multiplies the schedule by ``cohort / num_clients``
      (pass ``num_clients=``); ``"none"`` is the historical behavior.
    * ``emit_client_metrics`` — include the (K,) ``arrival_mask`` /
      ``staleness`` vectors in the metrics (default). Disable at large K
      so the per-event host transfer stays O(cohort).
    * ``arrival`` — the pop implementation (:data:`ARRIVALS`):
      ``"sort"`` the legacy O(K log K) lexsort, ``"topk"`` the O(K)-work
      composite-key ``lax.top_k`` pop (bit-identical, the large-K
      default-to-be), ``"topk:sharded"`` the client-mesh-sharded pop —
      pass ``mesh=`` (its client axes; works with any backend) and
      initialize with ``init_async_state(mesh=...)`` so the (K,)
      schedule scalars never land on one device. Under
      ``backend="lace_dp"`` the pop is already per-shard; ``"sort"`` /
      ``"topk"`` pick the local method there and ``"topk:sharded"`` is
      rejected.
    * ``paged_opt`` — host-paged per-client optimizer moments
      (:class:`HostOptPager`; requires ``snapshots="delta"`` and
      ``opt_state_policy="carry"``). The event takes an extra
      ``cohort_opt`` argument (the cohort's paged-in moments, replacing
      the fresh ``opt.init`` delta snapshots otherwise use) and returns
      the post-scan moments as a FOURTH output for the pager to write
      back — this is what lifts delta's stateless/reset restriction.
    * ``mesh`` / ``batch_specs`` — required iff ``backend="lace_dp"``:
      the whole event runs inside one ``shard_map`` with the client axis
      sharded over the mesh's client axes; each shard pops
      ``cohort / n_shards`` of its local finishers (balanced two-tier
      schedule, module docstring). Requires cohort and K divisible by
      the client-shard count and a shard-decomposable aggregator
      (``Aggregator.shard_local``).

    ``state.params["client"]`` always holds the *current* global client
    half broadcast over the K slots (checkpoint/eval-compatible with the
    sync runner) — over a single slot under ``snapshots="delta"``; the
    per-client training snapshots live in ``afed.client_params`` (dense)
    or ``afed.ring`` (delta).

    Metrics extend the engine's with the async observables:
    ``arrival_mask`` (K,), ``staleness`` (K,) pre-event ages (both
    gated on ``emit_client_metrics``), ``staleness_mean`` over the
    cohort, ``t_event``, and ``server_version`` post-event.

    Fault tolerance:

    * ``deadline`` / ``backoff`` — graceful degradation of the cohort
      barrier: the event fires at ``min(cohort-th finish, first finish +
      deadline)``; arrivals that miss it are excluded from the event
      (mask-folded out of the scan, so cohort priors cover only the
      present subset), keep their version/snapshot/moments, and are
      requeued at ``t_event + delay * backoff**retries`` (exponential
      backoff per consecutive miss — a stalled client stops blocking
      the schedule). ``deadline=None`` is the legacy unbounded wait.
    * ``faults`` — :class:`repro.fed.faults.FaultModel` (per-*arrival*
      here): drops leave the contribution mask, corruption poisons the
      arriving update in transit, stalls multiply the re-dispatch delay
      by ``stall_factor`` (rescued later by deadline/backoff).
    * ``guards`` — :class:`repro.fed.guards.GuardPolicy`: rejected
      arrivals trigger a ``lax.cond`` re-run of the cohort scan under
      the survivor mask (priors recomputed as if they never arrived)
      and are zeroed out of the delayed aggregation; they re-dispatch
      fresh from the new global. Bit-identical to the unguarded event
      when nothing is rejected. ``clip:TAU`` needs ``afed.guard``
      (``init_async_state(..., guards=...)``).
    """
    if opt_state_policy not in engine.OPT_STATE_POLICIES:
        raise ValueError(f"unknown opt_state_policy {opt_state_policy!r}; "
                         f"expected {engine.OPT_STATE_POLICIES}")
    if snapshots not in SNAPSHOT_MODES:
        raise ValueError(f"unknown snapshots mode {snapshots!r}; expected "
                         f"{SNAPSHOT_MODES}")
    if snapshots == "delta" and opt_state_policy == "average":
        raise ValueError(
            "snapshots='delta' stores no per-client optimizer state to "
            "average; use opt_state_policy 'reset' (or 'carry' with a "
            "stateless optimizer)")
    if cohort < 1:
        raise ValueError(f"cohort must be >= 1, got {cohort}")
    if arrival not in ARRIVALS:
        raise ValueError(f"unknown arrival {arrival!r}; expected {ARRIVALS}")
    if paged_opt and (snapshots != "delta" or opt_state_policy != "carry"):
        raise ValueError(
            "paged_opt pages per-client moments for snapshots='delta' + "
            "opt_state_policy='carry' (dense snapshots already store them "
            f"on device); got snapshots={snapshots!r}, "
            f"opt_state_policy={opt_state_policy!r}")
    from repro.fed import faults as _faults
    from repro.fed import guards as _guards

    if faults is not None:
        faults = _faults.make_faults(faults)
    if guards is not None:
        guards = _guards.make_guards(guards)
    if deadline is not None and deadline <= 0:
        raise ValueError(f"deadline must be > 0, got {deadline}")
    if backoff < 1.0:
        raise ValueError(f"backoff must be >= 1, got {backoff}")
    robust = (deadline is not None) or (faults is not None) \
        or (guards is not None)
    if robust and backend == "lace_dp":
        raise ValueError(
            "deadline/faults/guards are not supported on the lace_dp event "
            "(its pop and FL phase run inside shard_map); use a single-host "
            "backend")
    if robust and paged_opt:
        raise ValueError(
            "deadline/faults/guards are not supported with host-paged "
            "optimizer moments (the pager's arrival prediction does not "
            "model partial cohorts)")
    delta = snapshots == "delta"
    opt = optimizer if optimizer is not None else optimizers.sgd()
    agg = aggregator if aggregator is not None else _agg.weighted()
    sched = _resolve_schedule(schedule, scala, lr_scale, cohort, num_clients)

    if backend == "lace_dp":
        if arrival == "topk:sharded":
            raise ValueError(
                "backend 'lace_dp' pops per shard already (the balanced "
                "two-tier schedule); arrival 'sort' or 'topk' picks its "
                "local pop method")
        if paged_opt:
            raise ValueError("paged_opt is not supported on the lace_dp "
                             "event (its delta path keeps moments local)")
        return _make_async_runner_dp(
            model, scala, boundary=boundary, delays=delays, cohort=cohort,
            opt=opt, sched=sched,
            ce_chunk=ce_chunk, staleness_decay=staleness_decay,
            mix_rate=mix_rate, agg=agg, server_optimizer=server_optimizer,
            server_lr=server_lr, opt_state_policy=opt_state_policy,
            unroll=unroll, precision=precision, delta=delta,
            ring_size=ring_size, emit_client_metrics=emit_client_metrics,
            arrival=arrival, mesh=mesh, batch_specs=batch_specs)
    pop = make_arrival_pop(cohort, arrival, mesh=mesh)

    step = engine.make_split_step(model, scala, backend=backend,
                                  boundary=boundary,
                                  optimizer=opt, schedule=sched,
                                  ce_chunk=ce_chunk, precision=precision)

    def async_fn(state: engine.TrainState, afed: AsyncFedState,
                 round_batches, data_sizes=None, cohort_opt=None):
        K = afed.version.shape[0]
        if cohort > K:
            raise ValueError(f"cohort {cohort} exceeds the {K} client slots")
        if paged_opt and cohort_opt is None:
            raise ValueError(
                "the paged event needs cohort_opt= (the arrival cohort's "
                "paged-in moments — HostOptPager.gather over the idx "
                "make_arrival_pop predicts)")
        if delta and not paged_opt and opt_state_policy == "carry" \
                and jax.tree.leaves(state.opt_state["client"]):
            raise ValueError(
                "snapshots='delta' cannot carry per-client optimizer "
                "moments (none are stored); use a stateless optimizer "
                "(plain sgd), opt_state_policy='reset', or the host-paged "
                "moment store (paged_opt=True + HostOptPager)")

        if deadline is not None and isinstance(afed.retries, tuple):
            raise ValueError(
                "deadline needs per-client retry counters (afed.retries) — "
                "rebuild the state with init_async_state")
        if guards is not None and guards.clip > 0 \
                and isinstance(afed.guard, tuple):
            raise ValueError(
                "guard norm clipping needs afed.guard (running median) — "
                "build the state with init_async_state(..., guards=...)")

        # --- event pop: who arrives, and when ---
        idx, arrival_mask, t_event = pop(afed.finish_time, afed.version)
        present = retries_sub = None
        if deadline is not None:
            # graceful degradation of the cohort barrier: fire at
            # min(cohort-th finish, first finish + deadline); arrivals
            # past the cut are excluded from the event and backed off
            ft_sub = jnp.take(afed.finish_time, idx)
            t_event = jnp.minimum(t_event, jnp.min(ft_sub)
                                  + jnp.float32(deadline))
            present = (ft_sub <= t_event).astype(jnp.float32)
            arrival_mask = jnp.zeros((K,), jnp.float32).at[idx].set(present)
            retries_sub = jnp.take(afed.retries, idx)
        staleness = (afed.server_version - afed.version).astype(jnp.float32)

        # --- fault injection: per-arrival drop / corrupt / stall ---
        contrib = present
        corrupt_sub = stall_sub = corrupt_key = None
        key_rest = afed.key
        if faults is not None:
            k_ev, key_rest = jax.random.split(afed.key)
            k_masks, corrupt_key = jax.random.split(k_ev)
            fmasks = _faults.sample_fault_masks(faults, k_masks, cohort)
            alive = 1.0 - fmasks["drop"]
            contrib = alive if contrib is None else contrib * alive
            corrupt_sub = fmasks["corrupt"] * contrib
            stall_sub = fmasks["stall"]

        # --- sparse-slot local compute from the per-client snapshots:
        # the engine's gather, sourced from the snapshots (dense) or
        # reconstructed from the version ring (delta) ---
        if delta:
            snap_c, _ = ring_lookup(afed.ring, jnp.take(afed.version, idx),
                                    afed.server_version, ring_size)
            # carried moments: the paged-in rows when paging, else the
            # fresh init delta snapshots otherwise imply
            opt_sub = (cohort_opt if paged_opt
                       else jax.vmap(opt.init)(snap_c))
            sub = engine.TrainState(
                params={"client": snap_c, "server": state.params["server"]},
                opt_state={"client": opt_sub,
                           "server": state.opt_state["server"]},
                step=state.step)
        else:
            sub = engine._gather_clients(
                engine.TrainState(
                    params={"client": afed.client_params,
                            "server": state.params["server"]},
                    opt_state=state.opt_state, step=state.step), idx)
        b_lead = jax.tree.leaves(round_batches)[0].shape[1]
        if b_lead == K:
            sub_batches = jax.tree.map(lambda a: jnp.take(a, idx, axis=1),
                                       round_batches)
        elif b_lead == cohort:
            if agg.needs_priors:
                raise ValueError(
                    f"aggregator {agg.name!r} needs (K,)-indexed aggregation "
                    "priors, which cohort-sized round_batches cannot "
                    "provide; pass full (T, K, ...) batches")
            sub_batches = round_batches
        else:
            raise ValueError(
                f"round_batches client axis is {b_lead}; expected the {K} "
                f"static slots or the {cohort}-sized arrival cohort")
        # priors / logit adjustments recompute over the arrival cohort:
        # the gathered batch IS the cohort's concatenated batch (masked
        # down to the contributing subset under deadline/faults)
        sub0 = sub  # pre-scan cohort state: guard recompute / restores
        snap0 = sub0.params["client"]

        def run_local(mask_):
            body = (lambda s, b: step(s, b, mask_)) if mask_ is not None \
                else step
            s2, ms = jax.lax.scan(body, sub0, sub_batches, unroll=unroll)
            mets = jax.tree.map(lambda a: a[-1], ms)
            if corrupt_sub is not None:
                # the update is corrupted in transit, AFTER training
                cp = _faults.corrupt_update(faults, corrupt_key,
                                            s2.params["client"], corrupt_sub)
                s2 = engine.TrainState(
                    params={"client": cp, "server": s2.params["server"]},
                    opt_state=s2.opt_state, step=s2.step)
            return s2, mets

        sub, metrics = run_local(contrib)

        # --- guarded aggregation: screen the arriving updates ---
        accept = factor = None
        new_guard_state = afed.guard
        if guards is not None:
            base = (contrib if contrib is not None
                    else jnp.ones((cohort,), jnp.float32))
            delta_u = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                sub.params["client"], snap0)
            accept, factor, g_norms, new_guard_state = _guards.screen(
                guards, delta_u, base, afed.guard)
            survivor = base * accept
            rejected = base.sum() - survivor.sum()
            # >=1 rejection: re-run the cohort scan over the survivors
            # so the priors / logit adjustments match an event the
            # rejected arrivals never joined
            sub, metrics = jax.lax.cond(
                rejected > 0, lambda _: run_local(survivor),
                lambda _: (sub, metrics), None)
            if guards.clip > 0:
                delta2 = jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32)
                                  - b.astype(jnp.float32)),
                    sub.params["client"], snap0)
                _, factor, _, _ = _guards.screen(guards, delta2, survivor,
                                                 afed.guard)
            # survivor == base bitwise when nothing was rejected
            contrib = survivor

        mask_eff = arrival_mask
        if contrib is not None:
            mask_eff = jnp.zeros((K,), jnp.float32).at[idx].set(contrib)

        # --- staleness-weighted delayed aggregation (GAS / FedAsync) ---
        p_k = p_global = None
        if agg.needs_priors:
            p_k, p_global = _agg.aggregation_priors(
                model.num_classes, round_batches["labels"],
                round_batches.get("weights"), client_axis=1)
        ctx = _agg.AggContext(num_clients=K, mask=mask_eff,
                              data_sizes=data_sizes, p_k=p_k,
                              p_global=p_global)
        w_base, agg_state = agg.client_weights(ctx, afed.agg_state)
        decay = jnp.power(jnp.float32(staleness_decay), staleness)
        r_hat = normalize_client_weights(w_base * decay, mask_eff)
        pc_sub = sub.params["client"]
        if guards is not None and guards.clip > 0:
            pc_sub = _guards.apply_clip(snap0, pc_sub, factor)
        if accept is not None:
            # 0-weight x NaN = NaN: rejected rows must be zeroed out of
            # the average, not just down-weighted
            pc_sub = jax.tree.map(
                lambda p: jnp.where(
                    accept.reshape((-1,) + (1,) * (p.ndim - 1)) > 0,
                    p, jnp.zeros((), p.dtype)), pc_sub)
        cohort_avg = weighted_mean(pc_sub, jnp.take(r_hat, idx))
        mu = jnp.float32(mix_rate)
        global_c = jax.tree.map(lambda a: a[0], state.params["client"])
        new_global = jax.tree.map(
            lambda g, c: ((1.0 - mu) * g.astype(jnp.float32)
                          + mu * c.astype(jnp.float32)).astype(g.dtype),
            global_c, cohort_avg)

        # --- server half: in-scan updates (+ optional FedOpt on delta) ---
        new_ws = sub.params["server"]
        server_opt_state = afed.server_opt
        if server_optimizer is not None:
            ws_delta = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                state.params["server"], new_ws)
            new_ws, server_opt_state = server_optimizer.update(
                ws_delta, server_opt_state, state.params["server"], server_lr)

        # --- cohort opt-state at the event boundary ---
        if delta:
            new_client = stack_client_params(new_global, 1)
            opt_c = jax.vmap(opt.init)(new_client)
        else:
            sub_opt_c = sub.opt_state["client"]
            if opt_state_policy == "reset":
                sub_opt_c = jax.vmap(opt.init)(sub.params["client"])
            elif opt_state_policy == "average":
                r_sub = jnp.take(r_hat, idx)

                def avg(a):
                    wb = r_sub.reshape((-1,) + (1,) * (a.ndim - 1))
                    m = (a.astype(jnp.float32) * wb).sum(axis=0) \
                        .astype(a.dtype)
                    return jnp.broadcast_to(m[None], a.shape)

                sub_opt_c = jax.tree.map(avg, sub_opt_c)
            if present is not None:
                # deadline-missed arrivals never delivered: keep their
                # pre-event moments
                sub_opt_c = jax.tree.map(
                    lambda o0, o1: jnp.where(
                        present.reshape((-1,) + (1,) * (o1.ndim - 1)) > 0,
                        o1, o0),
                    sub0.opt_state["client"], sub_opt_c)
            opt_c = engine.scatter_rows(state.opt_state["client"], sub_opt_c,
                                        idx)
            new_client = stack_client_params(new_global, K)

        # --- re-dispatch the cohort at the new version ---
        new_version = afed.server_version + 1
        k_delay, k_carry = jax.random.split(key_rest)
        new_delays = delays.sample(k_delay, (cohort,)).astype(jnp.float32)
        eff_delays = new_delays
        if stall_sub is not None:
            # stalled clients straggle for stall_factor x the sampled
            # delay; deadline/backoff later rescues the schedule
            eff_delays = jnp.where(stall_sub > 0,
                                   eff_delays * jnp.float32(
                                       faults.stall_factor),
                                   eff_delays)
        new_retries = None
        if present is not None:
            boff = jnp.power(jnp.float32(backoff),
                             retries_sub.astype(jnp.float32))
            eff_delays = jnp.where(present > 0, eff_delays,
                                   new_delays * boff)
            new_retries = jnp.where(present > 0, 0,
                                    retries_sub + 1).astype(jnp.int32)
        if delta:
            slot = new_version % ring_size
            snap = afed.client_params
            ring = jax.tree.map(
                lambda r, g: r.at[slot].set(g.astype(r.dtype)),
                afed.ring, new_global)
            ring_versions = afed.ring_versions.at[slot].set(new_version)
        else:
            rows = stack_client_params(new_global, cohort)
            if present is not None:
                # absent arrivals keep computing from their original
                # snapshot — only present ones restart from the new one
                rows = jax.tree.map(
                    lambda r, s0: jnp.where(
                        present.reshape((-1,) + (1,) * (r.ndim - 1)) > 0,
                        r.astype(s0.dtype), s0),
                    rows, snap0)
            snap = engine.scatter_rows(afed.client_params, rows, idx)
            ring, ring_versions = afed.ring, afed.ring_versions
        ver_sub = jnp.full((cohort,), new_version, jnp.int32)
        if present is not None:
            ver_sub = jnp.where(present > 0, ver_sub,
                                jnp.take(afed.version, idx)).astype(jnp.int32)
        retries_out = afed.retries
        if new_retries is not None:
            retries_out = afed.retries.at[idx].set(new_retries)
        new_afed = AsyncFedState(
            client_params=snap,
            version=afed.version.at[idx].set(ver_sub),
            server_version=new_version,
            finish_time=afed.finish_time.at[idx].set(t_event + eff_delays),
            now=t_event,
            key=k_carry,
            agg_state=agg_state,
            server_opt=server_opt_state,
            ring=ring,
            ring_versions=ring_versions,
            retries=retries_out,
            guard=new_guard_state)
        new_state = engine.TrainState(
            params={"client": new_client, "server": new_ws},
            opt_state={"client": opt_c, "server": sub.opt_state["server"]},
            step=sub.step)
        metrics = dict(metrics)
        if emit_client_metrics:
            metrics.update(
                arrival_mask=arrival_mask, staleness=staleness,
                staleness_mean=(staleness * arrival_mask).sum()
                / jnp.maximum(arrival_mask.sum(), 1.0))
        else:
            metrics.update(staleness_mean=jnp.take(staleness, idx).mean())
        metrics.update(t_event=t_event, server_version=new_version)
        if guards is not None:
            metrics.update(guard_accept=accept, guard_norm=g_norms,
                           guard_rejected=rejected)
        if present is not None:
            metrics.update(deadline_missed=jnp.float32(cohort)
                           - present.sum())
        if paged_opt:
            return new_state, new_afed, metrics, sub.opt_state["client"]
        return new_state, new_afed, metrics

    return async_fn


# ---------------------------------------------------------------------------
# the manual-SPMD ("lace_dp") event program
# ---------------------------------------------------------------------------


def _half_specs(tree, client_spec):
    """{'client','server'} pytree -> PartitionSpecs: client leaves on
    ``client_spec``, server leaves replicated."""
    from jax.sharding import PartitionSpec as P

    return {"client": jax.tree.map(lambda _: client_spec, tree["client"]),
            "server": jax.tree.map(lambda _: P(), tree["server"])}


def _make_async_runner_dp(model, scala, *, boundary, delays, cohort, opt,
                          sched,
                          ce_chunk, staleness_decay, mix_rate, agg,
                          server_optimizer, server_lr, opt_state_policy,
                          unroll, precision, delta, ring_size,
                          emit_client_metrics, arrival, mesh, batch_specs):
    """The whole async event inside one ``shard_map`` (backend lace_dp).

    See :func:`make_async_runner` — this builds the same
    ``async_fn(state, afed, round_batches, data_sizes=None)`` with the
    client axis sharded over the mesh's client axes and a *per-shard*
    cohort pop (each shard waits for ``cohort / n_shards`` of its local
    finishers — the balanced two-tier schedule).
    """
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.sharding.logical import round_specs

    if mesh is None or batch_specs is None:
        raise ValueError("backend 'lace_dp' needs mesh= and batch_specs=")
    axes = engine.mesh_axes(mesh)
    n_shards = engine.client_shard_count(mesh)
    if cohort % n_shards:
        raise ValueError(f"cohort {cohort} must divide over the {n_shards} "
                         "client shards (per-shard balanced pop)")
    if agg.shard_local is None:
        raise ValueError(
            f"aggregator {agg.name!r} is not shard-decomposable "
            "(Aggregator.shard_local is None); the lace_dp event needs "
            "fedavg / weighted / hierarchical")
    if agg.stateful:
        raise ValueError(f"aggregator {agg.name!r} is stateful; the lace_dp "
                         "async event supports stateless aggregators only")
    if opt_state_policy == "average":
        raise ValueError("opt_state_policy 'average' is not supported on "
                         "the lace_dp async event; use 'carry' or 'reset'")
    cohort_l = cohort // n_shards
    cspec = P(axes.client or None)
    rb_specs = round_specs(batch_specs)
    m_specs = {"loss_server": P(), "loss_client": P(), "aux": P(),
               "staleness_mean": P(), "t_event": P(), "server_version": P()}
    if emit_client_metrics:
        m_specs.update(arrival_mask=cspec, staleness=cspec)

    def async_fn(state: engine.TrainState, afed: AsyncFedState,
                 round_batches, data_sizes=None):
        K = afed.version.shape[0]
        if K % n_shards:
            raise ValueError(f"{K} client slots must divide over the "
                             f"{n_shards} client shards")
        if delta and opt_state_policy == "carry" \
                and jax.tree.leaves(state.opt_state["client"]):
            raise ValueError(
                "snapshots='delta' cannot carry per-client optimizer "
                "moments; use a stateless optimizer or "
                "opt_state_policy='reset'")
        if jax.tree.leaves(round_batches)[0].shape[1] != K:
            raise ValueError("the lace_dp async event needs full (T, K, ...)"
                             " round_batches (sharded over the client axes)")
        if data_sizes is None:
            data_sizes = jnp.ones((K,), jnp.float32)

        pspec = P() if delta else cspec
        s_specs = engine.TrainState(
            params=_half_specs(state.params, pspec),
            opt_state=_half_specs(state.opt_state, pspec),
            step=P())
        a_specs = AsyncFedState(
            client_params=jax.tree.map(lambda _: cspec, afed.client_params),
            version=cspec, server_version=P(), finish_time=cspec, now=P(),
            key=P(),
            agg_state=jax.tree.map(lambda _: P(), afed.agg_state),
            server_opt=jax.tree.map(lambda _: P(), afed.server_opt),
            ring=jax.tree.map(lambda _: P(), afed.ring),
            ring_versions=P() if delta else (),
            retries=jax.tree.map(lambda _: cspec, afed.retries),
            guard=jax.tree.map(lambda _: P(), afed.guard))

        def body(st, af, rb, sizes_l):
            # --- per-shard pop of the local cohort (arrival= picks the
            # lexsort or the O(K_l)-work top-k; same schedule either way)
            idx, a_mask_l, t_l = arrival_cohort(af.finish_time, cohort_l,
                                                af.version, method=arrival)
            t_event = (jax.lax.pmax(t_l, axes.client) if axes.client
                       else t_l)
            stal_l = (af.server_version - af.version).astype(jnp.float32)

            # --- gather the local arrivals' snapshots ---
            if delta:
                snap_c, _ = ring_lookup(af.ring, jnp.take(af.version, idx),
                                        af.server_version, ring_size)
                sub = engine.TrainState(
                    params={"client": snap_c,
                            "server": st.params["server"]},
                    opt_state={"client": jax.vmap(opt.init)(snap_c),
                               "server": st.opt_state["server"]},
                    step=st.step)
            else:
                sub = engine._gather_clients(
                    engine.TrainState(
                        params={"client": af.client_params,
                                "server": st.params["server"]},
                        opt_state=st.opt_state, step=st.step), idx)
            sub_b = jax.tree.map(lambda a: jnp.take(a, idx, axis=1), rb)

            def step_body(s, b):
                grads, mets = engine.split_step_grads(
                    model, s.params, b, scala, backend="lace_dp",
                    boundary=boundary, ce_chunk=ce_chunk, axes=axes,
                    precision=precision)
                return engine._apply_updates(opt, s, grads,
                                             sched(s.step)), mets

            sub, ms = jax.lax.scan(step_body, sub, sub_b, unroll=unroll)
            metrics = dict(jax.tree.map(lambda a: a[-1], ms))

            # --- two-tier delayed aggregation: each shard (edge) folds
            # its cohort locally, the psum folds the edges ---
            w_base_l = agg.shard_local(a_mask_l, sizes_l, axes.client,
                                       n_shards)
            decay_l = jnp.power(jnp.float32(staleness_decay), stal_l)
            raw_l = w_base_l * decay_l * a_mask_l
            denom = raw_l.sum()
            if axes.client:
                denom = jax.lax.psum(denom, axes.client)
            r_l = raw_l / jnp.maximum(denom, 1e-8)
            part = weighted_mean(sub.params["client"], jnp.take(r_l, idx))
            cohort_avg = (jax.tree.map(
                lambda a: jax.lax.psum(a, axes.client), part)
                if axes.client else part)
            mu = jnp.float32(mix_rate)
            global_c = jax.tree.map(lambda a: a[0], st.params["client"])
            new_global = jax.tree.map(
                lambda g, c: ((1.0 - mu) * g.astype(jnp.float32)
                              + mu * c.astype(jnp.float32)).astype(g.dtype),
                global_c, cohort_avg)

            # --- server half (replicated; identical on every shard) ---
            new_ws = sub.params["server"]
            so_state = af.server_opt
            if server_optimizer is not None:
                ws_delta = jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32)
                                  - b.astype(jnp.float32)),
                    st.params["server"], new_ws)
                new_ws, so_state = server_optimizer.update(
                    ws_delta, so_state, st.params["server"], server_lr)

            # --- opt state / re-dispatch (local slots) ---
            new_version = af.server_version + 1
            k_delay, k_carry = jax.random.split(af.key)
            shard_ix = jnp.int32(0)
            for a in axes.client:
                shard_ix = shard_ix * dict(mesh.shape)[a] \
                    + jax.lax.axis_index(a)
            new_delays = delays.sample(
                jax.random.fold_in(k_delay, shard_ix),
                (cohort_l,)).astype(jnp.float32)
            if delta:
                new_client = stack_client_params(new_global, 1)
                opt_c = jax.vmap(opt.init)(new_client)
                slot = new_version % ring_size
                snap = af.client_params
                ring = jax.tree.map(
                    lambda r, g: r.at[slot].set(g.astype(r.dtype)),
                    af.ring, new_global)
                ring_versions = af.ring_versions.at[slot].set(new_version)
            else:
                sub_opt_c = sub.opt_state["client"]
                if opt_state_policy == "reset":
                    sub_opt_c = jax.vmap(opt.init)(sub.params["client"])
                opt_c = engine.scatter_rows(st.opt_state["client"],
                                            sub_opt_c, idx)
                new_client = stack_client_params(new_global,
                                                 af.version.shape[0])
                snap = engine.scatter_rows(
                    af.client_params,
                    stack_client_params(new_global, cohort_l), idx)
                ring, ring_versions = af.ring, af.ring_versions
            new_af = AsyncFedState(
                client_params=snap,
                version=af.version.at[idx].set(new_version),
                server_version=new_version,
                finish_time=af.finish_time.at[idx].set(t_event + new_delays),
                now=t_event,
                key=k_carry,
                agg_state=af.agg_state,
                server_opt=so_state,
                ring=ring,
                ring_versions=ring_versions,
                retries=af.retries,
                guard=af.guard)
            new_st = engine.TrainState(
                params={"client": new_client, "server": new_ws},
                opt_state={"client": opt_c,
                           "server": sub.opt_state["server"]},
                step=sub.step)
            s_sum = (stal_l * a_mask_l).sum()
            s_cnt = a_mask_l.sum()
            if axes.client:
                s_sum = jax.lax.psum(s_sum, axes.client)
                s_cnt = jax.lax.psum(s_cnt, axes.client)
            if emit_client_metrics:
                metrics.update(arrival_mask=a_mask_l, staleness=stal_l)
            metrics.update(staleness_mean=s_sum / jnp.maximum(s_cnt, 1.0),
                           t_event=t_event, server_version=new_version)
            return new_st, new_af, metrics

        fn = compat.shard_map(
            body, mesh=mesh,
            in_specs=(s_specs, a_specs, rb_specs, cspec),
            out_specs=(s_specs, a_specs, m_specs), check_vma=False)
        return fn(state, afed, round_batches, data_sizes)

    return async_fn
