"""Asynchronous split-federated execution on top of the split-step engine.

The synchronous round (:func:`repro.core.engine.make_round_runner`) is a
barrier: every participating client runs T local iterations from the same
aggregated model, then the FL phase averages. Real fleets are
asynchronous — clients finish at different times and their updates were
computed against *older* server params. GAS (arXiv:2409.01251) shows the
workable recipe is staleness-aware delayed aggregation; this module
implements it as a *jit-compatible event schedule*:

1. Every client holds a **snapshot** of the global client half (the
   params it trains from) tagged with the server **version** it was taken
   at, plus a sampled **finish time** (:mod:`repro.fed.delays`).
2. One call of the async runner is one **event**: the ``cohort`` earliest
   finishers arrive. Their T local iterations run on a dense sparse-slot
   axis (gathered from the static K slots, exactly the engine's
   ``slot_gather`` path), with label priors and logit adjustments
   recomputed over the *arrival cohort* — the same per-subset semantics
   the sync path applies per participating subset.
3. The arrivals' trained client halves are folded into the global model
   with **staleness-weighted delayed aggregation** (FedAsync/GAS-style
   model mixing): per-arrival weights are the aggregator's weights
   decayed by ``staleness_decay ** age`` (age = server versions elapsed
   since the snapshot), renormalized over the cohort, and the global
   client half moves ``mix_rate`` of the way to the cohort average. The
   server half trains in-scan as always (it is never averaged) with an
   optional FedOpt ``server_optimizer`` over its event delta.
4. The cohort re-snapshots the new global model at the new version,
   samples fresh delays, and the event clock advances to the cohort's
   latest arrival. Busy clients keep their snapshots and finish times.

Everything — cohort selection, gather/scatter, delay sampling, the
staleness weights — is pure jax inside one compiled program per event.

**The sync round is the zero-delay special case**: with
``delays=constant(0)`` and ``cohort=K`` every client arrives at every
event with staleness 0, the cohort average is the full FedAvg, and
``mix_rate=1`` replaces the global model with it — bit-for-bit the
synchronous round runner (test-enforced at fp32 tolerance in
``tests/test_async.py``).

:class:`AsyncFedState` invariants (maintained by :func:`init_async_state`
and every runner call; rely on them, don't re-derive):

* ``version[k] <= server_version`` elementwise; ``server_version``
  increments by exactly 1 per event.
* ``client_params[k]`` is the global client half as of ``version[k]`` —
  slots with ``version[k] == server_version`` hold the *current* global
  model.
* ``finish_time[k] >= now`` for busy clients; arrivals satisfy
  ``finish_time[k] <= new now`` at the event that pops them and are
  re-armed strictly into the future (for nonzero delays).
* ``server_version - version`` is the per-client staleness age — under a
  full-barrier schedule it reproduces the sync
  :func:`repro.fed.aggregators.staleness_weighted` age bookkeeping.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ScalaConfig
from repro.core import engine
from repro.core.split import (normalize_client_weights, stack_client_params,
                              weighted_mean)
from repro.fed import aggregators as _agg
from repro.fed.delays import DelayModel
from repro.optim import optimizers


@dataclass(frozen=True)
class AsyncFedState:
    """Per-client dispatch state threaded through async events.

    client_params: (K, ...) stacked per-client snapshots of the global
    client half (what each client is training from);
    version: (K,) int32 server version each snapshot was taken at;
    server_version: () int32 global version (events applied so far);
    finish_time: (K,) float32 simulated completion time per client;
    now: () float32 event clock (the last cohort's latest arrival);
    key: PRNG key driving delay sampling;
    agg_state: aggregator carry (e.g. staleness ages) — usually () since
    the runtime tracks ages itself via ``version``;
    server_opt: server-side FedOpt optimizer state (or ()).
    """

    client_params: Any
    version: Any
    server_version: Any
    finish_time: Any
    now: Any
    key: Any
    agg_state: Any = ()
    server_opt: Any = ()


jax.tree_util.register_dataclass(
    AsyncFedState,
    data_fields=("client_params", "version", "server_version", "finish_time",
                 "now", "key", "agg_state", "server_opt"),
    meta_fields=())


def init_async_state(key, client_params, delays: DelayModel, *,
                     aggregator=None,
                     server_optimizer: Optional[optimizers.Optimizer] = None,
                     server_params=None) -> AsyncFedState:
    """Dispatch all K clients at version 0.

    ``client_params`` is the stacked (K, ...) client half (every slot
    holds the same init — :func:`repro.core.split.stack_client_params`);
    each client's first completion delay is sampled immediately, so the
    first event pops the cohort of earliest finishers. Pass the same
    ``aggregator`` / ``server_optimizer`` the runner was built with so
    their state is initialized to matching shapes.
    """
    K = jax.tree.leaves(client_params)[0].shape[0]
    k_delay, k_carry = jax.random.split(jnp.asarray(key))
    if server_optimizer is not None and server_params is None:
        raise ValueError("init_async_state needs server_params when a "
                         "server_optimizer is given")
    return AsyncFedState(
        client_params=client_params,
        version=jnp.zeros((K,), jnp.int32),
        server_version=jnp.zeros((), jnp.int32),
        finish_time=delays.sample(k_delay, (K,)).astype(jnp.float32),
        now=jnp.zeros((), jnp.float32),
        key=k_carry,
        agg_state=aggregator.init(K) if aggregator is not None else (),
        server_opt=(server_optimizer.init(server_params)
                    if server_optimizer is not None else ()))


def arrival_cohort(finish_time, cohort: int, version=None):
    """The event schedule's pop: the ``cohort`` earliest finishers.

    Returns (idx (cohort,) ascending slot ids, mask (K,) 0/1 float32,
    t_event — the cohort's latest finish time, i.e. the new clock).
    Ties (equal finish times) break by snapshot ``version`` — the
    longest-waiting client goes first (FIFO) — then by slot id (lexsort
    is stable). Without the version key, degenerate schedules (zero or
    constant-tied delays with ``cohort < K``) would re-arm the lowest
    slot ids at the same finish time and starve every other slot; with
    it, zero delays pop slots round-robin in blocks of ``cohort``.
    """
    if version is None:
        order = jnp.argsort(finish_time)
    else:
        order = jnp.lexsort((version, finish_time))
    idx = jnp.sort(order[:cohort])
    K = finish_time.shape[0]
    mask = jnp.zeros((K,), jnp.float32).at[idx].set(1.0)
    t_event = jnp.max(jnp.take(finish_time, idx))
    return idx, mask, t_event


def make_async_runner(model: engine.SplitModel, scala: ScalaConfig, *,
                      delays: DelayModel,
                      cohort: int,
                      backend: str = "logits",
                      optimizer: Optional[optimizers.Optimizer] = None,
                      schedule: Optional[Callable] = None,
                      ce_chunk: Optional[int] = None,
                      staleness_decay: float = 0.5,
                      mix_rate: float = 1.0,
                      aggregator=None,
                      server_optimizer: Optional[optimizers.Optimizer] = None,
                      server_lr: float = 1.0,
                      opt_state_policy: str = "carry",
                      unroll=1,
                      precision: str = "f32"):
    """Build the async event program: ``async_fn(state, afed,
    round_batches, data_sizes=None) -> (state, afed, metrics)``.

    ``round_batches`` leaves are (T, K, Bk, ...) — one local-iteration
    schedule for every static slot; only the arrival cohort's columns are
    computed (sparse-slot gather), so the per-event cost is
    ~``cohort / K`` of a full sync round.

    * ``delays`` / ``cohort`` — the event schedule: completion delays per
      dispatch, and how many arrivals each event waits for
      (``cohort=K`` is a full barrier; ``cohort=1`` is fully async).
    * ``staleness_decay`` / ``mix_rate`` — delayed-aggregation knobs: an
      arrival whose snapshot is ``a`` versions old is decayed by
      ``staleness_decay ** a`` inside the cohort weights, and the global
      client half moves ``mix_rate`` toward the cohort average
      (FedAsync-style mixing; ``mix_rate=1`` replaces it).
    * ``aggregator`` — base per-arrival weights before the staleness
      decay (default: data-size :func:`repro.fed.aggregators.weighted`,
      matching the sync runner's default). Stateful aggregators thread
      their carry through ``afed.agg_state``; note the runtime already
      tracks ages via ``version``, so :func:`staleness_weighted` here
      would double-decay.
    * ``server_optimizer`` / ``server_lr`` — optional FedOpt on the
      server half's event delta (state in ``afed.server_opt``), the same
      semantics as the sync runner's.
    * ``opt_state_policy`` — the cohort's client optimizer state at the
      event boundary: ``carry`` scatters the cohort's updated moments
      back to their slots (busy clients' moments are untouched),
      ``reset`` zeroes the cohort's, ``average`` redistributes the
      cohort-weighted mean over the cohort slots.
    * ``precision`` — the engine step's compute policy
      (:data:`repro.core.engine.PRECISIONS`): ``"bf16"`` runs the
      cohort's local forward/backward in bfloat16 against f32 master
      params; the staleness weights, priors, and delayed aggregation
      stay f32.

    ``state.params["client"]`` always holds the *current* global client
    half broadcast over the K slots (checkpoint/eval-compatible with the
    sync runner); the per-client training snapshots live in
    ``afed.client_params``.

    Metrics extend the engine's with the async observables:
    ``arrival_mask`` (K,), ``staleness`` (K,) pre-event ages,
    ``staleness_mean`` over the cohort, ``t_event``, and
    ``server_version`` post-event.
    """
    if opt_state_policy not in engine.OPT_STATE_POLICIES:
        raise ValueError(f"unknown opt_state_policy {opt_state_policy!r}; "
                         f"expected {engine.OPT_STATE_POLICIES}")
    if backend == "lace_dp":
        raise ValueError("make_async_runner does not support the 'lace_dp' "
                         "backend (the sparse-slot gather crosses the "
                         "sharded client axis); use 'lace'")
    if cohort < 1:
        raise ValueError(f"cohort must be >= 1, got {cohort}")
    opt = optimizer if optimizer is not None else optimizers.sgd()
    agg = aggregator if aggregator is not None else _agg.weighted()
    step = engine.make_split_step(model, scala, backend=backend,
                                  optimizer=opt, schedule=schedule,
                                  ce_chunk=ce_chunk, precision=precision)

    def async_fn(state: engine.TrainState, afed: AsyncFedState,
                 round_batches, data_sizes=None):
        K = jax.tree.leaves(afed.client_params)[0].shape[0]
        if cohort > K:
            raise ValueError(f"cohort {cohort} exceeds the {K} client slots")

        # --- event pop: who arrives, and when ---
        idx, arrival_mask, t_event = arrival_cohort(afed.finish_time, cohort,
                                                    afed.version)
        staleness = (afed.server_version - afed.version).astype(jnp.float32)

        # --- sparse-slot local compute from the per-client snapshots:
        # the engine's gather, sourced from the snapshots rather than the
        # (slot-unified) global stacked params ---
        sub = engine._gather_clients(
            engine.TrainState(
                params={"client": afed.client_params,
                        "server": state.params["server"]},
                opt_state=state.opt_state, step=state.step), idx)
        sub_batches = jax.tree.map(lambda a: jnp.take(a, idx, axis=1),
                                   round_batches)
        # priors / logit adjustments recompute over the arrival cohort:
        # the gathered batch IS the cohort's concatenated batch
        sub, ms = jax.lax.scan(step, sub, sub_batches, unroll=unroll)
        metrics = jax.tree.map(lambda a: a[-1], ms)

        # --- staleness-weighted delayed aggregation (GAS / FedAsync) ---
        p_k = p_global = None
        if agg.needs_priors:
            p_k, p_global = _agg.aggregation_priors(
                model.num_classes, round_batches["labels"],
                round_batches.get("weights"), client_axis=1)
        ctx = _agg.AggContext(num_clients=K, mask=arrival_mask,
                              data_sizes=data_sizes, p_k=p_k,
                              p_global=p_global)
        w_base, agg_state = agg.client_weights(ctx, afed.agg_state)
        decay = jnp.power(jnp.float32(staleness_decay), staleness)
        r_hat = normalize_client_weights(w_base * decay, arrival_mask)
        cohort_avg = weighted_mean(sub.params["client"],
                                   jnp.take(r_hat, idx))
        mu = jnp.float32(mix_rate)
        global_c = jax.tree.map(lambda a: a[0], state.params["client"])
        new_global = jax.tree.map(
            lambda g, c: ((1.0 - mu) * g.astype(jnp.float32)
                          + mu * c.astype(jnp.float32)).astype(g.dtype),
            global_c, cohort_avg)

        # --- server half: in-scan updates (+ optional FedOpt on delta) ---
        new_ws = sub.params["server"]
        server_opt_state = afed.server_opt
        if server_optimizer is not None:
            delta = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                state.params["server"], new_ws)
            new_ws, server_opt_state = server_optimizer.update(
                delta, server_opt_state, state.params["server"], server_lr)

        # --- cohort opt-state at the event boundary ---
        sub_opt_c = sub.opt_state["client"]
        if opt_state_policy == "reset":
            sub_opt_c = jax.vmap(opt.init)(sub.params["client"])
        elif opt_state_policy == "average":
            r_sub = jnp.take(r_hat, idx)

            def avg(a):
                wb = r_sub.reshape((-1,) + (1,) * (a.ndim - 1))
                m = (a.astype(jnp.float32) * wb).sum(axis=0).astype(a.dtype)
                return jnp.broadcast_to(m[None], a.shape)

            sub_opt_c = jax.tree.map(avg, sub_opt_c)
        opt_c = engine.scatter_rows(state.opt_state["client"], sub_opt_c, idx)

        # --- re-dispatch the cohort at the new version ---
        new_version = afed.server_version + 1
        k_delay, k_carry = jax.random.split(afed.key)
        new_delays = delays.sample(k_delay, (cohort,)).astype(jnp.float32)
        snap = engine.scatter_rows(
            afed.client_params, stack_client_params(new_global, cohort), idx)
        new_afed = AsyncFedState(
            client_params=snap,
            version=afed.version.at[idx].set(new_version),
            server_version=new_version,
            finish_time=afed.finish_time.at[idx].set(t_event + new_delays),
            now=t_event,
            key=k_carry,
            agg_state=agg_state,
            server_opt=server_opt_state)
        new_state = engine.TrainState(
            params={"client": stack_client_params(new_global, K),
                    "server": new_ws},
            opt_state={"client": opt_c, "server": sub.opt_state["server"]},
            step=sub.step)
        metrics = dict(metrics)
        metrics.update(arrival_mask=arrival_mask, staleness=staleness,
                       staleness_mean=(staleness * arrival_mask).sum()
                       / jnp.maximum(arrival_mask.sum(), 1.0),
                       t_event=t_event,
                       server_version=new_version)
        return new_state, new_afed, metrics

    return async_fn
