import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Compile one (arch, shape) pair and print the loop-aware per-op
collective ranking — the dry-run 'profiler' used by §Perf.

  PYTHONPATH=src python -m repro.launch.profile_collectives \
      --arch qwen1.5-0.5b --shape train_4k [--multi-pod] [--save /tmp/x.txt]
"""  # noqa: E402

import argparse  # noqa: E402

import jax  # noqa: E402

from repro import compat
from repro.launch.dryrun import build_step  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.perf.roofline import collective_breakdown  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--save", default="")
    ap.add_argument("--no-constrain", action="store_true")
    args = ap.parse_args()

    if args.no_constrain:
        from repro.sharding import logical
        logical.CONSTRAIN = False

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    step, fargs, in_sh, out_sh, meta, cfg = build_step(
        args.arch, args.shape, mesh)
    with compat.set_mesh(mesh):
        hlo = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh) \
            .lower(*fargs).compile().as_text()
    if args.save:
        with open(args.save, "w") as f:
            f.write(hlo)
    items, total = collective_breakdown(hlo, top=args.top)
    print(f"{args.arch} {args.shape} total={total:.3e} B/device "
          f"t_coll={total/50e9:.2f}s")
    for b, op, shape, mult, opn in items:
        print(f"{b:10.3e} ({100*b/total:4.1f}%) x{mult:<4} {op:18s} "
              f"{shape:44s} {opn}")


if __name__ == "__main__":
    main()
