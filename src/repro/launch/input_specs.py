"""ShapeDtypeStruct stand-ins + logical axes for every lowered entrypoint.

No device allocation: these are exactly what ``jax.jit(...).lower()``
consumes for the multi-pod dry-run.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import transformer as T
from repro.models.common import dtype_of

SDS = jax.ShapeDtypeStruct


def _batch_axes_train() -> Dict[str, tuple]:
    return {
        "tokens": ("client", "per_client_batch", "seq"),
        "labels": ("client", "per_client_batch", "seq"),
        "weights": ("client", "per_client_batch", "seq"),
    }


def train_batch_specs(cfg: ModelConfig, shape: InputShape, num_clients: int
                      ) -> Tuple[dict, dict]:
    """(ShapeDtypeStruct tree, logical-axes tree) for one SCALA local step.

    Labels cover the full (prefix + text) sequence; prefix positions get
    zero weight — matching what the loss actually sees.
    """
    C = num_clients
    assert shape.global_batch % C == 0, (shape.name, C)
    bk = shape.global_batch // C
    P = cfg.num_prefix_tokens if cfg.frontend == "vision" else 0
    text = shape.seq_len - P
    assert text > 0

    specs = {
        "tokens": SDS((C, bk, text), jnp.int32),
        "labels": SDS((C, bk, shape.seq_len), jnp.int32),
        "weights": SDS((C, bk, shape.seq_len), jnp.float32),
    }
    axes = _batch_axes_train()
    emb_dtype = dtype_of(cfg.dtype)
    if cfg.frontend == "vision":
        specs["prefix_emb"] = SDS((C, bk, cfg.num_prefix_tokens,
                                   cfg.frontend_dim), emb_dtype)
        axes["prefix_emb"] = ("client", "per_client_batch", "prefix", "frontend")
    if cfg.frontend == "audio":
        specs["memory_emb"] = SDS((C, bk, cfg.num_prefix_tokens,
                                   cfg.frontend_dim), emb_dtype)
        axes["memory_emb"] = ("client", "per_client_batch", "prefix", "frontend")
    return specs, axes


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape
                        ) -> Tuple[dict, dict]:
    B = shape.global_batch
    P = cfg.num_prefix_tokens if cfg.frontend == "vision" else 0
    text = shape.seq_len - P
    specs = {"tokens": SDS((B, text), jnp.int32)}
    axes = {"tokens": ("batch", "seq")}
    emb_dtype = dtype_of(cfg.dtype)
    if cfg.frontend == "vision":
        specs["prefix_emb"] = SDS((B, cfg.num_prefix_tokens, cfg.frontend_dim),
                                  emb_dtype)
        axes["prefix_emb"] = ("batch", "prefix", "frontend")
    if cfg.frontend == "audio":
        specs["memory_emb"] = SDS((B, cfg.num_prefix_tokens, cfg.frontend_dim),
                                  emb_dtype)
        axes["memory_emb"] = ("batch", "prefix", "frontend")
    return specs, axes


def decode_batch_specs(cfg: ModelConfig, shape: InputShape
                       ) -> Tuple[dict, dict, dict, dict]:
    """Returns (batch_specs, batch_axes, cache_specs, cache_axes)."""
    B = shape.global_batch
    specs = {"tokens": SDS((B, 1), jnp.int32)}
    axes = {"tokens": ("batch", "seq")}
    if cfg.frontend == "audio":
        specs["memory_emb"] = SDS((B, cfg.num_prefix_tokens, cfg.frontend_dim),
                                  dtype_of(cfg.dtype))
        axes["memory_emb"] = ("batch", "prefix", "frontend")

    cache_shapes = jax.eval_shape(
        lambda: T.init_decode_cache(cfg, B, shape.seq_len))
    cache_ax = T.cache_axes(cfg)
    return specs, axes, cache_shapes, cache_ax


def param_specs(cfg: ModelConfig, num_clients: int = 0):
    """(ShapeDtypeStruct tree, logical-axes tree) for model params.

    num_clients > 0 -> SCALA layout (client half stacked over clients);
    num_clients == 0 -> merged/serving layout.
    """
    shapes = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    axes = T.param_axes(cfg)
    if num_clients > 0:
        shapes = dict(shapes)
        shapes["client"] = jax.tree.map(
            lambda s: SDS((num_clients,) + s.shape, s.dtype), shapes["client"])
        axes = dict(axes)
        axes["client"] = jax.tree.map(
            lambda a: ("client",) + a, axes["client"],
            is_leaf=lambda a: isinstance(a, tuple))
    return shapes, axes
