"""Serving driver: batched prefill + decode on the merged global model.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as T


def generate(params, cfg, prompt_tokens, max_len: int, gen: int,
             extra_batch=None, temperature: float = 0.0, seed: int = 0):
    """Greedy/temperature sampling. prompt_tokens: (B, P)."""
    B, P = prompt_tokens.shape
    cache = T.init_decode_cache(cfg, B, max_len)
    decode = jax.jit(
        lambda p, b, c, i: T.decode_step(p, b, c, i, cfg))

    key = jax.random.PRNGKey(seed)
    # prefill token-by-token through the decode path (cache-exact); a
    # production deployment would use the fused prefill (forward_prefill)
    # plus cache scatter — the dry-run lowers that path separately.
    tok = prompt_tokens[:, :1]
    gen_toks = []
    for i in range(P + gen - 1):
        batch = {"tokens": tok}
        if extra_batch:
            batch.update(extra_batch)
        logits, cache = decode(params, batch, cache, jnp.int32(i))
        if i + 1 < P:
            tok = prompt_tokens[:, i + 1:i + 2]
        else:
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits[:, 0] / temperature)[:, None]
            else:
                tok = jnp.argmax(logits[:, 0], axis=-1)[:, None]
            gen_toks.append(tok)
    return jnp.concatenate([prompt_tokens] + gen_toks, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(key, cfg)

    prompts = jax.random.randint(
        jax.random.fold_in(key, 1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)
    extra = None
    if cfg.frontend == "audio":
        extra = {"memory_emb": jnp.zeros(
            (args.batch, cfg.num_prefix_tokens, cfg.frontend_dim))}

    t0 = time.time()
    out = generate(params, cfg, prompts, args.prompt_len + args.gen,
                   args.gen, extra_batch=extra,
                   temperature=args.temperature, seed=args.seed)
    dt = time.time() - t0
    total_new = args.batch * args.gen
    print(f"generated {out.shape} in {dt:.1f}s "
          f"({total_new / dt:.1f} tok/s batched)")
    print("sample row:", out[0, :32].tolist())


if __name__ == "__main__":
    main()
