"""Serving driver: fused prefill + continuous batching on the merged
global model.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --batch 8 --prompt-len 32 --gen 16 --slots 4 --pages 64

Thin driver over :class:`repro.api.ServeSpec` / :func:`repro.api
.build_serve`: restores a federated training checkpoint (or fresh-inits
from ``--seed``), warms the compile caches, serves the request batch
with continuous batching, and prints tokens/s plus per-request latency
percentiles. ``--reference`` runs the token-by-token decode baseline
(:func:`generate`) instead — the oracle the serving equivalence tests
compare against.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T

# decode_step jits, keyed by the (hashable) ModelConfig — compiled once
# per config, shared across every generate() call
_DECODE_JIT = {}


def _decode_fn(cfg):
    fn = _DECODE_JIT.get(cfg)
    if fn is None:
        fn = _DECODE_JIT[cfg] = jax.jit(
            lambda p, b, c, i: T.decode_step(p, b, c, i, cfg))
    return fn


def generate(params, cfg, prompt_tokens, max_len: int, gen: int,
             extra_batch=None, temperature: float = 0.0, seed: int = 0,
             key=None):
    """Token-by-token reference path (prefill through the decode step).

    Greedy/temperature sampling; prompt_tokens: (B, P). ``key`` is the
    sampling stream (defaults to ``PRNGKey(seed)`` — pass an explicit
    key to keep it distinct from a param-init stream on the same seed).
    """
    B, P = prompt_tokens.shape
    cache = T.init_decode_cache(cfg, B, max_len)
    decode = _decode_fn(cfg)

    if key is None:
        key = jax.random.PRNGKey(seed)
    tok = prompt_tokens[:, :1]
    gen_toks = []
    for i in range(P + gen - 1):
        batch = {"tokens": tok}
        if extra_batch:
            batch.update(extra_batch)
        logits, cache = decode(params, batch, cache, jnp.int32(i))
        if i + 1 < P:
            tok = prompt_tokens[:, i + 1:i + 2]
        else:
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits[:, 0] / temperature)[:, None]
            else:
                tok = jnp.argmax(logits[:, 0], axis=-1)[:, None]
            gen_toks.append(tok)
    return jnp.concatenate([prompt_tokens] + gen_toks, axis=1)


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


def main():
    from repro.api import ServeSpec, build_serve
    from repro.serve import Request

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8,
                    help="number of requests")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=0,
                    help="cache length (0 = prompt-len + gen)")
    ap.add_argument("--pages", type=int, default=0,
                    help="page-pool size (0 = dense cache)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--static", action="store_true",
                    help="admission barrier (A/B against continuous)")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-step", type=int, default=None)
    ap.add_argument("--reference", action="store_true",
                    help="token-by-token baseline instead of the engine")
    args = ap.parse_args()

    max_len = args.max_len or (args.prompt_len + args.gen)
    key = jax.random.PRNGKey(args.seed)
    total_new = args.batch * args.gen

    if args.reference:
        from repro.configs import get_config

        cfg = get_config(args.arch)
        if args.reduced:
            cfg = cfg.reduced()
        params = T.init_params(key, cfg)
        prompts = jax.random.randint(
            jax.random.fold_in(key, 1), (args.batch, args.prompt_len), 0,
            cfg.vocab_size)
        sample_key = jax.random.fold_in(key, 2)   # != the param-init stream
        # warm up: same (B, 1) token and (B, max_len) cache shapes as the
        # timed run, so tok/s excludes compile
        generate(params, cfg, prompts[:, :2], max_len, 1,
                 temperature=args.temperature, key=sample_key)
        t0 = time.time()
        out = generate(params, cfg, prompts, max_len, args.gen,
                       temperature=args.temperature, key=sample_key)
        dt = time.time() - t0
        print(f"[reference] generated {out.shape} in {dt:.1f}s "
              f"({total_new / dt:.1f} tok/s batched)")
        print("sample row:", np.asarray(out[0, :32]).tolist())
        return

    spec = ServeSpec(
        arch=args.arch, reduced=args.reduced,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_step=args.checkpoint_step,
        slots=args.slots, max_len=max_len, pages=args.pages,
        page_size=args.page_size, temperature=args.temperature,
        seed=args.seed, admission="static" if args.static else "continuous")
    program = build_serve(spec)
    engine = program.engine

    prompts = np.asarray(jax.random.randint(
        jax.random.fold_in(key, 1), (args.batch, args.prompt_len), 0,
        program.cfg.vocab_size))
    engine.warmup([args.prompt_len])

    reqs = [Request(i, prompts[i], args.gen) for i in range(args.batch)]
    t0 = time.time()
    results = engine.serve(reqs)
    dt = time.time() - t0
    lats = [r.latency for r in results.values()]
    print(f"[{spec.admission}] {args.batch} reqs x {args.gen} tok on "
          f"{spec.slots} slots"
          + (f" ({spec.pages}x{spec.page_size}-token pages)"
             if spec.pages else " (dense cache)")
          + f": {dt:.1f}s ({total_new / dt:.1f} tok/s, "
          f"latency p50={_percentile(lats, 50):.2f}s "
          f"p99={_percentile(lats, 99):.2f}s, "
          f"cache {engine.state_bytes() / 1e6:.1f} MB)")
    print("sample row:", results[0].tokens[:32].tolist())


if __name__ == "__main__":
    main()
