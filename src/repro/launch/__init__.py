from repro.launch import input_specs, mesh  # noqa: F401
from repro.launch.mesh import make_host_mesh, make_production_mesh, num_clients_for  # noqa: F401
