"""End-to-end SCALA training driver (host-scale).

Trains a transformer LM with the full SCALA protocol — partial client
participation, eq. (3) batch sizing, T local iterations with concatenated
activations + dual logit-adjusted losses, FedAvg every round — on
synthetic domain-skewed token data.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --reduced \
      --rounds 20 --clients 16 --participation 0.25 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save
from repro.configs import ScalaConfig, get_config
from repro.core.scala import (init_scala_params, scala_aggregate,
                              scala_local_step_fused, transformer_split_model)
from repro.data.loader import lm_round_batches, sample_clients
from repro.data.synthetic import token_stream
from repro.models import transformer as T


def build_data(cfg, num_clients: int, docs_per_client: int, seq: int,
               seed: int):
    docs, domains = token_stream(
        n_docs=num_clients * docs_per_client, doc_len=seq + 1,
        vocab=cfg.vocab_size, num_domains=max(2, num_clients // 2), seed=seed)
    # domain-skewed assignment: client k prefers domain k % D
    rng = np.random.default_rng(seed + 1)
    by_client = []
    D = domains.max() + 1
    for k in range(num_clients):
        pref = k % D
        p = np.where(domains == pref, 8.0, 1.0)
        p = p / p.sum()
        idx = rng.choice(len(docs), size=docs_per_client, replace=False, p=p)
        by_client.append(docs[idx])
    return by_client


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--participation", type=float, default=0.25)
    ap.add_argument("--local-iters", type=int, default=5)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--server-batch", type=int, default=16)
    ap.add_argument("--docs-per-client", type=int, default=32)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-adjust", action="store_true",
                    help="ablation: plain SFL (no logit adjustments)")
    ap.add_argument("--checkpoint-dir", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    assert cfg.frontend is None, "LM driver supports text archs"
    print(f"arch={cfg.name} layers={cfg.num_layers} d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")

    sc = ScalaConfig(
        num_clients=args.clients, participation=args.participation,
        local_iters=args.local_iters, server_batch=args.server_batch,
        lr=args.lr, adjust_server=not args.no_adjust,
        adjust_client=not args.no_adjust)

    data = build_data(cfg, args.clients, args.docs_per_client, args.seq,
                      args.seed)
    model = transformer_split_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    C = sc.clients_per_round
    params = init_scala_params(
        key,
        lambda k: T.init_params(k, cfg)["client"],
        lambda k: T.init_params(k, cfg)["server"],
        C)
    n_params = sum(x.size for x in jax.tree.leaves(params["server"]))
    print(f"server params: {n_params/1e6:.1f}M, clients/round: {C}")

    step = jax.jit(lambda p, b: scala_local_step_fused(model, p, b, sc))
    rng = np.random.default_rng(args.seed)

    for rnd in range(args.rounds):
        t0 = time.time()
        selected = sample_clients(args.clients, C, rng)
        batches = lm_round_batches(data, selected, sc.server_batch,
                                   sc.local_iters, rng)
        sizes = jnp.asarray(batches.pop("sizes"))
        metrics = None
        for t in range(sc.local_iters):
            batch_t = {k: jnp.asarray(v[t]) for k, v in batches.items()}
            params, metrics = step(params, batch_t)
        params = scala_aggregate(params, sizes)
        dt = time.time() - t0
        print(f"round {rnd:3d} loss_s={float(metrics['loss_server']):.4f} "
              f"loss_c={float(metrics['loss_client']):.4f} ({dt:.1f}s)",
              flush=True)
        if args.checkpoint_dir:
            save(args.checkpoint_dir, rnd, params)

    print("done")
    return params


if __name__ == "__main__":
    main()
