"""End-to-end SCALA training driver (host-scale).

Trains a transformer LM with the full SCALA protocol — partial client
participation, eq. (3) batch sizing, T local iterations with concatenated
activations + dual logit-adjusted losses, FedAvg every round — on
synthetic domain-skewed token data.

Since the ``repro.api`` redesign this driver is a thin CLI adapter:
argparse populates a declarative :class:`repro.api.ExperimentSpec`
(optimizer, federation, execution mode, data — the same spec tree the
benchmarks and sweep manifests use) and hands it to
:class:`repro.api.Trainer`. The spec is the unit of reproducibility:

* ``--dump-config [PATH]`` — write the resolved spec as JSON (stdout if
  no PATH) and exit without training;
* ``--config PATH`` — load a spec JSON and run it verbatim (the other
  spec-level flags are ignored). ``--dump-config`` output fed back via
  ``--config`` reproduces the identical run (test-enforced).

Participation comes in two modes, selected by ``--participation``:

* a bare fraction (``--participation 0.25``) — legacy host-side subset
  sampling (execution mode ``"subset"``): each round stacks only the
  C = r*K sampled clients;
* a scheduler spec (``full`` | ``uniform:FRAC`` |
  ``dirichlet:FRAC[:ALPHA]``) — the fed layer's in-program mode
  (``"masked"``, or ``"sparse"`` with ``--slot-gather``): all K clients
  stay stacked and a per-round 0/1 mask (sampled inside the compiled
  round) selects the subset, recomputing priors / logit adjustments per
  subset. Note the batch-size semantics differ: eq. (3) splits
  ``--server-batch`` across all K *slots* before masking, so the
  participating subset sees ~FRAC * server_batch tokens per local step
  (vs the full server_batch across the C participants in fraction
  mode). Scale ``--server-batch`` by 1/FRAC for parity.

``--aggregator`` picks the FL-phase weighting (fedavg | weighted |
bias_compensated[:GAMMA] | staleness_weighted[:DECAY]) and
``--opt-state-policy`` the client optimizer state's round-boundary
behavior (carry | reset | average). ``--server-optimizer`` adds FedOpt
on the server half (the round delta as a pseudo-gradient at
``--server-lr``). ``--async`` switches to the asynchronous event
runtime (:mod:`repro.fed.runtime`) with ``--delay-spec`` / ``--cohort``
/ ``--staleness-decay`` / ``--mix-rate``; ``--delay-spec zero --cohort
K`` reproduces the synchronous rounds exactly. ``--snapshots delta
--ring-size R`` stores async snapshots as a ring of recent server
versions instead of a dense (K, ...) per-client copy — O(cohort + ring)
resident state, bit-identical updates (README §Scaling the client axis,
``benchmarks/BENCH_scale.json``) — and ``--lr-scale cohort`` rescales
the client schedule by cohort/clients.

Dispatch-efficiency knobs (README §Performance,
``benchmarks/BENCH_dispatch.json``): ``--precision bf16`` runs the
engine compute in bfloat16 against f32 master params,
``--boundary dual`` reverts the one-pass fused eq. 14/15 loss stage to
the literal two ``value_and_grad`` passes (gradients are bit-identical
either way; see ``benchmarks/BENCH_boundary.json``),
``--rounds-per-call R`` fuses R whole rounds into one compiled dispatch
(bit-identical to unfused rounds at f32; keep 1 while debugging), and
``--no-donate`` disables the in-place (donated) round-state update.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --reduced \
      --rounds 20 --clients 16 --participation uniform:0.25 --seq 128 \
      --aggregator bias_compensated --optimizer momentum \
      --schedule cosine --warmup 10

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --reduced \
      --rounds 40 --clients 16 --async --cohort 4 \
      --delay-spec lognormal:1:1.5 --staleness-decay 0.5

  PYTHONPATH=src python -m repro.launch.train --config sweep/run_003.json
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

import jax

from repro import api
from repro.checkpoint import save
from repro.configs import ScalaConfig
from repro.core import engine


def spec_from_args(args) -> api.ExperimentSpec:
    """Resolve the CLI surface into the declarative experiment spec."""
    # participation: bare fraction (legacy host-side "subset" mode) or a
    # fed scheduler spec (in-program "masked"/"sparse")
    try:
        part_frac = float(args.participation)
        scheduler_spec = None
    except ValueError:
        part_frac = 1.0
        scheduler_spec = args.participation

    mode = "subset" if scheduler_spec is None else "masked"
    if args.slot_gather:
        mode = "sparse"
    if args.async_mode:
        mode = "async"

    server_opt = (None if args.server_optimizer == "none"
                  else api.OptimSpec(
                      name=api.OPTIMIZER_ALIASES.get(args.server_optimizer,
                                                     args.server_optimizer),
                      lr=args.server_lr, momentum=args.momentum,
                      weight_decay=args.weight_decay))
    return api.ExperimentSpec(
        arch=args.arch, reduced=args.reduced, method="scala",
        rounds=args.rounds, seed=args.seed,
        scala=ScalaConfig(
            num_clients=args.clients, participation=part_frac,
            local_iters=args.local_iters, server_batch=args.server_batch,
            lr=args.lr, adjust_server=not args.no_adjust,
            adjust_client=not args.no_adjust),
        optim=api.OptimSpec(name=args.optimizer, momentum=args.momentum,
                            weight_decay=args.weight_decay,
                            schedule=args.schedule, warmup=args.warmup),
        fed=api.FedSpec(aggregator=args.aggregator,
                        participation=scheduler_spec,
                        opt_state_policy=args.opt_state_policy,
                        faults=args.faults or None,
                        guards=args.guards or None),
        execution=api.ExecutionSpec(
            mode=mode, backend="lace", delay=args.delay_spec,
            cohort=args.cohort, staleness_decay=args.staleness_decay,
            mix_rate=args.mix_rate, server_optimizer=server_opt,
            unroll=args.unroll, precision=args.precision,
            boundary=args.boundary,
            rounds_per_call=args.rounds_per_call,
            donate=not args.no_donate,
            snapshots=args.snapshots, ring_size=args.ring_size,
            lr_scale=args.lr_scale, arrival=args.arrival,
            opt_paging=args.opt_paging,
            deadline=args.deadline, backoff=args.backoff),
        data=api.DataSpec(kind="lm_synthetic", seq=args.seq,
                          docs_per_client=args.docs_per_client))


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="",
                    help="run a spec JSON (from --dump-config / a sweep "
                         "manifest) verbatim; spec-level flags are ignored")
    ap.add_argument("--dump-config", nargs="?", const="-", default=None,
                    metavar="PATH",
                    help="write the resolved ExperimentSpec JSON (stdout "
                         "if no PATH) and exit without training")
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--participation", default="0.25",
                    help="bare fraction (legacy host-side subset sampling) "
                         "or scheduler spec: full | uniform:FRAC | "
                         "dirichlet:FRAC[:ALPHA] (in-program masking)")
    ap.add_argument("--aggregator", default="weighted",
                    help="FL-phase weighting spec: fedavg | weighted | "
                         "bias_compensated[:GAMMA] | "
                         "staleness_weighted[:DECAY]")
    ap.add_argument("--opt-state-policy", default="carry",
                    choices=engine.OPT_STATE_POLICIES,
                    help="client optimizer state at the round boundary "
                         "(see engine.make_round_runner)")
    ap.add_argument("--slot-gather", action="store_true",
                    help="sparse-slot compute: gather the scheduler's "
                         "fixed subset into a dense axis before the local "
                         "scan (needs a scheduler spec --participation)")
    ap.add_argument("--server-optimizer", default="none",
                    choices=("none", "sgd", "momentum", "adamw", "fedavgm",
                             "fedadam"),
                    help="FedOpt on the server half's round/event delta")
    ap.add_argument("--server-lr", type=float, default=1.0)
    ap.add_argument("--async", dest="async_mode", action="store_true",
                    help="asynchronous event runtime (fed.make_async_runner)"
                         " instead of barrier rounds")
    ap.add_argument("--delay-spec", default="lognormal:1:1",
                    help="completion-delay model for --async: zero | "
                         "constant[:D] | uniform:LO:HI | "
                         "lognormal[:MEDIAN[:SIGMA]]")
    ap.add_argument("--cohort", type=int, default=0,
                    help="arrivals per async event (0 = clients/4, min 1)")
    ap.add_argument("--staleness-decay", type=float, default=0.5,
                    help="per-version decay of stale arrivals' weights")
    ap.add_argument("--mix-rate", type=float, default=1.0,
                    help="global-model mixing rate toward the cohort average")
    ap.add_argument("--snapshots", default="dense",
                    choices=("dense", "delta"),
                    help="async snapshot storage: dense keeps a (K, ...) "
                         "per-client copy of the client half; delta keeps "
                         "only a ring of recent server states "
                         "(O(cohort + ring) resident bytes)")
    ap.add_argument("--ring-size", type=int, default=64,
                    help="retained server versions for --snapshots delta")
    ap.add_argument("--lr-scale", default="none",
                    choices=("none", "cohort"),
                    help="async learning-rate scaling: cohort multiplies "
                         "the schedule by cohort/clients")
    ap.add_argument("--arrival", default="sort",
                    choices=("sort", "topk", "topk:sharded"),
                    help="async cohort-pop algorithm: sort = per-event "
                         "(K,) lexsort; topk = O(K)-work top-k pop "
                         "(bit-identical); topk:sharded adds a per-shard "
                         "pop + small merge on the client mesh")
    ap.add_argument("--opt-paging", default="none",
                    choices=("none", "host"),
                    help="host = page per-client optimizer moments to a "
                         "host store and gather only the arrival cohort "
                         "per event (delta+carry with any optimizer)")
    ap.add_argument("--local-iters", type=int, default=5)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--server-batch", type=int, default=16)
    ap.add_argument("--docs-per-client", type=int, default=32)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--optimizer", default="sgd",
                    choices=("sgd", "momentum", "adamw"))
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--weight-decay", type=float, default=0.0)
    ap.add_argument("--schedule", default="constant",
                    choices=("constant", "cosine"))
    ap.add_argument("--warmup", type=int, default=0,
                    help="warmup steps (local iterations) for --schedule cosine")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-adjust", action="store_true",
                    help="ablation: plain SFL (no logit adjustments)")
    ap.add_argument("--no-scan", action="store_true",
                    help="per-step Python round loop instead of the fused "
                         "scan program (A/B baseline)")
    ap.add_argument("--unroll", type=int, default=-1,
                    help="scan unroll factor: -1 = auto (full unroll on "
                         "CPU, where XLA runs while-loop bodies with "
                         "reduced parallelism; rolled elsewhere to keep "
                         "the HLO small), 0 = full unroll, N = factor")
    ap.add_argument("--precision", default="f32", choices=("f32", "bf16"),
                    help="engine compute policy: bf16 forward/backward "
                         "against f32 master params (priors, losses, "
                         "updates, aggregation stay f32)")
    ap.add_argument("--boundary", default="fused",
                    choices=("dual", "fused"),
                    help="split-boundary loss schedule: 'fused' computes "
                         "the eq. 14/15 pair in one pass over a shared "
                         "logits matmul (default; gradient-bitwise vs. "
                         "dual), 'dual' keeps two value_and_grad passes")
    ap.add_argument("--rounds-per-call", type=int, default=1,
                    help="rounds fused into one jitted dispatch (outer "
                         "lax.scan over whole rounds; keep 1 when "
                         "debugging or checkpointing every round)")
    ap.add_argument("--no-donate", action="store_true",
                    help="disable buffer donation of the round state "
                         "(donation updates params/opt-state in place; "
                         "disable only for debugging aliasing issues)")
    ap.add_argument("--faults", default="",
                    help="deterministic fault injection spec: comma-joined "
                         "drop:P | corrupt:P[:MODE[:SCALE]] (MODE nan | inf "
                         "| noise) | stall:P[:FACTOR] — chaos testing at "
                         "spec level (fed.faults)")
    ap.add_argument("--guards", default="",
                    help="aggregation guard spec: nonfinite and/or "
                         "clip:TAU[:BETA] — rejected clients shrink the "
                         "cohort and the eq. 14/15 logit adjustments are "
                         "recomputed over the survivors (fed.guards)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="async only: bound each event's cohort barrier; "
                         "clients not finished by (earliest finish + "
                         "DEADLINE) miss the event and are requeued with "
                         "exponential backoff")
    ap.add_argument("--backoff", type=float, default=2.0,
                    help="requeue delay multiplier per consecutive miss "
                         "(with --deadline)")
    ap.add_argument("--checkpoint-dir", default="",
                    help="save params-only checkpoints (servable via "
                         "launch/serve.py) at round-fusion boundaries")
    ap.add_argument("--state-dir", default="",
                    help="save FULL crash-recovery checkpoints (params + "
                         "optimizer + fed/async state + host RNG) via "
                         "Trainer.save at round-fusion boundaries; resume "
                         "with --resume")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest complete checkpoint from "
                         "--state-dir before training and run only the "
                         "remaining rounds (bit-identical continuation)")
    return ap


def _run_no_scan(spec: api.ExperimentSpec, args):
    """A/B baseline: per-step Python round loop (legacy federation
    settings only) — the one path that bypasses the fused program."""
    import time

    import jax.numpy as jnp
    import numpy as np

    from repro.api.build import text_split_init
    from repro.data.loader import lm_round_batches, sample_clients

    if (spec.execution.mode != "subset"
            or spec.fed.aggregator != "weighted"
            or spec.fed.opt_state_policy != "carry"
            or spec.execution.server_optimizer is not None
            or spec.execution.rounds_per_call != 1):
        raise SystemExit("--no-scan supports only the legacy federation "
                         "settings (fraction participation, no "
                         "--slot-gather, weighted aggregator, carry "
                         "opt-state policy, no server optimizer, "
                         "--rounds-per-call 1)")
    cfg = spec.model_config()
    sc = spec.scala
    data = api.build_lm_data(cfg, sc.num_clients, spec.data.docs_per_client,
                             spec.data.seq, spec.seed)
    C = sc.clients_per_round
    model, params = text_split_init(spec, C)
    opt = spec.optim.make()
    sched = spec.optim.make_schedule(spec.rounds * sc.local_iters,
                                     default_lr=sc.lr)
    state = engine.init_train_state(params, opt)
    # the shared donated jit wrapper from repro.api: even the legacy
    # per-step loop updates params/opt-state in place instead of copying
    step = api.donated_jit(
        engine.make_split_step(model, sc, backend="lace", optimizer=opt,
                               schedule=sched,
                               precision=spec.execution.precision),
        donate=spec.execution.donate)
    rng = np.random.default_rng(spec.seed)
    for rnd in range(spec.rounds):
        t0 = time.time()
        selected = sample_clients(sc.num_clients, C, rng)
        batches = lm_round_batches(data, selected, sc.server_batch,
                                   sc.local_iters, rng)
        sizes = jnp.asarray(batches.pop("sizes"))
        metrics = None
        for t in range(sc.local_iters):
            batch_t = {k: jnp.asarray(v[t]) for k, v in batches.items()}
            state, metrics = step(state, batch_t)
        state = dataclasses.replace(
            state, params=engine.scala_aggregate(state.params, sizes))
        print(f"round {rnd:3d} "
              f"loss_s={float(metrics['loss_server']):.4f} "
              f"loss_c={float(metrics['loss_client']):.4f} "
              f"({time.time() - t0:.1f}s)", flush=True)
        if args.checkpoint_dir:
            save(args.checkpoint_dir, rnd, state.params)
    print("done")
    return state.params


def main(argv=None):
    args = build_parser().parse_args(argv)

    try:
        if args.config:
            with open(args.config) as f:
                spec = api.ExperimentSpec.from_json(f.read())
        else:
            spec = spec_from_args(args)
    except ValueError as e:
        raise SystemExit(str(e))

    if args.dump_config is not None:
        try:
            spec.validate()        # a dumped manifest must be runnable
        except ValueError as e:
            raise SystemExit(str(e))
        payload = spec.to_json()
        if args.dump_config == "-":
            print(payload)
        else:
            with open(args.dump_config, "w") as f:
                f.write(payload + "\n")
            print(f"wrote {args.dump_config}", file=sys.stderr)
        return spec

    if args.no_scan:
        if spec.execution.mode == "async":
            raise SystemExit("--async compiles whole events; drop --no-scan")
        return _run_no_scan(spec, args)

    try:
        spec.validate()
    except ValueError as e:
        raise SystemExit(str(e))

    cfg = spec.model_config()
    print(f"arch={cfg.name} layers={cfg.num_layers} d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")
    assert cfg.frontend is None, "LM driver supports text archs"

    if args.resume and not args.state_dir:
        raise SystemExit("--resume needs --state-dir (the directory "
                         "Trainer.save wrote full-state checkpoints to)")

    trainer = api.Trainer(spec)
    meta = trainer.program.metadata
    n_params = sum(x.size for x in jax.tree.leaves(
        trainer.state.inner.params["server"]))
    print(f"server params: {n_params/1e6:.1f}M, "
          f"mode: {meta['mode']} (slots: {meta['slots']}), "
          f"participation: {spec.fed.participation or spec.scala.participation}, "
          f"aggregator: {spec.fed.aggregator}, "
          f"opt-state: {spec.fed.opt_state_policy}, "
          f"optimizer: {spec.optim.spec}, schedule: {spec.optim.schedule}")
    if meta["mode"] == "async":
        extra_async = (f" deadline={spec.execution.deadline} "
                       f"backoff={spec.execution.backoff}"
                       if spec.execution.deadline else "")
        print(f"async: delay={spec.execution.delay} "
              f"cohort={spec.execution.resolve_cohort(meta['slots'])}"
              f"/{meta['slots']} "
              f"staleness_decay={spec.execution.staleness_decay} "
              f"mix_rate={spec.execution.mix_rate}{extra_async}")
    if spec.fed.faults or spec.fed.guards:
        print(f"fault tolerance: faults={spec.fed.faults or 'none'} "
              f"guards={spec.fed.guards or 'none'}")

    start = 0
    if args.resume:
        start = trainer.resume(args.state_dir)
        print(f"resumed at round {start} from {args.state_dir}")

    label = "event" if meta["mode"] == "async" else "round"
    rpc = meta["rounds_per_call"]

    def on_round(rnd, metrics, dt):
        extra = ""
        if "t_event" in metrics:
            extra = (f" t={metrics['t_event']:.2f}"
                     f" stale={metrics['staleness_mean']:.2f}")
        if "guard_rejected" in metrics:
            extra += f" rej={metrics['guard_rejected']:.0f}"
        print(f"{label} {rnd:3d} loss_s={metrics['loss_server']:.4f} "
              f"loss_c={metrics['loss_client']:.4f}{extra} ({dt:.1f}s)",
              flush=True)
        # under round fusion trainer.state only advances per chunk, so a
        # per-round save would write the chunk-boundary params R times
        # under R wrong labels; save once per chunk, at the round the
        # params actually correspond to
        at_boundary = (rnd + 1) % rpc == 0 or rnd == spec.rounds - 1
        if args.checkpoint_dir and at_boundary:
            save(args.checkpoint_dir, rnd, trainer.state.inner.params)
        if args.state_dir and at_boundary:
            trainer.save(args.state_dir)

    trainer.run(spec.rounds - start, on_round=on_round)
    print("done")
    return trainer


# --- legacy kwarg-style helpers (deprecated; warn once per process) -------


def _legacy_build_data(cfg, num_clients: int, docs_per_client: int, seq: int,
                       seed: int):
    return api.build_lm_data(cfg, num_clients, docs_per_client, seq, seed)


def _legacy_build_schedule(args, total_steps: int):
    return api.OptimSpec(name="sgd", lr=args.lr, schedule=args.schedule,
                         warmup=args.warmup).make_schedule(total_steps)


_DEPRECATED_HELPERS = {
    "build_data": (_legacy_build_data, "repro.api.build_lm_data (or an "
                                       "api.DataSpec inside api.Trainer)"),
    "build_schedule": (_legacy_build_schedule,
                       "repro.api.OptimSpec.make_schedule"),
}


def __getattr__(name):
    if name in _DEPRECATED_HELPERS:
        fn, use = _DEPRECATED_HELPERS[name]
        api.warn_once(f"repro.launch.train.{name}", use)
        return fn
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


if __name__ == "__main__":
    main()
