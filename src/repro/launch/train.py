"""End-to-end SCALA training driver (host-scale).

Trains a transformer LM with the full SCALA protocol — partial client
participation, eq. (3) batch sizing, T local iterations with concatenated
activations + dual logit-adjusted losses, FedAvg every round — on
synthetic domain-skewed token data.

Built on the split-step engine (:mod:`repro.core.engine`): the fused-LACE
loss backend, a real optimizer from :mod:`repro.optim` (SGD default, the
paper's setting), an lr schedule driven by the global step counter, and
the whole round (T local iterations + FedAvg) compiled into ONE XLA
program via ``scala_round_scan`` — one dispatch per round instead of T+1
(``--no-scan`` falls back to the per-step Python loop for A/B timing).

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --reduced \
      --rounds 20 --clients 16 --participation 0.25 --seq 128 \
      --optimizer momentum --schedule cosine --warmup 10
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save
from repro.configs import ScalaConfig, get_config
from repro.core import engine
from repro.core.scala import transformer_split_model
from repro.data.loader import lm_round_batches, sample_clients
from repro.data.synthetic import token_stream
from repro.models import transformer as T
from repro.optim import make_optimizer, schedules


def build_data(cfg, num_clients: int, docs_per_client: int, seq: int,
               seed: int):
    docs, domains = token_stream(
        n_docs=num_clients * docs_per_client, doc_len=seq + 1,
        vocab=cfg.vocab_size, num_domains=max(2, num_clients // 2), seed=seed)
    # domain-skewed assignment: client k prefers domain k % D
    rng = np.random.default_rng(seed + 1)
    by_client = []
    D = domains.max() + 1
    for k in range(num_clients):
        pref = k % D
        p = np.where(domains == pref, 8.0, 1.0)
        p = p / p.sum()
        idx = rng.choice(len(docs), size=docs_per_client, replace=False, p=p)
        by_client.append(docs[idx])
    return by_client


def build_schedule(args, total_steps: int):
    if args.schedule == "cosine":
        return schedules.linear_warmup_cosine(args.lr, args.warmup,
                                              total_steps)
    return schedules.constant(args.lr)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--participation", type=float, default=0.25)
    ap.add_argument("--local-iters", type=int, default=5)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--server-batch", type=int, default=16)
    ap.add_argument("--docs-per-client", type=int, default=32)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--optimizer", default="sgd",
                    choices=("sgd", "momentum", "adamw"))
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--weight-decay", type=float, default=0.0)
    ap.add_argument("--schedule", default="constant",
                    choices=("constant", "cosine"))
    ap.add_argument("--warmup", type=int, default=0,
                    help="warmup steps (local iterations) for --schedule cosine")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-adjust", action="store_true",
                    help="ablation: plain SFL (no logit adjustments)")
    ap.add_argument("--no-scan", action="store_true",
                    help="per-step Python round loop instead of the fused "
                         "scan program (A/B baseline)")
    ap.add_argument("--unroll", type=int, default=-1,
                    help="scan unroll factor: -1 = auto (full unroll on "
                         "CPU, where XLA runs while-loop bodies with "
                         "reduced parallelism; rolled elsewhere to keep "
                         "the HLO small), 0 = full unroll, N = factor")
    ap.add_argument("--checkpoint-dir", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    assert cfg.frontend is None, "LM driver supports text archs"
    print(f"arch={cfg.name} layers={cfg.num_layers} d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")

    sc = ScalaConfig(
        num_clients=args.clients, participation=args.participation,
        local_iters=args.local_iters, server_batch=args.server_batch,
        lr=args.lr, adjust_server=not args.no_adjust,
        adjust_client=not args.no_adjust)

    data = build_data(cfg, args.clients, args.docs_per_client, args.seq,
                      args.seed)
    model = transformer_split_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    C = sc.clients_per_round
    params = engine.init_scala_params(
        key,
        lambda k: T.init_params(k, cfg)["client"],
        lambda k: T.init_params(k, cfg)["server"],
        C)
    n_params = sum(x.size for x in jax.tree.leaves(params["server"]))
    print(f"server params: {n_params/1e6:.1f}M, clients/round: {C}, "
          f"optimizer: {args.optimizer}, schedule: {args.schedule}")

    opt = make_optimizer(args.optimizer, momentum=args.momentum,
                         weight_decay=args.weight_decay)
    sched = build_schedule(args, args.rounds * sc.local_iters)
    state = engine.init_train_state(params, opt)

    if args.no_scan:
        step = jax.jit(engine.make_split_step(model, sc, backend="lace",
                                              optimizer=opt, schedule=sched))
    else:
        if args.unroll == -1:
            unroll = True if jax.default_backend() == "cpu" else 1
        else:
            unroll = True if args.unroll == 0 else args.unroll
        round_fn = jax.jit(engine.make_round_runner(
            model, sc, backend="lace", optimizer=opt, schedule=sched,
            unroll=unroll))
    rng = np.random.default_rng(args.seed)

    for rnd in range(args.rounds):
        t0 = time.time()
        selected = sample_clients(args.clients, C, rng)
        batches = lm_round_batches(data, selected, sc.server_batch,
                                   sc.local_iters, rng)
        sizes = jnp.asarray(batches.pop("sizes"))
        if args.no_scan:
            metrics = None
            for t in range(sc.local_iters):
                batch_t = {k: jnp.asarray(v[t]) for k, v in batches.items()}
                state, metrics = step(state, batch_t)
            state = dataclasses.replace(
                state, params=engine.scala_aggregate(state.params, sizes))
        else:
            batches = {k: jnp.asarray(v) for k, v in batches.items()}
            state, metrics = round_fn(state, batches, sizes)
        dt = time.time() - t0
        print(f"round {rnd:3d} loss_s={float(metrics['loss_server']):.4f} "
              f"loss_c={float(metrics['loss_client']):.4f} ({dt:.1f}s)",
              flush=True)
        if args.checkpoint_dir:
            save(args.checkpoint_dir, rnd, state.params)

    print("done")
    return state.params


if __name__ == "__main__":
    main()
