"""End-to-end SCALA training driver (host-scale).

Trains a transformer LM with the full SCALA protocol — partial client
participation, eq. (3) batch sizing, T local iterations with concatenated
activations + dual logit-adjusted losses, FedAvg every round — on
synthetic domain-skewed token data.

Built on the split-step engine (:mod:`repro.core.engine`) and the
federation layer (:mod:`repro.fed`): the fused-LACE loss backend, a real
optimizer from :mod:`repro.optim` (SGD default, the paper's setting), an
lr schedule driven by the global step counter, and the whole round
(T local iterations + the pluggable FL phase) compiled into ONE XLA
program via ``make_round_runner`` — one dispatch per round instead of
T+1 (``--no-scan`` falls back to the per-step Python loop for A/B
timing).

Participation comes in two modes, selected by ``--participation``:

* a bare fraction (``--participation 0.25``) — legacy host-side subset
  sampling: each round stacks only the C = r*K sampled clients;
* a scheduler spec (``full`` | ``uniform:FRAC`` |
  ``dirichlet:FRAC[:ALPHA]``) — the fed layer's in-program mode: all K
  clients stay stacked and a per-round 0/1 mask (sampled inside the
  compiled round) selects the subset, recomputing priors / logit
  adjustments per subset. Note the batch-size semantics differ: eq. (3)
  splits ``--server-batch`` across all K *slots* before masking, so the
  participating subset sees ~FRAC * server_batch tokens per local step
  (vs the full server_batch across the C participants in fraction
  mode). Scale ``--server-batch`` by 1/FRAC for parity.

``--aggregator`` picks the FL-phase weighting (fedavg | weighted |
bias_compensated | staleness_weighted) and ``--opt-state-policy`` the
client optimizer state's round-boundary behavior (carry | reset |
average). ``--slot-gather`` turns on the engine's sparse-slot compute
path (gather the scheduler's fixed-size subset into a dense axis before
the local scan), so a ``uniform:FRAC`` round costs ~FRAC of the full-K
compute. ``--server-optimizer`` adds FedOpt on the server half (the
round delta as a pseudo-gradient at ``--server-lr``).

``--async`` switches to the asynchronous event runtime
(:mod:`repro.fed.runtime`): clients finish after sampled delays
(``--delay-spec``: zero | constant[:D] | uniform:LO:HI |
lognormal[:MEDIAN[:SIGMA]]), each driver iteration pops the
``--cohort`` earliest arrivals, runs their T local iterations from
their per-client snapshots (sparse-slot compute), and folds them into
the global model with ``--staleness-decay``-weighted delayed
aggregation mixed at ``--mix-rate``. ``--delay-spec zero --cohort K``
reproduces the synchronous rounds exactly.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --reduced \
      --rounds 20 --clients 16 --participation uniform:0.25 --seq 128 \
      --aggregator bias_compensated --optimizer momentum \
      --schedule cosine --warmup 10

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --reduced \
      --rounds 40 --clients 16 --async --cohort 4 \
      --delay-spec lognormal:1:1.5 --staleness-decay 0.5
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import fed
from repro.checkpoint import save
from repro.configs import ScalaConfig, get_config
from repro.core import engine
from repro.core.scala import transformer_split_model
from repro.data.loader import lm_round_batches, sample_clients
from repro.data.synthetic import token_stream
from repro.models import transformer as T
from repro.optim import make_optimizer, schedules


def build_data(cfg, num_clients: int, docs_per_client: int, seq: int,
               seed: int):
    docs, domains = token_stream(
        n_docs=num_clients * docs_per_client, doc_len=seq + 1,
        vocab=cfg.vocab_size, num_domains=max(2, num_clients // 2), seed=seed)
    # domain-skewed assignment: client k prefers domain k % D
    rng = np.random.default_rng(seed + 1)
    by_client = []
    D = domains.max() + 1
    for k in range(num_clients):
        pref = k % D
        p = np.where(domains == pref, 8.0, 1.0)
        p = p / p.sum()
        idx = rng.choice(len(docs), size=docs_per_client, replace=False, p=p)
        by_client.append(docs[idx])
    return by_client


def build_schedule(args, total_steps: int):
    if args.schedule == "cosine":
        return schedules.linear_warmup_cosine(args.lr, args.warmup,
                                              total_steps)
    return schedules.constant(args.lr)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--participation", default="0.25",
                    help="bare fraction (legacy host-side subset sampling) "
                         "or scheduler spec: full | uniform:FRAC | "
                         "dirichlet:FRAC[:ALPHA] (in-program masking)")
    ap.add_argument("--aggregator", default="weighted",
                    choices=("fedavg", "weighted", "bias_compensated",
                             "staleness_weighted"))
    ap.add_argument("--opt-state-policy", default="carry",
                    choices=engine.OPT_STATE_POLICIES,
                    help="client optimizer state at the round boundary "
                         "(see engine.make_round_runner)")
    ap.add_argument("--slot-gather", action="store_true",
                    help="sparse-slot compute: gather the scheduler's "
                         "fixed subset into a dense axis before the local "
                         "scan (needs a scheduler spec --participation)")
    ap.add_argument("--server-optimizer", default="none",
                    choices=("none", "sgd", "momentum", "adamw"),
                    help="FedOpt on the server half's round/event delta")
    ap.add_argument("--server-lr", type=float, default=1.0)
    ap.add_argument("--async", dest="async_mode", action="store_true",
                    help="asynchronous event runtime (fed.make_async_runner)"
                         " instead of barrier rounds")
    ap.add_argument("--delay-spec", default="lognormal:1:1",
                    help="completion-delay model for --async: zero | "
                         "constant[:D] | uniform:LO:HI | "
                         "lognormal[:MEDIAN[:SIGMA]]")
    ap.add_argument("--cohort", type=int, default=0,
                    help="arrivals per async event (0 = clients/4, min 1)")
    ap.add_argument("--staleness-decay", type=float, default=0.5,
                    help="per-version decay of stale arrivals' weights")
    ap.add_argument("--mix-rate", type=float, default=1.0,
                    help="global-model mixing rate toward the cohort average")
    ap.add_argument("--local-iters", type=int, default=5)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--server-batch", type=int, default=16)
    ap.add_argument("--docs-per-client", type=int, default=32)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--optimizer", default="sgd",
                    choices=("sgd", "momentum", "adamw"))
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--weight-decay", type=float, default=0.0)
    ap.add_argument("--schedule", default="constant",
                    choices=("constant", "cosine"))
    ap.add_argument("--warmup", type=int, default=0,
                    help="warmup steps (local iterations) for --schedule cosine")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-adjust", action="store_true",
                    help="ablation: plain SFL (no logit adjustments)")
    ap.add_argument("--no-scan", action="store_true",
                    help="per-step Python round loop instead of the fused "
                         "scan program (A/B baseline)")
    ap.add_argument("--unroll", type=int, default=-1,
                    help="scan unroll factor: -1 = auto (full unroll on "
                         "CPU, where XLA runs while-loop bodies with "
                         "reduced parallelism; rolled elsewhere to keep "
                         "the HLO small), 0 = full unroll, N = factor")
    ap.add_argument("--checkpoint-dir", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    assert cfg.frontend is None, "LM driver supports text archs"
    print(f"arch={cfg.name} layers={cfg.num_layers} d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")

    # --- participation: bare fraction (legacy subset stacking) or a fed
    # scheduler spec (static K slots + in-program masking) ---
    try:
        part_frac = float(args.participation)
        scheduler = None
    except ValueError:
        part_frac = 1.0
        scheduler = fed.make_participation(args.participation, args.clients)
    aggregator = fed.make_aggregator(args.aggregator)
    server_opt = (None if args.server_optimizer == "none"
                  else make_optimizer(args.server_optimizer,
                                      momentum=args.momentum,
                                      weight_decay=args.weight_decay))
    if args.async_mode and args.no_scan:
        raise SystemExit("--async compiles whole events; drop --no-scan")
    if args.async_mode and scheduler is not None:
        raise SystemExit("--async replaces participation scheduling (the "
                         "arrival cohort IS the participating subset); "
                         "drop the --participation spec")
    if args.slot_gather and scheduler is None:
        raise SystemExit("--slot-gather needs a scheduler spec "
                         "(--participation uniform:FRAC | dirichlet:FRAC)")
    if args.no_scan and (scheduler is not None
                         or args.aggregator != "weighted"
                         or args.opt_state_policy != "carry"
                         or server_opt is not None):
        raise SystemExit("--no-scan supports only the legacy federation "
                         "settings (fraction participation, weighted "
                         "aggregator, carry opt-state policy, no server "
                         "optimizer)")
    if aggregator.stateful and args.async_mode:
        # the runtime already tracks per-client ages via version counters
        # and decays arrivals by --staleness-decay; a staleness aggregator
        # on top would decay twice
        raise SystemExit(f"--aggregator {args.aggregator} double-decays "
                         "under --async (the runtime applies "
                         "--staleness-decay itself); use a stateless "
                         "aggregator")
    if aggregator.stateful and scheduler is None:
        # legacy fraction mode re-samples WHICH clients occupy the C
        # stacked slots every round, so per-slot aggregator state (e.g.
        # staleness round ages) would track slots, not clients — and with
        # full slots the ages never leave 0 (silently plain weighted).
        raise SystemExit(f"--aggregator {args.aggregator} is stateful and "
                         "needs stable client identities: use a scheduler "
                         "spec (--participation uniform:FRAC | "
                         "dirichlet:FRAC[:A])")

    sc = ScalaConfig(
        num_clients=args.clients, participation=part_frac,
        local_iters=args.local_iters, server_batch=args.server_batch,
        lr=args.lr, adjust_server=not args.no_adjust,
        adjust_client=not args.no_adjust)

    data = build_data(cfg, args.clients, args.docs_per_client, args.seq,
                      args.seed)
    model = transformer_split_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    C = (args.clients if scheduler is not None or args.async_mode
         else sc.clients_per_round)
    params = engine.init_scala_params(
        key,
        lambda k: T.init_params(k, cfg)["client"],
        lambda k: T.init_params(k, cfg)["server"],
        C)
    n_params = sum(x.size for x in jax.tree.leaves(params["server"]))
    print(f"server params: {n_params/1e6:.1f}M, "
          f"participation: {args.participation} (slots: {C}), "
          f"aggregator: {args.aggregator}, "
          f"opt-state: {args.opt_state_policy}, "
          f"optimizer: {args.optimizer}, schedule: {args.schedule}")

    opt = make_optimizer(args.optimizer, momentum=args.momentum,
                         weight_decay=args.weight_decay)
    sched = build_schedule(args, args.rounds * sc.local_iters)
    state = engine.init_train_state(params, opt)

    if args.unroll == -1:
        unroll = True if jax.default_backend() == "cpu" else 1
    else:
        unroll = True if args.unroll == 0 else args.unroll

    afed = None
    if args.async_mode:
        delays = fed.make_delays(args.delay_spec)
        cohort = args.cohort if args.cohort > 0 else max(1, args.clients // 4)
        print(f"async: delay={args.delay_spec} cohort={cohort}/{C} "
              f"staleness_decay={args.staleness_decay} "
              f"mix_rate={args.mix_rate}")
        round_fn = jax.jit(fed.make_async_runner(
            model, sc, backend="lace", optimizer=opt, schedule=sched,
            delays=delays, cohort=cohort,
            staleness_decay=args.staleness_decay, mix_rate=args.mix_rate,
            aggregator=aggregator, server_optimizer=server_opt,
            server_lr=args.server_lr,
            opt_state_policy=args.opt_state_policy, unroll=unroll))
        afed = fed.init_async_state(
            jax.random.PRNGKey(args.seed + 1), params["client"], delays,
            aggregator=aggregator, server_optimizer=server_opt,
            server_params=params["server"])
        thread_fed = False
        fed_state = None
    elif args.no_scan:
        thread_fed = False
        fed_state = None
        step = jax.jit(engine.make_split_step(model, sc, backend="lace",
                                              optimizer=opt, schedule=sched))
    else:
        thread_fed = (scheduler is not None or aggregator.stateful
                      or server_opt is not None)
        fed_state = (fed.init_fed_state(jax.random.PRNGKey(args.seed + 1),
                                        aggregator, scheduler, num_clients=C,
                                        server_optimizer=server_opt,
                                        server_params=params["server"])
                     if thread_fed else None)
        round_fn = jax.jit(engine.make_round_runner(
            model, sc, backend="lace", optimizer=opt, schedule=sched,
            unroll=unroll, aggregator=aggregator, participation=scheduler,
            opt_state_policy=args.opt_state_policy,
            slot_gather=args.slot_gather, server_optimizer=server_opt,
            server_lr=args.server_lr))
    rng = np.random.default_rng(args.seed)

    for rnd in range(args.rounds):
        t0 = time.time()
        if scheduler is not None or args.async_mode:
            selected = np.arange(args.clients)   # all slots; mask in-program
        else:
            selected = sample_clients(args.clients, C, rng)
        batches = lm_round_batches(data, selected, sc.server_batch,
                                   sc.local_iters, rng)
        sizes = jnp.asarray(batches.pop("sizes"))
        extra = ""
        if args.async_mode:
            batches = {k: jnp.asarray(v) for k, v in batches.items()}
            state, afed, metrics = round_fn(state, afed, batches, sizes)
            extra = (f" t={float(metrics['t_event']):.2f}"
                     f" stale={float(metrics['staleness_mean']):.2f}")
        elif args.no_scan:
            metrics = None
            for t in range(sc.local_iters):
                batch_t = {k: jnp.asarray(v[t]) for k, v in batches.items()}
                state, metrics = step(state, batch_t)
            state = dataclasses.replace(
                state, params=engine.scala_aggregate(state.params, sizes))
        else:
            batches = {k: jnp.asarray(v) for k, v in batches.items()}
            if thread_fed:
                state, fed_state, metrics = round_fn(state, batches, sizes,
                                                     fed_state)
            else:
                state, metrics = round_fn(state, batches, sizes)
        dt = time.time() - t0
        label = "event" if args.async_mode else "round"
        print(f"{label} {rnd:3d} loss_s={float(metrics['loss_server']):.4f} "
              f"loss_c={float(metrics['loss_client']):.4f}{extra} ({dt:.1f}s)",
              flush=True)
        if args.checkpoint_dir:
            save(args.checkpoint_dir, rnd, state.params)

    print("done")
    return state.params


if __name__ == "__main__":
    main()
