import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production mesh; dump memory/cost/collective analysis for §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""  # noqa: E402

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec  # noqa: E402

from repro import compat
from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, ScalaConfig, get_config, get_shape  # noqa: E402
from repro.core.scala import (scala_local_step_fused,  # noqa: E402
                              scala_local_step_fused_dp,
                              transformer_split_model)
from repro.launch import input_specs as ispec  # noqa: E402
from repro.launch.mesh import make_production_mesh, num_clients_for  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.perf import roofline  # noqa: E402
from repro.sharding.logical import rules_for, tree_shardings, tree_specs  # noqa: E402


def skip_reason(cfg, shape) -> str:
    if shape.name == "long_500k" and not cfg.supports_long_decode:
        return "SKIP(full-attn): pure full-attention stack; 512k decode " \
               "requires sub-quadratic attention (see DESIGN.md §4)"
    return ""


def _replicated_tree(tree, mesh):
    rep = NamedSharding(mesh, PartitionSpec())
    return jax.tree.map(lambda _: rep, tree)


def build_step(arch: str, shape_name: str, mesh, *, remat=True,
               scala_overrides=None):
    """Returns (fn, args_shapes, in_shardings, out_shardings, meta)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    meta = {"arch": arch, "shape": shape_name, "mode": shape.mode,
            "sharding_profile": cfg.sharding_profile}
    rules = rules_for(cfg.sharding_profile)

    if shape.mode == "train":
        C = num_clients_for(mesh)
        params_sh, params_ax = ispec.param_specs(cfg, num_clients=C)
        batch_sh, batch_ax = ispec.train_batch_specs(cfg, shape, C)
        p_shard = tree_shardings(params_ax, params_sh, mesh, rules)
        b_shard = tree_shardings(batch_ax, batch_sh, mesh, rules)
        model = transformer_split_model(cfg, remat=remat)
        sc = ScalaConfig(**(scala_overrides or {}))

        if cfg.sharding_profile == "dp":
            # manual-SPMD step: one grad psum per step (§Perf)
            b_specs = tree_specs(batch_ax, batch_sh, mesh, rules)

            def step(params, batch):
                return scala_local_step_fused_dp(model, params, batch, sc,
                                                 mesh, b_specs)
        else:
            def step(params, batch):
                return scala_local_step_fused(model, params, batch, sc)

        metrics_shapes = jax.eval_shape(step, params_sh, batch_sh)[1]
        out_sh = (p_shard, _replicated_tree(metrics_shapes, mesh))
        meta["num_clients"] = C
        meta["tokens"] = shape.global_batch * shape.seq_len
        return step, (params_sh, batch_sh), (p_shard, b_shard), out_sh, meta, cfg

    # ZeRO/FSDP is a *training* sharding: per-layer weight gathers are
    # amortized over the huge train batch. Serving (prefill/decode) keeps
    # the TP layout — measured: fsdp prefill is 30-90x worse on
    # collectives and fsdp decode replicates the KV cache. The dp profile
    # serves prefill fine (plain data-parallel serving) but its decode
    # cache needs the TP kv-head sharding (§Perf-beyond).
    if cfg.sharding_profile == "fsdp":
        rules = rules_for("tp")
    elif cfg.sharding_profile == "dp" and shape.mode == "decode":
        rules = rules_for("tp")

    if shape.mode == "prefill":
        params_sh, params_ax = ispec.param_specs(cfg)
        batch_sh, batch_ax = ispec.prefill_batch_specs(cfg, shape)
        p_shard = tree_shardings(params_ax, params_sh, mesh, rules)
        b_shard = tree_shardings(batch_ax, batch_sh, mesh, rules)

        def step(params, batch):
            return T.forward_prefill(params, batch, cfg)

        meta["tokens"] = shape.global_batch * shape.seq_len
        return step, (params_sh, batch_sh), (p_shard, b_shard), None, meta, cfg

    # decode
    params_sh, params_ax = ispec.param_specs(cfg)
    batch_sh, batch_ax, cache_sh, cache_ax = ispec.decode_batch_specs(cfg, shape)
    p_shard = tree_shardings(params_ax, params_sh, mesh, rules)
    b_shard = tree_shardings(batch_ax, batch_sh, mesh, rules)
    c_shard = tree_shardings(cache_ax, cache_sh, mesh, rules)
    idx_sh = jax.ShapeDtypeStruct((), jnp.int32)
    rep = NamedSharding(mesh, PartitionSpec())

    def step(params, batch, cache, index):
        return T.decode_step(params, batch, cache, index, cfg)

    out_sh = (None, c_shard)
    meta["tokens"] = shape.global_batch  # one token per sequence
    return (step, (params_sh, batch_sh, cache_sh, idx_sh),
            (p_shard, b_shard, c_shard, rep), out_sh, meta, cfg)


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               remat: bool = True, scala_overrides=None,
               keep_hlo: bool = False) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name}

    reason = skip_reason(cfg, shape)
    if reason:
        record["status"] = "skip"
        record["reason"] = reason
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    try:
        step, args, in_sh, out_sh, meta, cfg = build_step(
            arch, shape_name, mesh, remat=remat,
            scala_overrides=scala_overrides)
        record.update(meta)

        t0 = time.time()
        with compat.set_mesh(mesh):
            jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = roofline.parse_collectives(hlo)
        coll_scoped = roofline.parse_collectives_scoped(hlo)

        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        min_bytes = float((getattr(mem, "argument_size_in_bytes", 0) or 0)
                          + (getattr(mem, "output_size_in_bytes", 0) or 0)
                          + (getattr(mem, "peak_memory_in_bytes", 0) or 0))
        terms = roofline.roofline_terms(flops, bytes_acc,
                                        coll["total_bytes"], min_bytes)
        terms_scoped = roofline.roofline_terms(
            flops, bytes_acc, coll_scoped["total_bytes"], min_bytes)

        params_sh, params_ax = (args[0], None)
        # model flops (active params)
        p_shapes, p_axes = ispec.param_specs(
            cfg, num_clients=meta.get("num_clients", 0))
        counts = roofline.count_params(
            p_shapes, p_axes,
            top_k=cfg.moe.top_k if cfg.moe else 0,
            num_experts=cfg.moe.num_experts if cfg.moe else 0)
        mf = roofline.model_flops(counts["active"], meta["tokens"],
                                  "train" if shape.mode == "train" else "serve")

        record.update({
            "status": "ok",
            "chips": chips,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "flops_per_device": flops,
            "bytes_per_device": bytes_acc,
            "collectives": coll,
            "collectives_scoped": coll_scoped,
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
            },
            "fits_hbm": bool(
                ((getattr(mem, "argument_size_in_bytes", 0) or 0)
                 + (getattr(mem, "output_size_in_bytes", 0) or 0)
                 + (getattr(mem, "peak_memory_in_bytes", 0) or 0))
                < 16e9),
            "roofline": terms,
            "roofline_scoped": terms_scoped,
            "params_total": counts["total"],
            "params_active": counts["active"],
            "model_flops_global": mf,
            "model_flops_per_device": mf / chips,
            "useful_flops_ratio": (mf / chips) / flops if flops else None,
        })
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="re-run even if a cached ok/skip record exists")
    ap.add_argument("--no-constrain", action="store_true",
                    help="disable in-graph activation sharding constraints "
                         "(reproduces the propagation-only §Perf baseline)")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    if args.no_constrain:
        from repro.sharding import logical
        logical.CONSTRAIN = False

    pairs = []
    archs = ASSIGNED_ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                pairs.append((a, s, mp))

    os.makedirs(args.out, exist_ok=True)
    for arch, shape, mp in pairs:
        mesh_name = "2x16x16" if mp else "16x16"
        path = os.path.join(args.out, f"{arch}__{shape}__{mesh_name}.json")
        if os.path.exists(path) and not args.force:
            with open(path) as f:
                prev = json.load(f)
            if prev.get("status") in ("ok", "skip"):
                print(f"[cached] {arch} {shape} {mesh_name}: {prev['status']}")
                continue
        rec = dryrun_one(arch, shape, multi_pod=mp, remat=not args.no_remat)
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f" compile={rec['compile_s']}s bottleneck={r['bottleneck']}"
                     f" tc={r['t_compute_s']:.3e} tm={r['t_memory_s']:.3e}"
                     f" tx={r['t_collective_s']:.3e}")
        elif status == "error":
            extra = " " + rec["error"][:200]
        print(f"[{status}] {arch} {shape} {mesh_name}{extra}", flush=True)


if __name__ == "__main__":
    main()
