"""Production mesh builders (functions — importing never touches jax
device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (data, model); 2 pods -> (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has — used by examples/smoke tests."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def client_axes(mesh) -> tuple:
    """Mesh axes that carry the client-parallel dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def num_clients_for(mesh) -> int:
    sizes = dict(mesh.shape)
    n = sizes.get("data", 1)
    if "pod" in sizes:
        n *= sizes["pod"]
    return n
