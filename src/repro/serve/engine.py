"""Continuous-batching serving engine over a fixed slot axis.

The engine holds ``slots`` concurrent sequences in one cache (dense or
paged, see :mod:`repro.serve.cache`) and runs generation as a stream of
identical jitted dispatches:

* **admit** — one fused-prefill dispatch per request
  (:func:`repro.models.transformer.forward_prefill_cached`): the whole
  prompt in one trunk pass, cache scattered in place of a freed slot,
  first token sampled from the last-position logits. Compiled once per
  distinct prompt length (prompts are never padded: padding would
  corrupt recurrent-mixer state and leave attendable garbage KV rows).
* **step** — one decode dispatch advancing *every* slot by one token.
  Each slot carries its own position, so :func:`decode_step` (whose
  index is a shared scalar) is ``vmap``-ed over the slot axis with a
  per-slot index — the per-slot math is exactly the single-sequence
  decode path, which is what makes engine output token-identical to the
  token-by-token baseline (test-enforced).

Requests are admitted from an arrival queue into freed slots *as
sequences finish* — no generation barrier — so short requests stop
occupying compute the moment they are done (``admission='static'``
restores the barrier for A/B benchmarking). All shapes are static:
slot count, cache layout, and table width never change, so the decode
step stays one compiled program regardless of the admission schedule.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.serve.cache import is_group_path, make_ops


@dataclass
class Request:
    """One generation request: prompt tokens + a token budget.

    ``deadline`` (optional) bounds the request's total time in the
    system, measured from ``arrival`` on the serve timeline (seconds
    under ``wall_clock=True``, decode steps otherwise). A slot still
    generating when its deadline passes is *evicted*: the partial
    sequence is returned (``Result.evicted == "deadline"``) and the slot
    and its pages are freed for the arrival queue — graceful degradation
    under overload instead of head-of-line blocking."""
    rid: int
    tokens: np.ndarray          # (P,) int32 prompt
    max_new: int                # tokens to generate (>= 1)
    arrival: float = 0.0        # seconds after serve() start
    deadline: Optional[float] = None  # max time in system, from arrival


@dataclass
class Result:
    rid: int
    tokens: np.ndarray          # (P + generated,) prompt + generated
    prompt_len: int
    arrival: float
    t_admit: float
    t_finish: float
    logits: Optional[List[np.ndarray]] = None
    # None = ran to its own max_new; "deadline" = wall-clock eviction;
    # "budget" = hit the engine-wide token_budget cap first
    evicted: Optional[str] = None

    @property
    def latency(self) -> float:
        return self.t_finish - self.arrival


@dataclass
class _Slot:
    active: bool = False        # occupancy flag (rid values are caller-owned)
    rid: int = 0
    length: int = 0             # tokens absorbed so far == next write index
    max_new: int = 0
    generated: int = 0
    last_tok: int = 0
    n_pages: int = 0
    budget: int = 0             # min(max_new, engine token_budget)
    expiry: float = float("inf")  # absolute eviction time on the timeline


class ServeEngine:
    """Continuous-batching generation over a merged (non-split) model.

    params: ``{'client': ..., 'server': ...}`` single-client layout, as
    produced by :func:`repro.models.transformer.init_params` or by
    merging a federated checkpoint (see :mod:`repro.api.serving`).
    """

    def __init__(self, params, cfg, *, slots: int = 4, max_len: int = 256,
                 pages: int = 0, page_size: int = 16,
                 temperature: float = 0.0, seed: int = 0,
                 admission: str = "continuous", record_logits: bool = False,
                 token_budget: Optional[int] = None):
        if not cfg.is_decoder:
            raise ValueError("ServeEngine requires a decoder arch")
        if cfg.frontend is not None:
            raise ValueError("ServeEngine serves text-only archs "
                             f"(frontend={cfg.frontend!r})")
        if admission not in ("continuous", "static"):
            raise ValueError(f"unknown admission mode {admission!r}")
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.temperature = float(temperature)
        self.admission = admission
        self.record_logits = record_logits
        # engine-wide cap on generated tokens per request (overload
        # protection): a request whose max_new exceeds it is evicted at
        # the cap with Result.evicted == "budget"
        if token_budget is not None and token_budget < 1:
            raise ValueError(f"token_budget must be >= 1, got {token_budget}")
        self.token_budget = token_budget
        # sampling stream, folded off the raw seed key so it never
        # collides with the param-init stream PRNGKey(seed)
        self._key = jax.random.fold_in(jax.random.PRNGKey(seed), 1)

        from repro.models.common import dtype_of
        self.ops = make_ops(cfg, slots, max_len, dtype_of(cfg.dtype),
                            pages=pages, page_size=page_size)

        # vmapped per-slot decode: strip the slot axis, run decode_step
        # at B=1 with this slot's own index, restore the slot axis.
        axes = jax.tree_util.tree_map_with_path(
            lambda p, _: 1 if is_group_path(p) else 0,
            jax.eval_shape(lambda: T.init_decode_cache(
                cfg, slots, max_len, dtype_of(cfg.dtype))))

        def one(tok, idx, cache1):
            cb = jax.tree_util.tree_map_with_path(
                lambda p, a: a[:, None] if is_group_path(p) else a[None],
                cache1)
            logits, nc = T.decode_step(
                params, {"tokens": tok[None, None]}, cb, idx, cfg)
            nc = jax.tree_util.tree_map_with_path(
                lambda p, a: a[:, 0] if is_group_path(p) else a[0], nc)
            return logits[0, 0], nc

        slot_decode = jax.vmap(one, in_axes=(0, 0, axes),
                               out_axes=(0, axes))

        def step_fn(cache, table, toks, idxs, ctr):
            dense = self.ops.gather(cache, table)
            logits, new_dense = slot_decode(toks, idxs, dense)
            logits = logits.astype(jnp.float32)
            nxt = self._pick(logits, ctr)
            return self.ops.scatter(cache, new_dense, table, idxs), nxt, logits

        self._step = jax.jit(step_fn, donate_argnums=(0,))
        self._admits: Dict[int, object] = {}  # prompt_len -> jitted admit

        # host-side bookkeeping
        self._cache = self.ops.init()
        self._table = np.full((slots, self.ops.max_pages), -1, np.int32)
        self._free_pages = list(range(pages - 1, -1, -1)) if pages else []
        self._free_slots = list(range(slots - 1, -1, -1))
        self._slot = [_Slot() for _ in range(slots)]
        self._out: Dict[int, list] = {}
        self._log: Dict[int, list] = {}
        self._admit_meta: Dict[int, tuple] = {}
        self._results: Dict[int, Result] = {}
        self._wave_open = True
        self._ctr = 0

    # -- sampling ----------------------------------------------------------

    def _pick(self, logits, ctr):
        """Greedy or temperature sampling; traced inside the jitted fns."""
        if self.temperature == 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        key = jax.random.fold_in(self._key, ctr)
        return jax.random.categorical(
            key, logits / self.temperature, axis=-1).astype(jnp.int32)

    def _admit_fn(self, prompt_len: int):
        fn = self._admits.get(prompt_len)
        if fn is None:
            def admit_fn(cache, prompt, table_row, slot, ctr):
                logits, req = T.forward_prefill_cached(
                    self.params, {"tokens": prompt}, self.cfg, self.max_len)
                cache = self.ops.admit(cache, req, table_row, slot)
                lg = logits[0, 0].astype(jnp.float32)
                return cache, self._pick(lg[None], ctr)[0], lg
            fn = self._admits[prompt_len] = jax.jit(
                admit_fn, donate_argnums=(0,))
        return fn

    # -- scheduling --------------------------------------------------------

    @property
    def n_active(self) -> int:
        return self.slots - len(self._free_slots)

    def _try_admit(self, req: Request, now: float,
                   results: Dict[int, Result]) -> bool:
        if not self._free_slots:
            return False
        n_pages = 0
        if self.ops.paged:
            n_pages = self.ops.pages_needed(len(req.tokens) + req.max_new)
            if n_pages > len(self._free_pages):
                return False
        slot = self._free_slots.pop()
        row = np.full((self.ops.max_pages,), -1, np.int32)
        for j in range(n_pages):
            row[j] = self._free_pages.pop()
        self._table[slot] = row

        prompt = jnp.asarray(req.tokens[None].astype(np.int32))
        self._cache, tok0, lg = self._admit_fn(len(req.tokens))(
            self._cache, prompt, jnp.asarray(row), jnp.int32(slot),
            jnp.int32(self._ctr))
        self._ctr += 1
        tok0 = int(tok0)

        s = self._slot[slot]
        s.active = True
        s.rid, s.length, s.max_new = req.rid, len(req.tokens), req.max_new
        s.generated, s.last_tok, s.n_pages = 1, tok0, n_pages
        s.budget = (req.max_new if self.token_budget is None
                    else min(req.max_new, self.token_budget))
        s.expiry = (float("inf") if req.deadline is None
                    else req.arrival + req.deadline)
        self._out[req.rid] = [tok0]
        if self.record_logits:
            self._log[req.rid] = [np.asarray(lg)]
        self._admit_meta[req.rid] = (req, now)
        if s.generated >= s.budget:
            self._finish(slot, now, results,
                         "budget" if s.budget < s.max_new else None)
        return True

    def _finish(self, slot: int, now: float, results: Dict[int, Result],
                evicted: Optional[str] = None):
        s = self._slot[slot]
        req, t_admit = self._admit_meta.pop(s.rid)
        self._free_pages.extend(
            int(p) for p in self._table[slot][:s.n_pages])
        self._table[slot] = -1
        self._free_slots.append(slot)
        results[s.rid] = Result(
            rid=s.rid,
            tokens=np.concatenate([req.tokens.astype(np.int32),
                                   np.asarray(self._out.pop(s.rid), np.int32)]),
            prompt_len=len(req.tokens), arrival=req.arrival,
            t_admit=t_admit, t_finish=now,
            logits=self._log.pop(s.rid, None), evicted=evicted)
        s.active = False

    def _evict_expired(self, now: float, results: Dict[int, Result]) -> int:
        """Free every slot whose request deadline has passed; returns the
        count evicted. The partial sequence generated so far is returned
        as the request's result (``evicted == "deadline"``)."""
        n = 0
        for slot, s in enumerate(self._slot):
            if s.active and now >= s.expiry:
                self._finish(slot, now, results, "deadline")
                n += 1
        return n

    def _step_once(self, now: float, results: Dict[int, Result]):
        toks = np.array([s.last_tok for s in self._slot], np.int32)
        idxs = np.array([s.length for s in self._slot], np.int32)
        self._cache, nxt, logits = self._step(
            self._cache, jnp.asarray(self._table), jnp.asarray(toks),
            jnp.asarray(idxs), jnp.int32(self._ctr))
        self._ctr += 1
        nxt = np.asarray(nxt)
        if self.record_logits:
            logits = np.asarray(logits)
        for slot, s in enumerate(self._slot):
            if not s.active:
                continue
            s.length += 1
            s.generated += 1
            s.last_tok = int(nxt[slot])
            self._out[s.rid].append(s.last_tok)
            if self.record_logits:
                self._log[s.rid].append(logits[slot])
            if s.generated >= s.budget:
                self._finish(slot, now, results,
                             "budget" if s.budget < s.max_new else None)

    # -- public API --------------------------------------------------------

    def admit(self, req: Request, now: float = 0.0) -> bool:
        """Prefill one request into a free slot (one fused dispatch).
        False if no slot (or, paged, not enough free pages) is available."""
        total = len(req.tokens) + req.max_new
        if req.max_new < 1:
            raise ValueError(f"request {req.rid}: max_new must be >= 1")
        if total > self.max_len:
            raise ValueError(
                f"request {req.rid}: {total} tokens > max_len={self.max_len}")
        return self._try_admit(req, now, self._results)

    def step(self, now: float = 0.0) -> None:
        """Advance every active slot by one token (one decode dispatch).
        Slots past their request deadline are evicted first, not
        stepped."""
        self._evict_expired(now, self._results)
        if self.n_active:
            self._step_once(now, self._results)

    def take_finished(self) -> Dict[int, Result]:
        """Pop and return the requests finished since the last call."""
        out, self._results = self._results, {}
        return out

    def serve(self, requests: List[Request], *,
              wall_clock: bool = True) -> Dict[int, Result]:
        """Run a batch of requests to completion. Arrivals are honoured
        on the wall clock (``wall_clock=False`` treats every request as
        already arrived — deterministic, for tests)."""
        for r in requests:
            total = len(r.tokens) + r.max_new
            if r.max_new < 1:
                raise ValueError(f"request {r.rid}: max_new must be >= 1")
            if total > self.max_len:
                raise ValueError(
                    f"request {r.rid}: {total} tokens > max_len={self.max_len}")
            if r.deadline is not None and r.deadline <= 0:
                raise ValueError(
                    f"request {r.rid}: deadline must be > 0, "
                    f"got {r.deadline}")
        pending = collections.deque(
            sorted(requests, key=lambda r: (r.arrival, r.rid)))
        results: Dict[int, Result] = {}
        t0 = time.monotonic()

        while pending or self.n_active:
            now = (time.monotonic() - t0) if wall_clock else float(self._ctr)
            # deadline evictions free slots BEFORE admission, so a queued
            # request can take over an expired slot this very iteration
            self._evict_expired(now, results)
            if self.n_active == 0:
                self._wave_open = True  # static mode: new admission wave
            arrived = bool(pending) and (not wall_clock
                                         or pending[0].arrival <= now)
            may_admit = (self.admission == "continuous" or self._wave_open)
            if arrived and may_admit:
                if self._try_admit(pending[0], now, results):
                    pending.popleft()
                    continue
                if self.n_active == 0:
                    raise RuntimeError(
                        "page pool too small for a single request — "
                        "raise ServeSpec.pages")
            if self.n_active:
                self._wave_open = False
                self._step_once(now, results)
            elif pending and wall_clock:
                time.sleep(min(0.01, max(0.0, pending[0].arrival - now)))
        return results

    def generate(self, prompts: np.ndarray, max_new: int) -> np.ndarray:
        """Batch convenience wrapper: all prompts arrive at t=0; returns
        (B, P + max_new) prompt+generated tokens, row i = prompt i."""
        prompts = np.asarray(prompts)
        reqs = [Request(i, prompts[i], max_new) for i in range(len(prompts))]
        res = self.serve(reqs, wall_clock=False)
        return np.stack([res[i].tokens for i in range(len(prompts))])

    def warmup(self, prompt_lens: List[int]):
        """Compile the admit dispatches for the given prompt lengths and
        the shared decode step, so serving latency excludes compile."""
        for P in prompt_lens:
            req = Request(rid=-(P + 1), tokens=np.zeros((P,), np.int32),
                          max_new=2)
            self.serve([req], wall_clock=False)

    def state_bytes(self) -> int:
        """Resident decode-cache bytes (pool budget when paged)."""
        return self.ops.state_bytes()
