"""Paged decode cache: fixed page pool + per-slot page table.

A dense decode cache allocates ``slots x max_len`` KV rows per attention
layer, so ``max_len`` is paid up front for every slot whether a request
uses 16 tokens or 16k. Here the KV rows of every attention layer live in
a fixed **page pool** instead, and each serving slot owns a small set of
pages recorded in a per-slot **page table**:

* ``pool``  — per attn leaf, ``(pages, page_size, kv, hd)`` (group-scanned
  layers carry a leading ``(n_scan,)`` axis). One *page id* indexes the
  same row range in every leaf's pool, so allocation is a single integer
  per ``page_size`` cache positions.
* ``table`` — ``(slots, max_pages)`` int32, host-managed; entry ``j`` is
  the page backing cache positions ``[j*page_size, (j+1)*page_size)``;
  ``-1`` marks unallocated.

Recurrent-mixer state (mamba / xLSTM) is O(1) per slot and stays dense.

The engine threads two helpers around :func:`repro.models.transformer
.decode_step` each step: :meth:`PagedOps.gather` materialises the dense
per-slot view the unmodified decode math expects, and
:meth:`PagedOps.scatter` writes the one new KV row per slot back into
the pool. Compute therefore runs on *identically-valued* dense views in
both modes, which is what makes paged serving bit-identical to dense
serving (test-enforced). Unallocated table entries read page 0 and write
out-of-bounds (dropped); those rows are always masked to exactly-zero
attention weight, so they never reach the output.

Windowed layers keep their ring semantics: a leaf with ring length
``L < max_len`` only ever touches positions ``pos % L``, i.e. the first
``ceil(L / page_size)`` table columns.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T


def is_group_path(path) -> bool:
    """True if a cache-tree path points under the scan-stacked 'groups'
    subtree (leaves there carry a leading (n_scan,) layer axis)."""
    return bool(path) and getattr(path[0], "key", None) == "groups"


def is_attn_path(path) -> bool:
    """True for KV-cache leaves (dict keys 'k'/'v'); the recurrent-mixer
    cache dicts ('conv', 'h', 'C', 'n', 'm', 'c') never use these keys."""
    return bool(path) and getattr(path[-1], "key", None) in ("k", "v")


@dataclass(frozen=True)
class _LeafInfo:
    group: bool     # leading (n_scan,) axis?
    attn: bool      # paged KV leaf vs dense recurrent-state leaf
    length: Optional[int]  # ring/cache length L for attn leaves
    shape: tuple    # dense shape (with the slot axis)
    dtype: object


def _leaf_infos(cfg, slots: int, max_len: int, dtype):
    tpl = jax.eval_shape(
        lambda: T.init_decode_cache(cfg, slots, max_len, dtype))
    flat, treedef = jax.tree_util.tree_flatten_with_path(tpl)
    infos = []
    for path, leaf in flat:
        group = is_group_path(path)
        attn = is_attn_path(path)
        length = leaf.shape[2 if group else 1] if attn else None
        infos.append(_LeafInfo(group, attn, length, leaf.shape, leaf.dtype))
    return infos, treedef


class DenseOps:
    """Trivial ops for the dense (non-paged) cache: the cache *is* the
    dense view, slot admission is a slot-axis overwrite."""

    paged = False

    def __init__(self, cfg, slots: int, max_len: int, dtype):
        self.cfg, self.slots, self.max_len, self.dtype = cfg, slots, max_len, dtype
        self.infos, self.treedef = _leaf_infos(cfg, slots, max_len, dtype)
        self.max_pages = 1  # dummy table width

    def init(self):
        return T.init_decode_cache(self.cfg, self.slots, self.max_len,
                                   self.dtype)

    def gather(self, cache, table):
        return cache

    def scatter(self, cache, new_dense, table, idxs):
        return new_dense

    def admit(self, cache, req_cache, table_row, slot):
        """Overwrite one slot with a B=1 request cache."""
        leaves = self.treedef.flatten_up_to(cache)
        reqs = self.treedef.flatten_up_to(req_cache)
        out = []
        for info, leaf, req in zip(self.infos, leaves, reqs):
            if info.group:
                out.append(leaf.at[:, slot].set(req[:, 0]))
            else:
                out.append(leaf.at[slot].set(req[0]))
        return jax.tree_util.tree_unflatten(self.treedef, out)

    def state_bytes(self) -> int:
        return sum(int(np.prod(i.shape)) * np.dtype(i.dtype).itemsize
                   for i in self.infos)


class PagedOps:
    """Gather/scatter between the page pool and the dense per-slot view."""

    paged = True

    def __init__(self, cfg, slots: int, max_len: int, dtype, *,
                 pages: int, page_size: int):
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.cfg, self.slots, self.max_len, self.dtype = cfg, slots, max_len, dtype
        self.pages, self.page_size = pages, page_size
        self.max_pages = math.ceil(max_len / page_size)
        self.infos, self.treedef = _leaf_infos(cfg, slots, max_len, dtype)

    # -- pool layout -------------------------------------------------------

    def _npages(self, length: int) -> int:
        return math.ceil(length / self.page_size)

    def init(self):
        """Pool tree: attn leaves become page pools, recurrent-state
        leaves stay dense. Same treedef as the dense cache."""
        out = []
        for i in self.infos:
            if i.attn:
                kv_hd = i.shape[-2:]
                shape = ((i.shape[0],) if i.group else ()) + \
                    (self.pages, self.page_size) + kv_hd
            else:
                shape = i.shape
            out.append(jnp.zeros(shape, i.dtype))
        return jax.tree_util.tree_unflatten(self.treedef, out)

    def pages_needed(self, target_len: int) -> int:
        """Table columns a request reaching ``target_len`` total tokens
        touches. Some leaf spans the full ``min(target, max_len)`` unless
        every layer is windowed; we budget for the worst leaf."""
        longest = max((i.length for i in self.infos if i.attn), default=0)
        return self._npages(min(target_len, longest)) if longest else 0

    # -- jit-traceable ops -------------------------------------------------

    def gather(self, paged, table):
        """Materialise the dense (slots, L, ...) view decode expects."""
        pools = self.treedef.flatten_up_to(paged)
        out = []
        for info, pool in zip(self.infos, pools):
            if not info.attn:
                out.append(pool)
                continue
            L = info.length
            npg = self._npages(L)
            cols = jnp.clip(table[:, :npg], 0)            # unalloc -> page 0
            if info.group:
                g = pool[:, cols]                         # (G,S,npg,ps,kv,hd)
                dense = g.reshape(g.shape[:2] + (npg * self.page_size,)
                                  + g.shape[4:])[:, :, :L]
            else:
                g = pool[cols]                            # (S,npg,ps,kv,hd)
                dense = g.reshape((g.shape[0], npg * self.page_size)
                                  + g.shape[3:])[:, :L]
            out.append(dense)
        return jax.tree_util.tree_unflatten(self.treedef, out)

    def scatter(self, paged, new_dense, table, idxs):
        """Write the one KV row each slot produced this step back into
        its page; recurrent-state leaves are taken wholesale."""
        pools = self.treedef.flatten_up_to(paged)
        dense = self.treedef.flatten_up_to(new_dense)
        arange = jnp.arange(self.slots)
        out = []
        for info, pool, nd in zip(self.infos, pools, dense):
            if not info.attn:
                out.append(nd)
                continue
            L = info.length
            widx = idxs % L
            pid = table[arange, widx // self.page_size]
            pid = jnp.where(pid < 0, self.pages, pid)     # unalloc -> drop
            off = widx % self.page_size
            if info.group:
                row = jnp.take_along_axis(
                    nd, widx[None, :, None, None, None], axis=2)[:, :, 0]
                out.append(pool.at[:, pid, off].set(row, mode="drop"))
            else:
                row = jnp.take_along_axis(
                    nd, widx[:, None, None, None], axis=1)[:, 0]
                out.append(pool.at[pid, off].set(row, mode="drop"))
        return jax.tree_util.tree_unflatten(self.treedef, out)

    def admit(self, paged, req_cache, table_row, slot):
        """Scatter a B=1 prefill cache into the slot's pages (attn) and
        its dense row (recurrent state)."""
        pools = self.treedef.flatten_up_to(paged)
        reqs = self.treedef.flatten_up_to(req_cache)
        out = []
        for info, pool, req in zip(self.infos, pools, reqs):
            if not info.attn:
                out.append(pool.at[:, slot].set(req[:, 0]) if info.group
                           else pool.at[slot].set(req[0]))
                continue
            L = info.length
            npg = self._npages(L)
            Lp = npg * self.page_size
            cols = table_row[:npg]
            cols = jnp.where(cols < 0, self.pages, cols)  # unalloc -> drop
            if info.group:
                r = req[:, 0]                             # (G,L,kv,hd)
                r = jnp.pad(r, ((0, 0), (0, Lp - L), (0, 0), (0, 0)))
                r = r.reshape((r.shape[0], npg, self.page_size) + r.shape[2:])
                out.append(pool.at[:, cols].set(r, mode="drop"))
            else:
                r = req[0]                                # (L,kv,hd)
                r = jnp.pad(r, ((0, Lp - L), (0, 0), (0, 0)))
                r = r.reshape((npg, self.page_size) + r.shape[1:])
                out.append(pool.at[cols].set(r, mode="drop"))
        return jax.tree_util.tree_unflatten(self.treedef, out)

    def state_bytes(self) -> int:
        total = 0
        for i in self.infos:
            if i.attn:
                kv_hd = int(np.prod(i.shape[-2:]))
                n = (i.shape[0] if i.group else 1) * self.pages \
                    * self.page_size * kv_hd
            else:
                n = int(np.prod(i.shape))
            total += n * np.dtype(i.dtype).itemsize
        return total


def make_ops(cfg, slots: int, max_len: int, dtype, *,
             pages: int = 0, page_size: int = 16):
    """pages == 0 selects the dense cache; pages > 0 the paged pool."""
    if pages > 0:
        return PagedOps(cfg, slots, max_len, dtype,
                        pages=pages, page_size=page_size)
    return DenseOps(cfg, slots, max_len, dtype)
