"""Serving subsystem: fused prefill + continuous batching + paged KV.

The inference-side counterpart to the federated training stack: a
trained SCALA global model (client half merged with server half) served
with one-dispatch prompt prefill, slot-recycling continuous batching,
and an optionally paged decode cache. See :mod:`repro.serve.engine` and
:mod:`repro.serve.cache`; the spec-level entry point is
:class:`repro.api.ServeSpec`.
"""
from repro.serve.cache import DenseOps, PagedOps, make_ops
from repro.serve.engine import Request, Result, ServeEngine

__all__ = ["DenseOps", "PagedOps", "make_ops",
           "Request", "Result", "ServeEngine"]
