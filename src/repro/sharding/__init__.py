from repro.sharding.logical import (  # noqa: F401
    RULES,
    replicated,
    spec_for,
    tree_shardings,
    tree_specs,
)
