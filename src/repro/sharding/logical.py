"""Logical-axis -> mesh-axis resolution with divisibility fallback.

Every param/activation leaf carries a tuple of logical axis names (see
``axes()`` functions in the model library). This module maps them onto
the physical mesh: each logical name has an ordered candidate list of
mesh-axis groups; the first group whose axes (a) exist in the mesh,
(b) are not already used by an earlier dim of the same leaf and
(c) divide the dim size, wins. Otherwise the dim is replicated.

This gives, on the production (data, model) mesh:
  client/batch -> data (client-parallelism), vocab/heads/ffn/experts ->
  model (tensor/expert parallelism), embed -> data (FSDP for the
  server-side halves that must fit — Jamba 398B), with automatic
  replication fallback for the small-head archs (whisper 6H, xlstm 4H).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Axes = Tuple[str, ...]

# ordered candidates: each entry is a tuple of mesh axes used together
RULES: Dict[str, Tuple[Tuple[str, ...], ...]] = {
    "client": (("pod", "data"), ("data",)),
    "batch": (("pod", "data"), ("data",)),
    "cache_batch": (("pod", "data"), ("data",)),
    "cache_seq": (("data",), ("model",)),
    "seq": (),
    "vocab": (("model",),),
    "embed": (("data",),),
    "embed_alt": (),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "head_dim": (),
    "head_dim_alt": (),
    "ffn": (("model",),),
    "expert_ffn": (("model",), ("data",)),
    "experts": (("model",),),
    "experts_router": (("model",),),
    "inner": (("model",),),
    "inner_alt": (),
    "state": (),
    "conv_k": (),
    "lowrank": (),
    "gates": (),
    "gate_kind": (),
    "layers": (),
    "position": (),
    "frontend": (),
    "prefix": (),
    "per_client_batch": (),
}


_DP_OVERRIDES = {
    # pure data/client parallelism: weights replicated, batch over all axes
    "client": (("pod", "data"), ("data",)),
    "batch": (("pod", "data", "model"), ("data", "model"), ("pod", "data"),
              ("data",)),
    "cache_batch": (("pod", "data", "model"), ("data", "model"),
                    ("pod", "data"), ("data",)),
    "per_client_batch": (("model",),),
    "cache_seq": (),
    "vocab": (), "embed": (), "heads": (), "kv_heads": (), "ffn": (),
    "expert_ffn": (), "experts": (), "experts_router": (), "inner": (),
}

RULES_DP: Dict[str, Tuple[Tuple[str, ...], ...]] = {**RULES, **_DP_OVERRIDES}

# ZeRO-3/FSDP profile: no tensor parallelism at all — batch over every
# mesh axis (same as "dp"), weights *sharded* over every axis on their
# embed dim and all-gathered at use (mid/large archs whose weights do
# not fit replicated). The layer scan slices one layer's shard per trip,
# so the gather is per-layer, classic FSDP.
_FSDP_OVERRIDES = {
    **_DP_OVERRIDES,
    "embed": (("pod", "data", "model"), ("data", "model"), ("data",)),
    "expert_ffn": (("pod", "data", "model"), ("data", "model"), ("data",)),
}

RULES_FSDP: Dict[str, Tuple[Tuple[str, ...], ...]] = {**RULES,
                                                      **_FSDP_OVERRIDES}


def rules_for(profile: str) -> Dict[str, Tuple[Tuple[str, ...], ...]]:
    return {"dp": RULES_DP, "fsdp": RULES_FSDP}.get(profile, RULES)


def is_axes(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(s, str) for s in x)


def spec_for(axes: Axes, shape: Sequence[int], mesh: Mesh,
             rules: Optional[Dict] = None) -> PartitionSpec:
    rules = RULES if rules is None else rules
    sizes = dict(mesh.shape)
    used = set()
    entries = []
    assert len(axes) == len(shape), (axes, tuple(shape))
    for name, dim in zip(axes, shape):
        choice = None
        for group in rules.get(name, ()):
            if not all(a in sizes for a in group):
                continue
            if any(a in used for a in group):
                continue
            total = int(np.prod([sizes[a] for a in group]))
            if dim % total != 0:
                continue
            choice = group
            break
        if choice is None:
            entries.append(None)
        else:
            used.update(choice)
            entries.append(choice if len(choice) > 1 else choice[0])
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


def tree_specs(axes_tree, shape_tree, mesh: Mesh, rules=None):
    """Map (axes pytree, ShapeDtypeStruct pytree) -> PartitionSpec pytree."""
    flat_axes = jax.tree.leaves(axes_tree, is_leaf=is_axes)
    flat_shapes, treedef = jax.tree.flatten(shape_tree)
    assert len(flat_axes) == len(flat_shapes), (
        f"axes/shape tree mismatch: {len(flat_axes)} vs {len(flat_shapes)}")
    specs = [spec_for(a, s.shape, mesh, rules)
             for a, s in zip(flat_axes, flat_shapes)]
    return jax.tree.unflatten(treedef, specs)


def round_specs(batch_specs):
    """Per-step batch PartitionSpecs -> per-round (T-stacked) specs.

    Round-granular programs (``core.engine.make_round_runner``,
    ``fed.runtime`` async events) consume batches with a leading local-
    iteration axis T prepended to every per-step leaf; T is a time axis
    and never sharded, so each spec simply gains a leading ``None``.
    """
    return jax.tree.map(
        lambda s: PartitionSpec(None, *s), batch_specs,
        is_leaf=lambda s: isinstance(s, PartitionSpec))


def tree_shardings(axes_tree, shape_tree, mesh: Mesh, rules=None):
    specs = tree_specs(axes_tree, shape_tree, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, PartitionSpec))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, PartitionSpec())


def client_scalar_spec(mesh: Mesh, n: int) -> PartitionSpec:
    """PartitionSpec for a (K,) per-client *schedule scalar* — the async
    runtime's ``version`` / ``finish_time`` tags and sampled delays
    (``fed.runtime.init_async_state(mesh=...)``). Resolves the "client"
    logical axis against this mesh with the standard divisibility
    fallback: replicated when K does not divide the client shard count.
    """
    return spec_for(("client",), (n,), mesh)


# ---------------------------------------------------------------------------
# in-graph activation constraints (§Perf iteration 1)
# ---------------------------------------------------------------------------

# Toggle for A/B measurement of the sharding-constraint optimization
# (dryrun --no-constrain reproduces the propagation-only baseline).
CONSTRAIN = True


def constrain(x, *axes):
    """``with_sharding_constraint`` against the *ambient* abstract mesh.

    ``axes`` is one entry per dim: None, a mesh-axis name, or a tuple of
    names. Axes missing from the current mesh are dropped; a dim whose
    size does not divide the requested axes is left unconstrained. Under
    no mesh (CPU unit tests, host training) this is a no-op, so model
    code can call it unconditionally.

    XLA's sharding propagation over the SCALA step has a failure mode
    where the server-trunk batch dim de-shards (involuntary full
    rematerialization -> every device computes the full concatenated
    batch). Pinning the residual stream's batch dim to ("pod","data")
    removes ~16x redundant compute+collectives; see EXPERIMENTS.md §Perf.
    """
    if not CONSTRAIN:
        return x
    from repro import compat

    mesh = compat.ambient_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    manual = compat.manual_axis_names(mesh)  # inside shard_map: already local
    sizes = {k: v for k, v in dict(mesh.shape).items() if k not in manual}
    spec = []
    for a, dim in zip(axes, x.shape):
        if a is None:
            spec.append(None)
            continue
        names = a if isinstance(a, tuple) else (a,)
        names = tuple(n for n in names if n in sizes)
        total = int(np.prod([sizes[n] for n in names])) if names else 1
        if not names or dim % total != 0:
            spec.append(None)
        else:
            spec.append(names if len(names) > 1 else names[0])
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*spec))
