"""Pallas TPU kernels for the perf-critical hot spots:

* lace       — fused logit-adjusted softmax CE (the paper's loss, eqs. 14/15)
* flash_attn — blocked attention with sliding-window skip
* mlstm      — chunkwise mLSTM for the xLSTM arch

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper), ref.py (pure-jnp oracle). Validated in interpret mode on CPU.
"""
from repro.kernels import flash_attn, lace, mlstm  # noqa: F401
