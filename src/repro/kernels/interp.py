"""Env-forced Pallas interpret mode for CI kernel legs.

``REPRO_PALLAS_INTERPRET=1`` forces every ``pl.pallas_call`` in this
package to run in interpret mode regardless of the ``interpret=``
argument the caller passed. CPU CI uses it to exercise the *kernel*
path of the oracle tests (kernels/{lace,flash_attn,mlstm}) instead of
only the jnp ref — the same tests then validate the Mosaic lowering
when run on a TPU host with the variable unset.
"""
from __future__ import annotations

import os


def force_interpret() -> bool:
    """True when the environment pins interpret mode on."""
    return os.environ.get("REPRO_PALLAS_INTERPRET", "") not in ("", "0")


def resolve(interpret: bool) -> bool:
    """The effective interpret flag for a ``pallas_call`` site."""
    return True if force_interpret() else bool(interpret)
