"""Pure-jnp oracle for flash attention (causal + sliding window)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mha_ref(q, k, v, *, causal: bool = True, window=None, scale=None):
    """q,k,v: (B, S, H, hd) (kv already repeated to H heads).
    Returns (B, S, H, hd)."""
    S, Skv = q.shape[1], k.shape[1]
    scale = scale or q.shape[-1] ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(Skv)[None, :]
    mask = jnp.ones((S, Skv), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= (qi - ki) < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)
