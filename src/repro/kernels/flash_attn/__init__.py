from repro.kernels.flash_attn import kernel, ops, ref  # noqa: F401
