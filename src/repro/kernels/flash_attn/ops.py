"""jit'd wrapper: (B,S,H,hd) GQA layout -> flash kernel layout and back."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.kernel import flash_attention_pallas


@functools.partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    interpret: bool = True):
    """q: (B,S,H,hd); k/v: (B,S,KV,hd) — KV heads repeated as needed."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    if KV != H:
        reps = H // KV
        k = jnp.repeat(k, reps, axis=2)
        v = jnp.repeat(v, reps, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    out = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                                 interpret=interpret)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
