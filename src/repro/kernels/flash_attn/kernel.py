"""Pallas TPU flash-attention forward (causal + sliding window).

Grid (batch*heads, q_blocks, kv_blocks), kv innermost; streaming
(m, l, acc) scratch per q block — the classic log-sum-exp recurrence.
With a sliding window the fully-masked kv blocks are skipped via
``pl.when`` so compute is O(S·w) per head, matching the windowed archs'
roofline. VMEM tiles: (QB, hd) + (KB, hd) + (QB, KB) scores, hd whole.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import interp

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  qb: int, kb: int, nkb: int, seq_kv: int, scale: float,
                  causal: bool, window):
    qi_blk = pl.program_id(1)
    kv_blk = pl.program_id(2)

    @pl.when(kv_blk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi_blk * qb
    k_start = kv_blk * kb

    # block-level reachability: skip fully-masked kv blocks — this is
    # what makes windowed attention O(S·w) instead of O(S²).
    reachable = jnp.bool_(True)
    if causal:
        reachable &= k_start <= q_start + qb - 1
    if window is not None:
        reachable &= k_start + kb - 1 >= q_start - (window - 1)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # (QB, hd)
        k = k_ref[0].astype(jnp.float32)                # (KB, hd)
        s = q @ k.T                                     # (QB, KB)
        qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) + q_start
        ki = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + k_start
        mask = ki < seq_kv
        if causal:
            mask &= ki <= qi
        if window is not None:
            mask &= (qi - ki) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v_ref[0].astype(
            jnp.float32)
        m_scr[...] = m_new

    @pl.when(kv_blk == nkb - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window=None,
                           qb: int = 128, kb: int = 128, scale=None,
                           interpret: bool = True):
    """q,k,v: (BH, S, hd) — batch*heads flattened (kv already repeated).
    Returns (BH, S, hd)."""
    BH, S, hd = q.shape
    Skv = k.shape[1]
    scale = scale or hd ** -0.5
    qb = min(qb, S)
    kb = min(kb, Skv)
    Sp = ((S + qb - 1) // qb) * qb
    Kp = ((Skv + kb - 1) // kb) * kb

    def pad(x, size):
        if x.shape[1] == size:
            return x
        return jnp.pad(x, ((0, 0), (0, size - x.shape[1]), (0, 0)))

    qp, kp, vp = pad(q, Sp), pad(k, Kp), pad(v, Kp)
    nqb, nkb = Sp // qb, Kp // kb

    out = pl.pallas_call(
        functools.partial(_flash_kernel, qb=qb, kb=kb, nkb=nkb, seq_kv=Skv,
                          scale=scale, causal=causal, window=window),
        grid=(BH, nqb, nkb),
        in_specs=[
            pl.BlockSpec((1, qb, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, kb, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, kb, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb, hd), jnp.float32),
        ],
        interpret=interp.resolve(interpret),
    )(qp, kp, vp)
    return out[:, :S]
