"""jit'd wrapper: batched multi-head mLSTM over the chunk kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mlstm.kernel import mlstm_chunk_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunkwise(q, k, v, i_raw, f_log, *, chunk: int = 64,
                    interpret: bool = True):
    """q,k,v: (B,S,H,hd); i_raw/f_log: (B,S,H). Returns h (B,S,H,hd)."""
    def per_head(q1, k1, v1, i1, f1):
        return mlstm_chunk_pallas(q1, k1, v1, i1, f1, chunk=chunk,
                                  interpret=interpret)

    # vmap over batch then heads (head axis moved in front of seq)
    fn = jax.vmap(jax.vmap(per_head))
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    it = i_raw.transpose(0, 2, 1)
    ft = f_log.transpose(0, 2, 1)
    h = fn(qt, kt, vt, it, ft)
    return h.transpose(0, 2, 1, 3)
