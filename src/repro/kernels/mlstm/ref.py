"""Pure-jnp oracle for the chunkwise mLSTM: the exact per-step recurrence
(arXiv:2405.04517, stabilized form)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mlstm_ref(q, k, v, i_raw, f_log):
    """q,k,v: (S, dk/dv) single head; i_raw, f_log: (S,). Step-by-step.

    Returns h (S, dv)."""
    S, dk = q.shape
    dv = v.shape[1]

    def step(state, inp):
        C, n, m = state
        qt, kt, vt, it, ft = inp
        m_new = jnp.maximum(ft + m, it)
        wf = jnp.exp(ft + m - m_new)
        wi = jnp.exp(it - m_new)
        C = wf * C + wi * jnp.outer(kt, vt)
        n = wf * n + wi * kt
        num = qt @ C
        den = jnp.maximum(jnp.abs(qt @ n), jnp.exp(-m_new))
        return (C, n, m_new), num / den

    state0 = (jnp.zeros((dk, dv)), jnp.zeros((dk,)), jnp.zeros(()))
    _, h = jax.lax.scan(step, state0, (q, k, v, i_raw, f_log))
    return h
