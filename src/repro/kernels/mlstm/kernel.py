"""Pallas TPU chunkwise mLSTM kernel.

One head per call (vmap over batch*heads outside). Grid = (n_chunks,),
sequential on TPU, with the inter-chunk state (C (dk,dv), n (dk,), m ())
living in VMEM scratch that persists across grid steps — the TPU-native
replacement for the CUDA recurrent kernel: within a chunk the quadratic
(L, L) gate-decay matrix runs on the MXU; across chunks only the O(dk·dv)
state is carried.

VMEM working set per step: (L,dk)+(L,dv) tiles + (L,L) decay + (dk,dv)
state — e.g. L=64, dk=dv=1024 → ~4.5MB f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import interp


def _mlstm_kernel(q_ref, k_ref, v_ref, i_ref, f_ref, h_ref,
                  C_scr, n_scr, m_scr, *, L: int):
    c = pl.program_id(0)

    @pl.when(c == 0)
    def _init():
        C_scr[...] = jnp.zeros_like(C_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.zeros_like(m_scr)

    q = q_ref[...].astype(jnp.float32)          # (L, dk)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)          # (L, dv)
    ii = i_ref[...].astype(jnp.float32)         # (L,)
    ff = f_ref[...].astype(jnp.float32)         # (L,) log-sigmoid forget

    C0 = C_scr[...]
    n0 = n_scr[...]
    m0 = m_scr[0]

    b = jnp.cumsum(ff)                          # decay from chunk start
    a = ii - b
    a_max = jax.lax.cummax(a, axis=0)
    m_t = jnp.maximum(m0 + b, b + a_max)        # (L,)

    w0 = jnp.exp(m0 + b - m_t)                  # (L,)
    h_inter = (q @ C0) * w0[:, None]            # (L, dv)
    d_inter = (q @ n0[:, None])[:, 0] * w0      # (L,)

    # intra-chunk decay matrix D[t,s] = exp(b_t - b_s + i_s - m_t), s<=t
    Dlog = b[:, None] - b[None, :] + ii[None, :] - m_t[:, None]
    row = jax.lax.broadcasted_iota(jnp.int32, Dlog.shape, 0)
    col = jax.lax.broadcasted_iota(jnp.int32, Dlog.shape, 1)
    D = jnp.where(col <= row, jnp.exp(Dlog), 0.0)

    scores = (q @ k.T) * D                      # (L, L)
    h_intra = scores @ v
    d_intra = jnp.sum(scores, axis=1)
    denom = jnp.maximum(jnp.abs(d_inter + d_intra), jnp.exp(-m_t))
    h_ref[...] = ((h_inter + h_intra) / denom[:, None]).astype(h_ref.dtype)

    # state to end of chunk
    F = b[L - 1]
    m_new = jnp.maximum(m0 + F, F + a_max[L - 1])
    wC0 = jnp.exp(m0 + F - m_new)
    wks = jnp.exp(F - b + ii - m_new)           # (L,)
    C_scr[...] = C0 * wC0 + (k * wks[:, None]).T @ v
    n_scr[...] = n0 * wC0 + jnp.sum(k * wks[:, None], axis=0)
    m_scr[0] = m_new


def mlstm_chunk_pallas(q, k, v, i_raw, f_log, *, chunk: int = 64,
                       interpret: bool = True):
    """q,k: (S, dk); v: (S, dv); gates (S,). Single head. Returns (S, dv)."""
    S, dk = q.shape
    dv = v.shape[1]
    L = min(chunk, S)
    while S % L:
        L //= 2
    nc = S // L

    return pl.pallas_call(
        functools.partial(_mlstm_kernel, L=L),
        grid=(nc,),
        in_specs=[
            pl.BlockSpec((L, dk), lambda c: (c, 0)),
            pl.BlockSpec((L, dk), lambda c: (c, 0)),
            pl.BlockSpec((L, dv), lambda c: (c, 0)),
            pl.BlockSpec((L,), lambda c: (c,)),
            pl.BlockSpec((L,), lambda c: (c,)),
        ],
        out_specs=pl.BlockSpec((L, dv), lambda c: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((S, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dk, dv), jnp.float32),
            pltpu.VMEM((dk,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interp.resolve(interpret),
    )(q, k, v, i_raw, f_log)
