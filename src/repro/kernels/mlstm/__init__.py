from repro.kernels.mlstm import kernel, ops, ref  # noqa: F401
