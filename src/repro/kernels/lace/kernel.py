"""Pallas TPU kernels for LACE (fused logit-adjusted softmax CE).

Three kernels (forward, backward-dfeats, backward-dW), each tiling the
vocab so the (tokens, V) logits never leave VMEM:

* ``fwd``: grid (token_blocks, vocab_blocks), vocab innermost; streaming
  (m, s, ll) scratch per token block; emits per-token nll and lse.
* ``bwd_dfeats``: same grid; recomputes z per vocab tile from the saved
  lse, accumulates dfeats[t] += g_tile @ W_tile^T over consecutive inner
  vocab steps.
* ``bwd_dw``: grid (vocab_blocks, token_blocks), tokens innermost;
  accumulates dW[v] += feats_tile^T @ g_tile.

Tile sizes: token_block x d feats tiles and d x vocab_block weight tiles;
d is kept whole (<= 8k: W tile bf16 fits VMEM at vocab_block 256). For
larger d a d-tiled variant would be needed — none of the assigned archs
exceeds d=8192.

``lace2_fwd/bwd_pallas`` (bottom) are the fused dual-prior variants:
one ``f @ w`` per vocab tile feeds BOTH adjusted LSE streams (eq. 14's
P_s and eq. 15's P_k), and the fused backward shares the recomputed
logits between the two softmax cotangents — two (m, s, ll) scratch
streams in the forward, two df outputs in one pass in the backward.

Validated against :mod:`repro.kernels.lace.ref` in interpret mode (CPU);
on TPU the same ``pallas_call``s lower to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import interp

NEG_INF = -1e30


def _fwd_kernel(feats_ref, w_ref, labels_ref, lp_ref, nll_ref, lse_ref,
                m_scr, s_scr, ll_scr, *, vb: int, nvb: int, tau: float):
    v = pl.program_id(1)

    @pl.when(v == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        s_scr[...] = jnp.zeros_like(s_scr)
        ll_scr[...] = jnp.zeros_like(ll_scr)

    f = feats_ref[...].astype(jnp.float32)          # (TB, d)
    w = w_ref[...].astype(jnp.float32)              # (d, VB)
    z = f @ w                                       # (TB, VB)
    z = z + tau * lp_ref[...].astype(jnp.float32)[None, :]

    labels = labels_ref[...]                        # (TB,)
    col = jax.lax.broadcasted_iota(jnp.int32, z.shape, 1) + v * vb
    ll_scr[...] += jnp.sum(
        jnp.where(col == labels[:, None], z, 0.0), axis=1)

    m_old = m_scr[...]
    m_new = jnp.maximum(m_old, jnp.max(z, axis=1))
    s_scr[...] = s_scr[...] * jnp.exp(m_old - m_new) + jnp.sum(
        jnp.exp(z - m_new[:, None]), axis=1)
    m_scr[...] = m_new

    @pl.when(v == nvb - 1)
    def _finish():
        lse = m_scr[...] + jnp.log(s_scr[...])
        lse_ref[...] = lse
        nll_ref[...] = lse - ll_scr[...]


def _bwd_dfeats_kernel(feats_ref, w_ref, labels_ref, lp_ref, lse_ref,
                       gw_ref, df_ref, *, vb: int, nvb: int, tau: float):
    v = pl.program_id(1)

    @pl.when(v == 0)
    def _init():
        df_ref[...] = jnp.zeros_like(df_ref)

    f = feats_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    z = f @ w + tau * lp_ref[...].astype(jnp.float32)[None, :]
    p = jnp.exp(z - lse_ref[...][:, None])
    labels = labels_ref[...]
    col = jax.lax.broadcasted_iota(jnp.int32, z.shape, 1) + v * vb
    g = (p - (col == labels[:, None]).astype(jnp.float32))
    g = g * gw_ref[...][:, None]                    # per-token weight*scale
    df_ref[...] += (g @ w.T).astype(df_ref.dtype)


def _bwd_dw_kernel(feats_ref, w_ref, labels_ref, lp_ref, lse_ref,
                   gw_ref, dw_ref, *, vb: int, ntb: int, tau: float):
    t = pl.program_id(1)
    v = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        dw_ref[...] = jnp.zeros_like(dw_ref)

    f = feats_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    z = f @ w + tau * lp_ref[...].astype(jnp.float32)[None, :]
    p = jnp.exp(z - lse_ref[...][:, None])
    labels = labels_ref[...]
    col = jax.lax.broadcasted_iota(jnp.int32, z.shape, 1) + v * vb
    g = (p - (col == labels[:, None]).astype(jnp.float32))
    g = g * gw_ref[...][:, None]
    dw_ref[...] += (f.T @ g).astype(dw_ref.dtype)


def _pad_to(x, size, axis, value=0):
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def lace_fwd_pallas(feats, w_head, labels, log_prior, *, tau: float = 1.0,
                    tb: int = 128, vb: int = 256, interpret: bool = True):
    """feats (N,d), w_head (d,V), labels (N,), log_prior (V,) ->
    (nll (N,), lse (N,)). Single prior row; vmap for groups."""
    N, d = feats.shape
    V = w_head.shape[1]
    Np = ((N + tb - 1) // tb) * tb
    Vp = ((V + vb - 1) // vb) * vb
    feats_p = _pad_to(feats, Np, 0)
    labels_p = _pad_to(labels, Np, 0, value=-1)
    w_p = _pad_to(w_head, Vp, 1)
    lp_p = _pad_to(log_prior, Vp, 0, value=NEG_INF)
    ntb, nvb = Np // tb, Vp // vb

    nll, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, vb=vb, nvb=nvb, tau=tau),
        grid=(ntb, nvb),
        in_specs=[
            pl.BlockSpec((tb, d), lambda t, v: (t, 0)),
            pl.BlockSpec((d, vb), lambda t, v: (0, v)),
            pl.BlockSpec((tb,), lambda t, v: (t,)),
            pl.BlockSpec((vb,), lambda t, v: (v,)),
        ],
        out_specs=[
            pl.BlockSpec((tb,), lambda t, v: (t,)),
            pl.BlockSpec((tb,), lambda t, v: (t,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np,), jnp.float32),
            jax.ShapeDtypeStruct((Np,), jnp.float32),
        ],
        scratch_shapes=_scratch3(tb),
        interpret=interp.resolve(interpret),
    )(feats_p, w_p, labels_p, lp_p)
    return nll[:N], lse[:N]


def _scratch3(tb):
    from jax.experimental.pallas import tpu as pltpu
    return [pltpu.VMEM((tb,), jnp.float32) for _ in range(3)]


def lace_bwd_pallas(feats, w_head, labels, log_prior, lse, token_scale, *,
                    tau: float = 1.0, tb: int = 128, vb: int = 256,
                    interpret: bool = True):
    """token_scale (N,): weight_i * g / w_sum. Returns (dfeats, dW) f32."""
    N, d = feats.shape
    V = w_head.shape[1]
    Np = ((N + tb - 1) // tb) * tb
    Vp = ((V + vb - 1) // vb) * vb
    feats_p = _pad_to(feats, Np, 0)
    labels_p = _pad_to(labels, Np, 0, value=-1)
    w_p = _pad_to(w_head, Vp, 1)
    lp_p = _pad_to(log_prior, Vp, 0, value=NEG_INF)
    lse_p = _pad_to(lse, Np, 0, value=0.0)
    gw_p = _pad_to(token_scale, Np, 0, value=0.0)
    ntb, nvb = Np // tb, Vp // vb

    df = pl.pallas_call(
        functools.partial(_bwd_dfeats_kernel, vb=vb, nvb=nvb, tau=tau),
        grid=(ntb, nvb),
        in_specs=[
            pl.BlockSpec((tb, d), lambda t, v: (t, 0)),
            pl.BlockSpec((d, vb), lambda t, v: (0, v)),
            pl.BlockSpec((tb,), lambda t, v: (t,)),
            pl.BlockSpec((vb,), lambda t, v: (v,)),
            pl.BlockSpec((tb,), lambda t, v: (t,)),
            pl.BlockSpec((tb,), lambda t, v: (t,)),
        ],
        out_specs=pl.BlockSpec((tb, d), lambda t, v: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, d), jnp.float32),
        interpret=interp.resolve(interpret),
    )(feats_p, w_p, labels_p, lp_p, lse_p, gw_p)

    dw = pl.pallas_call(
        functools.partial(_bwd_dw_kernel, vb=vb, ntb=ntb, tau=tau),
        grid=(nvb, ntb),
        in_specs=[
            pl.BlockSpec((tb, d), lambda v, t: (t, 0)),
            pl.BlockSpec((d, vb), lambda v, t: (0, v)),
            pl.BlockSpec((tb,), lambda v, t: (t,)),
            pl.BlockSpec((vb,), lambda v, t: (v,)),
            pl.BlockSpec((tb,), lambda v, t: (t,)),
            pl.BlockSpec((tb,), lambda v, t: (t,)),
        ],
        out_specs=pl.BlockSpec((d, vb), lambda v, t: (0, v)),
        out_shape=jax.ShapeDtypeStruct((d, Vp), jnp.float32),
        interpret=interp.resolve(interpret),
    )(feats_p, w_p, labels_p, lp_p, lse_p, gw_p)
    return df[:N], dw[:, :V]


# ---------------------------------------------------------------------------
# lace2 — fused dual-prior kernels (one z tile, two LSE streams)
# ---------------------------------------------------------------------------


def _fwd2_kernel(feats_ref, w_ref, labels_ref, lps_ref, lpk_ref,
                 nlls_ref, nllk_ref, lses_ref, lsek_ref,
                 ms_scr, ss_scr, lls_scr, mk_scr, sk_scr, llk_scr,
                 *, vb: int, nvb: int, tau: float):
    v = pl.program_id(1)

    @pl.when(v == 0)
    def _init():
        for m_scr, s_scr, ll_scr in ((ms_scr, ss_scr, lls_scr),
                                     (mk_scr, sk_scr, llk_scr)):
            m_scr[...] = jnp.full_like(m_scr, NEG_INF)
            s_scr[...] = jnp.zeros_like(s_scr)
            ll_scr[...] = jnp.zeros_like(ll_scr)

    f = feats_ref[...].astype(jnp.float32)          # (TB, d)
    w = w_ref[...].astype(jnp.float32)              # (d, VB)
    zb = f @ w                                      # ONE matmul per tile
    labels = labels_ref[...]
    col = jax.lax.broadcasted_iota(jnp.int32, zb.shape, 1) + v * vb
    hit = col == labels[:, None]

    for lp_ref, m_scr, s_scr, ll_scr in (
            (lps_ref, ms_scr, ss_scr, lls_scr),
            (lpk_ref, mk_scr, sk_scr, llk_scr)):
        z = zb + tau * lp_ref[...].astype(jnp.float32)[None, :]
        ll_scr[...] += jnp.sum(jnp.where(hit, z, 0.0), axis=1)
        m_old = m_scr[...]
        m_new = jnp.maximum(m_old, jnp.max(z, axis=1))
        s_scr[...] = s_scr[...] * jnp.exp(m_old - m_new) + jnp.sum(
            jnp.exp(z - m_new[:, None]), axis=1)
        m_scr[...] = m_new

    @pl.when(v == nvb - 1)
    def _finish():
        for m_scr, s_scr, ll_scr, lse_ref, nll_ref in (
                (ms_scr, ss_scr, lls_scr, lses_ref, nlls_ref),
                (mk_scr, sk_scr, llk_scr, lsek_ref, nllk_ref)):
            lse = m_scr[...] + jnp.log(s_scr[...])
            lse_ref[...] = lse
            nll_ref[...] = lse - ll_scr[...]


def _bwd2_dfeats_kernel(feats_ref, w_ref, labels_ref, lps_ref, lpk_ref,
                        lses_ref, lsek_ref, gws_ref, gwk_ref,
                        dfs_ref, dfk_ref, *, vb: int, nvb: int, tau: float):
    v = pl.program_id(1)

    @pl.when(v == 0)
    def _init():
        dfs_ref[...] = jnp.zeros_like(dfs_ref)
        dfk_ref[...] = jnp.zeros_like(dfk_ref)

    f = feats_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    zb = f @ w                                      # ONE matmul per tile
    labels = labels_ref[...]
    col = jax.lax.broadcasted_iota(jnp.int32, zb.shape, 1) + v * vb
    onehot = (col == labels[:, None]).astype(jnp.float32)

    for lp_ref, lse_ref, gw_ref, df_ref in (
            (lps_ref, lses_ref, gws_ref, dfs_ref),
            (lpk_ref, lsek_ref, gwk_ref, dfk_ref)):
        z = zb + tau * lp_ref[...].astype(jnp.float32)[None, :]
        p = jnp.exp(z - lse_ref[...][:, None])
        g = (p - onehot) * gw_ref[...][:, None]
        df_ref[...] += (g @ w.T).astype(df_ref.dtype)


def lace2_fwd_pallas(feats, w_head, labels, log_prior_s, log_prior_k, *,
                     tau: float = 1.0, tb: int = 128, vb: int = 256,
                     interpret: bool = True):
    """Both adjusted NLL/LSE streams from one logits pass.

    feats (N,d), w_head (d,V), labels (N,), log_prior_s/_k (V,) ->
    (nll_s, nll_k, lse_s, lse_k), each (N,). Single prior row per side;
    vmap for groups (per-client P_k rows become the mapped axis).
    """
    N, d = feats.shape
    V = w_head.shape[1]
    Np = ((N + tb - 1) // tb) * tb
    Vp = ((V + vb - 1) // vb) * vb
    feats_p = _pad_to(feats, Np, 0)
    labels_p = _pad_to(labels, Np, 0, value=-1)
    w_p = _pad_to(w_head, Vp, 1)
    lps_p = _pad_to(log_prior_s, Vp, 0, value=NEG_INF)
    lpk_p = _pad_to(log_prior_k, Vp, 0, value=NEG_INF)
    ntb, nvb = Np // tb, Vp // vb

    nll_s, nll_k, lse_s, lse_k = pl.pallas_call(
        functools.partial(_fwd2_kernel, vb=vb, nvb=nvb, tau=tau),
        grid=(ntb, nvb),
        in_specs=[
            pl.BlockSpec((tb, d), lambda t, v: (t, 0)),
            pl.BlockSpec((d, vb), lambda t, v: (0, v)),
            pl.BlockSpec((tb,), lambda t, v: (t,)),
            pl.BlockSpec((vb,), lambda t, v: (v,)),
            pl.BlockSpec((vb,), lambda t, v: (v,)),
        ],
        out_specs=[pl.BlockSpec((tb,), lambda t, v: (t,))
                   for _ in range(4)],
        out_shape=[jax.ShapeDtypeStruct((Np,), jnp.float32)
                   for _ in range(4)],
        scratch_shapes=_scratch3(tb) + _scratch3(tb),
        interpret=interp.resolve(interpret),
    )(feats_p, w_p, labels_p, lps_p, lpk_p)
    return nll_s[:N], nll_k[:N], lse_s[:N], lse_k[:N]


def lace2_bwd_pallas(feats, w_head, labels, log_prior_s, log_prior_k,
                     lse_s, lse_k, token_scale_s, token_scale_k, *,
                     tau: float = 1.0, tb: int = 128, vb: int = 256,
                     interpret: bool = True):
    """Fused dual backward: (df_s, df_k, dW_s), all f32.

    token_scale_s/_k (N,): per-token ``weight_i * cotangent_side`` — the
    two sides may carry different loss cotangents. The df pass shares one
    recomputed logits tile between both softmax cotangents; dW is emitted
    for the server side only (the split step discards the client head
    grad), reusing the single-prior dW kernel.
    """
    N, d = feats.shape
    V = w_head.shape[1]
    Np = ((N + tb - 1) // tb) * tb
    Vp = ((V + vb - 1) // vb) * vb
    feats_p = _pad_to(feats, Np, 0)
    labels_p = _pad_to(labels, Np, 0, value=-1)
    w_p = _pad_to(w_head, Vp, 1)
    lps_p = _pad_to(log_prior_s, Vp, 0, value=NEG_INF)
    lpk_p = _pad_to(log_prior_k, Vp, 0, value=NEG_INF)
    lses_p = _pad_to(lse_s, Np, 0, value=0.0)
    lsek_p = _pad_to(lse_k, Np, 0, value=0.0)
    gws_p = _pad_to(token_scale_s, Np, 0, value=0.0)
    gwk_p = _pad_to(token_scale_k, Np, 0, value=0.0)
    ntb, nvb = Np // tb, Vp // vb

    df_s, df_k = pl.pallas_call(
        functools.partial(_bwd2_dfeats_kernel, vb=vb, nvb=nvb, tau=tau),
        grid=(ntb, nvb),
        in_specs=[
            pl.BlockSpec((tb, d), lambda t, v: (t, 0)),
            pl.BlockSpec((d, vb), lambda t, v: (0, v)),
            pl.BlockSpec((tb,), lambda t, v: (t,)),
            pl.BlockSpec((vb,), lambda t, v: (v,)),
            pl.BlockSpec((vb,), lambda t, v: (v,)),
            pl.BlockSpec((tb,), lambda t, v: (t,)),
            pl.BlockSpec((tb,), lambda t, v: (t,)),
            pl.BlockSpec((tb,), lambda t, v: (t,)),
            pl.BlockSpec((tb,), lambda t, v: (t,)),
        ],
        out_specs=[pl.BlockSpec((tb, d), lambda t, v: (t, 0)),
                   pl.BlockSpec((tb, d), lambda t, v: (t, 0))],
        out_shape=[jax.ShapeDtypeStruct((Np, d), jnp.float32),
                   jax.ShapeDtypeStruct((Np, d), jnp.float32)],
        interpret=interp.resolve(interpret),
    )(feats_p, w_p, labels_p, lps_p, lpk_p, lses_p, lsek_p, gws_p, gwk_p)

    dw_s = pl.pallas_call(
        functools.partial(_bwd_dw_kernel, vb=vb, ntb=ntb, tau=tau),
        grid=(nvb, ntb),
        in_specs=[
            pl.BlockSpec((tb, d), lambda v, t: (t, 0)),
            pl.BlockSpec((d, vb), lambda v, t: (0, v)),
            pl.BlockSpec((tb,), lambda v, t: (t,)),
            pl.BlockSpec((vb,), lambda v, t: (v,)),
            pl.BlockSpec((tb,), lambda v, t: (t,)),
            pl.BlockSpec((tb,), lambda v, t: (t,)),
        ],
        out_specs=pl.BlockSpec((d, vb), lambda v, t: (0, v)),
        out_shape=jax.ShapeDtypeStruct((d, Vp), jnp.float32),
        interpret=interp.resolve(interpret),
    )(feats_p, w_p, labels_p, lps_p, lses_p, gws_p)
    return df_s[:N], df_k[:N], dw_s[:, :V]
