"""Pure-jnp oracle for the fused Logit-Adjusted Cross-Entropy (LACE).

Semantics (paper eqs. 14/15): per token i with features f_i, head weight
W (d, V), label y_i, prior row P[pid_i] and temperature tau,

    z_i   = f_i @ W + tau * log(P[pid_i] + eps)      (adjusted logits)
    nll_i = logsumexp(z_i) - z_i[y_i]
    loss  = sum_i w_i nll_i / sum_i w_i

This oracle materializes the full (N, V) logits — correct but memory-
hungry; it exists to validate the chunked ops and the Pallas kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lace_ref(feats, w_head, labels, *, prior_rows=None, prior_ids=None,
             tau: float = 1.0, weights=None, eps: float = 1e-8):
    """feats: (N, d); w_head: (d, V); labels: (N,) int;
    prior_rows: (K, V) or None; prior_ids: (N,) int into prior_rows.
    Returns scalar f32 loss."""
    z = (feats.astype(jnp.float32) @ w_head.astype(jnp.float32))
    if prior_rows is not None:
        lp = jnp.log(prior_rows.astype(jnp.float32) + eps)
        if prior_ids is None:
            adj = lp[0]
        else:
            adj = lp[prior_ids]
        z = z + tau * adj
    lse = jax.scipy.special.logsumexp(z, axis=-1)
    ll = jnp.take_along_axis(z, labels[:, None], axis=-1)[:, 0]
    nll = lse - ll
    if weights is None:
        return nll.mean()
    w = weights.astype(jnp.float32)
    return (nll * w).sum() / jnp.maximum(w.sum(), 1e-8)
