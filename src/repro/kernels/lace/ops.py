"""LACE — fused Logit-Adjusted Cross-Entropy, production ops.

``lace_loss`` computes the paper's adjusted CE (eqs. 14/15) **without
materializing the (N, V) logits**: a custom-vjp whose forward and
backward scan over token chunks, keeping only (G, chunk, V) logits live.
Inside each chunk the label log-prob is picked with an iota-mask (not a
gather), so vocab-sharded logits never force an all-gather under GSPMD.

Shapes: feats (G, N, d) — G parallel groups (SCALA clients) sharded over
the data axis, N tokens per group chunked sequentially; w_head (d, V);
labels/weights (G, N); prior_rows (K, V) with prior_ids (G,) selecting
each group's prior row (server loss: K=1; client loss: K=G). The ops are
shape-polymorphic in G — the sparse-slot and in-shard-gather paths call
them with a *gathered* subset of the client groups (G = cohort or the
shard-local K_active), with ``prior_ids`` indexing the gathered priors —
so group-axis mismatches are validated statically up front
(:func:`_check_args`) instead of broadcasting silently.

``impl='pallas'`` routes the inner chunk computation to the TPU kernel in
:mod:`repro.kernels.lace.kernel` (validated in interpret mode on CPU).

``lace2_*`` (bottom of this module) is the fused dual-prior boundary:
both SCALA losses (eq. 14 with P_s, eq. 15 with P_k) and their combined
VJP from ONE ``feats @ w_head`` product per chunk — see the section
banner below for the three entry points and the bitwise discipline.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _pick_chunk(n: int, target: int) -> int:
    """The scan chunk along the token axis: always ``min(n, target)``.

    Non-divisible N is handled by padding the token axis up to the next
    chunk multiple with zero-weight tokens (:func:`_pad_tokens`) — NOT
    by shrinking the chunk, which used to degrade to chunk=1 for prime
    N (one (G, 1, V) matmul per token)."""
    return min(n, target)


def _pad_tokens(c, feats, labels, weights):
    """Pad the token axis to the next multiple of the chunk ``c``.

    Padding tokens carry weight 0 and label 0: they contribute exactly
    zero to the weighted NLL sum, the weight sum, and every gradient
    (the backward's per-token cotangent is scaled by the weight), so
    loss values and grads match the unpadded math. Returns
    (feats, labels, weights, n_orig) — ``weights`` materialized even
    when the caller passed None, so the zero-weight rows are explicit.
    """
    G, N, _ = feats.shape
    if weights is None:
        weights = jnp.ones((G, N), jnp.float32)
    pad = (-N) % c
    if pad:
        feats = jnp.pad(feats, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        weights = jnp.pad(weights, ((0, 0), (0, pad)))
    return feats, labels, weights, N


def _check_args(feats, w_head, labels, prior_rows, prior_ids, weights):
    """Static shape validation for the fused ops.

    The group axis G varies call-to-call (full K, a gathered cohort, a
    shard-local subset), and numpy broadcasting would happily accept a
    (K,)-sized ``prior_ids`` against cohort-sized feats — producing
    wrong per-group adjustments with no error. Fail loudly instead.
    """
    if feats.ndim != 3:
        raise ValueError(f"feats must be (G, N, d), got {feats.shape}")
    G, N, d = feats.shape
    if w_head.ndim != 2 or w_head.shape[0] != d:
        raise ValueError(f"w_head must be (d={d}, V), got {w_head.shape}")
    if labels.shape != (G, N):
        raise ValueError(f"labels must be (G, N)=({G}, {N}), got "
                         f"{labels.shape}")
    if weights is not None and weights.shape != (G, N):
        raise ValueError(f"weights must be (G, N)=({G}, {N}), got "
                         f"{weights.shape}")
    if prior_ids is not None:
        if prior_rows is None:
            raise ValueError("prior_ids given without prior_rows")
        if prior_ids.shape != (G,):
            raise ValueError(
                f"prior_ids must be (G,)=({G},), got {prior_ids.shape} — "
                "gathered-group callers must gather the prior ids (or "
                "rows) alongside the feats")
    if prior_rows is not None and prior_rows.shape[-1] != w_head.shape[1]:
        raise ValueError(f"prior_rows vocab dim {prior_rows.shape[-1]} != "
                         f"head vocab dim {w_head.shape[1]}")


def _chunk_logits(f_c, w_head, lp_c, tau):
    """f_c: (G, c, d); w_head: (d, V); lp_c: (G, 1, V) or None."""
    z = jnp.einsum("gcd,dv->gcv", f_c.astype(jnp.float32),
                   w_head.astype(jnp.float32))
    if lp_c is not None:
        z = z + tau * lp_c
    return z


def _nll_from_logits(z, labels_c):
    """z: (G,c,V); labels: (G,c). iota-mask label pick (gather-free)."""
    m = jnp.max(z, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(z - m), axis=-1)) + m[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, z.shape, 2)
    ll = jnp.sum(jnp.where(iota == labels_c[..., None], z, 0.0), axis=-1)
    return lse - ll


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def lace_loss(feats, w_head, labels, prior_rows, prior_ids, weights,
              tau: float = 1.0, eps: float = 1e-8, chunk: int = 4096):
    loss, _ = _lace_fwd(feats, w_head, labels, prior_rows, prior_ids,
                        weights, tau, eps, chunk)
    return loss


def _prep(feats, labels, prior_rows, prior_ids, weights, tau, eps):
    G, N, d = feats.shape
    if weights is None:
        weights = jnp.ones((G, N), jnp.float32)
    if prior_rows is not None:
        lp_rows = jnp.log(prior_rows.astype(jnp.float32) + eps)
        if prior_ids is None:
            lp = jnp.broadcast_to(lp_rows[:1], (G,) + lp_rows.shape[1:])
        else:
            lp = lp_rows[prior_ids]                       # (G, V)
        lp = lp[:, None, :]                               # (G, 1, V)
    else:
        lp = None
    return weights, lp


def _fwd_impl(feats, w_head, labels, prior_rows, prior_ids, weights,
              tau, eps, chunk, mean):
    _check_args(feats, w_head, labels, prior_rows, prior_ids, weights)
    res_in = (feats, w_head, labels, prior_rows, prior_ids, weights)
    G, N0, d = feats.shape
    c = _pick_chunk(N0, chunk)
    feats, labels, weights, _ = _pad_tokens(c, feats, labels, weights)
    N = feats.shape[1]
    weights_f, lp = _prep(feats, labels, prior_rows, prior_ids, weights,
                          tau, eps)
    nc = N // c

    fc = feats.reshape(G, nc, c, d).swapaxes(0, 1)       # (nc, G, c, d)
    lc = labels.reshape(G, nc, c).swapaxes(0, 1)
    wc = weights_f.reshape(G, nc, c).swapaxes(0, 1)

    def body(carry, inp):
        nll_sum, w_sum = carry
        f_c, l_c, w_c = inp
        z = _chunk_logits(f_c, w_head, lp, tau)
        nll = _nll_from_logits(z, l_c)
        return (nll_sum + jnp.sum(nll * w_c), w_sum + jnp.sum(w_c)), None

    (nll_sum, w_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (fc, lc, wc))
    out = nll_sum / jnp.maximum(w_sum, 1e-8) if mean else nll_sum
    return out, res_in + (w_sum,)


def _bwd_impl(tau, eps, chunk, mean, res, g):
    feats, w_head, labels, prior_rows, prior_ids, weights, w_sum = res
    G, N0, d = feats.shape
    V = w_head.shape[1]
    c = _pick_chunk(N0, chunk)
    feats_p, labels_p, weights_p, _ = _pad_tokens(c, feats, labels, weights)
    N = feats_p.shape[1]
    weights_f, lp = _prep(feats_p, labels_p, prior_rows, prior_ids,
                          weights_p, tau, eps)
    nc = N // c

    fc = feats_p.reshape(G, nc, c, d).swapaxes(0, 1)
    lc = labels_p.reshape(G, nc, c).swapaxes(0, 1)
    wc = weights_f.reshape(G, nc, c).swapaxes(0, 1)
    scale = g / jnp.maximum(w_sum, 1e-8) if mean else g

    def body(dw, inp):
        f_c, l_c, w_c = inp
        z = _chunk_logits(f_c, w_head, lp, tau)
        m = jnp.max(z, axis=-1, keepdims=True)
        ez = jnp.exp(z - m)
        p = ez / jnp.sum(ez, axis=-1, keepdims=True)
        iota = jax.lax.broadcasted_iota(jnp.int32, z.shape, 2)
        onehot = (iota == l_c[..., None]).astype(jnp.float32)
        gi = (p - onehot) * (w_c * scale)[..., None]      # (G,c,V)
        df_c = jnp.einsum("gcv,dv->gcd", gi, w_head.astype(jnp.float32))
        dw = dw + jnp.einsum("gcd,gcv->dv", f_c.astype(jnp.float32), gi)
        return dw, df_c

    dw, dfc = jax.lax.scan(body, jnp.zeros((d, V), jnp.float32), (fc, lc, wc))
    dfeats = dfc.swapaxes(0, 1).reshape(G, N, d)[:, :N0].astype(feats.dtype)
    zeros_prior = (None if prior_rows is None
                   else jnp.zeros_like(prior_rows))
    f0 = lambda a: (None if a is None else
                    np.zeros(a.shape, jax.dtypes.float0)
                    if jnp.issubdtype(a.dtype, jnp.integer)
                    else jnp.zeros_like(a))
    return (dfeats, dw.astype(w_head.dtype), f0(labels), zeros_prior,
            f0(prior_ids), f0(weights))


def _lace_fwd(*a):
    return _fwd_impl(*a, True)


def _lace_bwd(tau, eps, chunk, res, g):
    return _bwd_impl(tau, eps, chunk, True, res, g)


lace_loss.defvjp(_lace_fwd, _lace_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def lace_nll_sum(feats, w_head, labels, prior_rows, prior_ids, weights,
                 tau: float = 1.0, eps: float = 1e-8, chunk: int = 4096):
    """Weighted *sum* of adjusted NLLs (no normalization) — the local term
    combined across shards by :func:`lace_loss_dp`."""
    out, _ = _fwd_impl(feats, w_head, labels, prior_rows, prior_ids,
                       weights, tau, eps, chunk, False)
    return out


def _lace_sum_fwd(*a):
    return _fwd_impl(*a, False)


def _lace_sum_bwd(tau, eps, chunk, res, g):
    return _bwd_impl(tau, eps, chunk, False, res, g)


lace_nll_sum.defvjp(_lace_sum_fwd, _lace_sum_bwd)


def lace_loss_dp(feats, w_head, labels, prior_rows, prior_ids, weights,
                 tau: float = 1.0, eps: float = 1e-8, chunk: int = 4096,
                 group_axes=("pod", "data"), token_axes=("model",)):
    """shard_map-wrapped LACE for the replicated-head ("dp") profile.

    Under GSPMD the chunked-CE backward re-all-reduces the (d, V)
    head-weight gradient partial on every chunk trip (§Perf iteration 3).
    Here the loss is computed per-shard on local tokens and combined with
    two scalar psums; the head-weight gradient is psummed exactly once by
    the shard_map transpose. Exact same value/grads as ``lace_loss``.

    feats (G, N, d) with G sharded over ``group_axes`` and N over
    ``token_axes``; w_head replicated. Falls back to ``lace_loss`` when
    there is no ambient mesh (CPU tests / host training).
    """
    from repro import compat

    mesh = compat.ambient_mesh()
    if mesh is None or not mesh.axis_names or compat.in_shard_map():
        return lace_loss(feats, w_head, labels, prior_rows, prior_ids,
                         weights, tau, eps, chunk)
    present = lambda axes: tuple(a for a in axes if a in mesh.axis_names)
    grp = present(group_axes)
    tok = present(token_axes)
    red = grp + tok
    if not red:
        return lace_loss(feats, w_head, labels, prior_rows, prior_ids,
                         weights, tau, eps, chunk)
    P = jax.sharding.PartitionSpec
    g_spec = grp if len(grp) > 1 else (grp[0] if grp else None)
    t_spec = tok if len(tok) > 1 else (tok[0] if tok else None)
    gt = P(g_spec, t_spec)
    gtd = P(g_spec, t_spec, None)

    per_client_prior = prior_ids is not None
    pr_spec = P(g_spec, None) if per_client_prior else P(None, None)

    def local(f_l, w_l, l_l, pr_l, wt_l):
        ids = (jnp.arange(f_l.shape[0]) if per_client_prior else None)
        nll = lace_nll_sum(f_l, w_l, l_l, pr_l, ids, wt_l, tau, eps, chunk)
        wsum = (jnp.sum(wt_l) if wt_l is not None
                else jnp.float32(l_l.size))
        return (jax.lax.psum(nll, red),
                jax.lax.psum(jnp.asarray(wsum, jnp.float32), red))

    in_specs = (gtd, P(None, None), gt,
                pr_spec if prior_rows is not None else P(),
                gt if weights is not None else P())
    fn = compat.shard_map(
        lambda f, w, l, pr, wt: local(
            f, w, l, pr if prior_rows is not None else None,
            wt if weights is not None else None),
        mesh=mesh, in_specs=in_specs, out_specs=(P(), P()),
        check_vma=False)  # scan carries start unvarying; values exact
    dummy = jnp.zeros((), jnp.float32)
    nll, wsum = fn(feats, w_head, labels,
                   prior_rows if prior_rows is not None else dummy[None, None],
                   weights if weights is not None else dummy[None, None])
    return nll / jnp.maximum(wsum, 1e-8)


# ---------------------------------------------------------------------------
# lace2 — fused dual-prior boundary (eq. 14 + eq. 15 in ONE pass)
# ---------------------------------------------------------------------------
#
# SCALA evaluates the adjusted CE twice per step: once with the server's
# concatenated prior P_s (eq. 14) and once with the per-client priors P_k
# (eq. 15). The two losses share everything except the prior shift: the
# ``feats @ w_head`` product, its transpose in the backward, and the
# per-chunk streaming machinery. ``lace2_*`` computes both NLLs (and the
# combined VJP) from ONE matmul per chunk — halving the dominant FLOPs.
#
# Three entry points:
#   * ``lace2_loss`` / ``lace2_nll_sum`` — custom-VJP pair ops returning
#     ``(out_s, out_k)``; the backward folds both cotangents into a single
#     ``dfeats``/``dw_head`` accumulation (one df matmul, one dW matmul).
#   * ``lace2_grads`` — direct value-and-grad for the engine's split step,
#     which needs the two feature cotangents SEPARATELY (they enter the
#     trunk pullback with different loss cotangents): returns
#     ``(out_s, out_k, df_s, df_k, dw_s, w_sum)`` in 4 matmuls where the
#     two-pass path spends 8.
#   * ``lace2_grads_dp`` — ambient-mesh shard-map wrapper mirroring
#     :func:`lace_loss_dp` (scalar psums + one dW psum).
#
# Bitwise discipline: every op below reuses the single-prior primitives
# (`_chunk_logits`-equivalent add order, `_nll_from_logits` reductions,
# `_bwd_impl`'s ``(w_c * scale)`` placement and accumulation order) so the
# fused f32 results are bit-identical to two independent lace calls.


def _check_args2(feats, w_head, labels, prior_rows_s, prior_ids_s,
                 prior_rows_k, prior_ids_k, weights):
    _check_args(feats, w_head, labels, prior_rows_s, prior_ids_s, weights)
    _check_args(feats, w_head, labels, prior_rows_k, prior_ids_k, weights)


def _prep2(feats, labels, prior_rows_s, prior_ids_s, prior_rows_k,
           prior_ids_k, weights, tau, eps):
    """Dual-prior variant of :func:`_prep`: one weights array, two lp."""
    weights, lp_s = _prep(feats, labels, prior_rows_s, prior_ids_s,
                          weights, tau, eps)
    _, lp_k = _prep(feats, labels, prior_rows_k, prior_ids_k,
                    weights, tau, eps)
    return weights, lp_s, lp_k


def _chunk_views(feats, labels, weights, c):
    """(G, N, ·) -> chunk-major (nc, G, c, ·) scan views."""
    G, N, d = feats.shape
    nc = N // c
    fc = feats.reshape(G, nc, c, d).swapaxes(0, 1)
    lc = labels.reshape(G, nc, c).swapaxes(0, 1)
    wc = weights.reshape(G, nc, c).swapaxes(0, 1)
    return fc, lc, wc, nc


def _w_sum_scan(wc):
    """Chunk-ordered weight-sum accumulation — same op sequence as the
    ``w_sum`` carry in :func:`_fwd_impl`, so the mean denominators (and
    the scales derived from them) are bit-identical to the two-pass path."""
    def body(ws, w_c):
        return ws + jnp.sum(w_c), None
    w_sum, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), wc)
    return w_sum


def _side_adjust(z_base, lp, tau):
    """Apply one prior's shift — the same ``z + tau * lp`` as
    :func:`_chunk_logits` (identity when the side has no prior)."""
    return z_base if lp is None else z_base + tau * lp


def _side_nll_stats(z, l_c):
    """max/exp/sum stats shared between the NLL value and softmax grads.

    Value path matches :func:`_nll_from_logits` op-for-op; ``ez``/``se``
    are reused by the backward's softmax (as in :func:`_bwd_impl`).
    """
    m = jnp.max(z, axis=-1, keepdims=True)
    ez = jnp.exp(z - m)
    se = jnp.sum(ez, axis=-1)
    lse = jnp.log(se) + m[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, z.shape, 2)
    onehot = (iota == l_c[..., None]).astype(jnp.float32)
    ll = jnp.sum(jnp.where(iota == l_c[..., None], z, 0.0), axis=-1)
    nll = lse - ll
    p = ez / se[..., None]
    return nll, p, onehot


def _fwd2_impl(feats, w_head, labels, prior_rows_s, prior_ids_s,
               prior_rows_k, prior_ids_k, weights, tau, eps, chunk, mean):
    _check_args2(feats, w_head, labels, prior_rows_s, prior_ids_s,
                 prior_rows_k, prior_ids_k, weights)
    res_in = (feats, w_head, labels, prior_rows_s, prior_ids_s,
              prior_rows_k, prior_ids_k, weights)
    G, N0, d = feats.shape
    c = _pick_chunk(N0, chunk)
    feats_p, labels_p, weights_p, _ = _pad_tokens(c, feats, labels, weights)
    weights_f, lp_s, lp_k = _prep2(feats_p, labels_p, prior_rows_s,
                                   prior_ids_s, prior_rows_k, prior_ids_k,
                                   weights_p, tau, eps)
    fc, lc, wc, _ = _chunk_views(feats_p, labels_p, weights_f, c)

    def body(carry, inp):
        nll_s_sum, nll_k_sum, w_sum = carry
        f_c, l_c, w_c = inp
        z = _chunk_logits(f_c, w_head, None, tau)        # ONE matmul
        nll_s = _nll_from_logits(_side_adjust(z, lp_s, tau), l_c)
        nll_k = _nll_from_logits(_side_adjust(z, lp_k, tau), l_c)
        return (nll_s_sum + jnp.sum(nll_s * w_c),
                nll_k_sum + jnp.sum(nll_k * w_c),
                w_sum + jnp.sum(w_c)), None

    zero = jnp.zeros((), jnp.float32)
    (nll_s_sum, nll_k_sum, w_sum), _ = jax.lax.scan(
        body, (zero, zero, zero), (fc, lc, wc))
    if mean:
        den = jnp.maximum(w_sum, 1e-8)
        out = (nll_s_sum / den, nll_k_sum / den)
    else:
        out = (nll_s_sum, nll_k_sum)
    return out, res_in + (w_sum,)


def _bwd2_impl(tau, eps, chunk, mean, res, g):
    (feats, w_head, labels, prior_rows_s, prior_ids_s, prior_rows_k,
     prior_ids_k, weights, w_sum) = res
    g_s, g_k = g
    G, N0, d = feats.shape
    V = w_head.shape[1]
    c = _pick_chunk(N0, chunk)
    feats_p, labels_p, weights_p, _ = _pad_tokens(c, feats, labels, weights)
    N = feats_p.shape[1]
    weights_f, lp_s, lp_k = _prep2(feats_p, labels_p, prior_rows_s,
                                   prior_ids_s, prior_rows_k, prior_ids_k,
                                   weights_p, tau, eps)
    fc, lc, wc, _ = _chunk_views(feats_p, labels_p, weights_f, c)
    den = jnp.maximum(w_sum, 1e-8)
    scale_s = g_s / den if mean else g_s
    scale_k = g_k / den if mean else g_k

    def body(dw, inp):
        f_c, l_c, w_c = inp
        z = _chunk_logits(f_c, w_head, None, tau)        # ONE matmul
        _, p_s, onehot = _side_nll_stats(_side_adjust(z, lp_s, tau), l_c)
        _, p_k, _ = _side_nll_stats(_side_adjust(z, lp_k, tau), l_c)
        gi = ((p_s - onehot) * (w_c * scale_s)[..., None]
              + (p_k - onehot) * (w_c * scale_k)[..., None])
        df_c = jnp.einsum("gcv,dv->gcd", gi, w_head.astype(jnp.float32))
        dw = dw + jnp.einsum("gcd,gcv->dv", f_c.astype(jnp.float32), gi)
        return dw, df_c

    dw, dfc = jax.lax.scan(body, jnp.zeros((d, V), jnp.float32), (fc, lc, wc))
    dfeats = dfc.swapaxes(0, 1).reshape(G, N, d)[:, :N0].astype(feats.dtype)
    f0 = lambda a: (None if a is None else
                    np.zeros(a.shape, jax.dtypes.float0)
                    if jnp.issubdtype(a.dtype, jnp.integer)
                    else jnp.zeros_like(a))
    zp = lambda a: None if a is None else jnp.zeros_like(a)
    return (dfeats, dw.astype(w_head.dtype), f0(labels), zp(prior_rows_s),
            f0(prior_ids_s), zp(prior_rows_k), f0(prior_ids_k), f0(weights))


@functools.partial(jax.custom_vjp, nondiff_argnums=(8, 9, 10))
def lace2_loss(feats, w_head, labels, prior_rows_s, prior_ids_s,
               prior_rows_k, prior_ids_k, weights,
               tau: float = 1.0, eps: float = 1e-8, chunk: int = 4096):
    """Both weighted-mean adjusted NLLs from one matmul per chunk.

    Returns ``(loss_s, loss_k)`` — the eq. 14 (prior ``_s``) and eq. 15
    (prior ``_k``) losses over the SAME feats/labels/weights. Either
    prior may be None (plain CE for that side). The custom backward
    folds both cotangents into one ``dfeats``/``dw_head`` accumulation.
    """
    out, _ = _lace2_fwd(feats, w_head, labels, prior_rows_s, prior_ids_s,
                        prior_rows_k, prior_ids_k, weights, tau, eps, chunk)
    return out


def _lace2_fwd(*a):
    return _fwd2_impl(*a, True)


def _lace2_bwd(tau, eps, chunk, res, g):
    return _bwd2_impl(tau, eps, chunk, True, res, g)


lace2_loss.defvjp(_lace2_fwd, _lace2_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(8, 9, 10))
def lace2_nll_sum(feats, w_head, labels, prior_rows_s, prior_ids_s,
                  prior_rows_k, prior_ids_k, weights,
                  tau: float = 1.0, eps: float = 1e-8, chunk: int = 4096):
    """Weighted *sums* of both adjusted NLLs (no normalization) — the
    local pair combined across shards by the dp paths."""
    out, _ = _fwd2_impl(feats, w_head, labels, prior_rows_s, prior_ids_s,
                        prior_rows_k, prior_ids_k, weights, tau, eps,
                        chunk, False)
    return out


def _lace2_sum_fwd(*a):
    return _fwd2_impl(*a, False)


def _lace2_sum_bwd(tau, eps, chunk, res, g):
    return _bwd2_impl(tau, eps, chunk, False, res, g)


lace2_nll_sum.defvjp(_lace2_sum_fwd, _lace2_sum_bwd)


def lace2_grads(feats, w_head, labels, prior_rows_s, prior_ids_s,
                prior_rows_k, prior_ids_k, weights,
                tau: float = 1.0, eps: float = 1e-8, chunk: int = 4096,
                mean: bool = True, scale: Optional[jax.Array] = None):
    """One-pass values AND grads for the engine's split boundary.

    The split step needs the two feature cotangents SEPARATELY (the
    server/client trunk pullbacks take different loss cotangents), so the
    pair ops' combined backward doesn't fit; this direct form computes,
    in a single scan with ONE logits matmul per chunk::

        out_s, out_k      eq. 14 / eq. 15 losses (mean or raw sums)
        df_s, df_k        d out_side / d feats (unit cotangent)
        dw_s              d out_s / d w_head (server side only — the
                          two-pass engine discards the client head grad)
        w_sum             the weight denominator (chunk-ordered)

    With ``mean=True`` each side's grads carry the ``1 / max(w_sum, eps)``
    scale exactly where :func:`_bwd_impl` applies it. ``scale`` overrides
    the per-token scale for both sides (dp callers pass ``1 / w_global``);
    ``mean=False, scale=None`` yields unit-cotangent raw-sum grads, the
    contract of the engine's ``lace_dp`` branch. 4 matmul-equivalents vs.
    8 for the two-pass path.
    """
    _check_args2(feats, w_head, labels, prior_rows_s, prior_ids_s,
                 prior_rows_k, prior_ids_k, weights)
    G, N0, d = feats.shape
    V = w_head.shape[1]
    c = _pick_chunk(N0, chunk)
    feats_p, labels_p, weights_p, _ = _pad_tokens(c, feats, labels, weights)
    N = feats_p.shape[1]
    weights_f, lp_s, lp_k = _prep2(feats_p, labels_p, prior_rows_s,
                                   prior_ids_s, prior_rows_k, prior_ids_k,
                                   weights_p, tau, eps)
    fc, lc, wc, _ = _chunk_views(feats_p, labels_p, weights_f, c)
    w_sum = _w_sum_scan(wc)
    if scale is None:
        one = jnp.ones((), jnp.float32)
        scale = one / jnp.maximum(w_sum, 1e-8) if mean else one

    def body(carry, inp):
        nll_s_sum, nll_k_sum, dw = carry
        f_c, l_c, w_c = inp
        z = _chunk_logits(f_c, w_head, None, tau)        # ONE matmul
        nll_s, p_s, onehot = _side_nll_stats(_side_adjust(z, lp_s, tau), l_c)
        nll_k, p_k, _ = _side_nll_stats(_side_adjust(z, lp_k, tau), l_c)
        gi_s = (p_s - onehot) * (w_c * scale)[..., None]
        gi_k = (p_k - onehot) * (w_c * scale)[..., None]
        w32 = w_head.astype(jnp.float32)
        df_s_c = jnp.einsum("gcv,dv->gcd", gi_s, w32)
        df_k_c = jnp.einsum("gcv,dv->gcd", gi_k, w32)
        dw = dw + jnp.einsum("gcd,gcv->dv", f_c.astype(jnp.float32), gi_s)
        return (nll_s_sum + jnp.sum(nll_s * w_c),
                nll_k_sum + jnp.sum(nll_k * w_c), dw), (df_s_c, df_k_c)

    zero = jnp.zeros((), jnp.float32)
    (nll_s_sum, nll_k_sum, dw), (dfc_s, dfc_k) = jax.lax.scan(
        body, (zero, zero, jnp.zeros((d, V), jnp.float32)), (fc, lc, wc))
    unchunk = lambda a: (a.swapaxes(0, 1).reshape(G, N, d)[:, :N0]
                         .astype(feats.dtype))
    if mean:
        den = jnp.maximum(w_sum, 1e-8)
        out_s, out_k = nll_s_sum / den, nll_k_sum / den
    else:
        out_s, out_k = nll_s_sum, nll_k_sum
    return (out_s, out_k, unchunk(dfc_s), unchunk(dfc_k),
            dw.astype(w_head.dtype), w_sum)


def lace2_grads_dp(feats, w_head, labels, prior_rows_s, prior_ids_s,
                   prior_rows_k, prior_ids_k, weights,
                   tau: float = 1.0, eps: float = 1e-8, chunk: int = 4096,
                   group_axes=("pod", "data"), token_axes=("model",)):
    """Ambient-mesh fused boundary, mirroring :func:`lace_loss_dp`.

    Per-shard :func:`lace2_grads` over local tokens, combined with scalar
    psums for the losses/denominator and ONE dW psum (vs. the per-chunk
    re-all-reduce GSPMD emits for the chunked backward). ``df_s``/``df_k``
    stay shard-local, matching the sharded feats. Falls back to
    :func:`lace2_grads` when there is no ambient mesh (where bitwise
    parity with the two-pass path is test-enforced); under a mesh the
    grads are the mathematically exact global-mean grads via explicit
    psums.
    """
    from repro import compat

    mesh = compat.ambient_mesh()
    if mesh is None or not mesh.axis_names or compat.in_shard_map():
        out = lace2_grads(feats, w_head, labels, prior_rows_s, prior_ids_s,
                          prior_rows_k, prior_ids_k, weights, tau, eps, chunk)
        return out[:5]
    present = lambda axes: tuple(a for a in axes if a in mesh.axis_names)
    grp = present(group_axes)
    tok = present(token_axes)
    red = grp + tok
    if not red:
        out = lace2_grads(feats, w_head, labels, prior_rows_s, prior_ids_s,
                          prior_rows_k, prior_ids_k, weights, tau, eps, chunk)
        return out[:5]
    P = jax.sharding.PartitionSpec
    g_spec = grp if len(grp) > 1 else (grp[0] if grp else None)
    t_spec = tok if len(tok) > 1 else (tok[0] if tok else None)
    gt = P(g_spec, t_spec)
    gtd = P(g_spec, t_spec, None)

    has_s = prior_rows_s is not None
    has_k = prior_rows_k is not None
    per_client_k = prior_ids_k is not None
    ps_spec = P(None, None)
    pk_spec = P(g_spec, None) if per_client_k else P(None, None)

    def local(f_l, w_l, l_l, prs_l, prk_l, wt_l):
        ids_k = jnp.arange(f_l.shape[0]) if per_client_k else None
        nll_s, nll_k, df_s, df_k, dw_s, ws_l = lace2_grads(
            f_l, w_l, l_l, prs_l if has_s else None, None,
            prk_l if has_k else None, ids_k,
            wt_l, tau, eps, chunk, mean=False, scale=None)
        den = jnp.maximum(
            jax.lax.psum(jnp.asarray(ws_l, jnp.float32), red), 1e-8)
        inv = jnp.ones((), jnp.float32) / den
        # unit-cotangent raw-sum grads -> global-mean grads (linear rescale)
        rescale = lambda a: (a.astype(jnp.float32) * inv).astype(a.dtype)
        return (jax.lax.psum(nll_s, red) * inv,
                jax.lax.psum(nll_k, red) * inv,
                rescale(df_s), rescale(df_k),
                jax.lax.psum(rescale(dw_s).astype(jnp.float32),
                             red).astype(dw_s.dtype))

    dummy = jnp.zeros((), jnp.float32)
    in_specs = (gtd, P(None, None), gt,
                ps_spec if has_s else P(),
                pk_spec if has_k else P(),
                gt if weights is not None else P())
    fn = compat.shard_map(
        lambda f, w, l, prs, prk, wt: local(
            f, w, l, prs if has_s else None, prk if has_k else None,
            wt if weights is not None else None),
        mesh=mesh, in_specs=in_specs,
        out_specs=(P(), P(), gtd, gtd, P(None, None)),
        check_vma=False)  # scan carries start unvarying; values exact
    return fn(feats, w_head, labels,
              prior_rows_s if has_s else dummy[None, None],
              prior_rows_k if has_k else dummy[None, None],
              weights if weights is not None else dummy[None, None])


# ---------------------------------------------------------------------------
# convenience wrappers
# ---------------------------------------------------------------------------


def lace_loss_flat(feats, w_head, labels, *, prior_rows=None, prior_ids=None,
                   weights=None, tau: float = 1.0, eps: float = 1e-8,
                   chunk: int = 4096):
    """(N, d) single-group convenience wrapper."""
    f = feats[None]
    l = labels[None]
    w = None if weights is None else weights[None]
    ids = None if prior_ids is None else prior_ids[None]
    return lace_loss(f, w_head, l, prior_rows, ids, w, tau, eps, chunk)
