"""LACE — fused Logit-Adjusted Cross-Entropy, production ops.

``lace_loss`` computes the paper's adjusted CE (eqs. 14/15) **without
materializing the (N, V) logits**: a custom-vjp whose forward and
backward scan over token chunks, keeping only (G, chunk, V) logits live.
Inside each chunk the label log-prob is picked with an iota-mask (not a
gather), so vocab-sharded logits never force an all-gather under GSPMD.

Shapes: feats (G, N, d) — G parallel groups (SCALA clients) sharded over
the data axis, N tokens per group chunked sequentially; w_head (d, V);
labels/weights (G, N); prior_rows (K, V) with prior_ids (G,) selecting
each group's prior row (server loss: K=1; client loss: K=G). The ops are
shape-polymorphic in G — the sparse-slot and in-shard-gather paths call
them with a *gathered* subset of the client groups (G = cohort or the
shard-local K_active), with ``prior_ids`` indexing the gathered priors —
so group-axis mismatches are validated statically up front
(:func:`_check_args`) instead of broadcasting silently.

``impl='pallas'`` routes the inner chunk computation to the TPU kernel in
:mod:`repro.kernels.lace.kernel` (validated in interpret mode on CPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _pick_chunk(n: int, target: int) -> int:
    """The scan chunk along the token axis: always ``min(n, target)``.

    Non-divisible N is handled by padding the token axis up to the next
    chunk multiple with zero-weight tokens (:func:`_pad_tokens`) — NOT
    by shrinking the chunk, which used to degrade to chunk=1 for prime
    N (one (G, 1, V) matmul per token)."""
    return min(n, target)


def _pad_tokens(c, feats, labels, weights):
    """Pad the token axis to the next multiple of the chunk ``c``.

    Padding tokens carry weight 0 and label 0: they contribute exactly
    zero to the weighted NLL sum, the weight sum, and every gradient
    (the backward's per-token cotangent is scaled by the weight), so
    loss values and grads match the unpadded math. Returns
    (feats, labels, weights, n_orig) — ``weights`` materialized even
    when the caller passed None, so the zero-weight rows are explicit.
    """
    G, N, _ = feats.shape
    if weights is None:
        weights = jnp.ones((G, N), jnp.float32)
    pad = (-N) % c
    if pad:
        feats = jnp.pad(feats, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        weights = jnp.pad(weights, ((0, 0), (0, pad)))
    return feats, labels, weights, N


def _check_args(feats, w_head, labels, prior_rows, prior_ids, weights):
    """Static shape validation for the fused ops.

    The group axis G varies call-to-call (full K, a gathered cohort, a
    shard-local subset), and numpy broadcasting would happily accept a
    (K,)-sized ``prior_ids`` against cohort-sized feats — producing
    wrong per-group adjustments with no error. Fail loudly instead.
    """
    if feats.ndim != 3:
        raise ValueError(f"feats must be (G, N, d), got {feats.shape}")
    G, N, d = feats.shape
    if w_head.ndim != 2 or w_head.shape[0] != d:
        raise ValueError(f"w_head must be (d={d}, V), got {w_head.shape}")
    if labels.shape != (G, N):
        raise ValueError(f"labels must be (G, N)=({G}, {N}), got "
                         f"{labels.shape}")
    if weights is not None and weights.shape != (G, N):
        raise ValueError(f"weights must be (G, N)=({G}, {N}), got "
                         f"{weights.shape}")
    if prior_ids is not None:
        if prior_rows is None:
            raise ValueError("prior_ids given without prior_rows")
        if prior_ids.shape != (G,):
            raise ValueError(
                f"prior_ids must be (G,)=({G},), got {prior_ids.shape} — "
                "gathered-group callers must gather the prior ids (or "
                "rows) alongside the feats")
    if prior_rows is not None and prior_rows.shape[-1] != w_head.shape[1]:
        raise ValueError(f"prior_rows vocab dim {prior_rows.shape[-1]} != "
                         f"head vocab dim {w_head.shape[1]}")


def _chunk_logits(f_c, w_head, lp_c, tau):
    """f_c: (G, c, d); w_head: (d, V); lp_c: (G, 1, V) or None."""
    z = jnp.einsum("gcd,dv->gcv", f_c.astype(jnp.float32),
                   w_head.astype(jnp.float32))
    if lp_c is not None:
        z = z + tau * lp_c
    return z


def _nll_from_logits(z, labels_c):
    """z: (G,c,V); labels: (G,c). iota-mask label pick (gather-free)."""
    m = jnp.max(z, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(z - m), axis=-1)) + m[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, z.shape, 2)
    ll = jnp.sum(jnp.where(iota == labels_c[..., None], z, 0.0), axis=-1)
    return lse - ll


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def lace_loss(feats, w_head, labels, prior_rows, prior_ids, weights,
              tau: float = 1.0, eps: float = 1e-8, chunk: int = 4096):
    loss, _ = _lace_fwd(feats, w_head, labels, prior_rows, prior_ids,
                        weights, tau, eps, chunk)
    return loss


def _prep(feats, labels, prior_rows, prior_ids, weights, tau, eps):
    G, N, d = feats.shape
    if weights is None:
        weights = jnp.ones((G, N), jnp.float32)
    if prior_rows is not None:
        lp_rows = jnp.log(prior_rows.astype(jnp.float32) + eps)
        if prior_ids is None:
            lp = jnp.broadcast_to(lp_rows[:1], (G,) + lp_rows.shape[1:])
        else:
            lp = lp_rows[prior_ids]                       # (G, V)
        lp = lp[:, None, :]                               # (G, 1, V)
    else:
        lp = None
    return weights, lp


def _fwd_impl(feats, w_head, labels, prior_rows, prior_ids, weights,
              tau, eps, chunk, mean):
    _check_args(feats, w_head, labels, prior_rows, prior_ids, weights)
    res_in = (feats, w_head, labels, prior_rows, prior_ids, weights)
    G, N0, d = feats.shape
    c = _pick_chunk(N0, chunk)
    feats, labels, weights, _ = _pad_tokens(c, feats, labels, weights)
    N = feats.shape[1]
    weights_f, lp = _prep(feats, labels, prior_rows, prior_ids, weights,
                          tau, eps)
    nc = N // c

    fc = feats.reshape(G, nc, c, d).swapaxes(0, 1)       # (nc, G, c, d)
    lc = labels.reshape(G, nc, c).swapaxes(0, 1)
    wc = weights_f.reshape(G, nc, c).swapaxes(0, 1)

    def body(carry, inp):
        nll_sum, w_sum = carry
        f_c, l_c, w_c = inp
        z = _chunk_logits(f_c, w_head, lp, tau)
        nll = _nll_from_logits(z, l_c)
        return (nll_sum + jnp.sum(nll * w_c), w_sum + jnp.sum(w_c)), None

    (nll_sum, w_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (fc, lc, wc))
    out = nll_sum / jnp.maximum(w_sum, 1e-8) if mean else nll_sum
    return out, res_in + (w_sum,)


def _bwd_impl(tau, eps, chunk, mean, res, g):
    feats, w_head, labels, prior_rows, prior_ids, weights, w_sum = res
    G, N0, d = feats.shape
    V = w_head.shape[1]
    c = _pick_chunk(N0, chunk)
    feats_p, labels_p, weights_p, _ = _pad_tokens(c, feats, labels, weights)
    N = feats_p.shape[1]
    weights_f, lp = _prep(feats_p, labels_p, prior_rows, prior_ids,
                          weights_p, tau, eps)
    nc = N // c

    fc = feats_p.reshape(G, nc, c, d).swapaxes(0, 1)
    lc = labels_p.reshape(G, nc, c).swapaxes(0, 1)
    wc = weights_f.reshape(G, nc, c).swapaxes(0, 1)
    scale = g / jnp.maximum(w_sum, 1e-8) if mean else g

    def body(dw, inp):
        f_c, l_c, w_c = inp
        z = _chunk_logits(f_c, w_head, lp, tau)
        m = jnp.max(z, axis=-1, keepdims=True)
        ez = jnp.exp(z - m)
        p = ez / jnp.sum(ez, axis=-1, keepdims=True)
        iota = jax.lax.broadcasted_iota(jnp.int32, z.shape, 2)
        onehot = (iota == l_c[..., None]).astype(jnp.float32)
        gi = (p - onehot) * (w_c * scale)[..., None]      # (G,c,V)
        df_c = jnp.einsum("gcv,dv->gcd", gi, w_head.astype(jnp.float32))
        dw = dw + jnp.einsum("gcd,gcv->dv", f_c.astype(jnp.float32), gi)
        return dw, df_c

    dw, dfc = jax.lax.scan(body, jnp.zeros((d, V), jnp.float32), (fc, lc, wc))
    dfeats = dfc.swapaxes(0, 1).reshape(G, N, d)[:, :N0].astype(feats.dtype)
    zeros_prior = (None if prior_rows is None
                   else jnp.zeros_like(prior_rows))
    f0 = lambda a: (None if a is None else
                    np.zeros(a.shape, jax.dtypes.float0)
                    if jnp.issubdtype(a.dtype, jnp.integer)
                    else jnp.zeros_like(a))
    return (dfeats, dw.astype(w_head.dtype), f0(labels), zeros_prior,
            f0(prior_ids), f0(weights))


def _lace_fwd(*a):
    return _fwd_impl(*a, True)


def _lace_bwd(tau, eps, chunk, res, g):
    return _bwd_impl(tau, eps, chunk, True, res, g)


lace_loss.defvjp(_lace_fwd, _lace_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def lace_nll_sum(feats, w_head, labels, prior_rows, prior_ids, weights,
                 tau: float = 1.0, eps: float = 1e-8, chunk: int = 4096):
    """Weighted *sum* of adjusted NLLs (no normalization) — the local term
    combined across shards by :func:`lace_loss_dp`."""
    out, _ = _fwd_impl(feats, w_head, labels, prior_rows, prior_ids,
                       weights, tau, eps, chunk, False)
    return out


def _lace_sum_fwd(*a):
    return _fwd_impl(*a, False)


def _lace_sum_bwd(tau, eps, chunk, res, g):
    return _bwd_impl(tau, eps, chunk, False, res, g)


lace_nll_sum.defvjp(_lace_sum_fwd, _lace_sum_bwd)


def lace_loss_dp(feats, w_head, labels, prior_rows, prior_ids, weights,
                 tau: float = 1.0, eps: float = 1e-8, chunk: int = 4096,
                 group_axes=("pod", "data"), token_axes=("model",)):
    """shard_map-wrapped LACE for the replicated-head ("dp") profile.

    Under GSPMD the chunked-CE backward re-all-reduces the (d, V)
    head-weight gradient partial on every chunk trip (§Perf iteration 3).
    Here the loss is computed per-shard on local tokens and combined with
    two scalar psums; the head-weight gradient is psummed exactly once by
    the shard_map transpose. Exact same value/grads as ``lace_loss``.

    feats (G, N, d) with G sharded over ``group_axes`` and N over
    ``token_axes``; w_head replicated. Falls back to ``lace_loss`` when
    there is no ambient mesh (CPU tests / host training).
    """
    from repro import compat

    mesh = compat.ambient_mesh()
    if mesh is None or not mesh.axis_names or compat.in_shard_map():
        return lace_loss(feats, w_head, labels, prior_rows, prior_ids,
                         weights, tau, eps, chunk)
    present = lambda axes: tuple(a for a in axes if a in mesh.axis_names)
    grp = present(group_axes)
    tok = present(token_axes)
    red = grp + tok
    if not red:
        return lace_loss(feats, w_head, labels, prior_rows, prior_ids,
                         weights, tau, eps, chunk)
    P = jax.sharding.PartitionSpec
    g_spec = grp if len(grp) > 1 else (grp[0] if grp else None)
    t_spec = tok if len(tok) > 1 else (tok[0] if tok else None)
    gt = P(g_spec, t_spec)
    gtd = P(g_spec, t_spec, None)

    per_client_prior = prior_ids is not None
    pr_spec = P(g_spec, None) if per_client_prior else P(None, None)

    def local(f_l, w_l, l_l, pr_l, wt_l):
        ids = (jnp.arange(f_l.shape[0]) if per_client_prior else None)
        nll = lace_nll_sum(f_l, w_l, l_l, pr_l, ids, wt_l, tau, eps, chunk)
        wsum = (jnp.sum(wt_l) if wt_l is not None
                else jnp.float32(l_l.size))
        return (jax.lax.psum(nll, red),
                jax.lax.psum(jnp.asarray(wsum, jnp.float32), red))

    in_specs = (gtd, P(None, None), gt,
                pr_spec if prior_rows is not None else P(),
                gt if weights is not None else P())
    fn = compat.shard_map(
        lambda f, w, l, pr, wt: local(
            f, w, l, pr if prior_rows is not None else None,
            wt if weights is not None else None),
        mesh=mesh, in_specs=in_specs, out_specs=(P(), P()),
        check_vma=False)  # scan carries start unvarying; values exact
    dummy = jnp.zeros((), jnp.float32)
    nll, wsum = fn(feats, w_head, labels,
                   prior_rows if prior_rows is not None else dummy[None, None],
                   weights if weights is not None else dummy[None, None])
    return nll / jnp.maximum(wsum, 1e-8)


# ---------------------------------------------------------------------------
# convenience wrappers
# ---------------------------------------------------------------------------


def lace_loss_flat(feats, w_head, labels, *, prior_rows=None, prior_ids=None,
                   weights=None, tau: float = 1.0, eps: float = 1e-8,
                   chunk: int = 4096):
    """(N, d) single-group convenience wrapper."""
    f = feats[None]
    l = labels[None]
    w = None if weights is None else weights[None]
    ids = None if prior_ids is None else prior_ids[None]
    return lace_loss(f, w_head, l, prior_rows, ids, w, tau, eps, chunk)
