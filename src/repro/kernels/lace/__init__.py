from repro.kernels.lace import kernel, ops, ref  # noqa: F401
