"""Participation benchmark: round latency vs. participation fraction.

The fed layer keeps the stacked client axis *static* and realizes
partial participation as a per-round 0/1 mask inside the compiled round
(:mod:`repro.fed.participation`), so the per-round compute is that of
all K slots regardless of the fraction — this bench measures what that
costs (and what the scan ``unroll`` setting does to it) against the
host-side alternative of re-stacking only the participants.

For each fraction r in the sweep, one scanned round program
(`engine.make_round_runner` with ``participation=uniform(K, r)`` +
``aggregator=fedavg``) is timed at unroll on/off on the width-scaled
AlexNet; `masked_vs_subset` additionally times the r=0.5 subset
physically re-stacked (C = r*K slots, no mask) as the lower bound.

Reports rounds/sec and writes ``BENCH_participation.json`` next to this
file (or to ``--out``).

  PYTHONPATH=src python -m benchmarks.participation [--rounds 10] [--K 8]
"""
from __future__ import annotations

import argparse
import time

import jax

from benchmarks.round_loop import _setup
from repro import fed, optim
from repro.configs import ScalaConfig
from repro.core import engine

FRACTIONS = (0.25, 0.5, 1.0)


def _time_rounds(round_fn, state, rb, sizes, fed_state, rounds: int):
    """Warm once, then time; returns (seconds_total, final_state)."""
    if fed_state is None:
        s, _ = round_fn(state, rb, sizes)
        jax.block_until_ready(jax.tree.leaves(s.params)[0])
        t0 = time.perf_counter()
        s = state
        for _ in range(rounds):
            s, _ = round_fn(s, rb, sizes)
        jax.block_until_ready(jax.tree.leaves(s.params)[0])
        return time.perf_counter() - t0, s
    s, f, _ = round_fn(state, rb, sizes, fed_state)
    jax.block_until_ready(jax.tree.leaves(s.params)[0])
    t0 = time.perf_counter()
    s, f = state, fed_state
    for _ in range(rounds):
        s, f, _ = round_fn(s, rb, sizes, f)
    jax.block_until_ready(jax.tree.leaves(s.params)[0])
    return time.perf_counter() - t0, s


def bench_participation(rounds: int = 10, K: int = 8, Bk: int = 16,
                        T: int = 5, lr: float = 0.05):
    """Returns the result dict (also printed/serialized by main)."""
    model, params, rb, sizes = _setup(K, Bk, T)
    sc = ScalaConfig(num_clients=K, participation=1.0, local_iters=T, lr=lr)
    res = {
        "bench": "participation",
        "config": {"rounds": rounds, "clients": K, "per_client_batch": Bk,
                   "local_iters": T, "lr": lr, "model": "alexnet-w0.125"},
        "backend": jax.default_backend(),
        "masked": {},
    }

    state = engine.init_train_state(params, optim.sgd())
    for frac in FRACTIONS:
        part = fed.uniform(K, frac)
        agg = fed.fedavg()
        fed_state = fed.init_fed_state(jax.random.PRNGKey(1), agg, part)
        entry = {}
        for name, unroll in (("rolled", 1), ("unrolled", True)):
            round_fn = jax.jit(engine.make_round_runner(
                model, sc, backend="logits", unroll=unroll,
                aggregator=agg, participation=part))
            secs, _ = _time_rounds(round_fn, state, rb, sizes, fed_state,
                                   rounds)
            entry[name] = {"seconds": round(secs, 4),
                           "rounds_per_sec": round(rounds / secs, 2)}
        res["masked"][f"frac={frac}"] = entry

    # lower bound: the r=0.5 subset physically re-stacked (no mask)
    C = max(1, round(K * 0.5))
    model_s, params_s, rb_s, sizes_s = _setup(C, Bk, T)
    state_s = engine.init_train_state(params_s, optim.sgd())
    round_fn = jax.jit(engine.make_round_runner(model_s, sc,
                                                backend="logits",
                                                unroll=True))
    secs, _ = _time_rounds(round_fn, state_s, rb_s, sizes_s, None, rounds)
    res["subset_restacked_frac=0.5"] = {
        "seconds": round(secs, 4),
        "rounds_per_sec": round(rounds / secs, 2)}
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--K", type=int, default=8)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--T", type=int, default=5)
    ap.add_argument("--smoke", action="store_true",
                    help="minimal sizes, no json written (CI bit-rot check)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.smoke:
        res = bench_participation(rounds=2, K=4, Bk=4, T=2)
    else:
        res = bench_participation(rounds=args.rounds, K=args.K,
                                  Bk=args.batch, T=args.T)
    from benchmarks.common import emit_bench
    emit_bench(res, args.out, "BENCH_participation.json", args.smoke)


if __name__ == "__main__":
    main()
