"""Shared experiment runner for the paper-table benchmarks.

Reproduces the paper's protocol on CIFAR-shaped synthetic data with the
paper's AlexNet (width-scaled for CPU tractability; width=1.0 recovers
the exact Appendix-E architecture): K clients, participation r, T local
iterations, server batch B, SGD eta=0.01, quantity (alpha) or Dirichlet
(beta) label skew — then runs SCALA and every baseline through it.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import fed
from repro.configs import ScalaConfig
from repro.core import baselines as B
from repro.core import engine
from repro.core.engine import SplitModel
from repro.core.losses import accuracy, per_class_accuracy
from repro import optim
from repro.data.loader import FederatedData, round_batches, sample_clients
from repro.data.partition import partition
from repro.data.synthetic import gaussian_images
from repro.models import alexnet as A

SCALA_METHODS = ("scala", "scala_noadj")
ALL_METHODS = SCALA_METHODS + B.FL_METHODS + B.SFL_METHODS


def emit_bench(res: Dict, out: Optional[str], default_name: str,
               smoke: bool) -> None:
    """Shared tail of every ``benchmarks/*.py`` main(): print the result
    json; persist it next to the benchmarks (or to ``--out``) unless this
    is a ``--smoke`` run without an explicit ``--out`` (CI must not
    clobber the committed BENCH files with smoke-sized numbers)."""
    import json
    import os

    print(json.dumps(res, indent=2))
    if smoke and out is None:
        print("smoke OK (no json written)")
        return
    path = out or os.path.join(os.path.dirname(os.path.abspath(__file__)),
                               default_name)
    with open(path, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {path}")


def make_dataset(n_train=2000, n_test=1000, num_classes=10, seed=0):
    x, y = gaussian_images(n_train + n_test, num_classes=num_classes,
                           seed=seed)
    return (x[:n_train], y[:n_train]), (x[n_train:], y[n_train:])


def _alexnet_fed_model(num_classes, split):
    def fwd(p, x):
        return A.forward(p, x, split)

    def feats(p, x):
        # features before the classifier: last FC activation
        return A.features(p, x)

    return B.FedModel(forward=fwd, num_classes=num_classes, features=feats)


def _alexnet_split_model(num_classes, split):
    def client_fwd(wc, batch):
        return {"x": A.client_forward_from_split(wc, batch["x"], split)}

    def server_fwd(ws, acts):
        return (A.server_forward_from_split(ws, acts["x"], split),
                jnp.zeros((), jnp.float32))

    return SplitModel(client_fwd=client_fwd, server_fwd=server_fwd,
                      num_classes=num_classes)


def run_experiment(method: str, *, alpha: Optional[int] = None,
                   beta: Optional[float] = None, K: int = 20, r: float = 0.2,
                   T: int = 5, rounds: int = 12, server_batch: int = 48,
                   lr: float = 0.05, width: float = 0.125,
                   num_classes: int = 10, n_train: int = 2000,
                   split: str = "s2", seed: int = 0,
                   aggregator: Optional[str] = None,
                   opt_state_policy: str = "carry",
                   execution: str = "subset",
                   server_optimizer: Optional[str] = None,
                   server_lr: float = 1.0) -> Dict:
    """Returns {'acc', 'balanced_acc', 'seconds'} on the held-out test set.

    ``aggregator``: optional :mod:`repro.fed` aggregator name for the FL
    phase (None = legacy data-size FedAvg); ``opt_state_policy`` is the
    SCALA engine's client opt-state round-boundary policy.

    ``execution`` (SCALA methods): how partial participation runs —

    * ``"subset"`` — legacy host-side sampling: each round stacks only
      the C = r*K sampled clients (C compute slots);
    * ``"masked"`` — all K slots stay stacked, an in-program
      ``fed.uniform(K, r)`` mask picks the subset (full-K compute);
    * ``"sparse"`` — same scheduler, but the engine gathers the subset
      into a dense [C] axis before the local scan (``slot_gather``) —
      subset compute at static shapes.

    The per-round participant batch is held comparable across modes
    (masked/sparse split ``server_batch / r`` over the K slots, eq. 3).

    ``server_optimizer``: optional :mod:`repro.optim` optimizer name for
    the server side — FedOpt over the SCALA server half's round delta,
    or over the FL baselines' aggregated-model round delta (FedAvgM /
    FedAdam) — applied at ``server_lr``."""
    (x, y), (x_test, y_test) = make_dataset(n_train=n_train, seed=seed)
    parts = partition(y, K, alpha=alpha, beta=beta, num_classes=num_classes,
                      seed=seed)
    data = FederatedData.from_partition(x, y, parts)
    rng = np.random.default_rng(seed + 7)
    key = jax.random.PRNGKey(seed)
    C = max(1, round(K * r))
    agg = fed.make_aggregator(aggregator) if aggregator else None
    server_opt = (optim.make_optimizer(server_optimizer)
                  if server_optimizer else None)
    if execution not in ("subset", "masked", "sparse"):
        raise ValueError(f"unknown execution mode {execution!r}")
    t0 = time.time()

    full = A.init_params(key, num_classes=num_classes, width=width)
    x_test_j = jnp.asarray(x_test)
    y_test_j = jnp.asarray(y_test)

    def finish(final_params_fwd):
        logits = final_params_fwd(x_test_j)
        return {
            "acc": float(accuracy(logits, y_test_j)),
            "balanced_acc": float(per_class_accuracy(logits, y_test_j,
                                                     num_classes)),
            "seconds": round(time.time() - t0, 1),
        }

    if method in SCALA_METHODS:
        adjust = method == "scala"
        sc = ScalaConfig(num_clients=K, participation=r, local_iters=T,
                         server_batch=server_batch, lr=lr,
                         adjust_server=adjust, adjust_client=adjust)
        model = _alexnet_split_model(num_classes, split)
        wc, ws = A.split_params(full, split)
        in_program = execution in ("masked", "sparse")
        slots = K if in_program else C
        params = {"client": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (slots,) + a.shape), wc),
            "server": ws}
        # engine round runner: T local iterations + FedAvg in ONE scanned
        # XLA program (backend "logits": AlexNet materializes its 10-way
        # logits; no trunk/head split needed). Full unroll: XLA:CPU runs
        # rolled-loop bodies with reduced parallelism (benchmarks/round_loop).
        scheduler = fed.uniform(K, r) if in_program else None
        if agg is not None and agg.stateful and not in_program:
            # the runner re-stacks a freshly sampled subset every round,
            # so per-slot aggregator state would not track clients
            raise ValueError(f"aggregator {agg.name!r} is stateful; "
                             "run_experiment's host-side subset sampling "
                             "has no stable client identities")
        state = engine.init_train_state(params, optim.sgd())
        round_fn = jax.jit(engine.make_round_runner(
            model, sc, backend="logits", unroll=True, aggregator=agg,
            participation=scheduler, slot_gather=execution == "sparse",
            server_optimizer=server_opt, server_lr=server_lr,
            opt_state_policy=opt_state_policy))
        thread_fed = in_program or server_opt is not None
        fed_state = (fed.init_fed_state(jax.random.fold_in(key, 11), agg,
                                        scheduler, num_clients=slots,
                                        server_optimizer=server_opt,
                                        server_params=ws)
                     if thread_fed else None)
        # eq. (3) parity across modes: in-program modes split the budget
        # over all K slots, so the r-subset sees ~server_batch samples
        batch_budget = round(server_batch / r) if in_program else server_batch
        for _ in range(rounds):
            sel = (np.arange(K) if in_program
                   else sample_clients(K, C, rng))
            rb = round_batches(data, sel, batch_budget, T, rng)
            sizes = jnp.asarray(rb.pop("sizes"))
            batches = {k: jnp.asarray(v) for k, v in rb.items()}
            if thread_fed:
                state, fed_state, _ = round_fn(state, batches, sizes,
                                               fed_state)
            else:
                state, _ = round_fn(state, batches, sizes)
        wc0 = jax.tree.map(lambda a: a[0], state.params["client"])
        merged = A.merge_params(wc0, state.params["server"])
        return finish(lambda xs: A.forward(merged, xs, split))

    if method in B.FL_METHODS:
        model = _alexnet_fed_model(num_classes, split)
        w = full
        state = B.init_fl_state(method, w, C, server_optimizer=server_opt)
        round_fn = jax.jit(
            lambda wg, rb, ds, st: B.make_fl_round(
                method, model, lr=lr, aggregator=agg,
                server_optimizer=server_opt,
                server_lr=server_lr)(wg, rb, ds, st))
        for _ in range(rounds):
            sel = sample_clients(K, C, rng)
            rb = round_batches(data, sel, server_batch, T, rng)
            sizes = jnp.asarray(rb.pop("sizes"))
            # 'weights' stays: the local losses ignore it, but the fed
            # aggregation priors use it to exclude zero-padded rows
            batches = {k: jnp.asarray(v).swapaxes(0, 1)
                       for k, v in rb.items()}
            w, state = round_fn(w, batches, sizes, state)
        return finish(lambda xs: A.forward(w, xs, split))

    if method in B.SFL_METHODS:
        model = _alexnet_split_model(num_classes, split)
        wc, ws = A.split_params(full, split)
        bcast = lambda t: jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (C,) + a.shape), t)
        state = {"wc": bcast(wc), "ws": ws}
        aux_head_fwd = None
        if method == "sfl_localloss":
            feat_dim = None
            probe = A.client_forward_from_split(wc, jnp.zeros((1, 32, 32, 3)),
                                                split)
            feat_dim = int(np.prod(probe.shape[1:]))
            aux0 = {"w": jax.random.normal(key, (feat_dim, num_classes)) * 0.05}
            state["aux"] = bcast(aux0)

            def aux_head_fwd(p, feats):
                return feats.reshape(feats.shape[0], -1) @ p["w"]

        round_fn = B.make_sfl_round(method, model, lr=lr,
                                    aux_head_fwd=aux_head_fwd,
                                    aggregator=agg)
        round_fn = jax.jit(round_fn)
        for _ in range(rounds):
            sel = sample_clients(K, C, rng)
            rb = round_batches(data, sel, server_batch, T, rng)
            sizes = jnp.asarray(rb.pop("sizes"))
            batches = {k: jnp.asarray(v).swapaxes(0, 1)
                       for k, v in rb.items()}
            state = round_fn(state, batches, sizes)
        wc0 = jax.tree.map(lambda a: a[0], state["wc"])
        merged = A.merge_params(wc0, state["ws"])
        return finish(lambda xs: A.forward(merged, xs, split))

    raise ValueError(f"unknown method {method!r}")
