"""Shared experiment runner for the paper-table benchmarks.

Reproduces the paper's protocol on CIFAR-shaped synthetic data with the
paper's AlexNet (width-scaled for CPU tractability; width=1.0 recovers
the exact Appendix-E architecture): K clients, participation r, T local
iterations, server batch B, SGD eta=0.01, quantity (alpha) or Dirichlet
(beta) label skew — then runs SCALA and every baseline through it.

Since the ``repro.api`` redesign, :func:`run_experiment` is a thin
kwargs adapter: it assembles a declarative
:class:`repro.api.ExperimentSpec` and runs it through
:class:`repro.api.Trainer`, so the benchmarks execute the *same*
programs as ``launch/train.py`` — including the execution-mode
vocabulary (``subset | masked | sparse | async``), which is owned by
:class:`repro.api.ExecutionSpec` and can no longer drift between
drivers.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

from repro import api
from repro.configs import ScalaConfig

# the method registry is owned by the spec layer — one vocabulary
SCALA_METHODS = api.SCALA_METHODS
ALL_METHODS = api.METHODS


def device_info() -> Dict:
    """The accelerator this benchmark actually ran on — stamped into
    every BENCH json so a committed number can never be mistaken for a
    different device class (CPU medians vs TPU/GPU runs), and so
    accelerator-gated legs can state their gate in-band."""
    import jax

    return {"platform": jax.devices()[0].platform,
            "device_count": jax.device_count(),
            "kind": getattr(jax.devices()[0], "device_kind", "")}


def emit_bench(res: Dict, out: Optional[str], default_name: str,
               smoke: bool) -> None:
    """Shared tail of every ``benchmarks/*.py`` main(): stamp the device
    (:func:`device_info`), print the result json; persist it next to
    the benchmarks (or to ``--out``) unless this is a ``--smoke`` run
    without an explicit ``--out`` (CI must not clobber the committed
    BENCH files with smoke-sized numbers)."""
    import json
    import os

    res.setdefault("device", device_info())
    print(json.dumps(res, indent=2))
    if smoke and out is None:
        print("smoke OK (no json written)")
        return
    path = out or os.path.join(os.path.dirname(os.path.abspath(__file__)),
                               default_name)
    with open(path, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {path}")


def experiment_spec(method: str, *, alpha: Optional[int] = None,
                    beta: Optional[float] = None, K: int = 20, r: float = 0.2,
                    T: int = 5, rounds: int = 12, server_batch: int = 48,
                    lr: float = 0.05, width: float = 0.125,
                    num_classes: int = 10, n_train: int = 2000,
                    split: str = "s2", seed: int = 0,
                    aggregator: Optional[str] = None,
                    opt_state_policy: str = "carry",
                    execution: str = "subset",
                    server_optimizer: Optional[str] = None,
                    server_lr: float = 1.0,
                    rounds_per_call: int = 1,
                    precision: str = "f32",
                    donate: bool = True) -> api.ExperimentSpec:
    """The paper-table kwargs -> a declarative ExperimentSpec."""
    in_program = execution in ("masked", "sparse")
    server_opt = (api.OptimSpec.parse(server_optimizer, default_lr=server_lr)
                  if server_optimizer else None)
    return api.ExperimentSpec(
        arch="alexnet-cifar", split=split, width=width,
        method=method, rounds=rounds, seed=seed,
        scala=ScalaConfig(num_clients=K, participation=r, local_iters=T,
                          server_batch=server_batch, lr=lr),
        fed=api.FedSpec(aggregator=aggregator or "weighted",
                        participation=f"uniform:{r}" if in_program else None,
                        opt_state_policy=opt_state_policy),
        # full unroll: XLA:CPU runs rolled-loop bodies with reduced
        # parallelism (benchmarks/round_loop)
        execution=api.ExecutionSpec(mode=execution, backend="logits",
                                    server_optimizer=server_opt, unroll=0,
                                    rounds_per_call=rounds_per_call,
                                    precision=precision, donate=donate),
        data=api.DataSpec(kind="image_synthetic", n_train=n_train,
                          num_classes=num_classes, alpha=alpha, beta=beta))


def run_experiment(method: str, **kw) -> Dict:
    """Returns {'acc', 'balanced_acc', 'seconds'} on the held-out test set.

    Keyword surface documented on :func:`experiment_spec`; notable ones:

    ``aggregator``: optional :mod:`repro.fed` aggregator spec for the FL
    phase (None = legacy data-size FedAvg); ``opt_state_policy`` is the
    SCALA engine's client opt-state round-boundary policy.

    ``execution`` (SCALA methods): how partial participation runs — the
    :class:`repro.api.ExecutionSpec` mode vocabulary. ``"subset"`` is
    the legacy host-side sampling (C = r*K stacked compute slots);
    ``"masked"`` / ``"sparse"`` keep all K slots and pick the
    ``fed.uniform(K, r)`` subset in-program (full-K vs gathered
    subset-cost compute). The per-round participant batch is held
    comparable across modes (masked/sparse split ``server_batch / r``
    over the K slots, eq. 3 — see :class:`repro.api.Trainer`).

    ``server_optimizer``: optional optimizer spec for the server side —
    FedOpt over the SCALA server half's round delta, or over the FL
    baselines' aggregated-model round delta (FedAvgM / FedAdam) —
    applied at ``server_lr``.

    ``rounds_per_call`` / ``precision`` / ``donate``: the
    :class:`repro.api.ExecutionSpec` dispatch-efficiency knobs (round
    fusion, bf16 compute against f32 master params, state buffer
    donation — see ``benchmarks/dispatch.py``)."""
    t0 = time.time()
    trainer = api.Trainer(experiment_spec(method, **kw))
    trainer.run()
    res = trainer.evaluate()
    res["seconds"] = round(time.time() - t0, 1)
    return res
