"""Dispatch benchmark: round fusion x buffer donation x precision.

Every round used to be one undonated jit dispatch driven by a Python
loop. PR 5 adds three multiplicative knobs on
:class:`repro.api.ExecutionSpec`, all exercised here through the same
``api.build`` program every driver runs:

* ``rounds_per_call`` — R whole rounds fused into ONE XLA program
  (trace-time round chain / outer ``lax.scan``), amortizing the
  per-dispatch host cost (pytree flatten, executable launch, output
  rewrap — ~0.4ms on this container) and pulling metrics to host once
  per chunk instead of once per round;
* ``donate`` — the program-state argument (stacked client params,
  optimizer moments, fed/async state) is donated to the jitted step, so
  the round state updates in place instead of being copied per dispatch;
* ``precision`` — ``"bf16"`` compute against f32 master params shrinks
  the live activation set the fused program keeps resident.

The default config is deliberately MICRO (K=2 clients, 1 image, T=1,
width-floor AlexNet): the benchmark isolates the dispatch layer, so the
per-round device compute must be comparable to the per-dispatch host
cost for the knobs to be visible at all. Measured reality on XLA:CPU:
even the width-floor round costs ~2ms of per-op overhead, so fusion
buys ~1.2-1.3x on the sparse/async modes (sub-ms savings per round) and
~1.0x on full-K masked compute — the ratio grows as rounds shrink
toward the dispatch cost (accelerator-scale models with sub-ms rounds
are where ``rounds_per_call`` earns its keep; see README §Performance
for when NOT to fuse).

For each execution mode (masked / sparse / async) the full grid
``rounds_per_call x donate x precision`` is timed; per mode,
``fused_speedup`` is rounds/s at the largest R over R=1 (donated f32).
A ``baseline_transpose_hoist`` leg A/Bs the FL-baseline batch-transpose
hoist (one whole-chunk swapaxes at the dispatch boundary vs the old
per-round transpose inside the fused scan — see
:func:`bench_baseline_hoist`). Writes ``BENCH_dispatch.json`` next to
this file (or to ``--out``).

  PYTHONPATH=src python -m benchmarks.dispatch [--rounds 192] [--K 2]
  PYTHONPATH=src python -m benchmarks.dispatch --smoke   # CI guard:
      asserts the fused async program is no slower than the unfused one
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import api
from repro.configs import ScalaConfig

MODES = ("masked", "sparse", "async")
RPCS = (1, 4, 16)
PRECISIONS = ("f32", "bf16")


def _spec(mode: str, rpc: int, donate: bool, precision: str, *, K: int,
          T: int, server_batch: int, width: float) -> api.ExperimentSpec:
    fed = (api.FedSpec(participation="uniform:0.5")
           if mode in ("masked", "sparse") else api.FedSpec())
    return api.ExperimentSpec(
        arch="alexnet-cifar", width=width, method="scala", rounds=8, seed=0,
        scala=ScalaConfig(num_clients=K, participation=0.5, local_iters=T,
                          server_batch=server_batch, lr=0.05),
        fed=fed,
        execution=api.ExecutionSpec(mode=mode, rounds_per_call=rpc,
                                    donate=donate, precision=precision,
                                    cohort=1 if mode == "async" else 0),
        data=api.DataSpec(kind="image_synthetic", n_train=100,
                          num_classes=10, alpha=2))


def _round_batches(K: int, Bk: int, T: int, rpc: int, seed: int = 0):
    """One chunk of synthetic round batches: leaves (T,K,Bk,...) — or
    (rpc,T,K,Bk,...) for a fused program — plus the (K,)/(rpc,K) sizes.
    The same round tiled ``rpc`` times: dispatch cost is shape-driven."""
    key = jax.random.PRNGKey(seed)
    b = {"x": jax.random.normal(key, (T, K, Bk, 32, 32, 3), jnp.float32),
         "labels": jax.random.randint(jax.random.fold_in(key, 1),
                                      (T, K, Bk), 0, 10),
         "weights": jnp.ones((T, K, Bk), jnp.float32)}
    sizes = jnp.full((K,), float(Bk))
    if rpc > 1:
        b = {k: jnp.broadcast_to(v[None], (rpc,) + v.shape).copy()
             for k, v in b.items()}
        sizes = jnp.broadcast_to(sizes[None], (rpc, K)).copy()
    return b, sizes


def _time_config(spec: api.ExperimentSpec, rounds: int, K: int, Bk: int,
                 T: int, reps: int = 3):
    """Build the program, warm it, and time ~``rounds`` rounds' worth of
    dispatches (state threads call to call, donation-style); the median
    of ``reps`` repetitions counters host timing noise at ms scale."""
    rpc = spec.execution.rounds_per_call
    program = api.build(spec)
    batches, sizes = _round_batches(K, Bk, T, rpc)
    state = program.init()
    state, m = program.step(state, batches, sizes)               # warm
    jax.block_until_ready(jax.tree.leaves(state)[0])
    calls = max(1, rounds // rpc)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(calls):
            state, m = program.step(state, batches, sizes)
        jax.block_until_ready(jax.tree.leaves(state)[0])
        times.append(time.perf_counter() - t0)
    secs = sorted(times)[len(times) // 2]
    return {"seconds": round(secs, 4),
            "rounds_per_sec": round(calls * rpc / secs, 2)}


def bench_dispatch(rounds: int = 192, K: int = 2, Bk: int = 1, T: int = 1,
                   width: float = 0.03125, modes=MODES, rpcs=RPCS,
                   precisions=PRECISIONS, donates=(True, False)):
    """Returns the result dict (also printed/serialized by main)."""
    res = {
        "bench": "dispatch",
        "config": {"rounds": rounds, "clients": K, "per_client_batch": Bk,
                   "local_iters": T, "model": f"alexnet-w{width}",
                   "rpcs": list(rpcs), "precisions": list(precisions),
                   "donates": list(donates)},
        "backend": jax.default_backend(),
        "modes": {},
    }
    for mode in modes:
        entry = {}
        for rpc in rpcs:
            for donate in donates:
                for prec in precisions:
                    spec = _spec(mode, rpc, donate, prec, K=K, T=T,
                                 server_batch=max(1, K * Bk // 2),
                                 width=width)
                    key = (f"rpc={rpc},donate="
                           f"{'on' if donate else 'off'},prec={prec}")
                    entry[key] = _time_config(spec, rounds, K, Bk, T)
        base = entry[f"rpc={rpcs[0]},donate=on,prec=f32"]
        top = entry[f"rpc={rpcs[-1]},donate=on,prec=f32"]
        entry["fused_speedup"] = round(
            top["rounds_per_sec"] / base["rounds_per_sec"], 3)
        res["modes"][mode] = entry
    return res


def _fl_spec(rpc: int, *, K: int, T: int, width: float) -> api.ExperimentSpec:
    return api.ExperimentSpec(
        arch="alexnet-cifar", width=width, method="fedavg", rounds=8, seed=0,
        scala=ScalaConfig(num_clients=K, participation=1.0, local_iters=T,
                          server_batch=K, lr=0.05),
        execution=api.ExecutionSpec(mode="subset", rounds_per_call=rpc),
        data=api.DataSpec(kind="image_synthetic", n_train=100,
                          num_classes=10, alpha=2))


def bench_baseline_hoist(rounds: int = 192, K: int = 2, Bk: int = 1,
                         T: int = 1, width: float = 0.03125, rpc: int = 16):
    """The FL-baseline transpose hoist, A/B'd.

    The FL/SFL baseline rounds consume client-major (C, T, ...) batches
    while the driver layout is iteration-major (T, C, ...); the
    transpose now lives in ``build()``'s dispatch wrapper — ONE
    whole-chunk ``swapaxes`` per fused ``rounds_per_call`` dispatch. The
    pre-hoist layout (a per-round transpose *inside* the fused scan) is
    reconstructed here by fusing the rpc=1 step — which carries its own
    per-call transpose — through the same ``_fuse_rounds``; both
    programs are semantically identical, only the transpose placement
    differs.

    Measured reality on XLA:CPU: ~1.2x rounds/s at the micro config on
    an idle machine (BENCH_dispatch.json: 324 vs 265 r/s), decaying to
    parity under load or at larger K x Bk where compute dominates — the
    per-round swapaxes inside the scan body is sub-ms, so the win is
    the dispatch-cost share, same story as ``rounds_per_call`` itself.
    Beyond wall-clock, the hoist keeps the layout shuffle ONCE at the
    dispatch boundary instead of replicated inside every FL/SFL round
    step, and this leg pins it at >= parity so a layout regression
    can't hide."""
    from repro.api.build import _fuse_rounds, donated_jit

    entry = {"hoisted": _time_config(_fl_spec(rpc, K=K, T=T, width=width),
                                     rounds, K, Bk, T)}

    spec1 = _fl_spec(1, K=K, T=T, width=width)
    prog1 = api.build(spec1, jit=False)
    step_old = donated_jit(
        _fuse_rounds(prog1.step, spec1.execution.resolve_unroll()),
        donate=True)
    batches, sizes = _round_batches(K, Bk, T, rpc)
    state = jax.tree.map(jnp.copy, prog1.init())
    state, _ = step_old(state, batches, sizes)                   # warm
    jax.block_until_ready(jax.tree.leaves(state)[0])
    calls = max(1, rounds // rpc)
    times = []
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(calls):
            state, _ = step_old(state, batches, sizes)
        jax.block_until_ready(jax.tree.leaves(state)[0])
        times.append(time.perf_counter() - t0)
    secs = sorted(times)[len(times) // 2]
    entry["per_round_transpose"] = {
        "seconds": round(secs, 4),
        "rounds_per_sec": round(calls * rpc / secs, 2)}
    entry["hoist_speedup"] = round(
        entry["hoisted"]["rounds_per_sec"]
        / entry["per_round_transpose"]["rounds_per_sec"], 3)
    return entry


def smoke_guard():
    """The fused-vs-unfused regression guard shared by
    ``benchmarks.dispatch --smoke`` and ``benchmarks.run --smoke``.

    Runs on the async micro round (the most dispatch-bound program:
    cohort=1 sparse-slot compute), where a fusion regression cannot hide
    behind compute; asserts fused rounds/s >= unfused. Wall-clock
    ratios at ~2ms/round are noisy even at median-of-3, so a sub-1.0
    first measurement gets ONE re-measure before failing — a real
    regression fails twice, a scheduler hiccup doesn't. Returns the
    last measured result dict."""
    res = None
    for attempt in (0, 1):
        res = bench_dispatch(rounds=96, modes=("async",), rpcs=(1, 16),
                             precisions=("f32",), donates=(True,))
        ratio = res["modes"]["async"]["fused_speedup"]
        print(f"fused-vs-unfused rounds/s ratio: {ratio}"
              + (" (retry)" if attempt else ""))
        if ratio >= 1.0:
            break
    assert ratio >= 1.0, (
        f"round fusion regressed: rounds_per_call=16 runs at {ratio}x "
        "the unfused round rate (expected >= 1; reproduced twice)")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=192)
    ap.add_argument("--K", type=int, default=2)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--T", type=int, default=1)
    ap.add_argument("--width", type=float, default=0.03125)
    ap.add_argument("--smoke", action="store_true",
                    help="minimal grid, no json written; asserts the "
                         "fused async program is >= as fast as the "
                         "unfused one (CI regression guard)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.smoke:
        res = smoke_guard()
    else:
        res = bench_dispatch(rounds=args.rounds, K=args.K, Bk=args.batch,
                             T=args.T, width=args.width)
        res["baseline_transpose_hoist"] = bench_baseline_hoist(
            rounds=args.rounds, K=args.K, Bk=args.batch, T=args.T,
            width=args.width)
    from benchmarks.common import emit_bench
    emit_bench(res, args.out, "BENCH_dispatch.json", args.smoke)


if __name__ == "__main__":
    main()
