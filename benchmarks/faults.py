"""Guarded-aggregation overhead benchmark (fault-tolerance subsystem).

PR 10 threads per-client update screening (non-finite rejection +
running-median norm clipping, :mod:`repro.fed.guards`) through the sync
round runner and the async event runtime. The guards add, per round: one
global L2 norm + finiteness reduction per client over the round delta,
one ``lax.cond`` whose recompute branch re-runs the local phase over the
surviving subset (taken only when something was actually rejected), and
the masked aggregation itself. With zero faults injected the recompute
branch never fires and outputs are bit-identical to the unguarded round
(``tests/test_faults.py``), so the honest cost of always-on guards is
the screen itself — that is what this bench measures:

* ``guard_overhead`` — guarded/unguarded median wall-clock per round at
  zero faults, for ``nonfinite`` and ``nonfinite,clip`` policies, in
  masked and async modes;
* the ``chaos`` leg runs NaN corruption + drops at 10% of the cohort
  under guards and records the rejected-client counts and final loss —
  the graceful-degradation claim in numbers (finite loss, cohort
  shrinks, schedule advances).

Numbers are stamped with :func:`benchmarks.common.device_info` like
every BENCH json — CPU medians claim nothing about accelerators.

  PYTHONPATH=src python -m benchmarks.faults [--rounds 8] [--reps 3]
  PYTHONPATH=src python -m benchmarks.faults --smoke   # CI guard:
      chaos run completes finite + guard overhead stays bounded
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit_bench


def _trainer(K, rounds, mode, faults=None, guards=None):
    from repro import api
    from repro.configs import ScalaConfig

    execution = (api.ExecutionSpec(mode="async", cohort=max(2, K // 4))
                 if mode == "async" else api.ExecutionSpec(mode=mode))
    spec = api.ExperimentSpec(
        arch="alexnet-cifar", method="scala", rounds=rounds, seed=0,
        scala=ScalaConfig(num_clients=K, participation=0.5, local_iters=2,
                          server_batch=48, lr=0.05),
        fed=api.FedSpec(faults=faults, guards=guards),
        execution=execution,
        data=api.DataSpec(kind="image_synthetic", n_train=60 * K, alpha=2))
    return api.Trainer(spec)


def _time_rounds(trainer, rounds, reps):
    """Median wall-clock of one round, compile excluded (first step)."""
    trainer.step()                                   # compile + warm
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(rounds):
            trainer.step()
        times.append((time.perf_counter() - t0) / rounds)
    return float(np.median(times))


def bench_faults(K: int = 8, rounds: int = 4, reps: int = 3):
    res = {"K": K, "rounds_per_rep": rounds, "reps": reps, "modes": {}}
    budget = 1 + rounds * reps                        # steps per trainer
    for mode in ("masked", "async"):
        row = {}
        t_plain = _time_rounds(_trainer(K, budget, mode), rounds, reps)
        row["unguarded_s_per_round"] = t_plain
        for guards in ("nonfinite", "nonfinite,clip:10.0"):
            t_g = _time_rounds(_trainer(K, budget, mode, guards=guards),
                               rounds, reps)
            row[guards] = {"s_per_round": t_g,
                           "guard_overhead": t_g / t_plain}
        res["modes"][mode] = row

    # chaos leg: 10% NaN corruption + 10% drops under nonfinite guards —
    # completion with finite loss and a shrinking effective cohort
    chaos = _trainer(K, rounds + 1, "masked",
                     faults="drop:0.1,corrupt:0.1:nan", guards="nonfinite")
    rejected = []
    loss = None
    for _ in range(rounds + 1):
        m = chaos.step()
        rejected.append(m.get("guard_rejected", 0.0))
        loss = m["loss_server"]
    res["chaos"] = {
        "faults": "drop:0.1,corrupt:0.1:nan",
        "final_loss": float(loss),
        "finite": bool(np.isfinite(loss)),
        "rounds": rounds + 1,
        "rejected_per_round": rejected,
        "rejected_total": float(np.sum(rejected)),
    }
    return res


def smoke_guard():
    """The CI guard shared with ``benchmarks.run --smoke``: the chaos
    run must complete with finite loss, and always-on guards at zero
    faults must stay within 2x the unguarded round (they add one screen
    reduction and an untaken cond branch; wall-clock ratios are noisy at
    smoke scale, so a failing first measurement gets ONE re-measure)."""
    res = None
    for attempt in (0, 1):
        res = bench_faults(K=4, rounds=2, reps=2)
        ov = max(res["modes"][m][g]["guard_overhead"]
                 for m in res["modes"]
                 for g in ("nonfinite", "nonfinite,clip:10.0"))
        print(f"max guard overhead (zero faults): {ov:.3f}x"
              + (" (retry)" if attempt else ""))
        if ov < 2.0:
            break
    assert res["chaos"]["finite"], \
        f"chaos run diverged: loss={res['chaos']['final_loss']}"
    assert ov < 2.0, (
        f"guard screen overhead regressed: {ov}x the unguarded round "
        "(expected < 2x; reproduced twice)")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config, no json written; asserts chaos "
                         "completion + bounded guard overhead (CI guard)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.smoke:
        res = smoke_guard()
    else:
        res = bench_faults(K=args.clients, rounds=args.rounds,
                           reps=args.reps)
    emit_bench(res, args.out, "BENCH_faults.json", args.smoke)


if __name__ == "__main__":
    main()
