"""Benchmark harness: one function per paper table/figure.

Each bench reproduces the *protocol* of a SCALA table at CPU-tractable
scale (synthetic CIFAR-shaped data, width-scaled AlexNet, reduced
rounds) and prints a CSV block ``table,setting,method,acc,balanced_acc,
seconds``.  The claim validated per table is the paper's *ordering*
(SCALA > baselines, and the trends across r / K / T / split point), not
the absolute accuracies — see EXPERIMENTS.md §Paper-validation.

Additionally, the roofline benches (paper has no table for these; they
back deliverable (g)) re-print the dry-run-derived roofline terms per
(arch x shape x mesh) from ``results/dryrun``.

Every experiment goes through :func:`benchmarks.common.run_experiment`,
which since the ``repro.api`` redesign assembles a declarative
:class:`repro.api.ExperimentSpec` — the execution-mode names used below
(``subset | masked | sparse``) are :class:`repro.api.ExecutionSpec`'s
vocabulary, shared verbatim with ``launch/train.py``.

Usage:
  PYTHONPATH=src python -m benchmarks.run            # standard (a few min)
  PYTHONPATH=src python -m benchmarks.run --quick    # smoke (~1 min)
  PYTHONPATH=src python -m benchmarks.run --table t1 # a single table
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

from benchmarks.common import run_experiment

HEADER = "table,setting,method,acc,balanced_acc,seconds"


def _emit(table: str, setting: str, method: str, res: dict) -> None:
    print(f"{table},{setting},{method},{res['acc']:.4f},"
          f"{res['balanced_acc']:.4f},{res['seconds']}", flush=True)


# ---------------------------------------------------------------------------
# Table 1 / Figure 4: SCALA vs FL baselines under quantity (alpha) and
# Dirichlet (beta) label skew.
# ---------------------------------------------------------------------------

def bench_table1(quick: bool) -> None:
    methods = ("scala", "fedavg", "fedprox", "fedlogit", "fedla") if quick \
        else ("scala", "scala_noadj", "fedavg", "fedprox", "feddyn",
              "feddecorr", "fedlogit", "fedla")
    rounds = 6 if quick else 10
    for setting, kw in (("alpha=2", dict(alpha=2)),
                        ("beta=0.05", dict(beta=0.05))):
        for m in methods:
            res = run_experiment(m, rounds=rounds, **kw)
            _emit("T1", setting, m, res)


# ---------------------------------------------------------------------------
# Table 2: participation ratio r sweep (alpha=2).
# ---------------------------------------------------------------------------

def bench_table2(quick: bool) -> None:
    ratios = (0.1, 0.5) if quick else (0.1, 0.2, 0.5)
    methods = ("scala", "fedavg") if quick else ("scala", "fedavg",
                                                 "fedla")
    rounds = 6 if quick else 10
    for r in ratios:
        for m in methods:
            res = run_experiment(m, alpha=2, r=r, rounds=rounds)
            _emit("T2", f"r={r}", m, res)


# ---------------------------------------------------------------------------
# Table 3: number-of-clients K sweep (alpha=2; r=50% for small K, 10%
# for large K, as in the paper).
# ---------------------------------------------------------------------------

def bench_table3(quick: bool) -> None:
    grid = ((10, 0.5), (20, 0.5)) if quick else ((10, 0.5), (20, 0.5),
                                                 (50, 0.1))
    methods = ("scala", "fedavg") if quick else ("scala", "fedavg",
                                                 "fedla")
    rounds = 6 if quick else 10
    for K, r in grid:
        for m in methods:
            res = run_experiment(m, alpha=2, K=K, r=r, rounds=rounds)
            _emit("T3", f"K={K},r={r}", m, res)


# ---------------------------------------------------------------------------
# Tables 5-6: SCALA vs the SFL family.
# ---------------------------------------------------------------------------

def bench_table5(quick: bool) -> None:
    methods = ("scala", "splitfed_v1", "splitfed_v2") if quick else (
        "scala", "splitfed_v1", "splitfed_v2", "splitfed_v3",
        "sfl_localloss")
    rounds = 6 if quick else 10
    for setting, kw in (("alpha=2", dict(alpha=2)),
                        ("beta=0.1", dict(beta=0.1))):
        for m in methods:
            res = run_experiment(m, rounds=rounds, **kw)
            _emit("T5", setting, m, res)


# ---------------------------------------------------------------------------
# Table 7: local-iteration (T) sweep.
# ---------------------------------------------------------------------------

def bench_table7(quick: bool) -> None:
    Ts = (1, 5) if quick else (1, 5, 10)
    methods = ("scala", "fedavg") if quick else ("scala", "fedavg", "fedla")
    rounds = 6 if quick else 10
    for T in Ts:
        for m in methods:
            res = run_experiment(m, alpha=2, T=T, rounds=rounds)
            _emit("T7", f"T={T}", m, res)


# ---------------------------------------------------------------------------
# Table 8: splitting-point sweep (client/server boundary depth).
# ---------------------------------------------------------------------------

def bench_table8(quick: bool) -> None:
    splits = ("s1", "s2") if quick else ("s1", "s2", "s3", "s4")
    rounds = 6 if quick else 10
    for sp in splits:
        res = run_experiment("scala", alpha=2, split=sp, rounds=rounds)
        _emit("T8", f"split={sp}", "scala", res)


# ---------------------------------------------------------------------------
# Roofline report (deliverable g): reprint dry-run-derived terms.
# ---------------------------------------------------------------------------

def bench_roofline(_quick: bool) -> None:
    root = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "results", "dryrun")
    files = sorted(glob.glob(os.path.join(root, "*.json")))
    if not files:
        print("roofline,NO_DRYRUN_RESULTS,,,,", flush=True)
        return
    print("roofline_table,arch,shape,mesh,status,bottleneck,"
          "t_compute_s,t_memory_s,t_collective_s,useful_flops_ratio",
          flush=True)
    for f in files:
        with open(f) as fh:
            r = json.load(fh)
        if r.get("status") != "ok":
            print(f"roofline,{r['arch']},{r['shape']},{r['mesh']},"
                  f"{r.get('status')},,,,,", flush=True)
            continue
        t = r.get("roofline_scoped", r["roofline"])
        ufr = r.get("useful_flops_ratio")
        print(f"roofline,{r['arch']},{r['shape']},{r['mesh']},ok,"
              f"{t['bottleneck']},{t['t_compute_s']:.3e},"
              f"{t['t_memory_s']:.3e},{t['t_collective_s']:.3e},"
              f"{'' if ufr is None else f'{ufr:.3f}'}", flush=True)


# ---------------------------------------------------------------------------
# Round-loop dispatch benchmark (engine scan vs Python loop; no paper
# table — backs the split-step engine's fused round program).
# ---------------------------------------------------------------------------

def bench_round_loop(quick: bool) -> None:
    from benchmarks.round_loop import bench_round_loop as _bench

    res = _bench(rounds=5 if quick else 20)
    for variant in ("python_loop", "scan", "scan_unrolled"):
        print(f"round_loop,steps_per_sec,{variant},"
              f"{res[variant]['steps_per_sec']},,"
              f"{res[variant]['seconds']}", flush=True)


# ---------------------------------------------------------------------------
# Participation benchmark (fed-layer masked rounds; no paper table —
# backs the pluggable federation layer's static-slot masking).
# ---------------------------------------------------------------------------

def bench_participation(quick: bool) -> None:
    from benchmarks.participation import bench_participation as _bench

    res = _bench(rounds=3 if quick else 10)
    for frac, entry in res["masked"].items():
        for variant in ("rolled", "unrolled"):
            print(f"participation,{frac},{variant},"
                  f"{entry[variant]['rounds_per_sec']},,"
                  f"{entry[variant]['seconds']}", flush=True)
    sub = res["subset_restacked_frac=0.5"]
    print(f"participation,frac=0.5,subset_restacked,"
          f"{sub['rounds_per_sec']},,{sub['seconds']}", flush=True)


# ---------------------------------------------------------------------------
# Dispatch benchmark (round fusion x donation x precision; no paper
# table — backs the PR-5 dispatch-efficiency layer).
# ---------------------------------------------------------------------------

def bench_dispatch(quick: bool) -> None:
    from benchmarks.dispatch import bench_dispatch as _bench

    res = _bench(rounds=48 if quick else 192)
    for mode, entry in res["modes"].items():
        for key, row in entry.items():
            if key == "fused_speedup":
                print(f"dispatch,{mode},fused_speedup,{row},,", flush=True)
            else:
                print(f"dispatch,{mode},{key},{row['rounds_per_sec']},,"
                      f"{row['seconds']}", flush=True)


def bench_boundary(quick: bool) -> None:
    from benchmarks.boundary import GRID, bench_boundary as _bench

    res = _bench(grid=GRID[:2] if quick else GRID, reps=3 if quick else 5)
    for backend, entry in res["backends"].items():
        for key, row in entry.items():
            cell = key.replace(",", ";")      # grid keys hold commas (CSV)
            if key in ("max_speedup", "min_speedup"):
                print(f"boundary,{backend},{cell},{row},,", flush=True)
            else:
                print(f"boundary,{backend},{cell},{row['fused_speedup']},,"
                      f"{row['fused_ms']}", flush=True)


# ---------------------------------------------------------------------------
# Async execution-layer benchmark (sparse-slot gather + event throughput;
# no paper table — backs the asynchronous split-federated runtime).
# ---------------------------------------------------------------------------

def bench_scale(quick: bool) -> None:
    from benchmarks.scale import bench_arrival, bench_scale as _bench

    res = _bench(ks=(100, 10_000) if quick else (100, 10_000, 1_000_000),
                 events=8 if quick else 16)
    for K, entry in res["K"].items():
        for leg in ("dense", "delta"):
            row = entry.get(leg, {})
            if "rounds_per_sec" in row:
                print(f"scale,K={K},{leg},{row['rounds_per_sec']},"
                      f"{row['state_bytes']['snapshot_bytes']},"
                      f"{row['seconds']}", flush=True)
    for K, flat in res["delta_flatness"].items():
        print(f"scale,K={K},delta_flatness,{flat},,", flush=True)
    arr = bench_arrival(ks=(10_000,) if quick else (10_000, 1_000_000),
                        events=8 if quick else 16)
    for K, entry in arr["K"].items():
        for leg in ("sort", "topk", "topk:sharded"):
            print(f"scale,K={K},arrival={leg},"
                  f"{entry[leg]['rounds_per_sec']},,"
                  f"{entry[leg]['seconds']}", flush=True)
        print(f"scale,K={K},topk_speedup_vs_sort,"
              f"{entry['topk_speedup_vs_sort']},,", flush=True)


def bench_async(quick: bool) -> None:
    from benchmarks.async_rounds import bench_async as _bench

    res = _bench(rounds=3 if quick else 10)
    for frac, entry in res["sparse_vs_masked"].items():
        for variant in ("masked", "sparse"):
            print(f"async,{frac},{variant},"
                  f"{entry[variant]['rounds_per_sec']},,"
                  f"{entry[variant]['seconds']}", flush=True)
    for spec, entry in res["async_events"].items():
        print(f"async,delay={spec},events,"
              f"{entry['events_per_sec']},"
              f"{entry['mean_cohort_staleness']},"
              f"{entry['seconds']}", flush=True)


def bench_serve(quick: bool) -> None:
    from benchmarks.serve import bench_serve as _bench

    res = _bench(arch=None if quick else "qwen1.5-0.5b",
                 n_requests=8 if quick else 12,
                 slots_list=(2,) if quick else (2, 4),
                 reps=2)
    for slots, entry in res["slots"].items():
        for leg in ("batch", "open_loop"):
            for admission in ("static", "continuous"):
                row = entry[leg][admission]
                print(f"serve,slots={slots}:{leg},{admission},"
                      f"{row['tok_per_sec']},,{row['seconds']}", flush=True)
            print(f"serve,slots={slots}:{leg},continuous_speedup,"
                  f"{entry[leg]['continuous_speedup']},,", flush=True)
        row = entry["paged"]
        print(f"serve,slots={slots}:paged,continuous,"
              f"{row['tok_per_sec']},,{row['seconds']}", flush=True)


def bench_faults(quick: bool) -> None:
    from benchmarks.faults import bench_faults as _bench

    res = _bench(K=4 if quick else 8, rounds=2 if quick else 4,
                 reps=2 if quick else 3)
    for mode, entry in res["modes"].items():
        for guards, row in entry.items():
            if guards == "unguarded_s_per_round":
                print(f"faults,{mode},unguarded,,,{row}", flush=True)
            else:
                print(f"faults,{mode},{guards.replace(',', ';')},"
                      f"{row['guard_overhead']},,{row['s_per_round']}",
                      flush=True)
    ch = res["chaos"]
    print(f"faults,chaos={ch['faults'].replace(',', ';')},nonfinite,"
          f"{ch['final_loss']},{ch['rejected_total']},", flush=True)


TABLES = {
    "t1": bench_table1,
    "t2": bench_table2,
    "t3": bench_table3,
    "t5": bench_table5,
    "t7": bench_table7,
    "t8": bench_table8,
    "round_loop": bench_round_loop,
    "participation": bench_participation,
    "async": bench_async,
    "dispatch": bench_dispatch,
    "boundary": bench_boundary,
    "scale": bench_scale,
    "roofline": bench_roofline,
    "serve": bench_serve,
    "faults": bench_faults,
}


def smoke() -> None:
    """Minimal end-to-end pass of the harness (CI bit-rot check): one
    tiny accuracy experiment through each sync execution mode (the
    ``api.ExecutionSpec`` names; ``async`` is covered by
    ``benchmarks.async_rounds --smoke``), one fused/bf16 run through the
    dispatch knobs, the dispatch fusion regression guard, the
    split-boundary fused-vs-dual loss guard, the delta-vs-dense snapshot
    scale guard, the topk-vs-sort arrival-pop guard, the
    continuous-vs-static serving guard, the guarded-aggregation
    chaos/overhead guard, plus the roofline
    reprint. The dispatch/scale/boundary/faults benches also have their
    own --smoke."""
    from benchmarks.boundary import smoke_guard as boundary_smoke_guard
    from benchmarks.dispatch import smoke_guard
    from benchmarks.faults import smoke_guard as faults_smoke_guard
    from benchmarks.scale import (arrival_smoke_guard,
                                  smoke_guard as scale_smoke_guard)
    from benchmarks.serve import smoke_guard as serve_smoke_guard

    print(HEADER, flush=True)
    for execution in ("subset", "masked", "sparse"):
        res = run_experiment("scala", alpha=2, K=4, r=0.5, T=2, rounds=2,
                             n_train=300, execution=execution)
        _emit("SMOKE", f"exec={execution}", "scala", res)
    res = run_experiment("fedavg", alpha=2, K=4, r=0.5, T=2, rounds=2,
                         n_train=300, server_optimizer="momentum",
                         server_lr=0.9)
    _emit("SMOKE", "fedavgm", "fedavg", res)
    res = run_experiment("scala", alpha=2, K=4, r=0.5, T=2, rounds=3,
                         n_train=300, execution="masked",
                         rounds_per_call=2, precision="bf16")
    _emit("SMOKE", "fused+bf16", "scala", res)
    # regression guard: fused rounds must be >= as fast as unfused ones
    # (shared with `benchmarks.dispatch --smoke`)
    guard = smoke_guard()
    print("SMOKE,dispatch_guard,fused_speedup,"
          f"{guard['modes']['async']['fused_speedup']},,", flush=True)
    # regression guard: the one-pass (fused) split-boundary loss stage
    # must be >= as fast as the two value_and_grad passes (shared with
    # `benchmarks.boundary --smoke`)
    bguard = boundary_smoke_guard()
    print("SMOKE,boundary_guard,fused_speedup,"
          f"{bguard['backends']['lace']['max_speedup']},,", flush=True)
    # regression guard: O(cohort + ring) delta snapshots must be >= as
    # fast as the dense (K, ...) scatter at K=1e4 (shared with
    # `benchmarks.scale --smoke`)
    sguard = scale_smoke_guard()
    print("SMOKE,scale_guard,delta_speedup_vs_dense,"
          f"{sguard['K']['10000']['delta_speedup_vs_dense']},,", flush=True)
    # regression guard: the O(K)-work top-k arrival pop must be >= as
    # fast as the per-event lexsort at K=1e4 (shared with
    # `benchmarks.scale --smoke`)
    aguard = arrival_smoke_guard()
    print("SMOKE,arrival_guard,topk_speedup_vs_sort,"
          f"{aguard['K']['10000']['topk_speedup_vs_sort']},,", flush=True)
    # regression guard: continuous batching must sustain >= the static
    # wave-barrier token rate on the serve engine (shared with
    # `benchmarks.serve --smoke`)
    vguard = serve_smoke_guard()
    print("SMOKE,serve_guard,continuous_speedup,"
          f"{vguard['slots']['2']['batch']['continuous_speedup']},,",
          flush=True)
    # regression guard: a 10%-corruption chaos run under guards must
    # complete with finite loss, and always-on guards at zero faults
    # must stay within 2x the unguarded round (shared with
    # `benchmarks.faults --smoke`)
    fguard = faults_smoke_guard()
    print("SMOKE,faults_guard,chaos_final_loss,"
          f"{fguard['chaos']['final_loss']},"
          f"{fguard['chaos']['rejected_total']},", flush=True)
    bench_roofline(True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--table", default=None, choices=sorted(TABLES))
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="paper-protocol settings (slow)")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal harness pass (CI)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    quick = args.quick and not args.full

    print(HEADER, flush=True)
    names = [args.table] if args.table else list(TABLES)
    for name in names:
        TABLES[name](quick)


if __name__ == "__main__":
    main()
