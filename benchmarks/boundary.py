"""Split-boundary loss-stage microbenchmark: fused (one-pass) vs dual.

The SCALA split step evaluates the adjusted CE twice — eq. 14 with the
concatenated prior P_s for the server update, eq. 15 with the per-client
priors P_k for the client gradients. PR 8 adds the ``boundary="fused"``
schedule (:data:`repro.core.engine.BOUNDARIES`): both values and both
cotangents from ONE pass over a shared ``feats @ w_head`` product —
:func:`repro.kernels.lace.ops.lace2_grads` for the LACE backends (4
matmul-equivalents per chunk vs. 8 for two ``value_and_grad`` passes),
:func:`repro.core.losses.dual_adjusted_xent` over the shared
materialized logits for the ``"logits"`` baseline. Gradients are
bit-identical f32 either way (``tests/test_boundary.py``), so this
benchmark is purely about wall-clock.

This bench times the LOSS STAGE in isolation (the boundary fusion's
whole effect; trunk/client compute is identical between schedules) over
a head-width x token-count x chunk grid per backend, both schedules
jitted the way the engine jits them. ``fused_speedup`` = dual seconds /
fused seconds per cell; the summary keys report the grid max/min.

The chunk axis is load-bearing: at cache-sized chunks (the mandatory
regime on accelerators, where the chunk bounds VMEM) the loss stage is
compute-bound and the halved matmul/exp count shows directly (~1.7-1.8x
on this container's XLA:CPU). The grid keeps one full-token-count chunk
cell as the memory-bound reference — there each chunk's logits buffer
(tokens x V x 4B, far beyond LLC) makes both schedules stream the same
bytes and the ratio sits near 1.0x, which is the honest reading for the
engine's CPU default (:func:`repro.core.engine.default_ce_chunk` caps
by element count, i.e. effectively unchunked at small vocab). The
``logits`` backend rows are near-1.0x by construction — that baseline
already shares the materialized logits between the two losses, so
fusion only merges elementwise softmax passes; it wins modestly at
cache-resident batches and LOSES at streaming sizes (the one-pass
:func:`~repro.core.losses.dual_adjusted_xent` keeps both sides'
intermediates live where XLA's per-side value_and_grad fusion streams
them), so the LACE backends carry the fusion win and the logits rows
are recorded as the honest baseline reading.

Device gating: the result carries the platform stamp every BENCH json
gets (:func:`benchmarks.common.device_info`), and ``--device`` asserts
the bench is running on the platform a committed number claims —
CPU medians here say nothing about TPU, where the Pallas ``lace2``
kernels (one logits tile feeding both NLL/LSE streams in VMEM) take
over from the XLA chunked scan. The bf16-input leg only runs on
accelerators (``cpu`` has no native bf16 matmul — its numbers would
gate nothing).

  PYTHONPATH=src python -m benchmarks.boundary [--reps 5]
  PYTHONPATH=src python -m benchmarks.boundary --smoke   # CI guard:
      asserts the fused schedule is no slower than the dual one
  PYTHONPATH=src python -m benchmarks.boundary --device tpu  # assert
      the recorded platform (accelerator-claimed numbers)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

# (head width d, token count per group, ce chunk); the last cell chunks
# at the full token count — the memory-bound one-chunk reference
GRID = ((128, 2048, 512), (256, 4096, 1024), (512, 2048, 512),
        (256, 4096, 4096))
BACKENDS = ("lace", "logits")
G = 4                # client groups (lace backend)
V = 8192             # classes / vocab
TAU = 1.3


def _lace_case(d: int, n: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    feats = jax.random.normal(key, (G, n, d), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (d, V),
                          jnp.float32) * 0.02
    labels = jax.random.randint(jax.random.fold_in(key, 2), (G, n), 0, V)
    p_s = jax.nn.softmax(
        jax.random.normal(jax.random.fold_in(key, 3), (V,)))[None]
    p_k = jax.nn.softmax(
        jax.random.normal(jax.random.fold_in(key, 4), (G, V)), axis=-1)
    return feats, w, labels, p_s, p_k


def _lace_pair(d: int, n: int, ck: int):
    """(dual_fn, fused_fn, args) for the LACE loss stage — the exact
    patterns of the engine's ``backend="lace"`` branch."""
    from repro.kernels.lace.ops import lace2_grads, lace_loss

    feats, w, labels, p_s, p_k = _lace_case(d, n)
    ids = jnp.arange(G)

    @jax.jit
    def dual(f, wh):
        ls, (gf_s, gw_s) = jax.value_and_grad(
            lambda a, b: lace_loss(a, b, labels, p_s, None, None,
                                   TAU, 1e-8, ck), argnums=(0, 1))(f, wh)
        lk, gf_k = jax.value_and_grad(
            lambda a: lace_loss(a, wh, labels, p_k, ids, None,
                                TAU, 1e-8, ck))(f)
        return ls, lk, gf_s, gf_k, gw_s

    @jax.jit
    def fused(f, wh):
        return lace2_grads(f, wh, labels, p_s, None, p_k, ids, None,
                           TAU, 1e-8, ck)[:5]

    return dual, fused, (feats, w)


def _logits_pair(d: int, n: int, ck: int):
    """(dual_fn, fused_fn, args) for the logits backend's loss stage
    over materialized (tokens, V) logits; ``d`` only scales the token
    count so both backends sweep the same grid labels, and ``ck`` is
    ignored (this baseline is unchunked by design)."""
    from repro.core import losses

    key = jax.random.PRNGKey(1)
    B = n * G // 2
    logits = jax.random.normal(key, (B, V), jnp.float32)
    labels = jax.random.randint(jax.random.fold_in(key, 2), (B,), 0, V)
    p_s = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 3), (V,)))
    p_k = jax.nn.softmax(
        jax.random.normal(jax.random.fold_in(key, 4), (B, V)), axis=-1)

    @jax.jit
    def dual(lg):
        ls, g_s = jax.value_and_grad(
            lambda z: losses.softmax_xent(z, labels, prior=p_s,
                                          tau=TAU))(lg)
        lk, g_k = jax.value_and_grad(
            lambda z: losses.softmax_xent(z, labels, prior=p_k,
                                          tau=TAU))(lg)
        return ls, lk, g_s, g_k

    @jax.jit
    def fused(lg):
        return losses.dual_adjusted_xent(lg, labels, prior_s=p_s,
                                         prior_k=p_k, tau=TAU)

    return dual, fused, (logits,)


def _median_time(fn, args, reps: int) -> float:
    jax.block_until_ready(fn(*args))                         # warm/compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def bench_boundary(grid=GRID, backends=BACKENDS, reps: int = 3):
    res = {
        "bench": "boundary",
        "config": {"groups": G, "classes": V, "tau": TAU,
                   "grid": [list(c) for c in grid], "reps": reps},
        "backend": jax.default_backend(),
        "backends": {},
    }
    for backend in backends:
        entry = {}
        for d, n, ck in grid:
            dual, fused, args = (_lace_pair(d, n, ck) if backend == "lace"
                                 else _logits_pair(d, n, ck))
            td = _median_time(dual, args, reps)
            tf = _median_time(fused, args, reps)
            entry[f"d={d},tokens={n},chunk={ck}"] = {
                "dual_ms": round(td * 1e3, 2),
                "fused_ms": round(tf * 1e3, 2),
                "fused_speedup": round(td / tf, 3),
            }
        ratios = [v["fused_speedup"] for v in entry.values()]
        entry["max_speedup"] = max(ratios)
        entry["min_speedup"] = min(ratios)
        res["backends"][backend] = entry
    return res


def bench_boundary_bf16(grid=GRID, reps: int = 3):
    """Accelerator-only leg: bf16 feats/head through the same pair (the
    chunked ops upcast per chunk; on TPU/GPU the halved input traffic
    compounds with the halved matmul count). Gated OUT on CPU — XLA:CPU
    emulates bf16 matmuls through f32, so the numbers would claim a
    device class this container doesn't have."""
    from repro.kernels.lace.ops import lace2_grads, lace_loss

    entry = {}
    for d, n, ck in grid:
        feats, w, labels, p_s, p_k = _lace_case(d, n)
        feats, w = feats.astype(jnp.bfloat16), w.astype(jnp.bfloat16)
        ids = jnp.arange(G)

        @jax.jit
        def dual(f, wh):
            ls, (gf_s, gw_s) = jax.value_and_grad(
                lambda a, b: lace_loss(a, b, labels, p_s, None, None,
                                       TAU, 1e-8, ck),
                argnums=(0, 1))(f, wh)
            lk, gf_k = jax.value_and_grad(
                lambda a: lace_loss(a, wh, labels, p_k, ids, None,
                                    TAU, 1e-8, ck))(f)
            return ls, lk, gf_s, gf_k, gw_s

        @jax.jit
        def fused(f, wh):
            return lace2_grads(f, wh, labels, p_s, None, p_k, ids, None,
                               TAU, 1e-8, ck)[:5]

        td = _median_time(dual, (feats, w), reps)
        tf = _median_time(fused, (feats, w), reps)
        entry[f"d={d},tokens={n},chunk={ck}"] = {
            "dual_ms": round(td * 1e3, 2),
            "fused_ms": round(tf * 1e3, 2),
            "fused_speedup": round(td / tf, 3),
        }
    return entry


def smoke_guard():
    """The fused-vs-dual regression guard shared by
    ``benchmarks.boundary --smoke`` and ``benchmarks.run --smoke``.

    One small cache-chunked LACE cell (the backend whose fusion carries
    the split engine; the compute-bound regime where the ratio is
    meaningful): asserts fused wall-clock <= dual. Wall-clock ratios on
    a shared CI box are noisy, so a sub-1.0 first measurement gets ONE
    re-measure before failing — a real regression fails twice, a
    scheduler hiccup doesn't. Returns the last measured result dict."""
    ratio = 0.0
    res = None
    for attempt in (0, 1):
        res = bench_boundary(grid=((128, 1024, 256),), backends=("lace",),
                             reps=3)
        ratio = res["backends"]["lace"]["max_speedup"]
        print(f"fused-vs-dual loss-stage ratio: {ratio}"
              + (" (retry)" if attempt else ""))
        if ratio >= 1.0:
            break
    assert ratio >= 1.0, (
        f"boundary fusion regressed: the one-pass loss stage runs at "
        f"{ratio}x the two-pass rate (expected >= 1; reproduced twice)")
    return res


def main():
    from benchmarks.common import device_info, emit_bench

    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="one-cell guard, no json written; asserts the "
                         "fused loss stage is >= as fast as the dual "
                         "one (CI regression guard)")
    ap.add_argument("--device", default=None,
                    help="assert the benchmark runs on this jax platform "
                         "(cpu/tpu/gpu) before timing — committed "
                         "accelerator numbers must not silently come "
                         "from a CPU container")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    dev = device_info()
    if args.device is not None and dev["platform"] != args.device:
        raise SystemExit(f"--device {args.device} but running on "
                         f"{dev['platform']}; refusing to record")

    if args.smoke:
        res = smoke_guard()
    else:
        res = bench_boundary(reps=args.reps)
        if dev["platform"] != "cpu":
            res["bf16"] = bench_boundary_bf16(reps=args.reps)
        else:
            res["bf16"] = "gated: accelerator-only leg (platform=cpu)"
    emit_bench(res, args.out, "BENCH_boundary.json", args.smoke)


if __name__ == "__main__":
    main()
