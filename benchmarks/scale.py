"""Scale benchmark: the client axis from 1e2 to 1e6 simulated clients.

The tentpole claim of the O(cohort + ring) async state
(``ExecutionSpec.snapshots="delta"``): event throughput at a FIXED
arrival cohort must be flat in the total client count K, because nothing
per-event touches O(K) *param-sized* state — snapshots are reconstructed
from a ``ring_size``-deep ring of recent global client halves
(:func:`repro.fed.runtime.ring_lookup`), the cohort trains on
cohort-sized batches, and only the (K,) version/finish-time scalars (8
bytes/client) remain per-client. The dense baseline scatters a (K, ...)
snapshot copy of the client half every event, so its rounds/s decays
with K and its resident bytes grow as O(K x |w_c|).

Both legs run the REAL runtime program (:func:`fed.make_async_runner`,
``backend="logits"``, micro AlexNet split, lognormal delays,
``emit_client_metrics=False``) on cohort-sized batches; per K the bench
reports rounds/s (warm, median-of-``reps``) and the
:func:`fed.async_state_bytes` accounting. Dense is skipped above
``--dense-max-k`` (default 1e5) — at K=1e6 the dense snapshots alone
would materialize ~K x |w_c| bytes, which is the point.

Headline numbers land in ``BENCH_scale.json`` (README §Scaling the
client axis); ``delta_flatness`` is rounds/s at the smallest K over
rounds/s at K, per K (acceptance: within 1.3x through K=1e4).

The :func:`bench_arrival` legs isolate the event *scheduler*: the delta
runner with ``arrival`` = ``sort`` (per-event O(K log K) lexsort) vs
``topk`` (O(K)-work composite-key ``lax.top_k`` pop, bit-identical) vs
``topk:sharded`` (per-shard pop + merge through shard_map). At K=1e6
the lexsort dominates the event, so ``topk_speedup_vs_sort`` is the
tentpole headline.

  PYTHONPATH=src python -m benchmarks.scale [--events 16] [--cohort 8]
  PYTHONPATH=src python -m benchmarks.scale --smoke   # CI guards:
      asserts delta rounds/s >= dense AND topk >= sort at the K=1e4
      micro config
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import fed, optim
from repro.configs import ScalaConfig
from repro.core import engine
from repro.core.scala import alexnet_split_model
from repro.core.split import stack_client_params
from repro.models import alexnet as A

KS = (100, 10_000, 1_000_000)
DENSE_MAX_K = 100_000


def _setup_model(width: float, num_classes: int = 10):
    model = alexnet_split_model("s2", num_classes=num_classes)
    full = A.init_params(jax.random.PRNGKey(0), num_classes=num_classes,
                         width=width)
    wc, ws = A.split_params(full, "s2")
    return model, wc, ws


def _cohort_batches(cohort: int, T: int, Bk: int, num_classes: int = 10):
    """Cohort-sized round batches — (T, cohort, Bk, ...), never (T, K,
    ...): the arrivals consume them directly, so batch materialization
    is O(cohort) regardless of K."""
    key = jax.random.PRNGKey(2)
    return {"x": jax.random.normal(key, (T, cohort, Bk, 32, 32, 3),
                                   jnp.float32),
            "labels": jax.random.randint(jax.random.fold_in(key, 1),
                                         (T, cohort, Bk), 0, num_classes),
            "weights": jnp.ones((T, cohort, Bk), jnp.float32)}


def _mk_leg(model, wc, ws, *, K: int, cohort: int, snapshots: str,
            ring: int, arrival: str = "sort", mesh=None):
    sc = ScalaConfig(lr=0.05)
    dm = fed.make_delays("lognormal:1:1")
    runner = jax.jit(fed.make_async_runner(
        model, sc, backend="logits", delays=dm, cohort=cohort,
        snapshots=snapshots, ring_size=ring, num_clients=K,
        emit_client_metrics=False, arrival=arrival, mesh=mesh),
        donate_argnums=(0, 1))
    slots = 1 if snapshots == "delta" else K
    params = {"client": stack_client_params(wc, slots), "server": ws}
    # the stacked client half and the afed snapshots alias the same
    # broadcast buffers — donation needs every argument leaf distinct
    state = jax.tree.map(jnp.copy,
                         engine.init_train_state(params, optim.sgd()))
    afed = fed.init_async_state(jax.random.PRNGKey(1), params["client"], dm,
                                snapshots=snapshots, ring_size=ring,
                                num_clients=K,
                                mesh=mesh if arrival == "topk:sharded"
                                else None)
    return runner, state, afed


def _time_leg(runner, state, afed, batches, events: int, reps: int = 3):
    """Warm the program, then time ``events`` async events (state
    threads call to call, donated); median of ``reps``."""
    state, afed, _ = runner(state, afed, batches)
    jax.block_until_ready(jax.tree.leaves(state)[0])
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(events):
            state, afed, _ = runner(state, afed, batches)
        jax.block_until_ready(jax.tree.leaves(state)[0])
        times.append(time.perf_counter() - t0)
    secs = sorted(times)[len(times) // 2]
    return ({"seconds": round(secs, 4),
             "rounds_per_sec": round(events / secs, 2)}, afed)


def bench_scale(ks=KS, cohort: int = 8, T: int = 2, Bk: int = 4,
                events: int = 16, width: float = 0.03125, ring: int = 64,
                reps: int = 3, dense_max_k: int = DENSE_MAX_K):
    """Returns the result dict (also printed/serialized by main)."""
    model, wc, ws = _setup_model(width)
    batches = _cohort_batches(cohort, T, Bk)
    res = {
        "bench": "scale",
        "config": {"cohort": cohort, "local_iters": T,
                   "per_client_batch": Bk, "events": events,
                   "model": f"alexnet-w{width}", "ring_size": ring,
                   "delays": "lognormal:1:1", "dense_max_k": dense_max_k},
        "backend": jax.default_backend(),
        "K": {},
    }
    for K in ks:
        # a 1e6-client pop costs an O(K log K) lexsort per event —
        # fewer timed events keep the sweep tractable without touching
        # the per-event cost being measured
        ev = events if K <= 100_000 else max(2, events // 8)
        entry = {}
        for snapshots in ("dense", "delta"):
            if snapshots == "dense" and K > dense_max_k:
                entry["dense"] = {"skipped":
                                  f"K={K} dense snapshots would "
                                  "materialize K x |w_c| bytes"}
                continue
            runner, state, afed = _mk_leg(model, wc, ws, K=K, cohort=cohort,
                                          snapshots=snapshots, ring=ring)
            timing, afed = _time_leg(runner, state, afed, batches, ev,
                                     reps=reps)
            timing["state_bytes"] = fed.async_state_bytes(afed)
            entry[snapshots] = timing
        if "rounds_per_sec" in entry.get("dense", {}):
            entry["delta_speedup_vs_dense"] = round(
                entry["delta"]["rounds_per_sec"]
                / entry["dense"]["rounds_per_sec"], 3)
        res["K"][str(K)] = entry
    base = res["K"][str(ks[0])]["delta"]["rounds_per_sec"]
    res["delta_flatness"] = {
        str(K): round(base / res["K"][str(K)]["delta"]["rounds_per_sec"], 3)
        for K in ks}
    return res


ARRIVAL_KS = (10_000, 1_000_000)


def bench_arrival(ks=ARRIVAL_KS, cohort: int = 8, T: int = 2, Bk: int = 4,
                  events: int = 16, width: float = 0.03125, ring: int = 64,
                  reps: int = 3):
    """The arrival-pop microbench: sort vs topk vs topk:sharded event
    rate on the delta runner (the schedule pop is the only thing that
    differs — the training work per event is identical, so the rate
    ratio isolates the pop).

    The legacy per-event lexsort is O(K log K) and dominates the event
    at K=1e6; the composite-key top-k pop is O(K) work on the fast f32
    ``lax.top_k`` path and bit-identical (tests/test_arrival.py). The
    ``topk:sharded`` leg runs the per-shard pop + merge through
    ``shard_map`` over ALL local devices — on a single-device CPU bench
    box that measures the shard_map overhead, not a distribution win
    (``shards`` in the config says which); its purpose at scale is the
    memory layout (no (K,) scalar ever resident on one device), not
    single-host rate.
    """
    import numpy as np
    from jax.sharding import Mesh

    model, wc, ws = _setup_model(width)
    batches = _cohort_batches(cohort, T, Bk)
    mesh = Mesh(np.asarray(jax.devices()), ("data",))
    res = {
        "config": {"cohort": cohort, "local_iters": T,
                   "per_client_batch": Bk, "events": events,
                   "model": f"alexnet-w{width}", "ring_size": ring,
                   "delays": "lognormal:1:1", "snapshots": "delta",
                   "shards": jax.device_count()},
        "K": {},
    }
    for K in ks:
        entry = {}
        for arrival in ("sort", "topk", "topk:sharded"):
            # the sort leg at K=1e6 runs ~1.5 ev/s — time fewer events
            # there so the sweep stays tractable (rates are normalized)
            ev = (max(2, events // 8)
                  if arrival == "sort" and K > 100_000 else events)
            runner, state, afed = _mk_leg(
                model, wc, ws, K=K, cohort=cohort, snapshots="delta",
                ring=ring, arrival=arrival,
                mesh=mesh if arrival == "topk:sharded" else None)
            timing, _ = _time_leg(runner, state, afed, batches, ev,
                                  reps=reps)
            entry[arrival] = timing
        entry["topk_speedup_vs_sort"] = round(
            entry["topk"]["rounds_per_sec"]
            / entry["sort"]["rounds_per_sec"], 3)
        res["K"][str(K)] = entry
    return res


def smoke_guard():
    """The delta-vs-dense regression guard shared by
    ``benchmarks.scale --smoke`` and ``benchmarks.run --smoke``.

    At the K=1e4 micro config the dense leg scatters a (K, ...) snapshot
    copy per event while delta touches O(cohort + ring); asserts delta
    rounds/s >= dense. Wall-clock ratios are noisy even at median-of-3,
    so a sub-1.0 first measurement gets ONE re-measure before failing —
    a real regression fails twice, a scheduler hiccup doesn't. Returns
    the last measured result dict."""
    res = None
    for attempt in (0, 1):
        res = bench_scale(ks=(10_000,), events=8, reps=3)
        ratio = res["K"]["10000"]["delta_speedup_vs_dense"]
        print(f"delta-vs-dense rounds/s ratio at K=1e4: {ratio}"
              + (" (retry)" if attempt else ""))
        if ratio >= 1.0:
            break
    assert ratio >= 1.0, (
        f"delta snapshots regressed: {ratio}x the dense event rate at "
        "K=1e4 (expected >= 1; reproduced twice)")
    return res


def arrival_smoke_guard():
    """The topk-vs-sort pop regression guard shared by
    ``benchmarks.scale --smoke`` and ``benchmarks.run --smoke``.

    The top-k pop replaces the per-event lexsort with strictly less
    work; asserts topk events/s >= sort at the K=1e4 micro config, with
    the same one-re-measure-on-noise policy as :func:`smoke_guard`.
    Returns the last measured result dict."""
    res = None
    for attempt in (0, 1):
        res = bench_arrival(ks=(10_000,), events=8, reps=3)
        ratio = res["K"]["10000"]["topk_speedup_vs_sort"]
        print(f"topk-vs-sort event rate ratio at K=1e4: {ratio}"
              + (" (retry)" if attempt else ""))
        if ratio >= 1.0:
            break
    assert ratio >= 1.0, (
        f"topk arrival pop regressed: {ratio}x the lexsort event rate "
        "at K=1e4 (expected >= 1; reproduced twice)")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ks", type=int, nargs="+", default=list(KS))
    ap.add_argument("--cohort", type=int, default=8)
    ap.add_argument("--T", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--events", type=int, default=16)
    ap.add_argument("--width", type=float, default=0.03125)
    ap.add_argument("--ring", type=int, default=64)
    ap.add_argument("--dense-max-k", type=int, default=DENSE_MAX_K)
    ap.add_argument("--smoke", action="store_true",
                    help="K=1e4 only, no json written; asserts the delta "
                         "event rate is >= the dense one (CI guard)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.smoke:
        res = smoke_guard()
        res["arrival_smoke"] = arrival_smoke_guard()["K"]
    else:
        res = bench_scale(ks=tuple(args.ks), cohort=args.cohort, T=args.T,
                          Bk=args.batch, events=args.events,
                          width=args.width, ring=args.ring,
                          dense_max_k=args.dense_max_k)
        res["arrival"] = bench_arrival(cohort=args.cohort, T=args.T,
                                       Bk=args.batch, events=args.events,
                                       width=args.width, ring=args.ring)
    from benchmarks.common import emit_bench
    emit_bench(res, args.out, "BENCH_scale.json", args.smoke)


if __name__ == "__main__":
    main()
