"""Serving benchmark: continuous vs static batching on the serve engine.

The tentpole claim of :mod:`repro.serve`: with mixed generation lengths,
continuous batching (admit into freed slots, no generation barrier)
sustains a higher token rate than static (wave-barrier) batching on the
SAME engine, cache, and jitted dispatches — the only difference is the
admission schedule, so the rate ratio isolates the scheduling win.

Two legs per slot count:

* ``batch`` — every request present at t=0 (``wall_clock=False``,
  deterministic schedule; median-of-``reps``). Static pays
  ``max(gen)`` steps per wave while short sequences hold dead slots;
  continuous backfills immediately.
* ``open_loop`` — requests arrive on the wall clock with inter-arrival
  gaps sampled from :func:`repro.fed.delays.make_delays` (the same
  delay models the async federation layer uses — serving arrivals are
  the same heavy-tailed process). Reports per-request latency
  p50/p99 (arrival -> finish) alongside tok/s.

A ``paged`` leg re-runs the continuous batch leg from the paged pool
(:class:`repro.serve.cache.PagedOps`) — output is bit-identical
(test-enforced), so the entry reports the cache-bytes ratio and the
gather/scatter overhead.

  PYTHONPATH=src python -m benchmarks.serve [--arch qwen1.5-0.5b --reduced]
  PYTHONPATH=src python -m benchmarks.serve --smoke   # CI guard:
      continuous tok/s >= static on the micro config (one re-measure)

Headline numbers land in ``BENCH_serve.json`` (README §Serving).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.fed.delays import make_delays
from repro.models import transformer as T
from repro.serve import Request, ServeEngine

# registry-free micro decoder for the CI smoke guard: compile cost is
# seconds, so the guard measures scheduling, not XLA
MICRO = ModelConfig(
    name="micro-serve", family="dense", source="bench", num_layers=2,
    d_model=32, num_heads=2, num_kv_heads=1, head_dim=16, d_ff=64,
    vocab_size=97, split_layer=1, dtype="float32", param_dtype="float32")

PROMPT_LENS = (8, 16)
GENS = (4, 16)                     # mixed budgets: the continuous win


def _setup(arch, reduced):
    if arch is None:
        cfg = MICRO
    else:
        from repro.configs import get_config
        cfg = get_config(arch)
        cfg = cfg.reduced() if reduced else cfg
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _requests(cfg, n, prompt_lens, gens, gap_spec, gap_scale, seed=0):
    """n mixed-length requests; open-loop arrivals = cumulative gaps
    sampled from the federation delay model (`gap_scale` seconds/unit)."""
    key = jax.random.PRNGKey(seed)
    gaps = np.asarray(make_delays(gap_spec).sample(
        jax.random.fold_in(key, 1), (n,))) * gap_scale
    arrivals = np.concatenate([[0.0], np.cumsum(gaps)[:-1]])
    reqs = []
    for i in range(n):
        P = prompt_lens[i % len(prompt_lens)]
        toks = np.asarray(jax.random.randint(
            jax.random.fold_in(key, 2 + i), (P,), 0, cfg.vocab_size))
        reqs.append(Request(i, toks, gens[i % len(gens)],
                            arrival=float(arrivals[i])))
    return reqs


def _percentile(xs, q):
    return round(float(np.percentile(np.asarray(xs), q)), 4)


def _run_leg(params, cfg, reqs, *, slots, max_len, admission,
             pages=0, page_size=16, open_loop=False, reps=1):
    eng = ServeEngine(params, cfg, slots=slots, max_len=max_len,
                      pages=pages, page_size=page_size, admission=admission)
    eng.warmup(sorted({len(r.tokens) for r in reqs}))
    total = sum(r.max_new for r in reqs)
    times, lats = [], []
    for _ in range(reps):
        t0 = time.time()
        res = eng.serve(list(reqs), wall_clock=open_loop)
        times.append(time.time() - t0)
        lats = [res[r.rid].latency for r in reqs]
    dt = float(np.median(times))
    out = {"seconds": round(dt, 4),
           "tok_per_sec": round(total / dt, 2),
           "cache_mb": round(eng.state_bytes() / 1e6, 3)}
    if open_loop:                  # latency is wall-clock only here
        out["latency_p50_s"] = _percentile(lats, 50)
        out["latency_p99_s"] = _percentile(lats, 99)
    return out


def bench_serve(arch=None, reduced=True, n_requests=12,
                slots_list=(2, 4), prompt_lens=PROMPT_LENS, gens=GENS,
                gap_spec="lognormal:1:1", gap_scale=0.02, reps=3,
                page_size=8):
    cfg, params = _setup(arch, reduced)
    max_len = max(prompt_lens) + max(gens)
    res = {
        "config": {"arch": cfg.name, "n_requests": n_requests,
                   "prompt_lens": list(prompt_lens), "gens": list(gens),
                   "max_len": max_len, "gap_delays": gap_spec,
                   "gap_scale_s": gap_scale, "page_size": page_size,
                   "reps": reps},
        "slots": {},
    }
    reqs = _requests(cfg, n_requests, prompt_lens, gens, gap_spec, gap_scale)
    for slots in slots_list:
        entry = {}
        for leg, open_loop in (("batch", False), ("open_loop", True)):
            sub = {}
            for admission in ("static", "continuous"):
                sub[admission] = _run_leg(
                    params, cfg, reqs, slots=slots, max_len=max_len,
                    admission=admission, open_loop=open_loop,
                    reps=1 if open_loop else reps)
            sub["continuous_speedup"] = round(
                sub["continuous"]["tok_per_sec"]
                / sub["static"]["tok_per_sec"], 3)
            entry[leg] = sub
        # paged pool sized to the live worst case; bit-identical output
        pages = slots * -(-max_len // page_size)
        paged = _run_leg(params, cfg, reqs, slots=slots, max_len=max_len,
                         admission="continuous", pages=pages,
                         page_size=page_size, reps=reps)
        paged["pages"] = pages
        paged["cache_ratio_vs_dense"] = round(
            paged["cache_mb"] / entry["batch"]["continuous"]["cache_mb"], 3)
        entry["paged"] = paged
        res["slots"][str(slots)] = entry
    return res


def smoke_guard():
    """The continuous-vs-static regression guard shared by
    ``benchmarks.serve --smoke`` and ``benchmarks.run --smoke``.

    On the micro decoder with mixed generation budgets, continuous
    admission must sustain >= the static-wave token rate (it runs
    strictly fewer decode dispatches for the same tokens). Wall-clock
    ratios are noisy, so a sub-1.0 first measurement gets ONE
    re-measure before failing. Returns the last measured result dict."""
    ratio = None
    res = None
    for attempt in (0, 1):
        res = bench_serve(arch=None, n_requests=8, slots_list=(2,),
                          prompt_lens=(6, 6), gens=(2, 10),
                          gap_scale=0.0, reps=3)
        ratio = res["slots"]["2"]["batch"]["continuous_speedup"]
        print(f"continuous-vs-static tok/s ratio (2 slots): {ratio}"
              + (" (retry)" if attempt else ""))
        if ratio >= 1.0:
            break
    assert ratio >= 1.0, (
        f"continuous batching regressed: {ratio}x the static token rate "
        "(expected >= 1; reproduced twice)")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b",
                    help="'micro' = the registry-free smoke decoder")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--n", type=int, default=12)
    ap.add_argument("--slots", type=int, nargs="+", default=[2, 4])
    ap.add_argument("--gap-scale", type=float, default=0.02)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="micro config, no json written; asserts the "
                         "continuous tok/s >= static (CI guard)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.smoke:
        res = smoke_guard()
    else:
        arch = None if args.arch == "micro" else args.arch
        res = bench_serve(arch=arch, reduced=args.reduced,
                          n_requests=args.n, slots_list=tuple(args.slots),
                          gap_scale=args.gap_scale, reps=args.reps)
    from benchmarks.common import emit_bench
    emit_bench(res, args.out, "BENCH_serve.json", args.smoke)


if __name__ == "__main__":
    main()
