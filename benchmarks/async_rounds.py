"""Async execution-layer benchmark: sparse-slot compute and event
throughput.

Two questions, both about the round execution path (no paper table —
backs the asynchronous split-federated execution layer):

1. **sparse-slot vs masked** — the fed layer's static-slot masking pays
   full-K client compute at every participation fraction
   (``BENCH_participation.json``); the engine's ``slot_gather`` path
   gathers the fixed-size subset into a dense axis before the local scan.
   For frac in {0.25, 0.5, 1.0} this times one scanned round each way
   and reports the speedup (the acceptance bar: frac=0.25 sparse ≤ 0.5×
   the masked round's time on CPU).

2. **event throughput vs delay distribution** — the async runner
   (``fed.make_async_runner``) pops a fixed-size arrival cohort per
   event; the delay distribution decides arrival order and staleness,
   not the per-event compute (cohort is static), so events/sec should be
   flat across distributions while mean staleness grows with the tail.
   Reported per delay spec: events/sec, local steps/sec, and the mean
   cohort staleness over the run.

Writes ``BENCH_async.json`` next to this file (or to ``--out``).

  PYTHONPATH=src python -m benchmarks.async_rounds [--rounds 10] [--K 8]
  PYTHONPATH=src python -m benchmarks.async_rounds --smoke   # CI
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from benchmarks.round_loop import _setup
from repro import fed, optim
from repro.configs import ScalaConfig
from repro.core import engine

FRACTIONS = (0.25, 0.5, 1.0)
DELAY_SPECS = ("constant:1", "uniform:0.5:2", "lognormal:1:1.5")


def _time_calls(fn, n: int):
    """Warm once, then time n calls of the nullary closure (which must
    return something blockable)."""
    jax.block_until_ready(jax.tree.leaves(fn())[0])
    t0 = time.perf_counter()
    out = None
    for _ in range(n):
        out = fn()
    jax.block_until_ready(jax.tree.leaves(out)[0])
    return time.perf_counter() - t0


def bench_async(rounds: int = 10, K: int = 8, Bk: int = 16, T: int = 5,
                lr: float = 0.05, cohort: int = 0):
    """Returns the result dict (also printed/serialized by main)."""
    model, params, rb, sizes = _setup(K, Bk, T)
    sc = ScalaConfig(num_clients=K, participation=1.0, local_iters=T, lr=lr)
    state = engine.init_train_state(params, optim.sgd())
    res = {
        "bench": "async_rounds",
        "config": {"rounds": rounds, "clients": K, "per_client_batch": Bk,
                   "local_iters": T, "lr": lr, "model": "alexnet-w0.125"},
        "backend": jax.default_backend(),
        "sparse_vs_masked": {},
        "async_events": {},
    }

    # --- 1. sparse-slot gather vs static-slot masking ---
    for frac in FRACTIONS:
        part = fed.uniform(K, frac)
        agg = fed.fedavg()
        entry = {}
        for name, gather in (("masked", False), ("sparse", True)):
            round_fn = jax.jit(engine.make_round_runner(
                model, sc, backend="logits", unroll=True, aggregator=agg,
                participation=part, slot_gather=gather))
            fs = fed.init_fed_state(jax.random.PRNGKey(1), agg, part)

            def call(round_fn=round_fn, fs=fs):
                s, _, _ = round_fn(state, rb, sizes, fs)
                return s.params

            secs = _time_calls(call, rounds)
            entry[name] = {"seconds": round(secs, 4),
                           "rounds_per_sec": round(rounds / secs, 2)}
        entry["sparse_over_masked"] = round(
            entry["sparse"]["seconds"] / entry["masked"]["seconds"], 3)
        res["sparse_vs_masked"][f"frac={frac}"] = entry

    # --- 2. async event throughput vs delay distribution ---
    m = cohort if cohort > 0 else max(1, K // 4)
    res["config"]["cohort"] = m
    for spec in DELAY_SPECS:
        dm = fed.make_delays(spec)
        async_fn = jax.jit(fed.make_async_runner(
            model, sc, backend="logits", delays=dm, cohort=m,
            staleness_decay=0.5, unroll=True))
        afed0 = fed.init_async_state(jax.random.PRNGKey(2),
                                     params["client"], dm)

        # warm
        s, af, mt = async_fn(state, afed0, rb, sizes)
        jax.block_until_ready(jax.tree.leaves(s.params)[0])
        t0 = time.perf_counter()
        s, af = state, afed0
        stales = []           # device scalars; no host sync inside the loop
        for _ in range(rounds):
            s, af, mt = async_fn(s, af, rb, sizes)
            stales.append(mt["staleness_mean"])
        jax.block_until_ready(jax.tree.leaves(s.params)[0])
        secs = time.perf_counter() - t0
        res["async_events"][spec] = {
            "seconds": round(secs, 4),
            "events_per_sec": round(rounds / secs, 2),
            "local_steps_per_sec": round(rounds * T / secs, 2),
            "mean_cohort_staleness": round(
                float(jnp.mean(jnp.stack(stales))), 3),
        }
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--K", type=int, default=8)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--T", type=int, default=5)
    ap.add_argument("--cohort", type=int, default=0,
                    help="arrivals per async event (0 = K/4)")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal sizes, no json written (CI bit-rot check)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.smoke:
        res = bench_async(rounds=2, K=4, Bk=4, T=2)
    else:
        res = bench_async(rounds=args.rounds, K=args.K, Bk=args.batch,
                          T=args.T, cohort=args.cohort)
    from benchmarks.common import emit_bench
    emit_bench(res, args.out, "BENCH_async.json", args.smoke)


if __name__ == "__main__":
    main()
