"""Round-loop benchmark: Python-loop dispatch vs the engine's
scan-compiled round (`engine.make_round_runner`).

All variants run the identical SCALA math (logits backend, plain SGD) on
the paper's width-scaled AlexNet; the only difference is dispatch:

  python_loop    T jitted step calls + FedAvg per round (legacy driver)
  scan           ONE jitted program per round, rolled lax.scan (small HLO
                 — the production setting for the deep archs; note
                 XLA:CPU executes while-loop bodies with reduced
                 parallelism, so this loses on CPU at toy scale)
  scan_unrolled  ONE jitted program per round, scan fully unrolled —
                 single dispatch AND no loop serialization

Reports steps/sec and writes ``BENCH_round_loop.json`` next to this file
(or to ``--out``).

  PYTHONPATH=src python -m benchmarks.round_loop [--rounds 20] [--T 5]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs import ScalaConfig
from repro.core import engine
from repro.core.scala import alexnet_split_model, scala_round
from repro.models import alexnet as A


def _setup(C: int, Bk: int, T: int, num_classes: int = 10, width: float = 0.125,
           seed: int = 0):
    model = alexnet_split_model("s2", num_classes=num_classes)
    full = A.init_params(jax.random.PRNGKey(seed), num_classes=num_classes,
                         width=width)
    wc, ws = A.split_params(full, "s2")
    params = {"client": jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (C,) + a.shape), wc),
        "server": ws}
    rng = np.random.default_rng(seed)
    rb = {
        "x": jnp.asarray(rng.normal(size=(T, C, Bk, 32, 32, 3)),
                         jnp.float32),
        "labels": jnp.asarray(rng.integers(0, num_classes, (T, C, Bk)),
                              jnp.int32),
        "weights": jnp.ones((T, C, Bk), jnp.float32),
    }
    sizes = jnp.ones((C,), jnp.float32)
    return model, params, rb, sizes


def bench_round_loop(rounds: int = 20, C: int = 4, Bk: int = 16, T: int = 5,
                     lr: float = 0.05):
    """Returns the result dict (also printed/serialized by main)."""
    model, params, rb, sizes = _setup(C, Bk, T)
    sc = ScalaConfig(num_clients=C, participation=1.0, local_iters=T, lr=lr)

    # --- baseline: Python loop, one jitted dispatch per local step ---
    from repro.core.scala import scala_local_step
    step = jax.jit(lambda p, b: scala_local_step(model, p, b, sc))
    p0, _ = scala_round(model, params, rb, sc, sizes, local_step=step)  # warm
    jax.block_until_ready(jax.tree.leaves(p0)[0])
    t0 = time.perf_counter()
    p_loop = params
    for _ in range(rounds):
        p_loop, _ = scala_round(model, p_loop, rb, sc, sizes, local_step=step)
    jax.block_until_ready(jax.tree.leaves(p_loop)[0])
    t_loop = time.perf_counter() - t0

    # --- engine: T local iterations + FedAvg in one scanned program ---
    state = engine.init_train_state(params, optim.sgd())
    steps = rounds * T
    res = {
        "bench": "round_loop",
        "config": {"rounds": rounds, "clients": C, "per_client_batch": Bk,
                   "local_iters": T, "lr": lr, "model": "alexnet-w0.125"},
        "python_loop": {"seconds": round(t_loop, 4),
                        "steps_per_sec": round(steps / t_loop, 2)},
        "backend": jax.default_backend(),
    }
    for name, unroll in (("scan", 1), ("scan_unrolled", True)):
        round_fn = jax.jit(engine.make_round_runner(model, sc,
                                                    backend="logits",
                                                    unroll=unroll))
        s0, _ = round_fn(state, rb, sizes)                              # warm
        jax.block_until_ready(jax.tree.leaves(s0.params)[0])
        t0 = time.perf_counter()
        s = state
        for _ in range(rounds):
            s, _ = round_fn(s, rb, sizes)
        jax.block_until_ready(jax.tree.leaves(s.params)[0])
        t = time.perf_counter() - t0
        # sanity: every driver lands on the same params
        drift = max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree.leaves(p_loop), jax.tree.leaves(s.params)))
        res[name] = {"seconds": round(t, 4),
                     "steps_per_sec": round(steps / t, 2),
                     "speedup_vs_loop": round(t_loop / t, 3),
                     "max_param_drift": drift}
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--T", type=int, default=5)
    ap.add_argument("--smoke", action="store_true",
                    help="minimal sizes, no json written (CI bit-rot check)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.smoke:
        res = bench_round_loop(rounds=2, C=2, Bk=4, T=2)
    else:
        res = bench_round_loop(rounds=args.rounds, C=args.clients,
                               Bk=args.batch, T=args.T)
    from benchmarks.common import emit_bench
    emit_bench(res, args.out, "BENCH_round_loop.json", args.smoke)


if __name__ == "__main__":
    main()
