import numpy as np
import pytest

from repro.data.loader import FederatedData, lm_round_batches, round_batches, sample_clients
from repro.data.partition import dirichlet_skew, partition, quantity_skew
from repro.data.synthetic import gaussian_images, token_stream


def test_quantity_skew_class_bound():
    rng = np.random.default_rng(0)
    labels = np.repeat(np.arange(10), 100)
    parts = quantity_skew(labels, num_clients=20, alpha=2, num_classes=10,
                          rng=rng)
    assert len(parts) == 20
    all_idx = np.concatenate(parts)
    assert len(all_idx) <= len(labels)
    for p in parts:
        classes = np.unique(labels[p])
        assert len(classes) <= 2  # at most alpha classes per client


def test_dirichlet_skew_partitions_everything():
    rng = np.random.default_rng(0)
    labels = np.repeat(np.arange(5), 50)
    parts = dirichlet_skew(labels, num_clients=8, beta=0.5, num_classes=5,
                           rng=rng)
    total = sum(len(p) for p in parts)
    assert total == len(labels)
    assert min(len(p) for p in parts) >= 2


def test_dirichlet_strong_skew_missing_classes():
    rng = np.random.default_rng(1)
    labels = np.repeat(np.arange(10), 100)
    parts = dirichlet_skew(labels, num_clients=10, beta=0.05, num_classes=10,
                           rng=rng)
    missing = sum(len(np.unique(labels[p])) < 10 for p in parts)
    assert missing >= 5  # strong skew -> most clients missing classes


def test_partition_dispatch():
    labels = np.repeat(np.arange(4), 25)
    with pytest.raises(AssertionError):
        partition(labels, 4)  # neither alpha nor beta
    p1 = partition(labels, 4, alpha=2, seed=0)
    p2 = partition(labels, 4, beta=0.5, seed=0)
    assert len(p1) == len(p2) == 4


def test_round_batches_shapes_and_weights():
    x, y = gaussian_images(200, num_classes=4, seed=0)
    parts = partition(y, 10, beta=0.3, num_classes=4, seed=0)
    data = FederatedData.from_partition(x, y, parts)
    rng = np.random.default_rng(0)
    sel = sample_clients(10, 4, rng)
    rb = round_batches(data, sel, server_batch=32, local_iters=3, rng=rng)
    T, C, Bk = rb["labels"].shape
    assert (T, C) == (3, 4)
    assert rb["x"].shape[:3] == (3, 4, Bk)
    # eq (3): per-client real rows proportional to |D_k|
    for ci in range(C):
        real = rb["weights"][0, ci].sum()
        assert real >= 1
    assert rb["sizes"].shape == (4,)


def test_lm_round_batches_next_token():
    docs, _ = token_stream(20, doc_len=17, vocab=50, seed=0)
    by_client = [docs[:10], docs[10:]]
    rng = np.random.default_rng(0)
    rb = lm_round_batches(by_client, np.array([0, 1]), server_batch=8,
                          local_iters=2, rng=rng)
    assert rb["tokens"].shape == rb["labels"].shape
    assert rb["tokens"].shape[-1] == 16
    # next-token alignment: labels are tokens shifted by one
    t, c, b = 0, 0, 0
    # can't check alignment directly (random docs), but ranges must be valid
    assert rb["tokens"].max() < 50 and rb["labels"].max() < 50


def test_token_stream_domain_skew():
    docs, domains = token_stream(100, doc_len=64, vocab=200, num_domains=4,
                                 seed=0)
    # different domains -> different unigram distributions
    def hist(d):
        sel = docs[domains == d].reshape(-1)
        h = np.bincount(sel, minlength=200).astype(float)
        return h / h.sum()
    h0, h1 = hist(0), hist(1)
    tv = 0.5 * np.abs(h0 - h1).sum()
    assert tv > 0.3  # strongly different


def test_gaussian_images_learnable_structure():
    x, y = gaussian_images(500, num_classes=4, seed=0)
    assert x.shape == (500, 32, 32, 3)
    # class means differ
    m0 = x[y == 0].mean(axis=0)
    m1 = x[y == 1].mean(axis=0)
    assert np.abs(m0 - m1).mean() > 0.1
