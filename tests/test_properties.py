"""Hypothesis property-based tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the 'hypothesis' extra (pip install .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import label_stats, losses
from repro.core.split import client_minibatch_sizes, fedavg
from repro.data.partition import quantity_skew
from repro.models.layers import rope

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(st.lists(st.integers(0, 9), min_size=1, max_size=200))
def test_prior_is_distribution(labels):
    p = label_stats.prior(label_stats.histogram(jnp.array(labels), 10))
    assert float(p.sum()) == np.testing.assert_allclose(
        float(p.sum()), 1.0, atol=1e-5) or True
    assert (np.asarray(p) >= 0).all()


@given(st.lists(st.integers(0, 4), min_size=2, max_size=50),
       st.floats(0.1, 10.0))
def test_histogram_weight_scaling(labels, scale):
    """Scaling all weights leaves the prior unchanged."""
    lab = jnp.array(labels)
    w = jnp.ones_like(lab, jnp.float32)
    p1 = label_stats.prior(label_stats.histogram(lab, 5, w))
    p2 = label_stats.prior(label_stats.histogram(lab, 5, w * scale))
    np.testing.assert_allclose(p1, p2, atol=1e-5)


@given(st.integers(2, 64), st.integers(2, 12))
def test_xent_shift_invariance(n, v):
    """softmax CE is invariant to adding a constant to all logits."""
    key = jax.random.PRNGKey(n * 13 + v)
    logits = jax.random.normal(key, (n, v))
    labels = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, v)
    l1 = losses.softmax_xent(logits, labels)
    l2 = losses.softmax_xent(logits + 3.7, labels)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


@given(st.integers(2, 10))
def test_uniform_prior_adjustment_is_noop(v):
    """eq. (14) with uniform P(y) == plain CE (up to the constant shift)."""
    key = jax.random.PRNGKey(v)
    logits = jax.random.normal(key, (8, v))
    labels = jax.random.randint(jax.random.fold_in(key, 1), (8,), 0, v)
    uniform = jnp.full((v,), 1.0 / v)
    l1 = losses.softmax_xent(logits, labels)
    l2 = losses.softmax_xent(logits, labels, prior=uniform)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)


@given(st.lists(st.floats(0.1, 100.0), min_size=2, max_size=8))
def test_fedavg_convexity(sizes):
    """Weighted average lies within [min, max] of client values."""
    C = len(sizes)
    key = jax.random.PRNGKey(C)
    vals = jax.random.normal(key, (C, 5))
    avg = fedavg({"w": vals}, jnp.array(sizes))["w"]
    assert (np.asarray(avg) <= np.asarray(vals.max(0)) + 1e-5).all()
    assert (np.asarray(avg) >= np.asarray(vals.min(0)) - 1e-5).all()


@given(st.lists(st.integers(1, 1000), min_size=1, max_size=10),
       st.integers(8, 512))
def test_minibatch_sizes_bounds(sizes, B):
    """eq. (3): every B_k >= 1 and sum <= B + C (flooring slack)."""
    bks = client_minibatch_sizes(sizes, B)
    assert (bks >= 1).all()
    assert bks.sum() <= B + len(sizes)


@given(st.integers(2, 8), st.integers(1, 4))
def test_quantity_skew_class_cap(num_classes, alpha):
    rng = np.random.default_rng(0)
    labels = np.repeat(np.arange(num_classes), 40)
    parts = quantity_skew(labels, 6, alpha, num_classes, rng)
    for p in parts:
        assert len(np.unique(labels[p])) <= max(1, alpha)


@given(st.integers(2, 32), st.integers(1, 60))
def test_rope_norm_preserving(half_pairs, pos):
    hd = half_pairs * 2
    key = jax.random.PRNGKey(hd + pos)
    x = jax.random.normal(key, (1, 3, 2, hd))
    y = rope.apply_rope(x, jnp.full((3,), pos), 10_000.0)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-4)


@given(st.integers(1, 6), st.integers(1, 6), st.integers(2, 6))
def test_lace_equals_ref_property(g, n_chunks, v):
    """Chunked LACE == materialized-logits reference for random shapes."""
    from repro.kernels.lace.ops import lace_loss
    from repro.kernels.lace.ref import lace_ref
    N = n_chunks * 4
    d = 8
    key = jax.random.PRNGKey(g * 100 + N + v)
    feats = jax.random.normal(key, (g, N, d))
    W = jax.random.normal(jax.random.fold_in(key, 1), (d, v)) * 0.2
    labels = jax.random.randint(jax.random.fold_in(key, 2), (g, N), 0, v)
    got = lace_loss(feats, W, labels, None, None, None, 1.0, 1e-8, 4)
    ref = lace_ref(feats.reshape(-1, d), W, labels.reshape(-1))
    np.testing.assert_allclose(float(got), float(ref), rtol=2e-5)


@given(st.integers(0, 2**31 - 1), st.integers(2, 4), st.floats(1.0, 4.0))
def test_moe_dispatch_conserves_tokens(seed, top_k, cap_factor):
    """With ample capacity, every routed token lands in exactly one
    expert slot per assignment (the vmapped per-group scatter must not
    drop or duplicate) and the combine weights sum to 1 per token."""
    from helpers import tiny_moe_cfg
    from repro.configs.base import MoEConfig
    from repro.models.layers import moe

    import dataclasses
    cfg = dataclasses.replace(
        tiny_moe_cfg(), moe=MoEConfig(num_experts=4, top_k=top_k,
                                      d_expert=16,
                                      capacity_factor=float(cap_factor)))
    key = jax.random.PRNGKey(seed)
    params = moe.moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model))
    y, aux = moe.moe_apply(params, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) >= 0.0
    # capacity_factor >= top_k guarantees no drops for <=8 tokens/group:
    # then output equals the dense brute-force reference
    if cap_factor >= 2.0:
        m = cfg.moe
        logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                            params["router"])
        gates = jax.nn.softmax(logits, -1)
        top_w, top_i = jax.lax.top_k(gates, m.top_k)
        top_w = top_w / top_w.sum(-1, keepdims=True)
        ref = jnp.zeros_like(x)
        for e in range(m.num_experts):
            h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, params["gate"][e])) \
                * jnp.einsum("bsd,df->bsf", x, params["up"][e])
            y_e = jnp.einsum("bsf,fd->bsd", h, params["down"][e])
            w_e = jnp.where(top_i == e, top_w, 0.0).sum(-1)
            ref = ref + y_e * w_e[..., None].astype(x.dtype)
        cap = moe.capacity(8, m)
        if cap >= 8 * m.top_k // m.num_experts + 8:  # truly ample only
            np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                       rtol=2e-2, atol=2e-2)
