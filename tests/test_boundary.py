"""One-pass split boundary (``boundary="fused"``) vs the two-pass dual.

Three layers under test:

* the ``lace2_*`` fused dual-prior ops against two-call compositions of
  the single-prior reference (values + grads for BOTH priors, prime
  token counts, zero-weight clients, bf16 inputs);
* the engine's per-backend fused-vs-dual contract: all gradients —
  hence the parameter updates — bit-identical f32 (``logits``, ``lace``
  here; ``lace_dp`` on a real 4-device mesh in the subprocess test),
  loss metrics equal for ``logits`` and 1-ulp for the LACE backends
  (their dual baseline reads values through ``value_and_grad``, whose
  residual-saving scan compiles to different roundings — see the
  ``repro.core.engine`` docstring);
* the spec/CLI surface: ``ExecutionSpec.boundary`` validation and the
  ``launch/train.py --boundary`` round-trip.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ScalaConfig
from repro.core import engine
from repro.kernels.lace.ops import (lace2_grads, lace2_loss, lace2_nll_sum,
                                    lace_loss, lace_nll_sum)
from repro.kernels.lace.ref import lace_ref


# --------------------------------------------------------------------------
# lace2 ops vs two-call reference compositions
# --------------------------------------------------------------------------


def _case(G, N, d, V, seed, dtype=jnp.float32, zero_client=False):
    key = jax.random.PRNGKey(seed)
    feats = jax.random.normal(key, (G, N, d)).astype(dtype)
    W = (jax.random.normal(jax.random.fold_in(key, 1), (d, V)) * 0.1
         ).astype(dtype)
    labels = jax.random.randint(jax.random.fold_in(key, 2), (G, N), 0, V)
    p_s = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 3), (V,)))
    p_k = jax.nn.softmax(
        jax.random.normal(jax.random.fold_in(key, 4), (G, V)), axis=-1)
    w = jax.random.uniform(jax.random.fold_in(key, 5), (G, N)) + 0.1
    if zero_client:
        w = w.at[0].set(0.0)                    # masked-out client
    return feats, W, labels, p_s, p_k, w


def _ref_pair(feats, W, labels, p_s, p_k, w, tau=1.0):
    """Two independent single-prior references over the flattened batch."""
    G, N, d = feats.shape
    f = feats.reshape(-1, d).astype(jnp.float32)
    l = labels.reshape(-1)
    wf = w.reshape(-1)
    ids = jnp.repeat(jnp.arange(G), N)
    ls = lace_ref(f, W.astype(jnp.float32), l, prior_rows=p_s[None],
                  tau=tau, weights=wf)
    lk = lace_ref(f, W.astype(jnp.float32), l, prior_rows=p_k,
                  prior_ids=ids, tau=tau, weights=wf)
    return ls, lk


@pytest.mark.parametrize("G,N,d,V", [(3, 257, 16, 61),     # prime tokens
                                     (4, 48, 24, 33),
                                     (2, 100, 8, 130)])
def test_lace2_loss_matches_two_call_reference(G, N, d, V):
    feats, W, labels, p_s, p_k, w = _case(G, N, d, V, G * V)
    got_s, got_k = lace2_loss(feats, W, labels, p_s[None], None, p_k,
                              jnp.arange(G), w, 1.3, 1e-8, 64)
    ref_s, ref_k = _ref_pair(feats, W, labels, p_s, p_k, w, tau=1.3)
    np.testing.assert_allclose(float(got_s), float(ref_s), rtol=1e-5)
    np.testing.assert_allclose(float(got_k), float(ref_k), rtol=1e-5)


def test_lace2_loss_zero_weight_client():
    feats, W, labels, p_s, p_k, w = _case(3, 40, 8, 17, 9, zero_client=True)
    got_s, got_k = lace2_loss(feats, W, labels, p_s[None], None, p_k,
                              jnp.arange(3), w, 1.0, 1e-8, 16)
    ref_s, ref_k = _ref_pair(feats, W, labels, p_s, p_k, w)
    np.testing.assert_allclose(float(got_s), float(ref_s), rtol=1e-5)
    np.testing.assert_allclose(float(got_k), float(ref_k), rtol=1e-5)


def test_lace2_loss_bf16_inputs():
    feats, W, labels, p_s, p_k, w = _case(2, 64, 16, 50, 3,
                                          dtype=jnp.bfloat16)
    got_s, got_k = lace2_loss(feats, W, labels, p_s[None], None, p_k,
                              jnp.arange(2), w, 1.0, 1e-8, 32)
    # the chunked op upcasts per chunk: f32-level agreement with the
    # f32 reference over the SAME (bf16-rounded) inputs
    ref_s, ref_k = _ref_pair(feats.astype(jnp.float32),
                             W.astype(jnp.float32), labels, p_s, p_k, w)
    np.testing.assert_allclose(float(got_s), float(ref_s), rtol=1e-5)
    np.testing.assert_allclose(float(got_k), float(ref_k), rtol=1e-5)


def test_lace2_pair_op_grads_match_reference_autodiff():
    """The custom VJP of the pair op: a weighted combination of both
    losses must backprop like the same combination of the references."""
    feats, W, labels, p_s, p_k, w = _case(3, 57, 12, 29, 11)

    def fused(f, wh):
        a, b = lace2_loss(f, wh, labels, p_s[None], None, p_k,
                          jnp.arange(3), w, 1.0, 1e-8, 16)
        return 0.7 * a + 1.3 * b

    def ref(f, wh):
        a, b = _ref_pair(f, wh, labels, p_s, p_k, w)
        return 0.7 * a + 1.3 * b

    gf, gw = jax.grad(fused, argnums=(0, 1))(feats, W)
    rf, rw = jax.grad(ref, argnums=(0, 1))(feats, W)
    np.testing.assert_allclose(gf, rf, atol=1e-6)
    np.testing.assert_allclose(gw, rw, atol=1e-6)


def test_lace2_grads_direct_form_bitwise_vs_two_pass():
    """The engine's direct form: values and per-side grads must be
    bit-identical to the exact two-pass ``value_and_grad`` patterns the
    dual engine branch runs (compared in the same eager regime)."""
    feats, W, labels, p_s, p_k, w = _case(3, 257, 16, 61, 21)
    ids = jnp.arange(3)
    ck = 64

    out_s, out_k, df_s, df_k, dw_s, w_sum = lace2_grads(
        feats, W, labels, p_s[None], None, p_k, ids, w, 1.0, 1e-8, ck)

    ls, (gf_s, gW_s) = jax.value_and_grad(
        lambda f, wh: lace_loss(f, wh, labels, p_s[None], None, w,
                                1.0, 1e-8, ck), argnums=(0, 1))(feats, W)
    lk, gf_k = jax.value_and_grad(
        lambda f: lace_loss(f, W, labels, p_k, ids, w,
                            1.0, 1e-8, ck))(feats)

    assert np.array_equal(np.asarray(df_s), np.asarray(gf_s))
    assert np.array_equal(np.asarray(df_k), np.asarray(gf_k))
    assert np.array_equal(np.asarray(dw_s), np.asarray(gW_s))
    # the direct-form values match the plain forward bitwise; the
    # value_and_grad readings sit within 1 ulp (see module docstring)
    direct_s = lace_loss(feats, W, labels, p_s[None], None, w,
                         1.0, 1e-8, ck)
    assert np.array_equal(np.asarray(out_s), np.asarray(direct_s))
    np.testing.assert_allclose(float(out_s), float(ls), rtol=1e-6)
    np.testing.assert_allclose(float(out_k), float(lk), rtol=1e-6)


def test_lace2_nll_sum_and_raw_grads_bitwise():
    """The ``mean=False`` flavor backs the lace_dp branch: raw weighted
    sums + unit-cotangent grads, bitwise vs the ``lace_nll_sum`` pair."""
    feats, W, labels, p_s, p_k, w = _case(2, 53, 8, 19, 33)
    ids = jnp.arange(2)
    ck = 16

    ns, nk, df_s, df_k, dw_s, _ = lace2_grads(
        feats, W, labels, p_s[None], None, p_k, ids, w, 1.0, 1e-8, ck,
        mean=False)
    pair = lace2_nll_sum(feats, W, labels, p_s[None], None, p_k, ids, w,
                         1.0, 1e-8, ck)
    assert np.array_equal(np.asarray(ns), np.asarray(pair[0]))
    assert np.array_equal(np.asarray(nk), np.asarray(pair[1]))

    _, (gf_s, gW_s) = jax.value_and_grad(
        lambda f, wh: lace_nll_sum(f, wh, labels, p_s[None], None, w,
                                   1.0, 1e-8, ck), argnums=(0, 1))(feats, W)
    _, gf_k = jax.value_and_grad(
        lambda f: lace_nll_sum(f, W, labels, p_k, ids, w,
                               1.0, 1e-8, ck))(feats)
    assert np.array_equal(np.asarray(df_s), np.asarray(gf_s))
    assert np.array_equal(np.asarray(df_k), np.asarray(gf_k))
    assert np.array_equal(np.asarray(dw_s), np.asarray(gW_s))


# --------------------------------------------------------------------------
# engine: fused vs dual, per backend
# --------------------------------------------------------------------------


def _grads_bitwise(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y)), \
            f"leaf {np.shape(x)} differs"


def _engine_setups():
    from test_engine import _setup_alexnet, _setup_transformer
    from helpers import tiny_cfg

    cfg = tiny_cfg()
    yield ("transformer",) + _setup_transformer(jax.random.PRNGKey(0), cfg)
    yield ("alexnet",) + _setup_alexnet(jax.random.PRNGKey(1))


@pytest.mark.parametrize("backend", ["logits", "lace"])
def test_engine_fused_grads_bitwise(backend):
    for name, model, params, batch in _engine_setups():
        if backend != "logits" and model.server_trunk is None:
            continue
        for adj in ((True, True), (True, False), (False, True)):
            sc = ScalaConfig(tau=1.3, adjust_server=adj[0],
                             adjust_client=adj[1])
            gd, md = engine.split_step_grads(model, params, batch, sc,
                                             backend=backend,
                                             boundary="dual")
            gf, mf = engine.split_step_grads(model, params, batch, sc,
                                             backend=backend,
                                             boundary="fused")
            _grads_bitwise(gd, gf)
            if backend == "logits":
                _grads_bitwise(md, mf)          # metrics incl. accuracy
            else:
                for k in md:                    # LACE metrics: 1 ulp
                    np.testing.assert_allclose(np.asarray(md[k]),
                                               np.asarray(mf[k]),
                                               rtol=1e-6)


def test_engine_logits_label_smoothing_falls_back_to_dual():
    """ls > 0 must route the fused request through the dual schedule —
    the outputs are then trivially bitwise equal."""
    for name, model, params, batch in _engine_setups():
        sc = ScalaConfig(tau=1.0, label_smoothing=0.1)
        gd, md = engine.split_step_grads(model, params, batch, sc,
                                         backend="logits", boundary="dual")
        gf, mf = engine.split_step_grads(model, params, batch, sc,
                                         backend="logits", boundary="fused")
        _grads_bitwise(gd, gf)
        _grads_bitwise(md, mf)


def test_engine_fused_with_participation_mask():
    """The fused path must fold the 0/1 mask exactly like the dual one
    (masked clients: zero loss weight, zero grads)."""
    from test_engine import _setup_transformer
    from helpers import tiny_cfg

    model, params, batch = _setup_transformer(jax.random.PRNGKey(5),
                                              tiny_cfg())
    mask = jnp.array([1.0, 0.0, 1.0])
    sc = ScalaConfig(tau=1.0)
    for backend in ("logits", "lace"):
        gd, _ = engine.split_step_grads(model, params, batch, sc,
                                        backend=backend, boundary="dual",
                                        mask=mask)
        gf, _ = engine.split_step_grads(model, params, batch, sc,
                                        backend=backend, boundary="fused",
                                        mask=mask)
        _grads_bitwise(gd, gf)
        zero = jax.tree.map(lambda g: np.asarray(g[1]), gf["client"])
        assert all(np.all(z == 0) for z in jax.tree.leaves(zero))


def test_engine_unknown_boundary_rejected():
    from test_engine import _setup_alexnet

    model, params, batch = _setup_alexnet(jax.random.PRNGKey(2))
    with pytest.raises(ValueError, match="unknown boundary"):
        engine.split_step_grads(model, params, batch, ScalaConfig(),
                                boundary="half")


# --------------------------------------------------------------------------
# lace_dp on a real mesh (subprocess, forced host devices)
# --------------------------------------------------------------------------

DP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
sys.path.insert(0, "tests")
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from helpers import tiny_cfg
from test_engine import _setup_transformer
from repro.configs import ScalaConfig
from repro.core import engine

model, params, batch = _setup_transformer(jax.random.PRNGKey(0), tiny_cfg(),
                                          C=4)
sc = ScalaConfig(tau=1.3, grad_reduce_dtype=None)
mesh = Mesh(np.array(jax.devices()).reshape(4,), ("data",))
bspecs = jax.tree.map(lambda _: P("data"), batch)

outs = {}
for boundary in ("dual", "fused"):
    new_p, mets = engine.local_step(model, params, batch, sc,
                                    backend="lace_dp", boundary=boundary,
                                    mesh=mesh, batch_specs=bspecs)
    outs[boundary] = (new_p, mets)

pd, md = outs["dual"]; pf, mf = outs["fused"]
bad = sum(0 if np.array_equal(np.asarray(x), np.asarray(y)) else 1
          for x, y in zip(jax.tree.leaves(pd), jax.tree.leaves(pf)))
merr = max(abs(float(md[k]) - float(mf[k])) /
           (1e-8 + abs(float(md[k]))) for k in md)
print("RESULT " + json.dumps({"bad_param_leaves": bad, "metric_rel": merr}))
"""


@pytest.mark.slow
def test_lace_dp_fused_params_bitwise_on_mesh():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", DP_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, out.stdout[-2000:]
    res = json.loads(line[0][len("RESULT "):])
    assert res["bad_param_leaves"] == 0, res
    assert res["metric_rel"] < 1e-6, res


# --------------------------------------------------------------------------
# spec / CLI surface
# --------------------------------------------------------------------------


def test_execution_spec_boundary_validation():
    from repro import api

    assert api.ExecutionSpec().boundary == "fused"
    assert api.ExecutionSpec(boundary="dual").boundary == "dual"
    with pytest.raises(ValueError, match="unknown boundary"):
        api.ExecutionSpec(boundary="twopass")


def test_train_cli_boundary_roundtrip(tmp_path):
    from repro import api
    from repro.launch.train import build_parser, spec_from_args

    args = build_parser().parse_args(
        ["--boundary", "dual", "--clients", "4", "--rounds", "1"])
    spec = spec_from_args(args)
    assert spec.execution.boundary == "dual"
    # JSON round-trip (the --dump-config / --config path)
    p = tmp_path / "spec.json"
    p.write_text(spec.to_json())
    back = api.ExperimentSpec.from_json(p.read_text())
    assert back.execution.boundary == "dual"
    assert back == spec
