import os
import sys

# tests import shared helpers; keep src on path when invoked bare
sys.path.insert(0, os.path.join(os.path.dirname(__file__)))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
