import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import tiny_cfg
from repro.models.layers import attention as A


@pytest.fixture
def qkv():
    key = jax.random.PRNGKey(0)
    B, S, H, hd = 2, 32, 4, 8
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    return q, k, v


def test_chunked_matches_dense(qkv):
    q, k, v = qkv
    pos = jnp.arange(q.shape[1])
    for window in (None, 8):
        dense = A.attend_dense(q, k, v, pos, pos, causal=True, window=window)
        chunked = A.attend_chunked(q, k, v, pos, pos, causal=True,
                                   window=window, chunk=8)
        np.testing.assert_allclose(dense, chunked, atol=1e-5)


def test_causal_mask(qkv):
    """Changing future tokens must not change past outputs."""
    q, k, v = qkv
    pos = jnp.arange(q.shape[1])
    out1 = A.attend_dense(q, k, v, pos, pos, causal=True, window=None)
    k2 = k.at[:, -1].set(99.0)
    v2 = v.at[:, -1].set(99.0)
    out2 = A.attend_dense(q, k2, v2, pos, pos, causal=True, window=None)
    np.testing.assert_allclose(out1[:, :-1], out2[:, :-1], atol=1e-6)


def test_window_mask(qkv):
    """Tokens beyond the window must not influence the output."""
    q, k, v = qkv
    S = q.shape[1]
    pos = jnp.arange(S)
    w = 4
    out1 = A.attend_dense(q, k, v, pos, pos, causal=True, window=w)
    k2 = k.at[:, 0].set(77.0)   # far outside the window of the last query
    v2 = v.at[:, 0].set(77.0)
    out2 = A.attend_dense(q, k2, v2, pos, pos, causal=True, window=w)
    np.testing.assert_allclose(out1[:, -1], out2[:, -1], atol=1e-6)
    # but the first token's output does change
    assert not jnp.allclose(out1[:, 0], out2[:, 0])


def _decode_all(params, x, cfg, window, cache_len):
    B, S, _ = x.shape
    cache = A.init_cache(cfg, B, cache_len, jnp.float32)
    outs = []
    for i in range(S):
        y, cache = A.attn_decode(params, x[:, i:i + 1], cache, i, cfg,
                                 window=window)
        outs.append(y)
    return jnp.concatenate(outs, axis=1)


def test_decode_matches_full_forward():
    cfg = tiny_cfg()
    key = jax.random.PRNGKey(0)
    params = A.attn_init(key, cfg)
    B, S = 2, 12
    x = jax.random.normal(jax.random.fold_in(key, 3), (B, S, cfg.d_model))
    pos = jnp.arange(S)
    full = A.attn_apply(params, x, cfg, positions=pos, window=None)
    dec = _decode_all(params, x, cfg, None, S)
    np.testing.assert_allclose(full, dec, atol=1e-4)


def test_decode_with_qkv_bias_and_gqa():
    cfg = tiny_cfg(qkv_bias=True, num_heads=4, num_kv_heads=2, head_dim=8)
    key = jax.random.PRNGKey(1)
    params = A.attn_init(key, cfg)
    x = jax.random.normal(key, (2, 10, cfg.d_model))
    full = A.attn_apply(params, x, cfg, positions=jnp.arange(10))
    dec = _decode_all(params, x, cfg, None, 10)
    np.testing.assert_allclose(full, dec, atol=1e-4)


def test_ring_cache_decode_matches_windowed_forward():
    from repro.models import blocks as B
    from repro.configs.base import BlockSpec
    cfg = tiny_cfg(window_pattern=(4,))
    spec = BlockSpec(mixer="attn", ffn="dense", window=4)
    key = jax.random.PRNGKey(2)
    params = B.block_init(key, spec, cfg)
    S = 14
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, S, cfg.d_model))
    full, _ = B.block_apply(params, x, spec, cfg, positions=jnp.arange(S))
    cache = B.block_cache_init(spec, cfg, 2, S, jnp.float32)
    assert cache["k"].shape[1] == 4  # ring buffer is window-sized
    outs = []
    for i in range(S):
        y, cache = B.block_decode(params, x[:, i:i + 1], cache, i, spec, cfg)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(full, dec, atol=1e-4)


def test_cross_attention_shapes():
    cfg = tiny_cfg()
    key = jax.random.PRNGKey(3)
    params = A.attn_init(key, cfg, cross=True)
    x = jax.random.normal(key, (2, 6, cfg.d_model))
    mem = jax.random.normal(jax.random.fold_in(key, 1), (2, 9, cfg.d_model))
    y = A.cross_attn_apply(params, x, mem, cfg)
    assert y.shape == x.shape and jnp.isfinite(y).all()
