"""Equivalence + behavior tests for the unified split-step engine.

Every legacy step variant must be reproduced by the corresponding engine
backend (the legacy entry points are now thin wrappers, so these tests
pin the *stateful* optimizer path against the stateless plain-SGD path),
and the scan-compiled round must match the Python-loop round.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import rand_batch, tiny_cfg
from repro import optim
from repro.configs import ScalaConfig
from repro.core import engine
from repro.core.scala import (alexnet_split_model, scala_local_step,
                              scala_local_step_fused, scala_round,
                              transformer_split_model)
from repro.models import alexnet as A
from repro.models import transformer as T


def _tree_allclose(a, b, atol=2e-5, rtol=1e-4):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(x, y, atol=atol, rtol=rtol)


def _setup_transformer(key, cfg, C=3, Bk=2, S=8):
    model = transformer_split_model(cfg)
    params = engine.init_scala_params(
        key, lambda k: T.init_params(k, cfg)["client"],
        lambda k: T.init_params(k, cfg)["server"], C)
    b = rand_batch(key, cfg, Bk, S)
    batch = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (C,) + a.shape), b)
    batch = dict(batch)
    batch["labels"] = jax.random.randint(jax.random.fold_in(key, 9),
                                         (C, Bk, S), 0, cfg.vocab_size)
    return model, params, batch


def _setup_alexnet(key, C=3, Bk=4, num_classes=10):
    model = alexnet_split_model("s2", num_classes=num_classes)
    full = A.init_params(key, num_classes=num_classes, width=0.125)
    wc, ws = A.split_params(full, "s2")
    params = {"client": jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (C,) + a.shape), wc),
        "server": ws}
    kx, ky = jax.random.split(jax.random.fold_in(key, 1))
    batch = {"x": jax.random.normal(kx, (C, Bk, 32, 32, 3)),
             "labels": jax.random.randint(ky, (C, Bk), 0, num_classes),
             "weights": jnp.ones((C, Bk), jnp.float32)}
    return model, params, batch


# --------------------------------------------------------------------------
# per-backend equivalence: stateful engine step == legacy plain-SGD step
# --------------------------------------------------------------------------


def test_engine_logits_backend_matches_legacy_transformer():
    cfg = tiny_cfg()
    model, params, batch = _setup_transformer(jax.random.PRNGKey(0), cfg)
    sc = ScalaConfig(lr=0.05)
    p_legacy, m_legacy = scala_local_step(model, params, batch, sc)

    step = engine.make_split_step(model, sc, backend="logits")
    state, m = step(engine.init_train_state(params, optim.sgd()), batch)
    assert int(state.step) == 1
    np.testing.assert_allclose(m["loss_server"], m_legacy["loss_server"],
                               rtol=1e-6)
    np.testing.assert_allclose(m["loss_client"], m_legacy["loss_client"],
                               rtol=1e-6)
    _tree_allclose(state.params, p_legacy)


def test_engine_lace_backend_matches_legacy_fused():
    cfg = tiny_cfg()
    model, params, batch = _setup_transformer(jax.random.PRNGKey(1), cfg)
    sc = ScalaConfig(lr=0.05)
    p_legacy, m_legacy = scala_local_step_fused(model, params, batch, sc,
                                                ce_chunk=8)
    step = engine.make_split_step(model, sc, backend="lace", ce_chunk=8)
    state, m = step(engine.init_train_state(params, optim.sgd()), batch)
    np.testing.assert_allclose(m["loss_server"], m_legacy["loss_server"],
                               rtol=1e-6)
    _tree_allclose(state.params, p_legacy)


def test_engine_logits_backend_matches_legacy_alexnet():
    model, params, batch = _setup_alexnet(jax.random.PRNGKey(2))
    sc = ScalaConfig(lr=0.05)
    p_legacy, m_legacy = scala_local_step(model, params, batch, sc)
    step = jax.jit(engine.make_split_step(model, sc, backend="logits"))
    state, m = step(engine.init_train_state(params, optim.sgd()), batch)
    np.testing.assert_allclose(m["loss_server"], m_legacy["loss_server"],
                               rtol=1e-5)
    np.testing.assert_allclose(m["accuracy"], m_legacy["accuracy"],
                               rtol=1e-6)
    _tree_allclose(state.params, p_legacy)


def test_lace_backend_requires_trunk():
    model, params, batch = _setup_alexnet(jax.random.PRNGKey(3))
    sc = ScalaConfig()
    with pytest.raises(ValueError, match="server_trunk"):
        engine.split_step_grads(model, params, batch, sc, backend="lace")
    with pytest.raises(ValueError, match="unknown backend"):
        engine.split_step_grads(model, params, batch, sc, backend="nope")


# --------------------------------------------------------------------------
# scan-compiled round == Python-loop round
# --------------------------------------------------------------------------


def _round_batches(key, cfg, T_steps, C, Bk, S):
    ks = jax.random.split(key, 3)
    return {
        "tokens": jax.random.randint(ks[0], (T_steps, C, Bk, S), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (T_steps, C, Bk, S), 0,
                                     cfg.vocab_size),
        "weights": jnp.ones((T_steps, C, Bk, S), jnp.float32),
    }


def test_round_scan_matches_python_round():
    cfg = tiny_cfg()
    key = jax.random.PRNGKey(4)
    model, params, _ = _setup_transformer(key, cfg)
    sc = ScalaConfig(lr=0.05)
    rb = _round_batches(jax.random.fold_in(key, 5), cfg, 3, 3, 2, 8)
    sizes = jnp.array([3.0, 1.0, 2.0])

    p_ref, m_ref = scala_round(model, params, rb, sc, sizes)

    state0 = engine.init_train_state(params, optim.sgd())
    state, m = jax.jit(
        engine.make_round_runner(model, sc, backend="logits"))(
        state0, rb, sizes)
    assert int(state.step) == 3
    np.testing.assert_allclose(m["loss_server"], m_ref["loss_server"],
                               rtol=1e-5)
    np.testing.assert_allclose(m["loss_client"], m_ref["loss_client"],
                               rtol=1e-5)
    _tree_allclose(state.params, p_ref)
    # FL phase applied: all client slots re-unified
    emb = state.params["client"]["embed"]["tok"]
    np.testing.assert_allclose(emb[0], emb[1])


def test_round_scan_convenience_wrapper():
    cfg = tiny_cfg()
    model, params, _ = _setup_transformer(jax.random.PRNGKey(6), cfg)
    sc = ScalaConfig(lr=0.05)
    rb = _round_batches(jax.random.PRNGKey(7), cfg, 2, 3, 2, 8)
    state0 = engine.init_train_state(params, optim.sgd())
    state, m = engine.scala_round_scan(model, state0, rb, sc,
                                       backend="lace", ce_chunk=8)
    assert int(state.step) == 2
    assert np.isfinite(float(m["loss_server"]))


# --------------------------------------------------------------------------
# real optimizers + schedules through the engine
# --------------------------------------------------------------------------


def test_momentum_state_is_threaded_and_stacked_per_client():
    cfg = tiny_cfg()
    model, params, batch = _setup_transformer(jax.random.PRNGKey(8), cfg)
    sc = ScalaConfig(lr=0.05)
    opt = optim.momentum(beta=0.9)
    step = engine.make_split_step(model, sc, backend="logits", optimizer=opt)
    state = engine.init_train_state(params, opt)
    C = jax.tree.leaves(params["client"])[0].shape[0]
    # every client opt-state leaf carries the stacked (C, ...) axis
    for m_leaf, p_leaf in zip(jax.tree.leaves(state.opt_state["client"]),
                              jax.tree.leaves(params["client"])):
        assert m_leaf.shape == p_leaf.shape and m_leaf.shape[0] == C

    state, _ = step(state, batch)
    state, _ = step(state, batch)
    assert int(state.step) == 2
    moved = [float(jnp.abs(l).max()) > 0
             for l in jax.tree.leaves(state.opt_state["server"])]
    assert any(moved)

    # momentum must differ from plain SGD after two steps
    sgd_step = engine.make_split_step(model, sc, backend="logits")
    s2 = engine.init_train_state(params, optim.sgd())
    s2, _ = sgd_step(s2, batch)
    s2, _ = sgd_step(s2, batch)
    d = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(state.params), jax.tree.leaves(s2.params)))
    assert d > 1e-6


def test_adamw_count_advances_inside_scan():
    cfg = tiny_cfg()
    model, params, _ = _setup_transformer(jax.random.PRNGKey(9), cfg)
    sc = ScalaConfig(lr=1e-3)
    rb = _round_batches(jax.random.PRNGKey(10), cfg, 3, 3, 2, 8)
    opt = optim.adamw()
    runner = engine.make_round_runner(model, sc, backend="logits",
                                      optimizer=opt)
    state, _ = jax.jit(runner)(engine.init_train_state(params, opt), rb)
    assert int(state.opt_state["server"]["count"]) == 3
    np.testing.assert_array_equal(
        np.asarray(state.opt_state["client"]["count"]), 3)


def test_schedule_drives_lr_from_step_counter():
    cfg = tiny_cfg()
    model, params, batch = _setup_transformer(jax.random.PRNGKey(11), cfg)
    sc = ScalaConfig(lr=0.05)
    # lr is 0.05 on step 0 and 0 afterwards: steps 2-3 must be no-ops
    sched = lambda step: jnp.where(step < 1, 0.05, 0.0)
    step = engine.make_split_step(model, sc, backend="logits",
                                  schedule=sched)
    s1, _ = step(engine.init_train_state(params, optim.sgd()), batch)
    s2, _ = step(s1, batch)
    _tree_allclose(s2.params, s1.params, atol=0, rtol=0)
    assert int(s2.step) == 2

    # constant-schedule default == legacy lr semantics
    ref, _ = scala_local_step(model, params, batch, sc)
    _tree_allclose(s1.params, ref)


# --------------------------------------------------------------------------
# "lace_dp" backend: stateful engine step inside shard_map
# --------------------------------------------------------------------------

_DP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import json
import jax
import jax.numpy as jnp

from repro import optim
from repro.configs import ScalaConfig, get_config
from repro.core import engine
from repro.core.scala import transformer_split_model
from repro.launch import input_specs as ispec
from repro.models import transformer as T
from repro.sharding.logical import RULES_DP, tree_specs

cfg = get_config("qwen1.5-0.5b").reduced()
C, BK, S = 2, 2, 16
model = transformer_split_model(cfg)
key = jax.random.PRNGKey(0)
full = T.init_params(key, cfg)
params = {
    "client": jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (C,) + a.shape), full["client"]),
    "server": full["server"],
}
tokens = jax.random.randint(jax.random.PRNGKey(1), (C, BK, S), 0,
                            cfg.vocab_size)
batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=-1),
         "weights": jnp.ones((C, BK, S), jnp.float32)}
sc = ScalaConfig(num_clients=C, participation=1.0, lr=0.05,
                 grad_reduce_dtype=None)
opt = optim.momentum(beta=0.9)

# reference: no mesh, stateful lace step with the same optimizer
ref_step = jax.jit(engine.make_split_step(model, sc, backend="lace",
                                          optimizer=opt))
ref = engine.init_train_state(params, opt)
for _ in range(2):
    ref, ref_m = ref_step(ref, batch)

mesh = jax.make_mesh((2, 2), ("data", "model"))
from repro.configs.base import InputShape
shape = InputShape(name="t", seq_len=S, global_batch=C * BK, mode="train")
b_sh, b_ax = ispec.train_batch_specs(cfg, shape, C)
b_specs = tree_specs(b_ax, b_sh, mesh, RULES_DP)
dp_step = jax.jit(engine.make_split_step(model, sc, backend="lace_dp",
                                         optimizer=opt, mesh=mesh,
                                         batch_specs=b_specs))
dp = engine.init_train_state(params, opt)
for _ in range(2):
    dp, dp_m = dp_step(dp, batch)

err = {"step": int(dp.step)}
for k in ("client", "server"):
    a = jax.tree.leaves(ref.params[k]); b = jax.tree.leaves(dp.params[k])
    err[k] = max(float(jnp.max(jnp.abs(x - y)) /
                       (1e-8 + float(jnp.max(jnp.abs(x)))))
                 for x, y in zip(a, b))
err["opt"] = max(float(jnp.max(jnp.abs(x - y)))
                 for x, y in zip(jax.tree.leaves(ref.opt_state),
                                 jax.tree.leaves(dp.opt_state)))
err["loss_server"] = abs(float(ref_m["loss_server"]) -
                         float(dp_m["loss_server"]))
print("RESULT " + json.dumps(err))
"""


@pytest.mark.slow
def test_engine_dp_backend_matches_lace_with_optimizer_state():
    """The stateful engine step with backend='lace_dp' (whole step — grads
    AND optimizer update — inside one shard_map) matches backend='lace'
    on a (data=2, model=2) mesh, momentum state included."""
    import json as _json
    import os as _os
    import subprocess
    import sys as _sys

    env = dict(_os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([_sys.executable, "-c", _DP_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=_os.path.dirname(_os.path.dirname(
                             _os.path.abspath(__file__))), timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, out.stdout[-2000:]
    err = _json.loads(line[0][len("RESULT "):])
    assert err["step"] == 2, err
    assert err["loss_server"] < 1e-5, err
    assert err["client"] < 5e-4, err
    assert err["server"] < 5e-4, err
    assert err["opt"] < 5e-4, err


def test_aggregate_preserves_server_and_optimizer_state():
    cfg = tiny_cfg()
    model, params, batch = _setup_transformer(jax.random.PRNGKey(12), cfg)
    sc = ScalaConfig(lr=0.05)
    opt = optim.momentum()
    step = engine.make_split_step(model, sc, backend="logits", optimizer=opt)
    state, _ = step(engine.init_train_state(params, opt), batch)
    agg = dataclasses.replace(
        state, params=engine.scala_aggregate(state.params))
    _tree_allclose(agg.params["server"], state.params["server"], atol=0,
                   rtol=0)
    # opt state is untouched by the FL phase (only params are averaged)
    _tree_allclose(agg.opt_state, state.opt_state, atol=0, rtol=0)
