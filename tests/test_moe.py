import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import tiny_moe_cfg
from repro.models.layers import moe


def _brute_force(params, x, cfg):
    """Dense reference: every token through its top-k experts, no capacity."""
    m = cfg.moe
    act = jax.nn.silu
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    gates = jax.nn.softmax(logits, -1)
    top_w, top_i = jax.lax.top_k(gates, m.top_k)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    out = jnp.zeros_like(x)
    for e in range(m.num_experts):
        h = act(jnp.einsum("bsd,df->bsf", x, params["gate"][e])) * \
            jnp.einsum("bsd,df->bsf", x, params["up"][e])
        y_e = jnp.einsum("bsf,fd->bsd", h, params["down"][e])
        w_e = jnp.where(top_i == e, top_w, 0.0).sum(-1)
        out = out + y_e * w_e[..., None].astype(x.dtype)
    return out


def test_moe_matches_brute_force_with_ample_capacity():
    cfg = tiny_moe_cfg()
    key = jax.random.PRNGKey(0)
    params = moe.moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    y, aux = moe.moe_apply(params, x, cfg)
    ref = _brute_force(params, x, cfg)
    np.testing.assert_allclose(y, ref, atol=1e-4)
    assert jnp.isfinite(aux)


def test_moe_capacity_drops_tokens():
    import dataclasses
    cfg = tiny_moe_cfg()
    tight = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    key = jax.random.PRNGKey(0)
    params = moe.moe_init(key, tight)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 32, cfg.d_model))
    y, _ = moe.moe_apply(params, x, tight)
    ref = _brute_force(params, x, tight)
    # capacity 0.25 must drop tokens -> outputs differ from unconstrained
    assert not jnp.allclose(y, ref, atol=1e-4)
    assert jnp.isfinite(y).all()


def test_moe_aux_loss_balanced_vs_skewed():
    """Uniform routing -> aux ~ router_aux_weight; skew -> larger."""
    cfg = tiny_moe_cfg()
    key = jax.random.PRNGKey(0)
    params = moe.moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, cfg.d_model))
    _, aux_rand = moe.moe_apply(params, x, cfg)
    # force max skew: huge router bias to expert 0
    skew = dict(params)
    skew["router"] = params["router"] * 0 + \
        jnp.eye(cfg.d_model, cfg.moe.num_experts) * 100
    x0 = jnp.zeros_like(x).at[..., 0].set(10.0)  # all tokens -> expert 0
    _, aux_skew = moe.moe_apply(skew, x0, cfg)
    assert float(aux_skew) > float(aux_rand)


def test_moe_decode_single_token():
    cfg = tiny_moe_cfg()
    key = jax.random.PRNGKey(0)
    params = moe.moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 1, cfg.d_model))
    y, aux = moe.moe_apply(params, x, cfg)
    assert y.shape == x.shape and jnp.isfinite(y).all()


def test_moe_grads_flow():
    cfg = tiny_moe_cfg()
    key = jax.random.PRNGKey(0)
    params = moe.moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model))

    def loss(p):
        y, aux = moe.moe_apply(p, x, cfg)
        return (y ** 2).mean() + aux

    g = jax.grad(loss)(params)
    for name in ("router", "gate", "up", "down"):
        assert float(jnp.abs(g[name]).max()) > 0, name
