"""Fault tolerance: injection, guarded aggregation, deadlines, recovery.

The acceptance bars (ISSUE 10):

(a) with guards enabled and ZERO faults injected, round outputs are
    bit-identical (f32) to the unguarded path — masked, sparse, and
    async modes, logits + lace backends inline and lace_dp masked via
    the multi-device subprocess leg;
(b) with NaN/Inf corruption injected, training stays finite and the
    surviving-subset priors / logit adjustments match a reference round
    computed from the post-rejection participation mask (the
    SCALA-specific part: a rejected client changes the eq. 14/15
    correction exactly as if it had never participated);
(c) async deadlines: a loose deadline reproduces the legacy barrier
    bitwise; a tight one proceeds with the partial cohort and requeues
    the missed clients with exponential backoff;
(d) ``Trainer.save``/``resume`` round-trips the FULL program state —
    params, optimizer moments, async/delta/ring state, retries, fault
    keys, host RNG — bit-identically, and the checkpoint layer survives
    a torn write by falling back to the previous step;
(e) ``ServeEngine`` evicts slots past their request deadline or the
    engine token budget, freeing slots/pages for the arrival queue.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api, fed, optim
from repro.configs import ScalaConfig
from repro.core import engine
from repro.fed.faults import FaultModel, make_faults
from repro.fed.guards import GuardPolicy, make_guards


def _tree_equal(a, b, what=""):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), what
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=what)


def _linear_split_model(d_in=4, d_mid=3, num_classes=3):
    def client_fwd(wc, batch):
        return {"x": batch["x"] @ wc["w"]}

    def server_fwd(ws, acts):
        return acts["x"] @ ws["w"], jnp.zeros((), jnp.float32)

    def server_trunk(ws, acts):          # features == acts; head is ws["w"]
        return acts["x"], jnp.zeros((), jnp.float32)

    def head_grad_merge(d_ws, g_w):
        return {"w": d_ws["w"] + g_w.astype(d_ws["w"].dtype)}

    return engine.SplitModel(client_fwd=client_fwd, server_fwd=server_fwd,
                             num_classes=num_classes,
                             server_trunk=server_trunk,
                             head_weight=lambda ws: ws["w"],
                             head_grad_merge=head_grad_merge)


def _linear_setup(key, slots, d_in=4, d_mid=3, num_classes=3):
    from repro.core.split import stack_client_params

    kc, ks = jax.random.split(key)
    wc = {"w": jax.random.normal(kc, (d_in, d_mid))}
    ws = {"w": jax.random.normal(ks, (d_mid, num_classes))}
    return {"client": stack_client_params(wc, slots), "server": ws}


def _linear_round_batches(key, T_steps, C, Bk=4, d_in=4, num_classes=3):
    kx, ky = jax.random.split(key)
    return {"x": jax.random.normal(kx, (T_steps, C, Bk, d_in)),
            "labels": jax.random.randint(ky, (T_steps, C, Bk), 0,
                                         num_classes)}


def _fixed_mask_scheduler(mask):
    """A stateless scheduler that emits ``mask`` every round — the
    reference for "as if the rejected clients never participated"."""
    mask = jnp.asarray(mask, jnp.float32)
    return fed.ParticipationScheduler(
        name="fixed", num_clients=mask.shape[0], stateful=False,
        init=lambda key=None: (), sample=lambda s: (mask, s),
        subset_size=None)


K = 6
MODEL = _linear_split_model()
SC = ScalaConfig(num_clients=K, participation=1.0, local_iters=2, lr=0.05)
PARAMS = _linear_setup(jax.random.PRNGKey(0), K)
RB = _linear_round_batches(jax.random.PRNGKey(1), T_steps=2, C=K)
SIZES = jnp.arange(1.0, K + 1.0)


# --------------------------------------------------------------------------
# spec parsing + rejection of incoherent combinations
# --------------------------------------------------------------------------


def test_fault_spec_grammar():
    fm = make_faults("drop:0.1,corrupt:0.05:nan,stall:0.02")
    assert fm.drop == 0.1 and fm.corrupt == 0.05 and fm.stall == 0.02
    assert fm.corrupt_mode == "nan" and fm.any_faults
    fm2 = make_faults("corrupt:0.2:noise:3.5,stall:0.1:50")
    assert fm2.corrupt_mode == "noise" and fm2.noise_scale == 3.5
    assert fm2.stall_factor == 50.0
    assert make_faults(None) is None
    assert make_faults(fm) is fm                    # passthrough
    assert isinstance(make_faults("drop:0"), FaultModel)
    for bad in ("", "drop", "drop:2", "corrupt:0.1:huh", "stall:0.1:0.5",
                "explode:0.1"):
        with pytest.raises(ValueError):
            make_faults(bad)


def test_guard_spec_grammar():
    gp = make_guards("nonfinite,clip:10.0:0.25")
    assert gp.nonfinite and gp.clip == 10.0 and gp.beta == 0.25
    assert gp.stateful
    assert not make_guards("nonfinite").stateful
    assert make_guards(None) is None
    assert make_guards(gp) is gp
    assert isinstance(make_guards("clip:5"), GuardPolicy)
    for bad in ("", "clip:0", "clip:-1", "median"):
        with pytest.raises(ValueError):
            make_guards(bad)


def test_incoherent_combinations_rejected():
    dm = fed.make_delays("zero")
    # deadline outside async, at spec level
    sp = api.ExperimentSpec(
        arch="alexnet-cifar", method="scala",
        scala=ScalaConfig(num_clients=4),
        data=api.DataSpec(kind="image_synthetic"),
        execution=api.ExecutionSpec(mode="masked", deadline=1.0))
    with pytest.raises(ValueError, match="deadline"):
        sp.validate()
    # faults/guards in host-subset mode, at spec level
    sp2 = api.ExperimentSpec(
        arch="alexnet-cifar", method="scala",
        scala=ScalaConfig(num_clients=4),
        data=api.DataSpec(kind="image_synthetic"),
        fed=api.FedSpec(faults="drop:0.1"),
        execution=api.ExecutionSpec(mode="subset"))
    with pytest.raises(ValueError, match="subset"):
        sp2.validate()
    # lace_dp async + robust, at constructor level
    with pytest.raises(ValueError, match="lace_dp"):
        fed.make_async_runner(MODEL, SC, delays=dm, cohort=2,
                              backend="lace_dp", guards="nonfinite")
    # paged optimizer state + robust
    with pytest.raises(ValueError, match="paged"):
        fed.make_async_runner(MODEL, SC, delays=dm, cohort=2,
                              snapshots="delta", paged_opt=True,
                              faults="drop:0.1")
    # sparse lace_dp gather + robust
    with pytest.raises(ValueError, match="lace_dp"):
        engine.make_round_runner(MODEL, SC, backend="lace_dp",
                                 slot_gather=True,
                                 participation=fed.uniform(K, 0.5),
                                 guards="nonfinite")
    # bad deadline / backoff values
    with pytest.raises(ValueError, match="deadline"):
        fed.make_async_runner(MODEL, SC, delays=dm, cohort=2, deadline=0.0)
    with pytest.raises(ValueError, match="backoff"):
        fed.make_async_runner(MODEL, SC, delays=dm, cohort=2, deadline=1.0,
                              backoff=0.5)


# --------------------------------------------------------------------------
# (a) guards on + zero faults == unguarded, bitwise
# --------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["logits", "lace"])
@pytest.mark.parametrize("sparse", [False, True], ids=["masked", "sparse"])
def test_guarded_zero_fault_bitwise_sync(backend, sparse):
    opt = optim.momentum(beta=0.9)
    part = fed.uniform(K, 0.5)
    agg = fed.weighted()
    kw = dict(backend=backend, optimizer=opt, aggregator=agg,
              participation=part, slot_gather=sparse)
    plain = jax.jit(engine.make_round_runner(MODEL, SC, **kw))
    guarded = jax.jit(engine.make_round_runner(
        MODEL, SC, guards="nonfinite,clip:1e6", **kw))

    st_p = engine.init_train_state(PARAMS, opt)
    st_g = st_p
    fs_p = fed.init_fed_state(jax.random.PRNGKey(5), agg, part)
    fs_g = fed.init_fed_state(jax.random.PRNGKey(5), agg, part,
                              guards="nonfinite,clip:1e6")
    for _ in range(3):
        st_p, fs_p, m_p = plain(st_p, RB, SIZES, fs_p)
        st_g, fs_g, m_g = guarded(st_g, RB, SIZES, fs_g)
    _tree_equal(st_p.params, st_g.params, "params")
    _tree_equal(st_p.opt_state, st_g.opt_state, "opt_state")
    _tree_equal(fs_p["sched"], fs_g["sched"], "sched state")
    for k in m_p:
        _tree_equal(m_p[k], m_g[k], f"metric {k}")
    assert float(np.asarray(m_g["guard_rejected"])) == 0.0


@pytest.mark.parametrize("backend", ["logits", "lace"])
@pytest.mark.parametrize("snapshots", ["dense", "delta"])
def test_guarded_zero_fault_bitwise_async(backend, snapshots):
    dm = fed.make_delays("lognormal:1:1")
    # delta snapshots store no per-client moments; keep sgd there
    opt = optim.sgd() if snapshots == "delta" else optim.momentum(beta=0.9)
    kw = dict(backend=backend, optimizer=opt, delays=dm, cohort=2,
              snapshots=snapshots, ring_size=3, num_clients=K)
    plain = jax.jit(fed.make_async_runner(MODEL, SC, **kw))
    guarded = jax.jit(fed.make_async_runner(MODEL, SC, guards="nonfinite",
                                            **kw))
    st_p = engine.init_train_state(PARAMS, opt)
    st_g = st_p
    af_p = fed.init_async_state(jax.random.PRNGKey(7), PARAMS["client"], dm,
                                snapshots=snapshots, ring_size=3)
    af_g = fed.init_async_state(jax.random.PRNGKey(7), PARAMS["client"], dm,
                                snapshots=snapshots, ring_size=3,
                                guards="nonfinite")
    for _ in range(4):
        st_p, af_p, m_p = plain(st_p, af_p, RB, SIZES)
        st_g, af_g, m_g = guarded(st_g, af_g, RB, SIZES)
    _tree_equal(st_p.params, st_g.params, "params")
    _tree_equal(st_p.opt_state, st_g.opt_state, "opt_state")
    _tree_equal(af_p.client_params, af_g.client_params, "snapshots")
    _tree_equal((af_p.finish_time, af_p.version, af_p.server_version,
                 af_p.ring, af_p.ring_versions),
                (af_g.finish_time, af_g.version, af_g.server_version,
                 af_g.ring, af_g.ring_versions), "schedule scalars")
    for k in m_p:
        _tree_equal(m_p[k], m_g[k], f"metric {k}")
    assert float(np.asarray(m_g["guard_rejected"])) == 0.0


# --------------------------------------------------------------------------
# (b) NaN corruption: rejection + survivor-recomputed priors
# --------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["nan", "inf"])
def test_corruption_rejected_and_priors_match_survivor_reference(mode):
    """The SCALA-specific acceptance bar: a corrupt (rejected) client
    must change the eq. 14/15 priors / logit adjustments exactly as if
    it had never participated — i.e. the guarded faulty round equals a
    clean round run with the post-rejection mask as its participation.
    """
    opt = optim.momentum(beta=0.9)
    # corrupt HALF the cohort so the recompute branch definitely fires
    faulty = jax.jit(engine.make_round_runner(
        MODEL, SC, backend="lace", optimizer=opt, aggregator=fed.weighted(),
        faults=f"corrupt:0.5:{mode}", guards="nonfinite"))
    st0 = engine.init_train_state(PARAMS, opt)
    fs = fed.init_fed_state(jax.random.PRNGKey(3), fed.weighted(),
                            num_clients=K, faults=f"corrupt:0.5:{mode}",
                            guards="nonfinite")
    st_f, fs_f, m_f = faulty(st0, RB, SIZES, fs)

    accept = np.asarray(m_f["guard_accept"])
    rejected = float(np.asarray(m_f["guard_rejected"]))
    assert rejected >= 1, "corruption at 50% should reject someone"
    assert rejected == K - accept.sum()
    for leaf in jax.tree_util.tree_leaves(st_f.params):
        assert bool(jnp.isfinite(leaf).all()), "NaN leaked into the params"

    # reference: no faults, no guards — the survivors ARE the cohort
    part = _fixed_mask_scheduler(accept)
    ref = jax.jit(engine.make_round_runner(
        MODEL, SC, backend="lace", optimizer=opt, aggregator=fed.weighted(),
        participation=part))
    fs_r = fed.init_fed_state(jax.random.PRNGKey(3), fed.weighted(), part)
    st_r, _, m_r = ref(st0, RB, SIZES, fs_r)
    _tree_equal(st_f.params, st_r.params, "survivor-masked params")
    np.testing.assert_array_equal(np.asarray(m_f["loss_server"]),
                                  np.asarray(m_r["loss_server"]))


def test_chaos_training_stays_finite_and_learns():
    """drop + NaN corruption at ~10% of the cohort for 8 rounds: every
    round's aggregate stays finite and the loss still goes down."""
    opt = optim.momentum(beta=0.9)
    runner = jax.jit(engine.make_round_runner(
        MODEL, SC, backend="lace", optimizer=opt, aggregator=fed.weighted(),
        faults="drop:0.1,corrupt:0.1:nan", guards="nonfinite"))
    st = engine.init_train_state(PARAMS, opt)
    fs = fed.init_fed_state(jax.random.PRNGKey(11), fed.weighted(),
                            num_clients=K, faults="drop:0.1,corrupt:0.1:nan",
                            guards="nonfinite")
    losses = []
    for r in range(8):
        rb = _linear_round_batches(jax.random.fold_in(jax.random.PRNGKey(2),
                                                      r), T_steps=2, C=K)
        st, fs, m = runner(st, rb, SIZES, fs)
        losses.append(float(np.asarray(m["loss_server"])))
        for leaf in jax.tree_util.tree_leaves(st.params):
            assert bool(jnp.isfinite(leaf).all()), f"round {r}"
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


def test_clip_guard_bounds_update_norm():
    """A noise-corrupted client's huge update gets clipped to the
    running-median budget instead of dominating the aggregate."""
    spec = "corrupt:0.2:noise:1000.0"

    def run(guards):
        opt = optim.sgd()
        runner = jax.jit(engine.make_round_runner(
            MODEL, SC, backend="lace", optimizer=opt,
            aggregator=fed.weighted(), faults=spec, guards=guards))
        st = engine.init_train_state(PARAMS, opt)
        fs = fed.init_fed_state(jax.random.PRNGKey(13), fed.weighted(),
                                num_clients=K, faults=spec, guards=guards)
        before = jax.tree_util.tree_leaves(st.params)
        for _ in range(3):
            st, fs, m = runner(st, RB, SIZES, fs)
        after = jax.tree_util.tree_leaves(st.params)
        drift = float(sum(jnp.sum((a - b) ** 2)
                          for a, b in zip(after, before)) ** 0.5)
        return drift, fs

    drift_plain, _ = run(None)
    drift_clip, fs = run("nonfinite,clip:2.0")
    # corrupted updates are ~1000x the clean norm: unguarded, they
    # dominate the aggregate; clipped against the running median, the
    # trajectory stays orders of magnitude closer to the clean one
    assert drift_clip < drift_plain / 100.0, (drift_clip, drift_plain)
    assert float(np.asarray(fs["guard"]["med"])) > 0.0   # median warmed up


# --------------------------------------------------------------------------
# (c) async deadlines + exponential backoff
# --------------------------------------------------------------------------


def test_loose_deadline_matches_legacy_bitwise():
    dm = fed.make_delays("lognormal:1:1")
    opt = optim.momentum(beta=0.9)
    kw = dict(backend="lace", optimizer=opt, delays=dm, cohort=2,
              num_clients=K)
    legacy = jax.jit(fed.make_async_runner(MODEL, SC, **kw))
    bounded = jax.jit(fed.make_async_runner(MODEL, SC, deadline=1e6, **kw))
    st_l = engine.init_train_state(PARAMS, opt)
    st_b = st_l
    af_l = fed.init_async_state(jax.random.PRNGKey(17), PARAMS["client"], dm)
    af_b = af_l
    for _ in range(4):
        st_l, af_l, m_l = legacy(st_l, af_l, RB, SIZES)
        st_b, af_b, m_b = bounded(st_b, af_b, RB, SIZES)
        assert float(np.asarray(m_b["deadline_missed"])) == 0.0
    _tree_equal(st_l.params, st_b.params, "params")
    _tree_equal((af_l.finish_time, af_l.version, af_l.key),
                (af_b.finish_time, af_b.version, af_b.key), "schedule")
    np.testing.assert_array_equal(np.asarray(af_b.retries), np.zeros(K))


def test_tight_deadline_partial_cohort_and_backoff():
    dm = fed.make_delays("lognormal:1:1")
    opt = optim.sgd()
    bounded = jax.jit(fed.make_async_runner(
        MODEL, SC, backend="lace", optimizer=opt, delays=dm, cohort=3,
        num_clients=K, deadline=0.05, backoff=3.0))
    st = engine.init_train_state(PARAMS, opt)
    af = fed.init_async_state(jax.random.PRNGKey(19), PARAMS["client"], dm)
    ft_before = np.asarray(af.finish_time).copy()
    missed_total = 0
    for _ in range(4):
        st, af, m = bounded(st, af, RB, SIZES)
        missed_total += int(np.asarray(m["deadline_missed"]))
        t_event = float(np.asarray(m["t_event"]))
        # the event never waits for the full cohort barrier
        assert t_event <= float(np.sort(ft_before)[0]) + 0.05 + 1e-6
        ft_before = np.asarray(af.finish_time).copy()
    assert missed_total > 0, "deadline=0.05 should miss arrivals"
    retries = np.asarray(af.retries)
    assert retries.max() >= 1, "missed clients must accrue retries"
    for leaf in jax.tree_util.tree_leaves(st.params):
        assert bool(jnp.isfinite(leaf).all())
    # requeued clients got fresh finite finish times (not +inf stalls)
    assert bool(np.isfinite(np.asarray(af.finish_time)).all())


def test_stall_fault_with_deadline_schedule_advances():
    """stall:P alone would park clients at huge finish times; the
    deadline lets events proceed with whoever arrived."""
    dm = fed.make_delays("lognormal:1:1")
    opt = optim.sgd()
    runner = jax.jit(fed.make_async_runner(
        MODEL, SC, backend="lace", optimizer=opt, delays=dm, cohort=2,
        num_clients=K, deadline=5.0, faults="stall:0.5:100",
        guards="nonfinite"))
    st = engine.init_train_state(PARAMS, opt)
    af = fed.init_async_state(jax.random.PRNGKey(23), PARAMS["client"], dm,
                              guards="nonfinite")
    for _ in range(4):
        st, af, m = runner(st, af, RB, SIZES)
    assert int(np.asarray(af.server_version)) == 4
    assert float(np.asarray(m["t_event"])) < 1e4
    for leaf in jax.tree_util.tree_leaves(st.params):
        assert bool(jnp.isfinite(leaf).all())


# --------------------------------------------------------------------------
# lace_dp masked guards: multi-device subprocess leg
# --------------------------------------------------------------------------


_DP_GUARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro import fed, optim
from repro.configs import ScalaConfig, get_config
from repro.configs.base import InputShape
from repro.core import engine
from repro.core.scala import transformer_split_model
from repro.launch import input_specs as ispec
from repro.models import transformer as T
from repro.sharding.logical import RULES_DP, tree_specs

cfg = get_config("qwen1.5-0.5b").reduced()
C, BK, S, TS = 2, 2, 16, 2
model = transformer_split_model(cfg)
full = T.init_params(jax.random.PRNGKey(0), cfg)
params = {
    "client": jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (C,) + a.shape), full["client"]),
    "server": full["server"],
}
tokens = jax.random.randint(jax.random.PRNGKey(1), (TS, C, BK, S), 0,
                            cfg.vocab_size)
rb = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=-1),
      "weights": jnp.ones((TS, C, BK, S), jnp.float32)}
sizes = jnp.asarray([2.0, 1.0])
sc = ScalaConfig(num_clients=C, participation=1.0, lr=0.05,
                 grad_reduce_dtype=None)
st0 = engine.init_train_state(params, optim.sgd())

mesh = jax.make_mesh((2, 2), ("data", "model"))
shape = InputShape(name="t", seq_len=S, global_batch=C * BK, mode="train")
b_sh, b_ax = ispec.train_batch_specs(cfg, shape, C)
b_specs = tree_specs(b_ax, b_sh, mesh, RULES_DP)

agg, part = fed.weighted(), fed.uniform(C, 0.5)
kw = dict(backend="lace_dp", ce_chunk=8, mesh=mesh, batch_specs=b_specs,
          aggregator=agg, participation=part)
plain = jax.jit(engine.make_round_runner(model, sc, **kw))
guarded = jax.jit(engine.make_round_runner(model, sc,
                                           guards="nonfinite", **kw))
fs_p = fed.init_fed_state(jax.random.PRNGKey(5), agg, part)
fs_g = fed.init_fed_state(jax.random.PRNGKey(5), agg, part,
                          guards="nonfinite")
st_p, fs_p, m_p = plain(st0, rb, sizes, fs_p)
st_g, fs_g, m_g = guarded(st0, rb, sizes, fs_g)
bitwise = int(all(
    bool(jnp.array_equal(a, b))
    for a, b in zip(jax.tree.leaves(st_p.params),
                    jax.tree.leaves(st_g.params))))
print("RESULT " + json.dumps({
    "bitwise": bitwise,
    "rejected": float(np.asarray(m_g["guard_rejected"])),
}))
"""


@pytest.mark.slow
def test_dp_masked_guards_zero_fault_bitwise_subprocess():
    """lace_dp (shard_map) masked round with guards on and zero faults
    is bitwise the unguarded lace_dp round — the third backend of the
    acceptance matrix."""
    import subprocess
    import sys

    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _DP_GUARD_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, out.stdout[-2000:]
    res = json.loads(line[-1][len("RESULT "):])
    assert res["bitwise"] == 1
    assert res["rejected"] == 0.0


# --------------------------------------------------------------------------
# (d) crash-recoverable training
# --------------------------------------------------------------------------


def _tiny_image_spec(**over):
    kw = dict(
        arch="alexnet-cifar", method="scala", rounds=4, seed=0,
        scala=ScalaConfig(num_clients=4, participation=0.5, local_iters=2,
                          server_batch=24, lr=0.05),
        data=api.DataSpec(kind="image_synthetic", n_train=200, alpha=2))
    kw.update(over)
    return api.ExperimentSpec(**kw)


@pytest.mark.slow
def test_trainer_resume_bitwise_async_delta_chaos(tmp_path):
    """Kill-and-restore mid-run under async + delta snapshots + faults +
    guards + deadline: the resumed trainer's final state and history are
    bitwise the uninterrupted run's — ring snapshots, schedule scalars,
    retries, fault keys, guard state, host RNG included."""
    def mk():
        return _tiny_image_spec(
            fed=api.FedSpec(faults="drop:0.2,corrupt:0.1:nan",
                            guards="nonfinite,clip:10.0"),
            execution=api.ExecutionSpec(mode="async", snapshots="delta",
                                        ring_size=2, cohort=2, deadline=5.0,
                                        backoff=2.0))

    straight = api.Trainer(mk())
    straight.run(4)

    d = str(tmp_path / "ckpt")
    first = api.Trainer(mk())
    first.run(2)
    first.save(d)
    resumed = api.Trainer(mk())                 # fresh process stand-in
    assert resumed.resume(d) == 2
    resumed.run(2)

    _tree_equal(straight.state, resumed.state, "full ProgramState")
    assert straight.history == resumed.history


def test_trainer_resume_bitwise_sync_masked(tmp_path):
    spec_kw = dict(fed=api.FedSpec(faults="drop:0.2", guards="nonfinite"),
                   execution=api.ExecutionSpec(mode="masked"))
    straight = api.Trainer(_tiny_image_spec(**spec_kw))
    straight.run(4)

    d = str(tmp_path / "ckpt")
    first = api.Trainer(_tiny_image_spec(**spec_kw))
    first.run(3)
    first.save(d)
    resumed = api.Trainer(_tiny_image_spec(**spec_kw))
    assert resumed.resume(d) == 3
    resumed.run(1)
    _tree_equal(straight.state, resumed.state, "full ProgramState")
    assert straight.history == resumed.history


def test_checkpoint_atomic_and_corrupt_fallback(tmp_path):
    from repro import checkpoint as C

    tree = {"a": np.arange(6.0).reshape(2, 3), "b": {"c": np.ones(4)}}
    d = str(tmp_path)
    C.save(d, 1, tree)
    tree2 = jax.tree.map(lambda a: a * 2, tree)
    C.save(d, 2, tree2)
    assert C.all_steps(d) == [1, 2]
    # no stray temp files after an atomic save
    assert not [f for f in os.listdir(d) if ".tmp" in f]

    # torn write: truncate the latest -> restore falls back to step 1
    with open(os.path.join(d, "ckpt_00000002.npz"), "r+b") as f:
        f.truncate(10)
    with pytest.warns(UserWarning, match="unreadable"):
        got = C.restore(d, tree)
    _tree_equal(got, tree, "fallback restore")
    # an explicitly pinned corrupt step raises instead of substituting
    with pytest.raises(Exception):
        C.restore(d, tree, step=2)


def test_trainer_resume_skips_torn_pair(tmp_path):
    spec_kw = dict(execution=api.ExecutionSpec(mode="masked"),
                   fed=api.FedSpec(participation="uniform:0.5"))
    d = str(tmp_path / "ckpt")
    t = api.Trainer(_tiny_image_spec(**spec_kw))
    t.run(1)
    t.save(d)
    t.run(1)
    t.save(d)
    # crash mid-save of the newest checkpoint: npz exists, meta torn
    with open(os.path.join(d, "meta_00000002.json"), "w") as f:
        f.write('{"round": 2, "hist')
    fresh = api.Trainer(_tiny_image_spec(**spec_kw))
    assert fresh.resume(d) == 1

    # host-paged optimizer state cannot be checkpointed -> targeted error
    sp = _tiny_image_spec(
        fed=api.FedSpec(opt_state_policy="carry"),
        execution=api.ExecutionSpec(mode="async", cohort=2, arrival="topk",
                                    snapshots="delta", ring_size=2,
                                    opt_paging="host"))
    paged = api.Trainer(sp)
    with pytest.raises(ValueError, match="host"):
        paged.save(str(tmp_path / "paged"))


# --------------------------------------------------------------------------
# (e) serving: per-request deadline / token-budget eviction
# --------------------------------------------------------------------------


def test_serve_deadline_and_budget_eviction():
    from helpers import tiny_cfg
    from repro.models import transformer as T
    from repro.serve import Request, ServeEngine

    cfg = dataclasses.replace(tiny_cfg(), dtype="float32",
                              param_dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (3, 4),
                                            0, cfg.vocab_size))

    # baseline: no deadlines -> behavior unchanged, nothing evicted
    eng = ServeEngine(params, cfg, slots=2, max_len=64)
    base = eng.serve([Request(i, prompts[i], 6) for i in range(3)],
                     wall_clock=False)
    assert all(r.evicted is None for r in base.values())
    assert all(len(r.tokens) == 4 + 6 for r in base.values())

    # single slot: rid0's deadline evicts it mid-generation and rid1
    # takes the freed slot (no head-of-line blocking)
    eng2 = ServeEngine(params, cfg, slots=1, max_len=64)
    res = eng2.serve([Request(0, prompts[0], 20, deadline=3.0),
                      Request(1, prompts[1], 4)], wall_clock=False)
    assert res[0].evicted == "deadline"
    assert 1 <= len(res[0].tokens) - 4 < 20      # partial sequence back
    assert res[1].evicted is None and len(res[1].tokens) - 4 == 4
    assert res[1].t_admit >= res[0].t_finish

    # engine-wide token budget: capped request evicted at the cap, and
    # its generated prefix is bitwise the uncapped generation's
    eng3 = ServeEngine(params, cfg, slots=2, max_len=64, token_budget=3)
    res3 = eng3.serve([Request(0, prompts[0], 10),
                       Request(1, prompts[1], 2)], wall_clock=False)
    assert res3[0].evicted == "budget" and len(res3[0].tokens) == 4 + 3
    assert res3[1].evicted is None
    np.testing.assert_array_equal(res3[0].tokens, base[0].tokens[:7])

    # paged cache: eviction returns the pages to the pool
    eng4 = ServeEngine(params, cfg, slots=2, max_len=64, pages=8,
                       page_size=4)
    res4 = eng4.serve([Request(0, prompts[0], 20, deadline=2.0),
                       Request(1, prompts[1], 20, deadline=2.0),
                       Request(2, prompts[2], 3, arrival=1.0)],
                      wall_clock=False)
    assert res4[0].evicted == "deadline" and res4[1].evicted == "deadline"
    assert res4[2].evicted is None
    assert len(eng4._free_pages) == 8 and len(eng4._free_slots) == 2

    with pytest.raises(ValueError, match="deadline"):
        eng.serve([Request(9, prompts[0], 2, deadline=0.0)],
                  wall_clock=False)
    with pytest.raises(ValueError, match="token_budget"):
        ServeEngine(params, cfg, slots=1, max_len=64, token_budget=0)
