"""Federation-layer tests: aggregators, participation scheduling, the
round runner's fed composition, per-subset prior recomputation, and the
opt-state round-boundary policies.

The acceptance bar for the refactor: (a) the default round runner (no
fed args) stays allclose-identical to the legacy Python-loop round;
(b) a masked round (participation=uniform(0.5)) with the
bias-compensated aggregator runs jitted end-to-end on every backend
(the "lace_dp" leg lives in the slow subprocess test at the bottom).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import tiny_cfg
from repro import fed, optim
from repro.configs import ScalaConfig
from repro.core import engine
from repro.core.label_stats import client_and_concat_priors
from repro.core.scala import (alexnet_split_model, scala_round,
                              transformer_split_model)
from repro.core.split import fedavg as split_fedavg
from repro.core.split import normalize_client_weights
from repro.models import alexnet as A
from repro.models import transformer as T


def _tree_allclose(a, b, atol=2e-5, rtol=1e-4):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(x, y, atol=atol, rtol=rtol)


def _setup_alexnet(key, C=4, Bk=6, num_classes=10):
    model = alexnet_split_model("s2", num_classes=num_classes)
    full = A.init_params(key, num_classes=num_classes, width=0.125)
    wc, ws = A.split_params(full, "s2")
    params = {"client": jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (C,) + a.shape), wc),
        "server": ws}
    kx, ky = jax.random.split(jax.random.fold_in(key, 1))
    batch = {"x": jax.random.normal(kx, (C, Bk, 32, 32, 3)),
             "labels": jax.random.randint(ky, (C, Bk), 0, num_classes),
             "weights": jnp.ones((C, Bk), jnp.float32)}
    return model, params, batch


def _alexnet_round_batches(key, T_steps=3, C=4, Bk=6, num_classes=10):
    kx, ky = jax.random.split(key)
    return {"x": jax.random.normal(kx, (T_steps, C, Bk, 32, 32, 3)),
            "labels": jax.random.randint(ky, (T_steps, C, Bk), 0,
                                         num_classes),
            "weights": jnp.ones((T_steps, C, Bk), jnp.float32)}


# --------------------------------------------------------------------------
# mask-safe normalization (core/split) — the scala_aggregate NaN fix
# --------------------------------------------------------------------------


def test_normalize_weights_zero_participation_clients():
    w = normalize_client_weights(jnp.array([3.0, 0.0, 1.0, 0.0]))
    np.testing.assert_allclose(w, [0.75, 0.0, 0.25, 0.0], rtol=1e-6)
    assert np.isfinite(np.asarray(w)).all()


def test_normalize_weights_all_zero_falls_back_uniform():
    w = normalize_client_weights(jnp.zeros((4,)))
    np.testing.assert_allclose(w, [0.25] * 4, rtol=1e-6)

    # masked: fall back to uniform over the participating subset
    w = normalize_client_weights(jnp.zeros((4,)),
                                 mask=jnp.array([1.0, 0.0, 1.0, 0.0]))
    np.testing.assert_allclose(w, [0.5, 0.0, 0.5, 0.0], rtol=1e-6)

    # mask AND weights all zero: still finite (uniform over everyone)
    w = normalize_client_weights(jnp.zeros((4,)), mask=jnp.zeros((4,)))
    assert np.isfinite(np.asarray(w)).all()
    np.testing.assert_allclose(np.asarray(w).sum(), 1.0, rtol=1e-6)


def test_scala_aggregate_zero_sizes_no_nans():
    _, params, _ = _setup_alexnet(jax.random.PRNGKey(0), C=3)
    # distinct per-slot params so averaging is observable
    params = {"client": jax.tree.map(
        lambda a: a * jnp.arange(1.0, 4.0).reshape(
            (3,) + (1,) * (a.ndim - 1)), params["client"]),
        "server": params["server"]}

    agg = engine.scala_aggregate(params, jnp.array([2.0, 0.0, 1.0]))
    for leaf in jax.tree.leaves(agg["client"]):
        assert np.isfinite(np.asarray(leaf)).all()
    # zero-participation client excluded from the average
    want = jax.tree.map(
        lambda a: (2.0 * a[0] + 1.0 * a[2]) / 3.0, params["client"])
    _tree_allclose(jax.tree.map(lambda a: a[0], agg["client"]), want)

    # all-zero sizes: uniform mean, never NaN / all-zero params
    agg0 = engine.scala_aggregate(params, jnp.zeros((3,)))
    want0 = jax.tree.map(lambda a: a.mean(axis=0), params["client"])
    _tree_allclose(jax.tree.map(lambda a: a[0], agg0["client"]), want0)


# --------------------------------------------------------------------------
# participation schedulers
# --------------------------------------------------------------------------


def test_full_scheduler_is_all_ones_and_stateless():
    part = fed.full(5)
    assert not part.stateful
    mask, state = part.sample(part.init(jax.random.PRNGKey(0)))
    np.testing.assert_array_equal(np.asarray(mask), np.ones(5))


@pytest.mark.parametrize("frac,m", [(0.5, 4), (0.25, 2), (0.01, 1)])
def test_uniform_scheduler_subset_size(frac, m):
    part = fed.uniform(8, frac)
    state = part.init(jax.random.PRNGKey(0))
    masks = []
    for _ in range(6):
        mask, state = part.sample(state)
        assert float(mask.sum()) == m
        assert set(np.unique(np.asarray(mask))) <= {0.0, 1.0}
        masks.append(np.asarray(mask))
    # the subset varies round to round (w.h.p. for these sizes)
    assert any(not np.array_equal(masks[0], mk) for mk in masks[1:])


def test_uniform_scheduler_deterministic_given_key():
    part = fed.uniform(8, 0.5)
    m1, _ = part.sample(part.init(jax.random.PRNGKey(3)))
    m2, _ = part.sample(part.init(jax.random.PRNGKey(3)))
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


def test_dirichlet_scheduler_subset_size_and_jit():
    part = fed.dirichlet(10, 0.3, alpha=0.2)
    state = part.init(jax.random.PRNGKey(1))
    sample = jax.jit(part.sample)
    for _ in range(4):
        mask, state = sample(state)
        assert float(mask.sum()) == 3
        assert set(np.unique(np.asarray(mask))) <= {0.0, 1.0}


def test_make_participation_specs():
    assert fed.make_participation("full", 8).name == "full"
    p = fed.make_participation("uniform:0.25", 8)
    assert p.name == "uniform" and p.num_clients == 8
    p = fed.make_participation("dirichlet:0.5:1.0", 8)
    assert p.name == "dirichlet"
    with pytest.raises(ValueError, match="unknown participation"):
        fed.make_participation("nope", 8)
    with pytest.raises(ValueError, match="uniform spec"):
        fed.make_participation("uniform", 8)


# --------------------------------------------------------------------------
# aggregators
# --------------------------------------------------------------------------


def test_weighted_aggregator_matches_split_fedavg():
    key = jax.random.PRNGKey(2)
    stacked = {"w": jax.random.normal(key, (4, 3, 2))}
    sizes = jnp.array([5.0, 1.0, 2.0, 2.0])
    agg = fed.weighted()
    ctx = fed.AggContext(num_clients=4, data_sizes=sizes)
    avg, _ = agg.aggregate(stacked, ctx)
    _tree_allclose(avg, split_fedavg(stacked, sizes), atol=1e-7)


def test_fedavg_aggregator_uniform_over_subset():
    stacked = {"w": jnp.arange(4.0).reshape(4, 1)}
    mask = jnp.array([1.0, 0.0, 1.0, 0.0])
    agg = fed.fedavg()
    avg, _ = agg.aggregate(stacked,
                           fed.AggContext(num_clients=4, mask=mask,
                                          data_sizes=jnp.array(
                                              [9.0, 9.0, 9.0, 9.0])))
    # uniform over participants {0, 2}, data sizes ignored
    np.testing.assert_allclose(np.asarray(avg["w"]), [1.0], rtol=1e-6)


def test_bias_compensated_downweights_skewed_client():
    # client 0's round labels match the global prior; client 1's don't
    p_k = jnp.array([[0.5, 0.5], [1.0, 0.0]])
    p_global = jnp.array([0.5, 0.5])
    agg = fed.bias_compensated(gamma=2.0)
    assert agg.needs_priors
    w, _ = agg.client_weights(
        fed.AggContext(num_clients=2, p_k=p_k, p_global=p_global), ())
    w = np.asarray(w)
    assert w[0] > w[1] > 0
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)
    # gamma=0 recovers the data-size weighting
    w0, _ = fed.bias_compensated(gamma=0.0).client_weights(
        fed.AggContext(num_clients=2, p_k=p_k, p_global=p_global,
                       data_sizes=jnp.array([1.0, 3.0])), ())
    np.testing.assert_allclose(np.asarray(w0), [0.25, 0.75], rtol=1e-6)


def test_staleness_weighted_ages_and_decay():
    agg = fed.staleness_weighted(decay=0.5)
    assert agg.stateful
    state = agg.init(3)
    np.testing.assert_array_equal(np.asarray(state["age"]), np.zeros(3))

    # round 1: only client 0 participates -> ages [0, 1, 1]
    w, state = agg.client_weights(
        fed.AggContext(num_clients=3, mask=jnp.array([1.0, 0.0, 0.0])),
        state)
    np.testing.assert_allclose(np.asarray(w), [1.0, 0.0, 0.0], rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(state["age"]), [0.0, 1.0, 1.0])

    # round 2: absent again -> ages grow
    _, state = agg.client_weights(
        fed.AggContext(num_clients=3, mask=jnp.array([1.0, 0.0, 0.0])),
        state)
    np.testing.assert_array_equal(np.asarray(state["age"]), [0.0, 2.0, 2.0])

    # round 3: everyone returns; clients 1/2 decayed by 0.5^2
    w, state = agg.client_weights(
        fed.AggContext(num_clients=3, mask=jnp.ones(3)), state)
    np.testing.assert_allclose(np.asarray(w),
                               np.array([1.0, 0.25, 0.25]) / 1.5, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(state["age"]), np.zeros(3))


def test_make_aggregator_registry():
    for name in fed.AGGREGATORS:
        # hierarchical has no bare form — the edge count is mandatory
        spec = "hierarchical:2" if name == "hierarchical" else name
        assert fed.make_aggregator(spec).name == name
    with pytest.raises(ValueError, match="unknown aggregator"):
        fed.make_aggregator("nope")


# --------------------------------------------------------------------------
# per-subset prior recomputation (the paper's partial-participation core)
# --------------------------------------------------------------------------


def test_masked_priors_equal_subset_priors():
    key = jax.random.PRNGKey(4)
    C, Bk, N = 5, 16, 7
    labels = jax.random.randint(key, (C, Bk), 0, N)
    weights = jnp.ones((C, Bk), jnp.float32)
    mask = jnp.array([1.0, 0.0, 1.0, 1.0, 0.0])
    sub = jnp.array([0, 2, 3])

    # priors with mask folded into the weights (what the engine does)
    p_k_m, p_s_m = client_and_concat_priors(labels, N,
                                            weights * mask[:, None])
    # priors computed on ONLY the participating clients' labels
    p_k_s, p_s_s = client_and_concat_priors(labels[sub], N, weights[sub])

    np.testing.assert_allclose(np.asarray(p_s_m), np.asarray(p_s_s),
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(p_k_m[sub]), np.asarray(p_k_s),
                               atol=1e-7)
    # masked-out clients degrade to the uniform prior (zero histogram)
    np.testing.assert_allclose(np.asarray(p_k_m[1]), np.full(N, 1.0 / N),
                               atol=1e-7)


def test_masked_step_equals_substacked_step():
    """split_step_grads with a mask == the step on the physically
    re-stacked participating subset: losses, server grads, and the
    participants' client grads; absentees get exactly zero grads."""
    model, params, batch = _setup_alexnet(jax.random.PRNGKey(5))
    sc = ScalaConfig(lr=0.05)
    mask = jnp.array([1.0, 0.0, 1.0, 0.0])
    sub = jnp.array([0, 2])

    g_m, m_m = engine.split_step_grads(model, params, batch, sc,
                                       backend="logits", mask=mask)
    params_s = {"client": jax.tree.map(lambda a: a[sub], params["client"]),
                "server": params["server"]}
    batch_s = jax.tree.map(lambda a: a[sub], batch)
    g_s, m_s = engine.split_step_grads(model, params_s, batch_s, sc,
                                       backend="logits")

    np.testing.assert_allclose(m_m["loss_server"], m_s["loss_server"],
                               rtol=1e-6)
    np.testing.assert_allclose(m_m["loss_client"], m_s["loss_client"],
                               rtol=1e-6)
    _tree_allclose(g_m["server"], g_s["server"], atol=1e-6)
    _tree_allclose(jax.tree.map(lambda a: a[sub], g_m["client"]),
                   g_s["client"], atol=1e-6)
    for leaf in jax.tree.leaves(g_m["client"]):
        np.testing.assert_array_equal(
            np.asarray(leaf[jnp.array([1, 3])]), 0.0)


# --------------------------------------------------------------------------
# round runner on the fed layer
# --------------------------------------------------------------------------


def test_default_runner_matches_legacy_python_round():
    """Acceptance: fedavg weights + full participation == pre-refactor
    make_round_runner == legacy Python-loop round (allclose, fp32)."""
    key = jax.random.PRNGKey(6)
    model, params, _ = _setup_alexnet(key)
    sc = ScalaConfig(lr=0.05)
    rb = _alexnet_round_batches(jax.random.fold_in(key, 1))
    sizes = jnp.array([3.0, 1.0, 2.0, 4.0])

    p_ref, m_ref = scala_round(model, params, rb, sc, sizes)
    state0 = engine.init_train_state(params, optim.sgd())

    # default fed path (aggregator=None -> weighted, no scheduler)
    st_def, m_def = jax.jit(engine.make_round_runner(
        model, sc, backend="logits"))(state0, rb, sizes)
    _tree_allclose(st_def.params, p_ref)
    np.testing.assert_allclose(m_def["loss_server"], m_ref["loss_server"],
                               rtol=1e-5)

    # explicit full-participation scheduler + weighted aggregator
    agg, part = fed.weighted(), fed.full(4)
    runner = jax.jit(engine.make_round_runner(
        model, sc, backend="logits", aggregator=agg, participation=part))
    fs = fed.init_fed_state(jax.random.PRNGKey(0), agg, part)
    st_exp, _, m_exp = runner(state0, rb, sizes, fs)
    _tree_allclose(st_exp.params, st_def.params, atol=1e-6)

    # fedavg == weighted when the sizes are uniform
    agg_f = fed.fedavg()
    runner_f = jax.jit(engine.make_round_runner(
        model, sc, backend="logits", aggregator=agg_f, participation=part))
    fs_f = fed.init_fed_state(jax.random.PRNGKey(0), agg_f, part)
    st_f, _, _ = runner_f(state0, rb, jnp.ones((4,)), fs_f)
    st_u, _ = jax.jit(engine.make_round_runner(
        model, sc, backend="logits"))(state0, rb, None)
    _tree_allclose(st_f.params, st_u.params, atol=1e-6)


def test_masked_round_jitted_logits_backend():
    """Acceptance: participation=uniform(0.5) + bias_compensated runs
    jitted end-to-end (logits backend) and changes only via the subset."""
    key = jax.random.PRNGKey(7)
    model, params, _ = _setup_alexnet(key)
    sc = ScalaConfig(lr=0.05)
    rb = _alexnet_round_batches(jax.random.fold_in(key, 1))
    sizes = jnp.array([3.0, 1.0, 2.0, 4.0])

    agg, part = fed.bias_compensated(), fed.uniform(4, 0.5)
    runner = jax.jit(engine.make_round_runner(
        model, sc, backend="logits", aggregator=agg, participation=part))
    state = engine.init_train_state(params, optim.sgd())
    fs = fed.init_fed_state(jax.random.PRNGKey(1), agg, part)
    for _ in range(2):
        state, fs, metrics = runner(state, rb, sizes, fs)
    assert np.isfinite(float(metrics["loss_server"]))
    assert np.isfinite(float(metrics["loss_client"]))
    for leaf in jax.tree.leaves(state.params):
        assert np.isfinite(np.asarray(leaf)).all()
    # FL phase applied: all slots re-unified
    c0 = jax.tree.leaves(state.params["client"])[0]
    np.testing.assert_allclose(np.asarray(c0[0]), np.asarray(c0[1]))


def test_masked_round_jitted_lace_backend():
    """Acceptance: the same masked round on the fused-LACE backend."""
    cfg = tiny_cfg()
    model = transformer_split_model(cfg)
    C, Bk, S, T_steps = 4, 2, 8, 2
    params = engine.init_scala_params(
        jax.random.PRNGKey(8),
        lambda k: T.init_params(k, cfg)["client"],
        lambda k: T.init_params(k, cfg)["server"], C)
    ks = jax.random.split(jax.random.PRNGKey(9), 2)
    rb = {"tokens": jax.random.randint(ks[0], (T_steps, C, Bk, S), 0,
                                       cfg.vocab_size),
          "labels": jax.random.randint(ks[1], (T_steps, C, Bk, S), 0,
                                       cfg.vocab_size),
          "weights": jnp.ones((T_steps, C, Bk, S), jnp.float32)}
    sc = ScalaConfig(lr=0.05)

    agg, part = fed.bias_compensated(), fed.uniform(C, 0.5)
    runner = jax.jit(engine.make_round_runner(
        model, sc, backend="lace", ce_chunk=8, aggregator=agg,
        participation=part))
    state = engine.init_train_state(params, optim.sgd())
    fs = fed.init_fed_state(jax.random.PRNGKey(2), agg, part)
    state, fs, metrics = runner(state, rb, None, fs)
    assert int(state.step) == T_steps
    assert np.isfinite(float(metrics["loss_server"]))
    for leaf in jax.tree.leaves(state.params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_stateful_runner_requires_fed_state():
    model, params, _ = _setup_alexnet(jax.random.PRNGKey(10))
    sc = ScalaConfig(lr=0.05)
    rb = _alexnet_round_batches(jax.random.PRNGKey(11))
    runner = engine.make_round_runner(
        model, sc, backend="logits", participation=fed.uniform(4, 0.5))
    state = engine.init_train_state(params, optim.sgd())
    with pytest.raises(ValueError, match="fed_state"):
        runner(state, rb, None)
    with pytest.raises(ValueError, match="opt_state_policy"):
        engine.make_round_runner(model, sc, opt_state_policy="nope")


# --------------------------------------------------------------------------
# opt-state round-boundary policies
# --------------------------------------------------------------------------


def _run_policy_round(policy, key=jax.random.PRNGKey(12)):
    model, params, _ = _setup_alexnet(key)
    sc = ScalaConfig(lr=0.05)
    rb = _alexnet_round_batches(jax.random.fold_in(key, 1))
    sizes = jnp.array([3.0, 1.0, 2.0, 4.0])
    opt = optim.momentum(beta=0.9)
    runner = jax.jit(engine.make_round_runner(
        model, sc, backend="logits", optimizer=opt,
        opt_state_policy=policy))
    state, _ = runner(engine.init_train_state(params, opt), rb, sizes)
    return state


def test_opt_state_policy_carry_keeps_per_slot_momentum():
    state = _run_policy_round("carry")
    leaves = jax.tree.leaves(state.opt_state["client"])
    assert any(float(jnp.abs(l).max()) > 0 for l in leaves)
    # per-slot moments differ (each client saw different data)
    l0 = leaves[0]
    assert float(jnp.abs(l0[0] - l0[1]).max()) > 0


def test_opt_state_policy_reset_zeroes_client_momentum():
    state = _run_policy_round("reset")
    for leaf in jax.tree.leaves(state.opt_state["client"]):
        np.testing.assert_array_equal(np.asarray(leaf), 0.0)
    # the server half always carries
    assert any(float(jnp.abs(l).max()) > 0
               for l in jax.tree.leaves(state.opt_state["server"]))


def test_opt_state_policy_average_redistributes_momentum():
    carry = _run_policy_round("carry")
    avg = _run_policy_round("average")
    w = normalize_client_weights(jnp.array([3.0, 1.0, 2.0, 4.0]))
    for lc, la in zip(jax.tree.leaves(carry.opt_state["client"]),
                      jax.tree.leaves(avg.opt_state["client"])):
        # every slot holds the weighted mean of the carried moments
        wb = np.asarray(w).reshape((-1,) + (1,) * (lc.ndim - 1))
        want = (np.asarray(lc, np.float32) * wb).sum(axis=0)
        for c in range(la.shape[0]):
            np.testing.assert_allclose(np.asarray(la[c]), want,
                                       atol=1e-6, rtol=1e-5)
    # params are unaffected by the opt-state policy
    _tree_allclose(carry.params, avg.params, atol=0, rtol=0)


# --------------------------------------------------------------------------
# baselines on the fed layer
# --------------------------------------------------------------------------


def test_fl_round_accepts_fed_aggregator():
    from repro.core import baselines as B

    num_classes = 6
    model = B.FedModel(
        forward=lambda p, x: x.reshape(x.shape[0], -1) @ p["w"],
        num_classes=num_classes)
    key = jax.random.PRNGKey(13)
    w = {"w": jax.random.normal(key, (12, num_classes)) * 0.1}
    C, T_steps, Bk = 3, 2, 4
    rbs = {"x": jax.random.normal(jax.random.fold_in(key, 1),
                                  (C, T_steps, Bk, 12)),
           "labels": jax.random.randint(jax.random.fold_in(key, 2),
                                        (C, T_steps, Bk), 0, num_classes)}
    sizes = jnp.array([2.0, 1.0, 1.0])
    round_fn = B.make_fl_round("fedavg", model, lr=0.1,
                               aggregator=fed.bias_compensated())
    w2, _ = round_fn(w, rbs, sizes, {})
    for leaf in jax.tree.leaves(w2):
        assert np.isfinite(np.asarray(leaf)).all()


def test_baseline_aggregation_priors_exclude_padded_rows():
    """Zero-weight padding rows (loader pads every client to bk_max)
    must not count as class-0 samples in the aggregation priors."""
    from repro.core.baselines import _aggregation_priors

    labels = jnp.array([[[2, 2, 0, 0]], [[1, 1, 1, 1]]])   # (C=2, T=1, Bk=4)
    weights = jnp.array([[[1.0, 1.0, 0.0, 0.0]],           # client 0 padded
                         [[1.0, 1.0, 1.0, 1.0]]])
    p_k, p_global = _aggregation_priors(3, {"labels": labels,
                                            "weights": weights})
    np.testing.assert_allclose(np.asarray(p_k[0]), [0.0, 0.0, 1.0],
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(p_global),
                               [0.0, 4.0 / 6.0, 2.0 / 6.0], atol=1e-7)
    # without weights the padding would leak in as class 0
    p_k_u, _ = _aggregation_priors(3, {"labels": labels})
    assert float(p_k_u[0, 0]) > 0


def test_sfl_round_accepts_fed_aggregator():
    from repro.core import baselines as B

    model, params, _ = _setup_alexnet(jax.random.PRNGKey(14), C=3)
    key = jax.random.PRNGKey(15)
    C, T_steps, Bk = 3, 2, 4
    rbs = {"x": jax.random.normal(key, (C, T_steps, Bk, 32, 32, 3)),
           "labels": jax.random.randint(jax.random.fold_in(key, 1),
                                        (C, T_steps, Bk), 0, 10)}
    state = {"wc": params["client"], "ws": params["server"]}
    round_fn = B.make_sfl_round("splitfed_v1", model, lr=0.05,
                                aggregator=fed.bias_compensated())
    out = round_fn(state, rbs, jnp.array([2.0, 1.0, 1.0]))
    for leaf in jax.tree.leaves(out):
        assert np.isfinite(np.asarray(leaf)).all()


# --------------------------------------------------------------------------
# "lace_dp" backend: the shard_map step inside the scanned round
# --------------------------------------------------------------------------

_DP_ROUND_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import json
import jax
import jax.numpy as jnp

from repro import fed, optim
from repro.configs import ScalaConfig, get_config
from repro.configs.base import InputShape
from repro.core import engine
from repro.core.scala import transformer_split_model
from repro.launch import input_specs as ispec
from repro.models import transformer as T
from repro.sharding.logical import RULES_DP, tree_specs

cfg = get_config("qwen1.5-0.5b").reduced()
C, BK, S, TS = 2, 2, 16, 3
model = transformer_split_model(cfg)
key = jax.random.PRNGKey(0)
full = T.init_params(key, cfg)
params = {
    "client": jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (C,) + a.shape), full["client"]),
    "server": full["server"],
}
tokens = jax.random.randint(jax.random.PRNGKey(1), (TS, C, BK, S), 0,
                            cfg.vocab_size)
rb = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=-1),
      "weights": jnp.ones((TS, C, BK, S), jnp.float32)}
sizes = jnp.asarray([2.0, 1.0])
sc = ScalaConfig(num_clients=C, participation=1.0, lr=0.05,
                 grad_reduce_dtype=None)
st0 = engine.init_train_state(params, optim.sgd())

# reference: the single-program lace backend, same scanned round
r_lace = jax.jit(engine.make_round_runner(model, sc, backend="lace",
                                          ce_chunk=8))
st_l, m_l = r_lace(st0, rb, sizes)

mesh = jax.make_mesh((2, 2), ("data", "model"))
shape = InputShape(name="t", seq_len=S, global_batch=C * BK, mode="train")
b_sh, b_ax = ispec.train_batch_specs(cfg, shape, C)
b_specs = tree_specs(b_ax, b_sh, mesh, RULES_DP)

# (a) dp shard_map step inside the scan == lace scanned round
r_dp = jax.jit(engine.make_round_runner(model, sc, backend="lace_dp",
                                        ce_chunk=8, mesh=mesh,
                                        batch_specs=b_specs))
st_d, m_d = r_dp(st0, rb, sizes)
err = {}
err["params"] = max(
    float(jnp.max(jnp.abs(a - b)) / (1e-8 + float(jnp.max(jnp.abs(a)))))
    for a, b in zip(jax.tree.leaves(st_l.params), jax.tree.leaves(st_d.params)))
err["loss"] = abs(float(m_l["loss_server"]) - float(m_d["loss_server"]))

# (b) masked dp round: uniform(0.5) + bias_compensated, jitted end-to-end
agg, part = fed.bias_compensated(), fed.uniform(C, 0.5)
r_m = jax.jit(engine.make_round_runner(model, sc, backend="lace_dp",
                                       ce_chunk=8, mesh=mesh,
                                       batch_specs=b_specs, aggregator=agg,
                                       participation=part))
fs = fed.init_fed_state(jax.random.PRNGKey(5), agg, part)
st_m, fs2, m_m = r_m(st0, rb, sizes, fs)
err["masked_finite"] = int(all(
    bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(st_m.params))
    and bool(jnp.isfinite(jnp.asarray(m_m["loss_server"]))))
err["masked_slots_unified"] = int(bool(jnp.allclose(
    jax.tree.leaves(st_m.params["client"])[0][0],
    jax.tree.leaves(st_m.params["client"])[0][1])))
print("RESULT " + json.dumps(err))
"""


@pytest.mark.slow
def test_dp_backend_round_scan_matches_lace_and_runs_masked():
    """Satellite: the lace_dp shard_map step wrapped inside
    make_round_runner's scan matches the lace scanned round; acceptance:
    the masked bias-compensated round runs jitted on lace_dp too."""
    import json as _json
    import os as _os
    import subprocess
    import sys as _sys

    env = dict(_os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([_sys.executable, "-c", _DP_ROUND_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=_os.path.dirname(_os.path.dirname(
                             _os.path.abspath(__file__))), timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, out.stdout[-2000:]
    err = _json.loads(line[0][len("RESULT "):])
    assert err["params"] < 5e-4, err
    assert err["loss"] < 1e-5, err
    assert err["masked_finite"] == 1, err
    assert err["masked_slots_unified"] == 1, err


# --------------------------------------------------------------------------
# hierarchical (edge -> server) aggregation
# --------------------------------------------------------------------------


def test_hierarchical_weighted_tiers_equal_flat_weighted():
    """edge='weighted', top='weighted' is exactly flat data-size
    weighting: w_k = (n_k/S_e) * (S_e/tot) = n_k/tot."""
    mask = jnp.array([1, 0, 1, 1, 0, 1, 1, 1], jnp.float32)
    sizes = jnp.arange(1.0, 9.0)
    ctx = fed.AggContext(num_clients=8, mask=mask, data_sizes=sizes)
    for edges in (1, 2, 4, 8):
        w_h, _ = fed.hierarchical(edges).client_weights(ctx, ())
        w_f, _ = fed.weighted().client_weights(ctx, ())
        np.testing.assert_allclose(np.asarray(w_h), np.asarray(w_f),
                                   atol=1e-6)


def test_hierarchical_top_fedavg_equalizes_regions():
    """top='fedavg' gives every non-empty edge equal say regardless of
    data mass; an empty edge gets exactly zero."""
    mask = jnp.array([1, 1, 1, 1, 0, 0, 1, 1], jnp.float32)
    sizes = jnp.array([100.0, 100.0, 1.0, 1.0, 50.0, 50.0, 1.0, 1.0])
    ctx = fed.AggContext(num_clients=8, mask=mask, data_sizes=sizes)
    w, _ = fed.hierarchical(4, top="fedavg").client_weights(ctx, ())
    w = np.asarray(w)
    # 3 non-empty edges at 1/3 each; edge 2 (slots 4-5) empty
    np.testing.assert_allclose(w.reshape(4, 2).sum(axis=1),
                               [1 / 3, 1 / 3, 0.0, 1 / 3], atol=1e-6)
    # within edge 0 the data-size split still applies
    np.testing.assert_allclose(w[0] / w[1], 1.0, atol=1e-6)
    np.testing.assert_allclose(w.sum(), 1.0, atol=1e-6)


def test_hierarchical_all_empty_falls_back_flat():
    ctx = fed.AggContext(num_clients=4, mask=jnp.zeros((4,)),
                         data_sizes=jnp.ones((4,)))
    w, _ = fed.hierarchical(2).client_weights(ctx, ())
    assert np.isfinite(np.asarray(w)).all()
    np.testing.assert_allclose(np.asarray(w).sum(), 1.0, atol=1e-6)


def test_hierarchical_spec_and_validation():
    agg = fed.make_aggregator("hierarchical:4")
    assert agg.name == "hierarchical" and agg.shard_local is not None
    assert fed.make_aggregator("hierarchical:2:fedavg:fedavg").name \
        == "hierarchical"
    with pytest.raises(ValueError, match="tiers"):
        fed.hierarchical(2, edge="nope")
    with pytest.raises(ValueError, match="edges"):
        fed.hierarchical(0)
    with pytest.raises(ValueError, match="divide"):
        fed.hierarchical(3).client_weights(
            fed.AggContext(num_clients=4, mask=jnp.ones((4,))), ())
    with pytest.raises(ValueError):
        fed.make_aggregator("hierarchical")


@pytest.mark.parametrize("spec", ["fedavg", "weighted", "hierarchical:4"])
def test_shard_local_decomposition_matches_flat_weights(spec):
    """The shard_local contract: concatenating each shard's local raw
    weights, masking, and renormalizing globally reproduces the flat
    client_weights — for every shard count the slots divide over."""
    agg = fed.make_aggregator(spec)
    C = 8
    mask = jnp.array([1, 0, 1, 1, 0, 1, 1, 1], jnp.float32)
    sizes = jnp.arange(2.0, 10.0)
    w_flat, _ = agg.client_weights(
        fed.AggContext(num_clients=C, mask=mask, data_sizes=sizes), ())
    for n_shards in (1, 2, 4):
        # vmap with an axis name stands in for the sharded client axis:
        # the psum inside shard_local reduces over the shard blocks
        # exactly as it would inside the real shard_map
        blocks = jax.vmap(
            lambda m, s: agg.shard_local(m, s, ("c",), n_shards),
            axis_name="c")(mask.reshape(n_shards, -1),
                           sizes.reshape(n_shards, -1))
        raw = blocks.reshape(-1) * mask
        w = raw / raw.sum()
        np.testing.assert_allclose(np.asarray(w), np.asarray(w_flat),
                                   atol=1e-6)
    with pytest.raises(ValueError, match="divide"):
        fed.hierarchical(2).shard_local(mask[:2], sizes[:2], (), n_shards=4)


def test_shard_local_absent_on_stateful_and_prior_aggregators():
    assert fed.bias_compensated().shard_local is None
    assert fed.staleness_weighted().shard_local is None


# --------------------------------------------------------------------------
# shards-balanced uniform participation
# --------------------------------------------------------------------------


def test_uniform_shards_balanced_blocks():
    part = fed.make_participation("uniform:0.5:4", 16)
    assert part.shards == 4 and part.subset_size == 8
    state = part.init(jax.random.PRNGKey(0))
    for _ in range(5):
        mask, state = part.sample(state)
        blocks = np.asarray(mask).reshape(4, 4)
        # every contiguous block contributes exactly m/shards clients
        np.testing.assert_array_equal(blocks.sum(axis=1), np.full(4, 2))
    # subset size rounds UP to a shard multiple
    p2 = fed.uniform(16, 0.3, shards=4)   # 4.8 -> 8? no: ceil to mult of 4
    assert p2.subset_size % 4 == 0
    assert fed.make_participation("uniform:0.25", 8).shards == 1
    with pytest.raises(ValueError, match="shards"):
        fed.uniform(6, 0.5, shards=4)


def test_uniform_shards_one_matches_legacy_subset_size():
    assert fed.uniform(8, 0.5, shards=1).subset_size \
        == fed.uniform(8, 0.5).subset_size


# --------------------------------------------------------------------------
# "lace_dp" sparse-slot and async events (in-shard gather)
# --------------------------------------------------------------------------

_DP_SPARSE_ASYNC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import json
import jax
import jax.numpy as jnp

from repro import fed, optim
from repro.configs import ScalaConfig, get_config
from repro.configs.base import InputShape
from repro.core import engine
from repro.core.scala import transformer_split_model
from repro.launch import input_specs as ispec
from repro.models import transformer as T
from repro.sharding.logical import RULES_DP, tree_specs

cfg = get_config("qwen1.5-0.5b").reduced()
C, BK, S, TS = 4, 1, 16, 2
model = transformer_split_model(cfg)
key = jax.random.PRNGKey(0)
full = T.init_params(key, cfg)
params = {
    "client": jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (C,) + a.shape), full["client"]),
    "server": full["server"],
}
tokens = jax.random.randint(jax.random.PRNGKey(1), (TS, C, BK, S), 0,
                            cfg.vocab_size)
rb = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=-1),
      "weights": jnp.ones((TS, C, BK, S), jnp.float32)}
sizes = jnp.asarray([2.0, 1.0, 3.0, 1.0])
sc = ScalaConfig(num_clients=C, participation=1.0, lr=0.05,
                 grad_reduce_dtype=None)
st0 = engine.init_train_state(params, optim.sgd())

mesh = jax.make_mesh((2, 2), ("data", "model"))
n_shards = engine.client_shard_count(mesh)
assert n_shards == 2, n_shards
shape = InputShape(name="t", seq_len=S, global_batch=C * BK, mode="train")
b_sh, b_ax = ispec.train_batch_specs(cfg, shape, C)
b_specs = tree_specs(b_ax, b_sh, mesh, RULES_DP)
res = {}

# (a) lace_dp sparse-slot round == the masked lace round, same masks
agg = fed.weighted()
part = fed.make_participation("uniform:0.5:2", C)
r_sparse = jax.jit(engine.make_round_runner(
    model, sc, backend="lace_dp", ce_chunk=8, mesh=mesh,
    batch_specs=b_specs, aggregator=agg, participation=part,
    slot_gather=True))
r_masked = jax.jit(engine.make_round_runner(
    model, sc, backend="lace", ce_chunk=8, aggregator=agg,
    participation=part))
fs_s = fed.init_fed_state(jax.random.PRNGKey(5), agg, part)
fs_m = fed.init_fed_state(jax.random.PRNGKey(5), agg, part)
st_s, st_m = st0, st0
for _ in range(2):
    st_s, fs_s, m_s = r_sparse(st_s, rb, sizes, fs_s)
    st_m, fs_m, m_m = r_masked(st_m, rb, sizes, fs_m)
res["sparse_params"] = max(
    float(jnp.max(jnp.abs(a - b)) / (1e-8 + float(jnp.max(jnp.abs(a)))))
    for a, b in zip(jax.tree.leaves(st_s.params),
                    jax.tree.leaves(st_m.params)))
res["sparse_loss"] = abs(float(m_s["loss_server"])
                         - float(m_m["loss_server"]))

# (b) lace_dp async at zero delays + full cohort == the lace async
dm = fed.make_delays("zero")
r_async_dp = jax.jit(fed.make_async_runner(
    model, sc, backend="lace_dp", ce_chunk=8, delays=dm, cohort=C,
    mesh=mesh, batch_specs=b_specs))
r_async = jax.jit(fed.make_async_runner(
    model, sc, backend="lace", ce_chunk=8, delays=dm, cohort=C))
af_d = fed.init_async_state(jax.random.PRNGKey(6), params["client"], dm)
af_r = fed.init_async_state(jax.random.PRNGKey(6), params["client"], dm)
sa_d, sa_r = st0, st0
for _ in range(2):
    sa_d, af_d, ma_d = r_async_dp(sa_d, af_d, rb, sizes)
    sa_r, af_r, ma_r = r_async(sa_r, af_r, rb, sizes)
res["async_params"] = max(
    float(jnp.max(jnp.abs(a - b)) / (1e-8 + float(jnp.max(jnp.abs(a)))))
    for a, b in zip(jax.tree.leaves(sa_d.params),
                    jax.tree.leaves(sa_r.params)))
res["async_loss"] = abs(float(ma_d["loss_server"])
                        - float(ma_r["loss_server"]))
res["async_versions_ok"] = int(
    (jnp.asarray(af_d.version) == 2).all() and int(af_d.server_version) == 2)

# (c) lace_dp async delta snapshots == lace_dp dense, sparse cohort
dm2 = fed.make_delays("zero")
for snapshots, slots in (("dense", C), ("delta", 1)):
    p = {"client": jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (slots,) + a.shape),
        full["client"]), "server": full["server"]}
    st = engine.init_train_state(p, optim.sgd())
    af = fed.init_async_state(jax.random.PRNGKey(7), p["client"], dm2,
                              snapshots=snapshots, ring_size=4,
                              num_clients=C)
    rr = jax.jit(fed.make_async_runner(
        model, sc, backend="lace_dp", ce_chunk=8, delays=dm2, cohort=2,
        snapshots=snapshots, ring_size=4, num_clients=C, mesh=mesh,
        batch_specs=b_specs))
    for _ in range(3):
        st, af, mm = rr(st, af, rb, sizes)
    if snapshots == "dense":
        ref_c = jax.tree.leaves(st.params["client"])[0][0]
        ref_v = jnp.asarray(af.version)
    else:
        res["delta_params"] = float(jnp.max(jnp.abs(
            jax.tree.leaves(st.params["client"])[0][0] - ref_c)))
        res["delta_versions_ok"] = int(
            (jnp.asarray(af.version) == ref_v).all())
print("RESULT " + json.dumps(res))
"""


@pytest.mark.slow
def test_dp_sparse_and_async_match_single_program():
    """Tentpole (b): the lace_dp in-shard gather — the sparse-slot round
    matches the masked lace round for the same masks, the lace_dp async
    event at zero delays + full cohort matches the single-program async,
    and delta snapshots agree with dense inside the shard_map too."""
    import json as _json
    import os as _os
    import subprocess
    import sys as _sys

    env = dict(_os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([_sys.executable, "-c", _DP_SPARSE_ASYNC_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=_os.path.dirname(_os.path.dirname(
                             _os.path.abspath(__file__))), timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, out.stdout[-2000:]
    res = _json.loads(line[0][len("RESULT "):])
    assert res["sparse_params"] < 5e-4, res
    assert res["sparse_loss"] < 1e-4, res
    assert res["async_params"] < 5e-4, res
    assert res["async_loss"] < 1e-4, res
    assert res["async_versions_ok"] == 1, res
    assert res["delta_params"] < 1e-6, res
    assert res["delta_versions_ok"] == 1, res
