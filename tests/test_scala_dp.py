"""The manual-SPMD ("dp") SCALA step must match the GSPMD fused step
bit-for-bit (same math, different collective schedule).

Runs in a subprocess with 8 forced host devices so the shard_map path is
exercised on a real (data=4, model=2) mesh.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ScalaConfig, get_config
from repro.core.scala import (scala_local_step_fused,
                              scala_local_step_fused_dp,
                              transformer_split_model)
from repro.launch import input_specs as ispec
from repro.models import transformer as T
from repro.sharding.logical import RULES_DP, tree_specs, tree_shardings

cfg = get_config("qwen1.5-0.5b").reduced()
assert cfg.sharding_profile == "dp"
C, BK, S = 4, 4, 32
model = transformer_split_model(cfg)
key = jax.random.PRNGKey(0)
full = T.init_params(key, cfg)
params = {
    "client": jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (C,) + a.shape), full["client"]),
    "server": full["server"],
}
kb = jax.random.PRNGKey(1)
tokens = jax.random.randint(kb, (C, BK, S), 0, cfg.vocab_size)
labels = jnp.roll(tokens, -1, axis=-1)
weights = jnp.ones((C, BK, S), jnp.float32)
batch = {"tokens": tokens, "labels": labels, "weights": weights}
# exact-reduction mode for the equivalence check (production default
# compresses the grad psum to bf16)
sc = ScalaConfig(num_clients=C, participation=1.0, lr=0.05,
                 grad_reduce_dtype=None)

# reference: no mesh, plain fused step
ref_params, ref_m = jax.jit(
    lambda p, b: scala_local_step_fused(model, p, b, sc))(params, batch)

mesh = jax.make_mesh((4, 2), ("data", "model"))
from dataclasses import replace
from repro.configs.base import InputShape
shape = InputShape(name="t", seq_len=S, global_batch=C * BK, mode="train")
b_sh, b_ax = ispec.train_batch_specs(cfg, shape, C)
b_specs = tree_specs(b_ax, b_sh, mesh, RULES_DP)
from repro import compat
with compat.set_mesh(mesh):
    dp_params, dp_m = jax.jit(
        lambda p, b: scala_local_step_fused_dp(model, p, b, sc, mesh,
                                               b_specs))(params, batch)

err = {}
for k in ("client", "server"):
    a = jax.tree.leaves(ref_params[k]); b = jax.tree.leaves(dp_params[k])
    err[k] = max(float(jnp.max(jnp.abs(x - y)) /
                       (1e-8 + float(jnp.max(jnp.abs(x)))))
                 for x, y in zip(a, b))
err["loss_server"] = abs(float(ref_m["loss_server"]) -
                         float(dp_m["loss_server"]))
err["loss_client"] = abs(float(ref_m["loss_client"]) -
                         float(dp_m["loss_client"]))
print("RESULT " + json.dumps(err))
"""


@pytest.mark.slow
def test_dp_step_matches_fused():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, out.stdout[-2000:]
    err = json.loads(line[0][len("RESULT "):])
    assert err["loss_server"] < 1e-5, err
    assert err["loss_client"] < 1e-5, err
    assert err["client"] < 5e-4, err
    assert err["server"] < 5e-4, err


# The in-shard gather's mesh-dependent validation: the client shard
# count comes from the mesh, so the shards-balanced-scheduler and
# divisibility checks only fire on a real multi-device mesh (the
# single-device suite can't reach them). Construction-only — no compute.
GATHER_VALIDATION_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import jax
import jax.numpy as jnp

from repro import fed
from repro.configs import ScalaConfig, get_config
from repro.configs.base import InputShape
from repro.core import engine
from repro.core.scala import transformer_split_model
from repro.launch import input_specs as ispec
from repro.sharding.logical import RULES_DP, tree_specs

cfg = get_config("qwen1.5-0.5b").reduced()
C, S = 4, 16
model = transformer_split_model(cfg)
sc = ScalaConfig(num_clients=C, participation=1.0, lr=0.05)
mesh = jax.make_mesh((2, 2), ("data", "model"))
assert engine.client_shard_count(mesh) == 2
assert engine.client_shard_count(jax.make_mesh((1, 4),
                                               ("data", "model"))) == 1
shape = InputShape(name="t", seq_len=S, global_batch=C, mode="train")
b_sh, b_ax = ispec.train_batch_specs(cfg, shape, C)
b_specs = tree_specs(b_ax, b_sh, mesh, RULES_DP)

def expect(msg, **kw):
    kw.setdefault("mesh", mesh)
    kw.setdefault("batch_specs", b_specs)
    try:
        engine.make_round_runner(model, sc, backend="lace_dp",
                                 slot_gather=True, aggregator=kw.pop(
                                     "aggregator", fed.weighted()), **kw)
    except ValueError as e:
        assert msg in str(e), (msg, str(e))
    else:
        raise AssertionError(f"no ValueError containing {msg!r}")

# a legacy (shards=1) scheduler cannot balance 2 client shards
expect("shards-balanced", participation=fed.uniform(C, 0.5))
# per-shard aggregation needs a shard-decomposable aggregator
expect("shard-decomposable",
       participation=fed.make_participation("uniform:0.5:2", C),
       aggregator=fed.bias_compensated())
# cross-slot opt-state averaging cannot span shards
expect("'average'", participation=fed.make_participation(
    "uniform:0.5:2", C), opt_state_policy="average")
# the balanced config constructs fine
engine.make_round_runner(model, sc, backend="lace_dp", slot_gather=True,
                         aggregator=fed.weighted(), mesh=mesh,
                         batch_specs=b_specs,
                         participation=fed.make_participation(
                             "uniform:0.5:2", C))
print("RESULT ok")
"""


@pytest.mark.slow
def test_dp_slot_gather_mesh_validation():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", GATHER_VALIDATION_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "RESULT ok" in out.stdout, out.stdout[-2000:]
