"""O(K)-work event scheduling: equivalence suite.

The acceptance bars for the arrival-pop refactor:

(a) the composite-key top-k pop (``arrival="topk"``) is *bit-identical*
    to the legacy per-event lexsort — same idx, mask, and t_event on
    every schedule, including the version FIFO tie-break and the slot-id
    stability rule — both at the pop level and over whole runner event
    sequences;
(b) the mesh-sharded pop (``arrival="topk:sharded"``) matches the
    single-device pop exactly on a multi-device mesh (subprocess with a
    forced 4-device CPU topology), as do the sharded schedule-scalar
    layouts (``init_async_state(mesh=...)`` / ``delays.sample_sharded``);
(c) the host-paged optimizer store (``opt_paging="host"``) makes
    delta+carry bitwise-identical to dense+carry for a *stateful*
    optimizer (momentum) — the restriction it lifts — while keeping the
    device moment stack at one slot;
(d) the satellite selection rewrites (dirichlet Gumbel-top-k via
    ``lax.top_k``, ``slot_gather_indices`` via cumsum compaction) are
    selection-identical to the argsort code they replaced.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import fed, optim
from repro.configs import ScalaConfig
from repro.core import engine
from repro.core.scala import alexnet_split_model
from repro.models import alexnet as A


def _setup_alexnet(key, C=4, num_classes=10):
    model = alexnet_split_model("s2", num_classes=num_classes)
    full = A.init_params(key, num_classes=num_classes, width=0.0625)
    wc, ws = A.split_params(full, "s2")
    params = {"client": jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (C,) + a.shape), wc),
        "server": ws}
    return model, params, wc


def _round_batches(key, T_steps=2, C=4, Bk=4, num_classes=10):
    kx, ky = jax.random.split(key)
    return {"x": jax.random.normal(kx, (T_steps, C, Bk, 32, 32, 3)),
            "labels": jax.random.randint(ky, (T_steps, C, Bk), 0,
                                         num_classes),
            "weights": jnp.ones((T_steps, C, Bk), jnp.float32)}


# --------------------------------------------------------------------------
# (a) topk pop == lexsort pop, bit for bit
# --------------------------------------------------------------------------


def _random_schedule(rng, K):
    kind = rng.integers(4)
    if kind == 0:
        ft = np.zeros(K, np.float32)
    elif kind == 1:
        ft = np.full(K, float(rng.integers(1, 5)), np.float32)
    elif kind == 2:
        ft = rng.lognormal(0.0, 1.0, K).astype(np.float32)
    else:
        # integer-valued: maximal finish-time ties
        ft = rng.integers(0, 3, K).astype(np.float32)
    if rng.integers(2):
        v = rng.integers(0, rng.choice([4, 1 << 20, 1 << 30]),
                         K).astype(np.int32)
    else:
        v = None
    return jnp.asarray(ft), None if v is None else jnp.asarray(v)


def test_topk_pop_bit_identical_to_lexsort_randomized():
    rng = np.random.default_rng(0)
    for K, cohort in [(7, 1), (7, 3), (7, 7), (16, 4), (16, 11)]:
        for _ in range(8):
            ft, v = _random_schedule(rng, K)
            ref = fed.arrival_cohort(ft, cohort, v, method="sort")
            new = fed.arrival_cohort(ft, cohort, v, method="topk")
            for r, n in zip(ref, new):
                np.testing.assert_array_equal(np.asarray(r), np.asarray(n))


def test_topk_pop_known_tiebreaks():
    # finish-time tie -> lowest version (FIFO), then lowest slot id
    ft = jnp.array([1.0, 1.0, 1.0, 2.0])
    v = jnp.array([5, 3, 3, 0], jnp.int32)
    idx, mask, t = fed.arrival_cohort(ft, 2, v, method="topk")
    np.testing.assert_array_equal(np.asarray(idx), [1, 2])
    np.testing.assert_array_equal(np.asarray(mask), [0, 1, 1, 0])
    assert float(t) == 1.0
    # negative versions (never produced by the runtime, but the two's-
    # complement split must stay monotone): -2 pops before 1
    idx, _, _ = fed.arrival_cohort(jnp.zeros(3), 1,
                                   jnp.array([1, -2, 0], jnp.int32),
                                   method="topk")
    assert int(idx[0]) == 1


def test_arrival_cohort_rejects_unknown_method():
    with pytest.raises(ValueError, match="arrival"):
        fed.arrival_cohort(jnp.zeros(4), 2, method="bogus")
    with pytest.raises(ValueError, match="sharded_arrival_cohort"):
        fed.arrival_cohort(jnp.zeros(4), 2, method="topk:sharded")


@pytest.mark.parametrize("delay_spec", ["zero", "constant:2",
                                        "lognormal:1:1"])
def test_topk_runner_event_sequence_matches_sort(delay_spec):
    """The acceptance bar: whole event sequences — masks, versions,
    finish times, params — bit-identical between arrival='sort' and
    'topk' under tie-free AND tie-heavy delay schedules."""
    key = jax.random.PRNGKey(5)
    K, cohort = 8, 3
    dm = fed.make_delays(delay_spec)
    sc = ScalaConfig(lr=0.05)
    traces = {}
    for arr in ("sort", "topk"):
        model, params, _ = _setup_alexnet(key, C=K)
        runner = jax.jit(fed.make_async_runner(
            model, sc, delays=dm, cohort=cohort, arrival=arr))
        state = engine.init_train_state(params, optim.sgd())
        afed = fed.init_async_state(jax.random.PRNGKey(6),
                                    params["client"], dm)
        seq = []
        for e in range(6):
            rb = _round_batches(jax.random.fold_in(key, e), C=K)
            state, afed, m = runner(state, afed, rb)
            seq.append((np.asarray(m["arrival_mask"]),
                        np.asarray(afed.version),
                        np.asarray(afed.finish_time)))
        seq.append(tuple(np.asarray(l) for l in
                         jax.tree.leaves(state.params)))
        traces[arr] = seq
    for a, b in zip(traces["sort"], traces["topk"]):
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)


# --------------------------------------------------------------------------
# (c) host-paged optimizer store
# --------------------------------------------------------------------------


def test_paged_delta_carry_matches_dense_carry_momentum():
    """opt_paging lifts the delta restriction: delta+carry+momentum via
    the host pager follows the dense+carry trajectory bit for bit
    (within the ring horizon), with a one-slot device moment stack."""
    key = jax.random.PRNGKey(9)
    K, cohort, ring = 8, 3, 64
    dm = fed.make_delays("lognormal:1:1")
    sc = ScalaConfig(lr=0.05)
    mom = optim.momentum(0.9)
    model, params_d, wc = _setup_alexnet(key, C=K)

    r_dense = jax.jit(fed.make_async_runner(
        model, sc, delays=dm, cohort=cohort, optimizer=mom,
        opt_state_policy="carry", snapshots="dense"))
    st_d = engine.init_train_state(params_d, mom)
    af_d = fed.init_async_state(jax.random.PRNGKey(10),
                                params_d["client"], dm)

    r_paged = fed.make_async_runner(
        model, sc, delays=dm, cohort=cohort, optimizer=mom,
        opt_state_policy="carry", snapshots="delta", ring_size=ring,
        num_clients=K, paged_opt=True)
    pop = jax.jit(fed.make_arrival_pop(cohort, "topk"))
    ev = jax.jit(r_paged)
    params_p = {"client": jax.tree.map(lambda a: a[None], wc),
                "server": params_d["server"]}
    st_p = engine.init_train_state(params_p, mom)
    af_p = fed.init_async_state(jax.random.PRNGKey(10),
                                params_p["client"], dm, snapshots="delta",
                                ring_size=ring, num_clients=K)
    pager = fed.HostOptPager(mom, wc, K)
    assert pager.nbytes() > 0

    for e in range(6):
        rb = _round_batches(jax.random.fold_in(key, e), C=K)
        st_d, af_d, _ = r_dense(st_d, af_d, rb)
        idx = np.asarray(pop(af_p.finish_time, af_p.version)[0])
        cohort_opt = pager.gather(idx)
        st_p, af_p, _, new_co = ev(st_p, af_p, rb, None, cohort_opt)
        pager.scatter(idx, new_co)
    gd = np.asarray(jax.tree.leaves(st_d.params["client"])[0][0])
    gp = np.asarray(jax.tree.leaves(st_p.params["client"])[0][0])
    np.testing.assert_array_equal(gd, gp)
    for sd, sp in zip(jax.tree.leaves(st_d.params["server"]),
                      jax.tree.leaves(st_p.params["server"])):
        np.testing.assert_array_equal(np.asarray(sd), np.asarray(sp))
    # the lifted restriction costs no device memory: moments stay 1-slot
    for leaf in jax.tree.leaves(st_p.opt_state["client"]):
        assert leaf.shape[0] == 1, leaf.shape
    # ... and the full-K moments live in host numpy
    for leaf in jax.tree.leaves(pager._store):
        assert isinstance(leaf, np.ndarray) and leaf.shape[0] == K


def test_paged_requires_delta_carry():
    model, params, _ = _setup_alexnet(jax.random.PRNGKey(0), C=4)
    dm = fed.make_delays("zero")
    with pytest.raises(ValueError, match="paged_opt"):
        fed.make_async_runner(model, ScalaConfig(), delays=dm, cohort=2,
                              paged_opt=True, snapshots="dense")


@pytest.mark.slow
def test_paged_delta_carry_runs_at_10k_clients_without_dense_moments():
    """The scale acceptance: K=1e4 delta+carry+momentum events run with
    the (K, ...) moment stack on the *host* and a single param/moment
    slot on device."""
    K, cohort = 10_000, 32
    key = jax.random.PRNGKey(21)
    dm = fed.make_delays("lognormal:1:1")
    sc = ScalaConfig(lr=0.05)
    mom = optim.momentum(0.9)
    model = alexnet_split_model("s2", num_classes=10)
    full = A.init_params(key, num_classes=10, width=0.0625)
    wc, ws = A.split_params(full, "s2")
    runner = fed.make_async_runner(
        model, sc, delays=dm, cohort=cohort, optimizer=mom,
        opt_state_policy="carry", snapshots="delta", ring_size=64,
        num_clients=K, arrival="topk", paged_opt=True,
        emit_client_metrics=False)
    pop = jax.jit(fed.make_arrival_pop(cohort, "topk"))
    ev = jax.jit(runner)
    params = {"client": jax.tree.map(lambda a: a[None], wc), "server": ws}
    state = engine.init_train_state(params, mom)
    afed = fed.init_async_state(jax.random.PRNGKey(22), params["client"],
                                dm, snapshots="delta", ring_size=64,
                                num_clients=K)
    pager = fed.HostOptPager(mom, wc, K)
    rb = {"x": jax.random.normal(key, (1, K, 1, 32, 32, 3), jnp.float32),
          "labels": jax.random.randint(jax.random.fold_in(key, 1),
                                       (1, K, 1), 0, 10),
          "weights": jnp.ones((1, K, 1), jnp.float32)}
    for _ in range(2):
        idx = np.asarray(pop(afed.finish_time, afed.version)[0])
        cohort_opt = pager.gather(idx)
        state, afed, m, new_co = ev(state, afed, rb, None, cohort_opt)
        pager.scatter(idx, new_co)
    assert np.isfinite(float(m["loss_server"]))
    for leaf in jax.tree.leaves(state.opt_state["client"]):
        assert leaf.shape[0] == 1, leaf.shape
    for leaf in jax.tree.leaves(state.params["client"]):
        assert leaf.shape[0] == 1, leaf.shape


# --------------------------------------------------------------------------
# (d) satellite selection rewrites
# --------------------------------------------------------------------------


def test_dirichlet_topk_selection_matches_argsort():
    """Regression for the Gumbel-top-k rewrite: lax.top_k picks the same
    subset the descending argsort prefix picked, on the same key
    stream."""
    for seed in range(5):
        sched = fed.participation.dirichlet(24, 0.25, alpha=0.3)
        state = sched.init(jax.random.PRNGKey(seed))
        for _ in range(3):
            key = state["key"]
            mask, state = sched.sample(state)
            # replay the legacy selection on the identical key stream
            _, k_avail, k_gumbel = jax.random.split(key, 3)
            g = jax.random.gamma(k_avail, jnp.float32(0.3), (24,))
            avail = g / jnp.maximum(g.sum(), 1e-8)
            score = jnp.log(avail + 1e-20) + jax.random.gumbel(
                k_gumbel, (24,))
            top_old = jnp.argsort(-score)[:sched.subset_size]
            mask_old = jnp.zeros((24,), jnp.float32).at[top_old].set(1.0)
            np.testing.assert_array_equal(np.asarray(mask),
                                          np.asarray(mask_old))


def test_slot_gather_indices_matches_sorted_argsort():
    """The cumsum compaction is bit-identical to the old
    ``sort(argsort(-mask)[:k])`` — including deficient masks, where both
    fill with the lowest absent slot ids."""
    rng = np.random.default_rng(3)
    for C in (5, 16, 33):
        for _ in range(20):
            n_on = int(rng.integers(0, C + 1))
            mask = np.zeros(C, np.float32)
            mask[rng.choice(C, n_on, replace=False)] = 1.0
            mask_j = jnp.asarray(mask)
            for k_active in {1, max(1, n_on - 1), max(1, n_on),
                             min(C, n_on + 2), C}:
                ref = jnp.sort(jnp.argsort(-mask_j)[:k_active])
                new = engine.slot_gather_indices(mask_j, k_active)
                np.testing.assert_array_equal(np.asarray(ref),
                                              np.asarray(new))


# --------------------------------------------------------------------------
# spec plumbing
# --------------------------------------------------------------------------


def test_spec_validation_arrival_and_paging():
    from repro import api
    ex = api.ExecutionSpec  # noqa: N806
    with pytest.raises(ValueError, match="unknown arrival"):
        ex(arrival="bogus")
    with pytest.raises(ValueError, match="unknown opt_paging"):
        ex(opt_paging="device")

    def spec(**kw):
        return api.ExperimentSpec(
            method="scala", arch="alexnet-cifar",
            scala=ScalaConfig(num_clients=8),
            optim=api.OptimSpec(name="momentum"),
            fed=api.FedSpec(opt_state_policy="carry"),
            execution=ex(**kw),
            data=api.DataSpec(kind="image_synthetic", alpha=2))

    with pytest.raises(ValueError, match="mode 'async' only"):
        spec(mode="masked", arrival="topk").validate()
    with pytest.raises(ValueError, match="snapshots='delta'"):
        spec(mode="async", opt_paging="host").validate()
    with pytest.raises(ValueError, match="rounds_per_call"):
        spec(mode="async", snapshots="delta", opt_paging="host",
             rounds_per_call=2).validate()
    # delta+carry+momentum: rejected without paging, accepted with it
    with pytest.raises(ValueError, match="cannot carry"):
        spec(mode="async", snapshots="delta").validate()
    spec(mode="async", snapshots="delta", opt_paging="host").validate()
    spec(mode="async", arrival="topk").validate()
    # sharded arrival needs a mesh at build time
    with pytest.raises(ValueError, match="mesh"):
        api.build(spec(mode="async", arrival="topk:sharded"))


# --------------------------------------------------------------------------
# (b) the sharded pop on a real multi-device mesh (subprocess)
# --------------------------------------------------------------------------


_SHARDED_POP_SCRIPT = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
sys.path.insert(0, "src")
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from repro import fed

assert jax.device_count() == 4
mesh = Mesh(np.array(jax.devices()).reshape(4), ("data",))
rng = np.random.default_rng(0)
err = {"pop": 0, "layout": 0, "delay": 0}

K = 32
for cohort in (1, 4, 8):
    for trial in range(3):
        if trial == 0:
            ft = jnp.zeros((K,), jnp.float32)       # maximal ties
        else:
            ft = jnp.asarray(rng.lognormal(0, 1, K).astype(np.float32))
        v = jnp.asarray(rng.integers(0, 5, K).astype(np.int32))
        ref = fed.arrival_cohort(ft, cohort, v, method="sort")
        new = fed.sharded_arrival_cohort(ft, cohort, v, mesh=mesh)
        for r, n in zip(ref, new):
            if not np.array_equal(np.asarray(r), np.asarray(n)):
                err["pop"] += 1

# init_async_state(mesh=...) is bit-identical to the unsharded init and
# actually lays the schedule scalars out over the client axis
dm = fed.make_delays("lognormal:1:1")
wc = {"w": jnp.ones((K, 3), jnp.float32)}
a0 = fed.init_async_state(jax.random.PRNGKey(1), wc, dm)
a1 = fed.init_async_state(jax.random.PRNGKey(1), wc, dm, mesh=mesh)
if not np.array_equal(np.asarray(a0.finish_time), np.asarray(a1.finish_time)):
    err["layout"] += 1
if not np.array_equal(np.asarray(a0.version), np.asarray(a1.version)):
    err["layout"] += 1
if len(a1.finish_time.sharding.device_set) != 4:
    err["layout"] += 1

d0 = dm.sample(jax.random.PRNGKey(2), (K,))
d1 = dm.sample_sharded(jax.random.PRNGKey(2), K, mesh)
if not np.array_equal(np.asarray(d0), np.asarray(d1)):
    err["delay"] += 1
if len(d1.sharding.device_set) != 4:
    err["delay"] += 1

print("RESULT " + json.dumps(err))
"""


@pytest.mark.slow
def test_sharded_pop_matches_single_device_pop():
    """arrival='topk:sharded' on a forced 4-device CPU mesh: idx, mask,
    and t_event all equal the single-device lexsort pop; the sharded
    schedule-scalar init and delay sampling are bit-identical to the
    unsharded versions and actually distributed."""
    import json as _json
    import os as _os
    import subprocess
    import sys as _sys

    env = dict(_os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([_sys.executable, "-c", _SHARDED_POP_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=_os.path.dirname(_os.path.dirname(
                             _os.path.abspath(__file__))), timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, out.stdout[-2000:]
    err = _json.loads(line[0][len("RESULT "):])
    assert err == {"pop": 0, "layout": 0, "delay": 0}, err
