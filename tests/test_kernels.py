"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn.kernel import flash_attention_pallas
from repro.kernels.flash_attn.ops import flash_attention
from repro.kernels.flash_attn.ref import mha_ref
from repro.kernels.lace.kernel import (lace2_bwd_pallas, lace2_fwd_pallas,
                                       lace_bwd_pallas, lace_fwd_pallas)
from repro.kernels.lace.ops import lace_loss, lace_loss_flat
from repro.kernels.lace.ref import lace_ref
from repro.kernels.mlstm.kernel import mlstm_chunk_pallas
from repro.kernels.mlstm.ops import mlstm_chunkwise
from repro.kernels.mlstm.ref import mlstm_ref


# --------------------------------------------------------------------------
# LACE
# --------------------------------------------------------------------------

LACE_SHAPES = [
    # (N, d, V, tb, vb)
    (64, 16, 50, 32, 16),
    (100, 32, 130, 64, 64),       # non-divisible N and V (padding paths)
    (128, 48, 256, 128, 256),     # single blocks
    (257, 24, 61, 32, 32),        # prime-ish
]


@pytest.mark.parametrize("N,d,V,tb,vb", LACE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lace_fwd_kernel_sweep(N, d, V, tb, vb, dtype):
    key = jax.random.PRNGKey(N + V)
    feats = jax.random.normal(key, (N, d)).astype(dtype)
    W = (jax.random.normal(jax.random.fold_in(key, 1), (d, V)) * 0.1
         ).astype(dtype)
    labels = jax.random.randint(jax.random.fold_in(key, 2), (N,), 0, V)
    prior = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 3), (V,)))
    w = jnp.ones((N,))
    nll, lse = lace_fwd_pallas(feats, W, labels, jnp.log(prior + 1e-8),
                               tau=1.0, tb=tb, vb=vb)
    loss = (nll * w).sum() / w.sum()
    ref = lace_ref(feats.astype(jnp.float32), W.astype(jnp.float32), labels,
                   prior_rows=prior[None], weights=w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(float(loss), float(ref), rtol=tol, atol=tol)


@pytest.mark.parametrize("N,d,V,tb,vb", LACE_SHAPES[:2])
def test_lace_bwd_kernel_sweep(N, d, V, tb, vb):
    key = jax.random.PRNGKey(V)
    feats = jax.random.normal(key, (N, d))
    W = jax.random.normal(jax.random.fold_in(key, 1), (d, V)) * 0.1
    labels = jax.random.randint(jax.random.fold_in(key, 2), (N,), 0, V)
    prior = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 3), (V,)))
    w = (jax.random.uniform(jax.random.fold_in(key, 4), (N,)) > 0.2
         ).astype(jnp.float32)
    lp = jnp.log(prior + 1e-8)
    _, lse = lace_fwd_pallas(feats, W, labels, lp, tb=tb, vb=vb)
    df, dw = lace_bwd_pallas(feats, W, labels, lp, lse, w / w.sum(),
                             tb=tb, vb=vb)
    rdf, rdw = jax.grad(
        lambda f, ww: lace_ref(f, ww, labels, prior_rows=prior[None],
                               weights=w), argnums=(0, 1))(feats, W)
    np.testing.assert_allclose(df, rdf, atol=1e-6)
    np.testing.assert_allclose(dw, rdw, atol=1e-6)


def test_lace_chunked_ops_grouped_priors():
    key = jax.random.PRNGKey(0)
    G, N, d, V = 4, 48, 16, 33
    feats = jax.random.normal(key, (G, N, d))
    W = jax.random.normal(jax.random.fold_in(key, 1), (d, V)) * 0.1
    labels = jax.random.randint(jax.random.fold_in(key, 2), (G, N), 0, V)
    prior = jax.nn.softmax(
        jax.random.normal(jax.random.fold_in(key, 3), (G, V)))
    got = lace_loss(feats, W, labels, prior, jnp.arange(G), None,
                    1.0, 1e-8, 16)
    ref = lace_ref(feats.reshape(-1, d), W, labels.reshape(-1),
                   prior_rows=prior, prior_ids=jnp.repeat(jnp.arange(G), N))
    np.testing.assert_allclose(float(got), float(ref), rtol=1e-5)


def test_lace_flat_wrapper():
    key = jax.random.PRNGKey(1)
    N, d, V = 32, 8, 19
    feats = jax.random.normal(key, (N, d))
    W = jax.random.normal(jax.random.fold_in(key, 1), (d, V)) * 0.1
    labels = jax.random.randint(jax.random.fold_in(key, 2), (N,), 0, V)
    got = lace_loss_flat(feats, W, labels)
    ref = lace_ref(feats, W, labels)
    np.testing.assert_allclose(float(got), float(ref), rtol=1e-5)


# --------------------------------------------------------------------------
# LACE2 (fused dual-prior boundary kernel)
# --------------------------------------------------------------------------


def _lace2_case(N, d, V, seed):
    key = jax.random.PRNGKey(seed)
    feats = jax.random.normal(key, (N, d))
    W = jax.random.normal(jax.random.fold_in(key, 1), (d, V)) * 0.1
    labels = jax.random.randint(jax.random.fold_in(key, 2), (N,), 0, V)
    prior_s = jax.nn.softmax(
        jax.random.normal(jax.random.fold_in(key, 3), (V,)))
    prior_k = jax.nn.softmax(
        jax.random.normal(jax.random.fold_in(key, 4), (V,)))
    return feats, W, labels, prior_s, prior_k


@pytest.mark.parametrize("N,d,V,tb,vb", LACE_SHAPES)
def test_lace2_fwd_kernel_matches_two_single_passes(N, d, V, tb, vb):
    feats, W, labels, prior_s, prior_k = _lace2_case(N, d, V, N + V)
    lps, lpk = jnp.log(prior_s + 1e-8), jnp.log(prior_k + 1e-8)
    nll_s, nll_k, lse_s, lse_k = lace2_fwd_pallas(feats, W, labels, lps, lpk,
                                                  tau=1.3, tb=tb, vb=vb)
    rs_nll, rs_lse = lace_fwd_pallas(feats, W, labels, lps, tau=1.3,
                                     tb=tb, vb=vb)
    rk_nll, rk_lse = lace_fwd_pallas(feats, W, labels, lpk, tau=1.3,
                                     tb=tb, vb=vb)
    np.testing.assert_allclose(nll_s, rs_nll, atol=1e-5)
    np.testing.assert_allclose(nll_k, rk_nll, atol=1e-5)
    np.testing.assert_allclose(lse_s, rs_lse, atol=1e-5)
    np.testing.assert_allclose(lse_k, rk_lse, atol=1e-5)


@pytest.mark.parametrize("N,d,V,tb,vb", LACE_SHAPES[:2])
def test_lace2_bwd_kernel_matches_refs(N, d, V, tb, vb):
    feats, W, labels, prior_s, prior_k = _lace2_case(N, d, V, V)
    key = jax.random.PRNGKey(7 * V)
    w = (jax.random.uniform(key, (N,)) > 0.2).astype(jnp.float32)
    lps, lpk = jnp.log(prior_s + 1e-8), jnp.log(prior_k + 1e-8)
    _, _, lse_s, lse_k = lace2_fwd_pallas(feats, W, labels, lps, lpk,
                                          tb=tb, vb=vb)
    ts = w / w.sum()
    df_s, df_k, dw_s = lace2_bwd_pallas(feats, W, labels, lps, lpk,
                                        lse_s, lse_k, ts, ts, tb=tb, vb=vb)
    # side-by-side vs the single-prior bwd kernel...
    rdf_s, rdw_s = lace_bwd_pallas(feats, W, labels, lps, lse_s, ts,
                                   tb=tb, vb=vb)
    rdf_k, _ = lace_bwd_pallas(feats, W, labels, lpk, lse_k, ts,
                               tb=tb, vb=vb)
    np.testing.assert_allclose(df_s, rdf_s, atol=1e-6)
    np.testing.assert_allclose(df_k, rdf_k, atol=1e-6)
    np.testing.assert_allclose(dw_s, rdw_s, atol=1e-6)
    # ...and vs autodiff of the jnp reference (both sides)
    gdf_s, gdw_s = jax.grad(
        lambda f, ww: lace_ref(f, ww, labels, prior_rows=prior_s[None],
                               weights=w), argnums=(0, 1))(feats, W)
    gdf_k = jax.grad(
        lambda f: lace_ref(f, W, labels, prior_rows=prior_k[None],
                           weights=w))(feats)
    np.testing.assert_allclose(df_s, gdf_s, atol=1e-5)
    np.testing.assert_allclose(df_k, gdf_k, atol=1e-5)
    np.testing.assert_allclose(dw_s, gdw_s, atol=1e-5)


# --------------------------------------------------------------------------
# Flash attention
# --------------------------------------------------------------------------

FLASH_SHAPES = [
    # (B, S, H, hd, qb, kb, window)
    (1, 128, 2, 16, 64, 64, None),
    (2, 200, 3, 32, 64, 64, None),     # padded seq
    (2, 256, 2, 16, 64, 64, 32),       # window smaller than seq
    (1, 96, 1, 8, 32, 32, 7),          # odd window
    (1, 64, 2, 16, 128, 128, None),    # block bigger than seq
]


@pytest.mark.parametrize("B,S,H,hd,qb,kb,window", FLASH_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_sweep(B, S, H, hd, qb, kb, window, dtype):
    key = jax.random.PRNGKey(S + (window or 0))
    q = jax.random.normal(key, (B, S, H, hd)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd)).astype(dtype)
    ref = mha_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                  v.astype(jnp.float32), causal=True, window=window)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    out = flash_attention_pallas(qf, kf, vf, causal=True, window=window,
                                 qb=qb, kb=kb)
    out = out.reshape(B, H, S, hd).transpose(0, 2, 1, 3).astype(jnp.float32)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(out, ref, atol=tol, rtol=tol)


def test_flash_ops_gqa_repeat():
    key = jax.random.PRNGKey(0)
    B, S, H, KV, hd = 2, 64, 4, 2, 16
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    out = flash_attention(q, k, v, causal=True)
    kf = jnp.repeat(k, H // KV, axis=2)
    vf = jnp.repeat(v, H // KV, axis=2)
    ref = mha_ref(q, kf, vf, causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-5)


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------

MLSTM_SHAPES = [
    # (S, dk, dv, chunk)
    (64, 16, 16, 16),
    (96, 8, 24, 32),
    (128, 32, 32, 64),
    (60, 16, 16, 64),     # chunk > S with non-divisible fallback
]


@pytest.mark.parametrize("S,dk,dv,chunk", MLSTM_SHAPES)
def test_mlstm_kernel_sweep(S, dk, dv, chunk):
    key = jax.random.PRNGKey(S + dk)
    q = jax.random.normal(key, (S, dk)) * 0.3
    k = jax.random.normal(jax.random.fold_in(key, 1), (S, dk)) * 0.3
    v = jax.random.normal(jax.random.fold_in(key, 2), (S, dv))
    i_raw = jax.random.normal(jax.random.fold_in(key, 3), (S,))
    f_log = jax.nn.log_sigmoid(
        jax.random.normal(jax.random.fold_in(key, 4), (S,)) + 2.0)
    ref = mlstm_ref(q, k, v, i_raw, f_log)
    out = mlstm_chunk_pallas(q, k, v, i_raw, f_log, chunk=chunk)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_mlstm_ops_batched_heads():
    key = jax.random.PRNGKey(0)
    B, S, H, hd = 2, 32, 3, 8
    q = jax.random.normal(key, (B, S, H, hd)) * 0.3
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd)) * 0.3
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    i_raw = jax.random.normal(jax.random.fold_in(key, 3), (B, S, H))
    f_log = jax.nn.log_sigmoid(
        jax.random.normal(jax.random.fold_in(key, 4), (B, S, H)) + 2.0)
    out = mlstm_chunkwise(q, k, v, i_raw, f_log, chunk=16)
    ref = mlstm_ref(q[1, :, 2], k[1, :, 2], v[1, :, 2], i_raw[1, :, 2],
                    f_log[1, :, 2])
    np.testing.assert_allclose(out[1, :, 2], ref, rtol=2e-4, atol=2e-4)
